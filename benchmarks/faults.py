"""Fault tolerance: how much accuracy De-VertiFL's knowledge exchange
gives up as clients fail-stop (crash), lag (straggle), and ship
corrupted payloads the exchange guard must quarantine -- and whether
the Session divergence watchdog actually recovers a poisoned run.

Two sections per entry:

grid      the fault-rate x schedule grid runs as ONE padded lane batch
          through ``repro.core.sweep.run_padded_cells``: rates,
          durations and corruption kind are traced per-lane state, so
          every cell shares a single compiled round
          (``round_traces == 1`` is recorded).  Each cell carries its
          guard telemetry (crash / straggle / corruption / quarantine
          client-round counts) and the ``spec_hash`` of the
          ExperimentSpec it corresponds to.
recovery  one Session.run under a hot fault plan with the divergence
          watchdog armed (an explicit RetryPolicy), recording the
          ``timings["fault"]`` counters -- watchdog trips, reseeded
          retries, and the guard totals -- end to end.

Results append to ``benchmarks/results/BENCH_faults.json`` (same
append-only rules as BENCH_protocol.json), one dated git-SHA-keyed
entry per run.

Run:    PYTHONPATH=src python -m benchmarks.faults
Smoke:  PYTHONPATH=src python -m benchmarks.faults --smoke
        (toy sizes, no result-file write; the scripts/ci.sh
        fault-smoke lane runs this)
"""
from __future__ import annotations

import datetime
import json
import os

import jax

from benchmarks.protocol_bench import RESULTS, _append_entry
from repro.api import ExperimentSpec, build, git_sha, spec_grid
from repro.core.sweep import run_padded_cells
from repro.faults import RetryPolicy

FULL = dict(dataset="mnist", n_clients=3, seeds=(0, 1), rounds=3,
            epochs=2, n_samples=2000,
            crash_rates=(0.0, 0.1, 0.2, 0.4),
            corrupt_rates=(0.0, 0.05, 0.2),
            schedules=("sync", "stale_k:2"))
SMOKE = dict(dataset="mnist", n_clients=3, seeds=(0,), rounds=1,
             epochs=1, n_samples=512,
             crash_rates=(0.0, 0.2), corrupt_rates=(0.0, 0.2),
             schedules=("sync", "stale_k:2"))


def fault_name(crash: float, corrupt: float) -> str:
    """The canonical fault string of one (crash rate, corrupt rate)
    grid cell ("none" for the fault-free corner)."""
    parts = []
    if crash > 0:
        parts.append(f"crash:{crash:g}:2")
    if corrupt > 0:
        parts.append(f"corrupt:{corrupt:g}")
    return "+".join(parts) or "none"


def run(smoke=False, results_path=None):
    """Sweep fault-rate x schedule, run the recovery probe, append the
    entry, return bench CSV rows.  smoke=True shrinks to toy sizes and
    (unless results_path is given) skips the file write."""
    cfg = SMOKE if smoke else FULL
    faults = tuple(fault_name(cr, co) for cr in cfg["crash_rates"]
                   for co in cfg["corrupt_rates"])
    specs = spec_grid(
        datasets=(cfg["dataset"],), modes=("devertifl",),
        client_counts=(cfg["n_clients"],), seeds=cfg["seeds"],
        schedules=cfg["schedules"], faults=faults,
        rounds=cfg["rounds"], epochs=cfg["epochs"],
        n_samples=cfg["n_samples"])
    out = run_padded_cells(cfg["dataset"], "devertifl", specs)

    grid, rows = {}, []
    none_f1 = None
    for spec in specs:
        key = f"{spec.fault}/{spec.schedule}/{spec.n_clients}"
        cell = out["cells"][key]
        grid[f"{spec.fault}/{spec.schedule}"] = {
            "f1_mean": cell["f1_mean"], "f1_std": cell["f1_std"],
            "acc_mean": cell["acc_mean"],
            "final_loss_mean": cell["final_loss_mean"],
            "fault_telemetry": cell["fault_telemetry"],
            "spec_hash": spec.spec_hash,
        }
        if spec.fault == "none" and spec.schedule == "sync":
            none_f1 = cell["f1_mean"]
        rows.append((f"faults/{spec.fault}/{spec.schedule}", 0.0,
                     f"f1={cell['f1_mean']:.3f}"))

    # recovery probe: a hot composite plan under the armed watchdog --
    # the interesting numbers are the telemetry counters, not f1
    rspec = ExperimentSpec(
        dataset=cfg["dataset"], mode="devertifl",
        n_clients=cfg["n_clients"], seeds=(0,), rounds=cfg["rounds"],
        epochs=cfg["epochs"], n_samples=cfg["n_samples"],
        fault="crash:0.2:2+straggle:0.5:2+corrupt:0.2", eval_every=0)
    rres = build(rspec).run(retry=RetryPolicy(max_retries=2))
    recovery = {
        "spec_hash": rspec.spec_hash, "fault": rspec.fault,
        "f1_mean": rres.metrics["f1"],
        "fault_telemetry": rres.timings["fault"],
    }
    rows.append(("faults/recovery", rres.timings["wall_s"],
                 f"trips={rres.timings['fault']['watchdog_trips']} "
                 f"retries={rres.timings['fault']['retries']}"))

    entry = {
        "date": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "git_sha": git_sha(),
        "backend": jax.default_backend(),
        "config": {k: v for k, v in cfg.items()},
        "round_traces": out["round_traces"],
        "lanes": out["lanes"],
        "devices": out["devices"],
        # the trajectory: accuracy as a function of crash/corrupt rate
        # and schedule, fault-free sync as the reference corner
        "none_f1": none_f1,
        "grid": grid,
        "recovery": recovery,
    }
    if results_path is None and not smoke:
        os.makedirs(RESULTS, exist_ok=True)
        results_path = os.path.join(RESULTS, "BENCH_faults.json")
    if results_path is not None:
        _append_entry(entry, results_path)
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(
        description="Fault-tolerance sweep + recovery probe (appends "
                    "to BENCH_faults.json)")
    ap.add_argument("--smoke", action="store_true",
                    help="toy sizes, no result-file write")
    args = ap.parse_args()
    for r in run(smoke=args.smoke):
        print(",".join(str(x) for x in r))
