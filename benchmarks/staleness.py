"""Staleness / participation tolerance: how much accuracy De-VertiFL's
knowledge exchange gives up when the exchange is allowed to lag
(stale_k) and clients drop out of rounds (partial) -- the relaxations
async/pipelined deployments actually make.

The whole k x participation grid runs as ONE padded lane batch through
``repro.core.sweep.run_padded_cells``: schedule (k, p) values are
traced per-lane state, so every cell shares a single compiled round
(``round_traces == 1`` is recorded in the entry).  Results append to
``benchmarks/results/BENCH_staleness.json`` (same append-only rules as
BENCH_protocol.json), one dated git-SHA-keyed entry per run, each cell
stamped with the ``spec_hash`` of the ExperimentSpec it corresponds
to.

Run:    PYTHONPATH=src python -m benchmarks.staleness
Smoke:  PYTHONPATH=src python -m benchmarks.staleness --smoke
        (toy sizes, no result-file write; the scripts/ci.sh
        schedule-smoke lane runs this)
"""
from __future__ import annotations

import datetime
import json
import os

import jax

from benchmarks.protocol_bench import RESULTS, _append_entry
from repro.api import ExperimentSpec, git_sha, spec_grid
from repro.core.sweep import run_padded_cells

FULL = dict(dataset="mnist", n_clients=3, seeds=(0, 1), rounds=3,
            epochs=2, n_samples=2000, ks=(0, 1, 2, 4, 8),
            participations=(1.0, 0.8, 0.5))
SMOKE = dict(dataset="mnist", n_clients=3, seeds=(0,), rounds=1,
             epochs=1, n_samples=512, ks=(0, 2),
             participations=(1.0, 0.5))


def schedule_name(k: int, p: float) -> str:
    """The canonical schedule string of one (staleness, participation)
    grid cell ("sync" for the paper-literal corner)."""
    parts = []
    if k > 0:
        parts.append(f"stale_k:{k}")
    if p < 1.0:
        parts.append(f"partial:{p:g}")
    return "+".join(parts) or "sync"


def run(smoke=False, results_path=None):
    """Sweep k x participation, append the trajectory entry, return
    bench CSV rows.  smoke=True shrinks to toy sizes and (unless
    results_path is given) skips the file write."""
    cfg = SMOKE if smoke else FULL
    ks, ps = cfg["ks"], cfg["participations"]
    schedules = tuple(schedule_name(k, p) for k in ks for p in ps)
    specs = spec_grid(
        datasets=(cfg["dataset"],), modes=("devertifl",),
        client_counts=(cfg["n_clients"],), seeds=cfg["seeds"],
        schedules=schedules, rounds=cfg["rounds"], epochs=cfg["epochs"],
        n_samples=cfg["n_samples"])
    out = run_padded_cells(cfg["dataset"], "devertifl", specs)

    grid, rows = {}, []
    sync_f1 = None
    for spec in specs:
        key = f"{spec.schedule}/{spec.n_clients}" \
            if schedules != ("sync",) else spec.n_clients
        cell = out["cells"][key]
        grid[spec.schedule] = {
            "f1_mean": cell["f1_mean"], "f1_std": cell["f1_std"],
            "acc_mean": cell["acc_mean"],
            "final_loss_mean": cell["final_loss_mean"],
            "spec_hash": spec.spec_hash,
        }
        if spec.schedule == "sync":
            sync_f1 = cell["f1_mean"]
        rows.append((f"staleness/{spec.schedule}", 0.0,
                     f"f1={cell['f1_mean']:.3f}"))

    entry = {
        "date": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "git_sha": git_sha(),
        "backend": jax.default_backend(),
        "config": {k: v for k, v in cfg.items()},
        "round_traces": out["round_traces"],
        "lanes": out["lanes"],
        "devices": out["devices"],
        # the trajectory: accuracy as a function of staleness depth
        # and participation, sync as the reference corner
        "sync_f1": sync_f1,
        "grid": grid,
    }
    if results_path is None and not smoke:
        os.makedirs(RESULTS, exist_ok=True)
        results_path = os.path.join(RESULTS, "BENCH_staleness.json")
    if results_path is not None:
        _append_entry(entry, results_path)
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(
        description="Staleness/participation-vs-accuracy sweep "
                    "(appends to BENCH_staleness.json)")
    ap.add_argument("--smoke", action="store_true",
                    help="toy sizes, no result-file write")
    args = ap.parse_args()
    for r in run(smoke=args.smoke):
        print(",".join(str(x) for x in r))
