"""Benchmarks reproducing the paper's figures (F1 vs participant count).

  Fig. 3: MNIST      De-VertiFL vs non-federated
  Fig. 4: FMNIST     De-VertiFL vs non-federated
  Fig. 5: Titanic    De-VertiFL vs non-federated
  Fig. 6: Bank       De-VertiFL vs non-federated
  Fig. 7: all four   De-VertiFL vs VertiComb-style backward exchange

Offline container -> synthetic stand-in datasets with matched shapes and
information geometry (see repro/data/synthetic.py). The claims being
validated are the paper's *trends*: federated >> non-federated, the gap
grows with participants, binary tasks are more stable.

Round counts are scaled: our synthetic sets are ~10x smaller than
MNIST's 60k, so we use more rounds to reach a comparable optimizer-step
budget (paper: 5 rounds x 5 epochs x 937 batches; ours: 15 x 5 x ~75).
--paper runs the full client range 2..10 with multiple seeds.
"""
from __future__ import annotations

import json
import os
import time

from repro.api import ExperimentSpec, build

RESULTS = os.path.join(os.path.dirname(__file__), "results")

_DATASET_SETTINGS = {
    "mnist": dict(rounds=15, epochs=5, n_samples=6000),
    "fmnist": dict(rounds=15, epochs=5, n_samples=6000),
    # paper: 1000 rounds x 1 epoch on 891 rows; scaled to 150
    "titanic": dict(rounds=150, epochs=1, n_samples=None),
    # paper: 20 rounds x 10 epochs; bank is easy -- keep as-is but on 8k
    "bank": dict(rounds=20, epochs=10, n_samples=8000),
}


def fig_curve(dataset, clients, modes=("devertifl", "non_federated"),
              seeds=(0,), settings=None):
    """One spec per (n_clients, mode) point: a multi-seed spec rides
    the seed-vmapped sweep cell (one compile per point), eval_every=0
    skips the per-round evals the figures never read.  Each point
    records its spec_hash, joinable to the bench trajectory."""
    st = dict(_DATASET_SETTINGS[dataset])
    st.update(settings or {})
    out = {m: [] for m in modes}
    for nc in clients:
        for mode in modes:
            spec = ExperimentSpec(dataset=dataset, n_clients=nc,
                                  mode=mode, seeds=seeds, eval_every=0,
                                  fedavg=(mode != "non_federated"), **st)
            m = build(spec).run().metrics
            out[mode].append({"n_clients": nc,
                              "f1_mean": m["f1"],
                              "f1_std": m.get("f1_std", 0.0),
                              "n_seeds": len(seeds),
                              "spec_hash": spec.spec_hash})
    return out


def run_figure(name, dataset, clients, modes, seeds, quick=False):
    t0 = time.time()
    curve = fig_curve(dataset, clients, modes, seeds)
    dt = time.time() - t0
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, f"{name}.json")
    with open(path, "w") as f:
        json.dump({"dataset": dataset, "curves": curve,
                   "wall_s": round(dt, 1)}, f, indent=1)
    rows = []
    for mode, pts in curve.items():
        for p in pts:
            rows.append((f"{name}/{mode}/n{p['n_clients']}",
                         dt * 1e6 / max(len(clients), 1),
                         f"f1={p['f1_mean']:.3f}"))
    return rows


def main(quick=True, paper=False):
    clients = list(range(2, 11)) if paper else [2, 5, 9]
    t_clients = [c for c in clients if c <= 9]  # titanic: 9 features max
    seeds = (0, 1, 2) if paper else (0,)
    rows = []
    rows += run_figure("fig3_mnist", "mnist", clients,
                       ("devertifl", "non_federated"), seeds)
    rows += run_figure("fig4_fmnist", "fmnist", clients,
                       ("devertifl", "non_federated"), seeds)
    rows += run_figure("fig5_titanic", "titanic", t_clients,
                       ("devertifl", "non_federated"), seeds)
    rows += run_figure("fig6_bank", "bank", clients,
                       ("devertifl", "non_federated"), seeds)
    # Fig. 7: De-VertiFL vs VertiComb (backward exchange), one dataset
    # pair per family in quick mode
    fig7 = [2, 5, 9] if not paper else clients
    rows += run_figure("fig7_mnist_verticomb", "mnist", fig7,
                       ("devertifl", "verticomb"), seeds)
    rows += run_figure("fig7_bank_verticomb", "bank", fig7,
                       ("devertifl", "verticomb"), seeds)
    return rows


if __name__ == "__main__":
    for r in main():
        print(",".join(str(x) for x in r))
