"""Table II: De-VertiFL vs literature configurations.

  PyVertical row: MNIST, 2 participants          (accuracy)
  Flower row:     Titanic, 3 participants        (accuracy)
  SplitNN row:    Bank Marketing, 2 participants (F1)

Each literature framework is represented by our SplitNN-style
centralized split-learning implementation under the SAME participant
count and round budget, vs De-VertiFL under identical conditions --
matching the paper's comparison protocol (section IV-E).

Both sides of every row are declarative ``repro.api`` specs: the
De-VertiFL side is one federated session (a standalone scan-fused run
for one seed -- bit-for-bit the sweep lane -- or the seed-vmapped
sweep cell for several), the baseline is the same spec with
``mode="splitnn"``.  Each row records both specs' hashes so the JSON
is joinable to the exact configurations that produced it.
"""
from __future__ import annotations

import json
import os
import time

from repro.api import ExperimentSpec, build

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def run(seeds=(0,)):
    rows = []
    cases = [
        # (row name, dataset, n_clients, rounds, epochs, metric)
        ("mnist_vs_pyvertical", "mnist", 2, 10, 5, "acc"),
        ("titanic_vs_flower", "titanic", 3, 150, 1, "acc"),
        ("bank_vs_splitnn", "bank", 2, 20, 10, "f1"),
    ]
    table = {}
    for name, ds, nc, rounds, epochs, metric in cases:
        t0 = time.time()
        n_samples = 6000 if ds in ("mnist", "fmnist") else None
        fed_spec = ExperimentSpec(
            dataset=ds, mode="devertifl", n_clients=nc, rounds=rounds,
            epochs=epochs, seeds=seeds, n_samples=n_samples,
            eval_every=0)   # final metrics only, as the sweep cell does
        base_spec = fed_spec.replace(mode="splitnn", seeds=(0,))
        fed = build(fed_spec).run()
        base = build(base_spec).run()
        dt = time.time() - t0
        fm = fed.metrics
        table[name] = {
            "devertifl": {"f1": fm["f1"], "acc": fm["acc"],
                          "f1_std": fm.get("f1_std", 0.0),
                          "seeds": list(seeds),
                          "spec_hash": fed.spec_hash},
            "split_baseline": dict(base.metrics,
                                   spec_hash=base.spec_hash),
            "metric": metric,
        }
        rows.append((f"table2/{name}/devertifl", dt * 1e6,
                     f"{metric}={fm[metric]:.3f}"))
        rows.append((f"table2/{name}/baseline", dt * 1e6,
                     f"{metric}={base.metrics[metric]:.3f}"))
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "table2.json"), "w") as f:
        json.dump(table, f, indent=1)
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
