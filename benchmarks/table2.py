"""Table II: De-VertiFL vs literature configurations.

  PyVertical row: MNIST, 2 participants          (accuracy)
  Flower row:     Titanic, 3 participants        (accuracy)
  SplitNN row:    Bank Marketing, 2 participants (F1)

Each literature framework is represented by our SplitNN-style
centralized split-learning implementation under the SAME participant
count and round budget, vs De-VertiFL under identical conditions --
matching the paper's comparison protocol (section IV-E).

The De-VertiFL side runs on the sweep engine (repro.core.sweep): each
row is one seed-vmapped cell, so per-seed federations share a single
compiled scan-based round function.
"""
from __future__ import annotations

import json
import os
import time

from repro.core.baselines import SplitNN, SplitNNConfig
from repro.core.sweep import SweepConfig, run_cell

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def run(seeds=(0,)):
    rows = []
    cases = [
        # (row name, dataset, n_clients, rounds, epochs, metric)
        ("mnist_vs_pyvertical", "mnist", 2, 10, 5, "acc"),
        ("titanic_vs_flower", "titanic", 3, 150, 1, "acc"),
        ("bank_vs_splitnn", "bank", 2, 20, 10, "f1"),
    ]
    table = {}
    for name, ds, nc, rounds, epochs, metric in cases:
        t0 = time.time()
        n_samples = 6000 if ds in ("mnist", "fmnist") else None
        cell = run_cell(ds, "devertifl", nc,
                        SweepConfig(seeds=seeds, rounds=rounds,
                                    epochs=epochs, n_samples=n_samples))
        base = SplitNN(SplitNNConfig(
            dataset=ds, n_clients=nc, rounds=rounds, epochs=epochs,
            n_samples=n_samples)).train()
        dt = time.time() - t0
        table[name] = {
            "devertifl": {"f1": cell["f1_mean"], "acc": cell["acc_mean"],
                          "f1_std": cell["f1_std"],
                          "seeds": cell["seeds"]},
            "split_baseline": base,
            "metric": metric,
        }
        fed_metric = cell[f"{metric}_mean"]
        rows.append((f"table2/{name}/devertifl", dt * 1e6,
                     f"{metric}={fed_metric:.3f}"))
        rows.append((f"table2/{name}/baseline", dt * 1e6,
                     f"{metric}={base[metric]:.3f}"))
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "table2.json"), "w") as f:
        json.dump(table, f, indent=1)
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
