"""Table II: De-VertiFL vs literature configurations.

  PyVertical row: MNIST, 2 participants          (accuracy)
  Flower row:     Titanic, 3 participants        (accuracy)
  SplitNN row:    Bank Marketing, 2 participants (F1)

Each literature framework is represented by our SplitNN-style
centralized split-learning implementation under the SAME participant
count and round budget, vs De-VertiFL under identical conditions --
matching the paper's comparison protocol (section IV-E).
"""
from __future__ import annotations

import json
import os
import time

from repro.core import train_federation
from repro.core.baselines import SplitNN, SplitNNConfig

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def run():
    rows = []
    cases = [
        # (row name, dataset, n_clients, rounds, epochs, metric)
        ("mnist_vs_pyvertical", "mnist", 2, 10, 5, "acc"),
        ("titanic_vs_flower", "titanic", 3, 150, 1, "acc"),
        ("bank_vs_splitnn", "bank", 2, 20, 10, "f1"),
    ]
    table = {}
    for name, ds, nc, rounds, epochs, metric in cases:
        t0 = time.time()
        kw = dict(n_samples=6000) if ds in ("mnist", "fmnist") else {}
        fed = train_federation(dataset=ds, n_clients=nc, rounds=rounds,
                               epochs=epochs, **kw)
        base = SplitNN(SplitNNConfig(
            dataset=ds, n_clients=nc, rounds=rounds, epochs=epochs,
            n_samples=kw.get("n_samples"))).train()
        dt = time.time() - t0
        table[name] = {
            "devertifl": {k: fed["final"][k] for k in ("f1", "acc")},
            "split_baseline": base,
            "metric": metric,
        }
        rows.append((f"table2/{name}/devertifl", dt * 1e6,
                     f"{metric}={fed['final'][metric]:.3f}"))
        rows.append((f"table2/{name}/baseline", dt * 1e6,
                     f"{metric}={base[metric]:.3f}"))
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "table2.json"), "w") as f:
        json.dump(table, f, indent=1)
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
