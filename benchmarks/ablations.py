"""Beyond-paper ablations:

1. exchange point — the paper's text/Fig. 1 says hidden-layer outputs
   are exchanged; Algorithm 1 exchanges the model OUTPUT (y-hat). Both
   are implemented (ProtocolConfig.exchange_at); this ablation measures
   the difference the ambiguity makes.
2. weighted FedAvg — the paper's conclusion names "more sophisticated
   aggregation methods" as future work; we weight each client's
   parameters by its owned-feature count.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.api import ExperimentSpec, build
from repro.core.protocol import DeVertiFL, ProtocolConfig

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def exchange_point_ablation(dataset="mnist", n_clients=5, seeds=(0, 1)):
    """One multi-seed spec per exchange point (the seeds ride the
    vmapped sweep cell); each entry records its spec_hash."""
    out = {}
    for ex, label in [(-1, "logits (Algorithm 1)"),
                      (1, "hidden layer 1 (Fig. 1 text)"),
                      (2, "hidden layer 2"),
                      (3, "hidden layer 3")]:
        spec = ExperimentSpec(dataset=dataset, n_clients=n_clients,
                              rounds=12, epochs=5, n_samples=6000,
                              exchange_at=ex, seeds=seeds, eval_every=0)
        m = build(spec).run().metrics
        out[label] = {"f1_mean": m["f1"],
                      "f1_std": m.get("f1_std", 0.0),
                      "spec_hash": spec.spec_hash}
    return out


def weighted_fedavg_ablation(dataset="mnist", n_clients=7, seeds=(0, 1)):
    """Uniform FedAvg vs feature-count-weighted FedAvg.  Stays on the
    DeVertiFL engine directly: a custom fedavg_fn is an engine-level
    knob (set_fedavg) the declarative spec deliberately does not
    express."""
    import jax
    import jax.numpy as jnp
    out = {}
    for weighted in (False, True):
        f1s = []
        for seed in seeds:
            pcfg = ProtocolConfig(dataset=dataset, n_clients=n_clients,
                                  rounds=12, epochs=5, n_samples=6000,
                                  seed=seed)
            fed = DeVertiFL(pcfg)
            if weighted:
                w = jnp.asarray([len(ix) for ix in fed.partition],
                                jnp.float32)
                w = w / w.sum()

                def weighted_avg(stacked):
                    def avg(leaf):
                        ws = w.reshape((-1,) + (1,) * (leaf.ndim - 1))
                        m = (leaf * ws).sum(0, keepdims=True)
                        return jnp.broadcast_to(m, leaf.shape)
                    return jax.tree.map(avg, stacked)

                fed.set_fedavg(weighted_avg)
            r = fed.train()
            f1s.append(r["final"]["f1"])
        key = "weighted_by_features" if weighted else "uniform (paper)"
        out[key] = {"f1_mean": float(np.mean(f1s)),
                    "f1_std": float(np.std(f1s))}
    return out


def run():
    t0 = time.time()
    res = {
        "exchange_point": exchange_point_ablation(),
        "weighted_fedavg": weighted_fedavg_ablation(),
    }
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "ablations.json"), "w") as f:
        json.dump(res, f, indent=1)
    rows = []
    for abl, entries in res.items():
        for variant, v in entries.items():
            rows.append((f"ablation/{abl}/{variant}",
                         (time.time() - t0) * 1e6,
                         f"f1={v['f1_mean']:.3f}±{v['f1_std']:.3f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
