"""Wire-transform tradeoff: what De-VertiFL's exchange gives up -- and
what an adversary gains -- as the exchanged hidden stacks are
quantized (int8), sparsified (topk), and DP-noised on the wire.

Two sections per entry:

grid       the transform x schedule grid runs as ONE padded lane batch
           through ``repro.core.sweep.run_padded_cells``: transform
           gates/knobs are traced per-lane state, so every cell shares
           a single compiled round (``round_traces == 1`` is
           recorded).  Each cell carries its f1, bytes-on-wire
           telemetry (raw vs encoded ints), and the ``spec_hash`` of
           the ExperimentSpec it corresponds to.
probes     a per-cell hidden-state inversion probe: one Session.run
           per (transform, schedule), then a ridge-style linear probe
           fit from client 0's ON-THE-WIRE hiddens (post
           ``wire_apply_static``) over half the test set to
           reconstruct client 0's canonical input column block,
           scored as relative MSE on the held-out half (1.0 == as bad
           as predicting the column means; lower == more leakage).
           Each probe session also records end-to-end steps/sec and
           the run's ``timings["wire"]`` byte counters.

Results append to ``benchmarks/results/BENCH_wire.json`` (same
append-only rules as BENCH_protocol.json), one dated git-SHA-keyed
entry per run.

Run:    PYTHONPATH=src python -m benchmarks.wire
Smoke:  PYTHONPATH=src python -m benchmarks.wire --smoke
        (toy sizes; STILL appends -- the entry is flagged
        ``"smoke": true`` so full-size trajectory readers can filter
        it out.  The scripts/ci.sh wire-smoke lane runs this.)
"""
from __future__ import annotations

import datetime
import json
import os

import jax
import numpy as np

from benchmarks.protocol_bench import RESULTS, _append_entry
from repro.api import ExperimentSpec, build, git_sha, spec_grid
from repro.core.protocol import make_h_all_fn
from repro.core.sweep import run_padded_cells
from repro.wire import get_wire_plan, wire_apply_static

FULL = dict(dataset="mnist", n_clients=3, seeds=(0, 1), rounds=3,
            epochs=2, n_samples=2000,
            transforms=("none", "int8", "topk:0.5", "topk:0.25",
                        "dp:0.1", "topk:0.5+int8+dp:0.1"),
            schedules=("sync", "stale_k:2"))
SMOKE = dict(dataset="mnist", n_clients=3, seeds=(0,), rounds=1,
             epochs=1, n_samples=512,
             transforms=("none", "int8", "topk:0.5+int8+dp:0.1"),
             schedules=("sync",))


def inversion_probe(spec: ExperimentSpec) -> dict:
    """Train the cell's federation, then try to reconstruct client 0's
    input columns from what client 0 actually put on the wire.

    The probe is the standard linear model-inversion baseline: fit
    ``x0 ~ [h0_wire, 1] @ w`` by least squares on the first half of
    the test set, score relative MSE on the second half against the
    predict-the-column-means baseline.  ``h0_wire`` is client 0's
    exchanged stack AFTER the static wire codec (the dp stage is a
    training-time release control and is skipped, matching serving),
    so the number measures leakage through the bytes a peer receives.
    """
    sess = build(spec)
    rr = sess.run()
    fed = sess.federation
    plan = get_wire_plan(spec.transform)
    h_all_fn = make_h_all_fn(fed.model, fed.pcfg, layout=fed.layout)
    import jax.numpy as jnp
    xte_c = jnp.asarray(fed.layout.apply(fed.xte))
    h = h_all_fn(rr.params, xte_c, fed.layout.arrays())
    if not plan.is_none:
        h = wire_apply_static(plan, h)
    h0 = np.asarray(h[0], np.float64)                 # [T, W] on-wire
    x0 = np.asarray(xte_c[:, :fed.layout.sizes[0]], np.float64)
    t = h0.shape[0] // 2
    a = np.concatenate([h0, np.ones((h0.shape[0], 1))], axis=1)
    w, *_ = np.linalg.lstsq(a[:t], x0[:t], rcond=None)
    resid = a[t:] @ w - x0[t:]
    base = x0[t:] - x0[:t].mean(axis=0)
    rel_mse = float((resid ** 2).sum() /
                    max((base ** 2).sum(), 1e-12))
    steps = spec.rounds * spec.epochs * fed.n_batches
    out = {
        "spec_hash": spec.spec_hash,
        "f1": rr.metrics["f1"],
        "inversion_rel_mse": rel_mse,
        "steps_per_sec": steps / max(rr.timings["wall_s"], 1e-9),
    }
    if "wire" in rr.timings:
        out["wire"] = rr.timings["wire"]
    return out


def run(smoke=False, results_path=None):
    """Sweep transform x schedule, run the per-cell inversion probes,
    append the entry, return bench CSV rows.  smoke=True shrinks to
    toy sizes (the entry is still appended, flagged smoke)."""
    cfg = SMOKE if smoke else FULL
    specs = spec_grid(
        datasets=(cfg["dataset"],), modes=("devertifl",),
        client_counts=(cfg["n_clients"],), seeds=cfg["seeds"],
        schedules=cfg["schedules"], transforms=cfg["transforms"],
        rounds=cfg["rounds"], epochs=cfg["epochs"],
        n_samples=cfg["n_samples"])
    out = run_padded_cells(cfg["dataset"], "devertifl", specs)

    grid, rows = {}, []
    none_f1 = None
    probed = set()
    for spec in specs:
        key = f"{spec.transform}/{spec.fault}/{spec.schedule}/" \
              f"{spec.n_clients}"
        cell = out["cells"][key]
        gkey = f"{spec.transform}/{spec.schedule}"
        if gkey in probed:
            continue
        probed.add(gkey)
        probe = inversion_probe(spec.replace(
            seeds=(cfg["seeds"][0],), eval_every=0))
        grid[gkey] = {
            "f1_mean": cell["f1_mean"], "f1_std": cell["f1_std"],
            "acc_mean": cell["acc_mean"],
            "final_loss_mean": cell["final_loss_mean"],
            "wire": cell.get("wire"),
            "spec_hash": spec.spec_hash,
            "probe": probe,
        }
        if spec.transform == "none" and spec.schedule == "sync":
            none_f1 = cell["f1_mean"]
        enc = (probe.get("wire") or {}).get("encoded_bytes", 0)
        raw = (probe.get("wire") or {}).get("raw_bytes", 0)
        rows.append((f"wire/{gkey}", 0.0,
                     f"f1={cell['f1_mean']:.3f} "
                     f"inv={probe['inversion_rel_mse']:.3f} "
                     f"bytes={enc}/{raw}"))

    entry = {
        "date": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "git_sha": git_sha(),
        "backend": jax.default_backend(),
        "config": {k: v for k, v in cfg.items()},
        "round_traces": out["round_traces"],
        "lanes": out["lanes"],
        "devices": out["devices"],
        # the trajectory: accuracy, bytes-on-wire, and inversion
        # leakage as a function of wire transform, transform-free sync
        # as the reference corner
        "none_f1": none_f1,
        "grid": grid,
        "smoke": smoke,
    }
    if results_path is None:
        os.makedirs(RESULTS, exist_ok=True)
        results_path = os.path.join(RESULTS, "BENCH_wire.json")
    _append_entry(entry, results_path)
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(
        description="Wire-transform tradeoff sweep + inversion probes "
                    "(appends to BENCH_wire.json)")
    ap.add_argument("--smoke", action="store_true",
                    help="toy sizes (entry still appended, flagged "
                         "smoke)")
    ap.add_argument("--out", default=None,
                    help="append the entry here instead of "
                         "benchmarks/results/BENCH_wire.json (CI "
                         "lanes point this at a throwaway path)")
    args = ap.parse_args()
    for r in run(smoke=args.smoke, results_path=args.out):
        print(",".join(str(x) for x in r))
