"""Render the roofline table from the dry-run JSON records (deliverable
g). Produces the markdown table embedded in EXPERIMENTS.md section
Roofline and CSV rows for benchmarks.run."""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")

_SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
                "long_500k": 3}


def load_records(mesh=None, exchange=None):
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if mesh and r.get("mesh") != mesh:
            continue
        if exchange and r.get("exchange") != exchange:
            continue
        recs.append(r)
    recs.sort(key=lambda r: (r["arch"], _SHAPE_ORDER.get(r["shape"], 9),
                             r.get("mesh", "")))
    return recs


def markdown_table(recs):
    lines = [
        "| arch | shape | mesh | compute (ms) | memory (ms) | "
        "collective (ms) | bottleneck | useful-FLOP frac | status |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("status") == "ok":
            t = r["roofline"]
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                f"| {t['compute_s']*1e3:.3f} | {t['memory_s']*1e3:.3f} "
                f"| {t['collective_s']*1e3:.3f} | {t['bottleneck']} "
                f"| {t.get('useful_flop_frac', 0):.2f} | ok |")
        else:
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r.get('mesh','-')} "
                f"| - | - | - | - | - | {r.get('status')}: "
                f"{r.get('reason', r.get('error', ''))[:60]} |")
    return "\n".join(lines)


def run():
    rows = []
    for r in load_records(mesh="16x16"):
        if r.get("status") != "ok":
            continue
        t = r["roofline"]
        rows.append((f"roofline/{r['arch']}/{r['shape']}",
                     t["bound_s"] * 1e6,
                     f"bottleneck={t['bottleneck']}"))
    return rows


if __name__ == "__main__":
    recs = load_records()
    print(markdown_table(recs))
