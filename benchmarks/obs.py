"""Observability overhead: what the in-scan metric taps cost.

Three lanes per entry, all steady-state (post-compile) scan-round
throughput via the protocol bench harness:

levels     the SAME federation timed at obs="none" | "basic" | "full".
           "none" is the untouched legacy engine (the ObsImpl wrapper
           is never constructed); "basic" adds the per-round loss
           series; "full" adds exchange-stack norms, grad norms and
           the quarantine/bytes/staleness counters.  The entry records
           steps/sec per level plus the overhead of each level
           relative to "none" -- the number the <5%% acceptance bar in
           docs/ARCHITECTURE.md section 12 watches.
parity     the "full" run's final params are asserted bitwise equal to
           the "none" run's before anything is recorded: a tap that
           perturbs training is a correctness bug, and a perf entry
           for it would be meaningless.
grid       the obs x schedule x transform x fault grid as ONE padded
           lane batch through ``repro.core.sweep.run_padded_cells``
           (obs level rides the traced lane state like the other
           axes), recording ``round_traces`` -- pinned at 1.

Appends one dated git-SHA-keyed entry to
``benchmarks/results/BENCH_obs.json`` (same append-only rules as
BENCH_protocol.json).

Run:    PYTHONPATH=src python -m benchmarks.obs
Smoke:  PYTHONPATH=src python -m benchmarks.obs --smoke
        (toy sizes; STILL appends -- the entry is flagged
        ``"smoke": true``.  The scripts/ci.sh obs-smoke lane runs
        this with --out pointed at a throwaway path.)
"""
from __future__ import annotations

import datetime
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.protocol_bench import (RESULTS, _append_entry,
                                       _bench_engine, _scan_round)
from repro.api import ExperimentSpec, build, git_sha
from repro.core.protocol import train_keys
from repro.core.sweep import SweepConfig, run_padded_cells

FULL = dict(dataset="mnist", n_clients=3, rounds=2, epochs=2,
            n_samples=4000, iters=3,
            grid=dict(client_counts=(2, 3), seeds=(0, 1), rounds=2,
                      epochs=1, n_samples=1024,
                      schedules=("sync", "stale_k:1"),
                      transforms=("none", "int8"),
                      faults=("none", "crash:0.5")))
# overhead deltas are a few percent, so even the smoke lane needs
# enough iterations for the timer to resolve them (a round at these
# sizes is ~10ms); iters=10 keeps the whole lane under a second
SMOKE = dict(dataset="mnist", n_clients=3, rounds=1, epochs=1,
             n_samples=640, iters=10,
             grid=dict(client_counts=(2, 3), seeds=(0,), rounds=1,
                       epochs=1, n_samples=512,
                       schedules=("sync",),
                       transforms=("none", "int8"),
                       faults=("none",)))

LEVELS = ("none", "basic", "full")


def _final_params(spec):
    """Train one round stack end to end; return (params, steps/sec)."""
    sess = build(spec)
    rr = sess.run()
    steps = spec.rounds * spec.epochs * sess.federation.n_batches
    return rr.params, steps / max(rr.timings["wall_s"], 1e-9)


def run(smoke=False, results_path=None):
    """Bench the tap levels, assert tap parity, run the obs grid,
    append the entry, return bench CSV rows."""
    cfg = SMOKE if smoke else FULL
    _, lk = train_keys(jax.random.PRNGKey(0))
    rkey = jax.random.fold_in(lk, 0)
    si = jnp.zeros((), jnp.int32)

    base = ExperimentSpec(dataset=cfg["dataset"],
                          n_clients=cfg["n_clients"],
                          rounds=cfg["rounds"], epochs=cfg["epochs"],
                          n_samples=cfg["n_samples"], seeds=(0,),
                          eval_every=0)

    # parity gate: obs="full" must not perturb training at all
    p_none, _ = _final_params(base)
    p_full, _ = _final_params(base.replace(obs="full"))
    for a, b in zip(jax.tree.leaves(p_none), jax.tree.leaves(p_full)):
        if not np.array_equal(np.asarray(a), np.asarray(b)):
            raise AssertionError(
                "obs='full' params diverged from obs='none' -- the "
                "taps are perturbing training; refusing to record a "
                "perf entry for a broken engine")

    # steady-state throughput per level (same spec_hash by design:
    # obs is hash-excluded, so all three lanes ARE one experiment)
    levels, rows = {}, []
    for level in LEVELS:
        spec = base.replace(obs=level)
        fed = build(spec).federation
        sps = _bench_engine(fed, _scan_round(fed, rkey, si),
                            fed.pcfg.epochs * fed.n_batches,
                            iters=cfg["iters"])
        levels[level] = {"steps_per_sec": sps,
                         "spec_hash": spec.spec_hash}
    overhead = {
        level: 100.0 * (1.0 - levels[level]["steps_per_sec"] /
                        max(levels["none"]["steps_per_sec"], 1e-9))
        for level in LEVELS[1:]}
    for level in LEVELS:
        extra = ("" if level == "none" else
                 f"_overhead={overhead[level]:.1f}%")
        rows.append((f"obs/{level}", 0.0,
                     f"steps_per_sec="
                     f"{levels[level]['steps_per_sec']:.1f}{extra}"))

    # the obs grid shares ONE compiled round with every other lane
    # axis.  Spec grids keep obs grid-common (all levels share one
    # spec_hash -- obs is hash-excluded, an obs level is not a
    # different experiment), so the multi-level axis is expressed at
    # the SweepConfig layer directly.
    g = cfg["grid"]
    scfg = SweepConfig(datasets=(cfg["dataset"],),
                       modes=("devertifl",),
                       client_counts=g["client_counts"],
                       seeds=g["seeds"], rounds=g["rounds"],
                       epochs=g["epochs"],
                       n_samples=g["n_samples"],
                       schedules=g["schedules"],
                       transforms=g["transforms"], faults=g["faults"],
                       obs=LEVELS)
    out = run_padded_cells(cfg["dataset"], "devertifl", scfg)
    rows.append(("obs/grid", 0.0,
                 f"cells={len(out['cells'])}"
                 f"_round_traces={out['round_traces']}"))

    entry = {
        "date": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "git_sha": git_sha(),
        "backend": jax.default_backend(),
        "config": {k: v for k, v in cfg.items() if k != "grid"},
        "levels": levels,
        "overhead_pct": overhead,
        "parity": True,            # the gate above raised otherwise
        "grid": {"cells": len(out["cells"]),
                 "round_traces": out["round_traces"],
                 "lanes": out["lanes"],
                 "devices": out["devices"]},
        "smoke": smoke,
    }
    if results_path is None:
        os.makedirs(RESULTS, exist_ok=True)
        results_path = os.path.join(RESULTS, "BENCH_obs.json")
    _append_entry(entry, results_path)
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(
        description="Observability tap-overhead bench (appends to "
                    "BENCH_obs.json)")
    ap.add_argument("--smoke", action="store_true",
                    help="toy sizes (entry still appended, flagged "
                         "smoke)")
    ap.add_argument("--out", default=None,
                    help="append the entry here instead of "
                         "benchmarks/results/BENCH_obs.json (CI "
                         "lanes point this at a throwaway path)")
    args = ap.parse_args()
    for r in run(smoke=args.smoke, results_path=args.out):
        print(",".join(str(x) for x in r))
