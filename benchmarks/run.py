# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV covering: Fig 3-7 (F1 curves), Table II (literature comparison),
# kernel micro-benchmarks, and the roofline table from the dry-run.
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser(
        description="One function per paper table/figure; prints "
                    "name,us_per_call,derived CSV rows.")
    ap.add_argument("--paper", action="store_true",
                    help="full client range 2..10, 3 seeds (slow)")
    ap.add_argument("--smoke", action="store_true",
                    help="fast perf-regression canary (~1 min): runs ONLY "
                         "the protocol lane (engine + schedule + sweep "
                         "throughput), the staleness schedule sweep, the "
                         "fault-tolerance sweep, the wire-transform "
                         "sweep, and the serving "
                         "offered-load sweep at toy sizes and "
                         "skips the figures, table2, kernels, roofline, "
                         "and ablations lanes; nothing is written to "
                         "benchmarks/results/. Paired with the 'fast' "
                         "pytest marker in scripts/ci.sh.")
    ap.add_argument("--only", default=None,
                    help="comma list of lanes to run: figures,table2,"
                         "kernels,roofline,ablations,protocol,staleness,"
                         "faults,wire,serving (default: all; "
                         "incompatible with --smoke)")
    args = ap.parse_args()
    which = set((args.only or
                 "figures,table2,kernels,roofline,ablations,protocol,"
                 "staleness,faults,wire,serving,analysis").split(","))
    if args.smoke:
        if args.only:
            ap.error("--smoke runs only the protocol + staleness + "
                     "faults + wire + serving + analysis lanes; drop "
                     "--only")
        which = {"protocol", "staleness", "faults", "wire", "serving",
                 "analysis"}

    rows = []
    t0 = time.time()
    if "analysis" in which:
        # static-audit smoke: taint/deadness/retrace over the sync x
        # slice subset (the full grid is the CI `analysis` lane).  A
        # violation here is a correctness regression, not a perf one,
        # so it aborts the bench rather than printing a row quietly.
        from repro.analysis.audit import audit_combos
        ta = time.time()
        report = audit_combos(schedules=("sync",),
                              first_layers=("slice",),
                              lane_check=False)
        if not report.ok:
            print(report.summary(), file=sys.stderr)
            sys.exit(1)
        rows.append(("analysis/audit_smoke",
                     f"{(time.time()-ta)*1e6:.0f}",
                     f"combos={len(report.combos)}_traces="
                     f"{report.static_round_traces}"))
    if "protocol" in which:
        from benchmarks import protocol_bench
        rows += protocol_bench.run(smoke=args.smoke)
    if "staleness" in which:
        from benchmarks import staleness
        rows += staleness.run(smoke=args.smoke)
    if "faults" in which:
        from benchmarks import faults
        rows += faults.run(smoke=args.smoke)
    if "wire" in which:
        import os
        import tempfile

        from benchmarks import wire
        # the wire bench appends even under --smoke (its entry is the
        # deliverable); keep the smoke entry out of benchmarks/results/
        rows += wire.run(
            smoke=args.smoke,
            results_path=os.path.join(tempfile.mkdtemp(),
                                      "BENCH_wire.json")
            if args.smoke else None)
    if "serving" in which:
        from benchmarks import serving
        rows += serving.run(smoke=args.smoke)
    if "kernels" in which:
        from benchmarks import kernels_bench
        rows += kernels_bench.run()
    if "roofline" in which:
        from benchmarks import roofline_table
        rows += roofline_table.run()
    if "table2" in which:
        from benchmarks import table2
        rows += table2.run()
    if "figures" in which:
        from benchmarks import figures
        rows += figures.main(paper=args.paper)
    if "ablations" in which:
        from benchmarks import ablations
        rows += ablations.run()

    print("name,us_per_call,derived")
    for r in rows:
        print(",".join(str(x) for x in r))
    print(f"# total wall time: {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == '__main__':
    main()
