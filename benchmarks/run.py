# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV covering: Fig 3-7 (F1 curves), Table II (literature comparison),
# kernel micro-benchmarks, and the roofline table from the dry-run.
# ``--report`` instead aggregates every benchmarks/results/BENCH_*.json
# trajectory into one chronological, git-SHA-keyed perf table.
from __future__ import annotations

import argparse
import sys
import time


def _fmt_num(v):
    if isinstance(v, int):
        return str(v)
    return f"{v:.4g}"


def _headline(entry, max_items=6):
    """A few representative numeric scalars from one trajectory entry
    (top level, plus one dict level down), in insertion order."""
    skip = {"date", "git_sha", "backend", "smoke", "config",
            "spec_hash", "spec_hashes", "lanes", "devices"}
    out = []
    for k, v in entry.items():
        if len(out) >= max_items:
            break
        if k in skip or isinstance(v, bool):
            continue
        if isinstance(v, (int, float)):
            out.append(f"{k}={_fmt_num(v)}")
        elif isinstance(v, dict):
            for k2, v2 in v.items():
                if len(out) >= max_items:
                    break
                if isinstance(v2, bool) or k2 in skip:
                    continue
                if isinstance(v2, (int, float)):
                    out.append(f"{k}.{k2}={_fmt_num(v2)}")
                elif isinstance(v2, dict) and \
                        isinstance(v2.get("steps_per_sec"),
                                   (int, float)):
                    out.append(
                        f"{k}.{k2}="
                        f"{_fmt_num(v2['steps_per_sec'])}/s")
    return out


def trajectory_report(results_dir=None) -> int:
    """Print the accumulated perf trajectories: one section per
    BENCH_*.json, one dated git-SHA-keyed line per appended entry
    (append order IS chronological -- the files are append-only)."""
    import glob
    import json
    import os
    d = results_dir or os.path.join(os.path.dirname(__file__),
                                    "results")
    paths = sorted(glob.glob(os.path.join(d, "BENCH_*.json")))
    if not paths:
        print(f"no BENCH_*.json trajectories under {d}; run the "
              "benches first (python -m benchmarks.run)")
        return 1
    for path in paths:
        try:
            with open(path) as f:
                data = json.load(f)
        except (json.JSONDecodeError, OSError) as e:
            print(f"\n== {os.path.basename(path)}: unreadable ({e})")
            continue
        data = data if isinstance(data, list) else [data]
        print(f"\n== {os.path.basename(path)} ({len(data)} entries)")
        for e in data:
            if not isinstance(e, dict):
                continue
            flag = " smoke" if e.get("smoke") else ""
            print(f"  {str(e.get('date', '?'))[:19]:<20}"
                  f"{str(e.get('git_sha', '?')):<18}"
                  + " ".join(_headline(e)) + flag)
    return 0


def main() -> None:
    ap = argparse.ArgumentParser(
        description="One function per paper table/figure; prints "
                    "name,us_per_call,derived CSV rows.")
    ap.add_argument("--paper", action="store_true",
                    help="full client range 2..10, 3 seeds (slow)")
    ap.add_argument("--smoke", action="store_true",
                    help="fast perf-regression canary (~1 min): runs ONLY "
                         "the protocol lane (engine + schedule + sweep "
                         "throughput), the staleness schedule sweep, the "
                         "fault-tolerance sweep, the wire-transform "
                         "sweep, the serving offered-load sweep, and "
                         "the obs tap-overhead lane at toy sizes and "
                         "skips the figures, table2, kernels, roofline, "
                         "and ablations lanes; nothing is written to "
                         "benchmarks/results/. Paired with the 'fast' "
                         "pytest marker in scripts/ci.sh.")
    ap.add_argument("--only", default=None,
                    help="comma list of lanes to run: figures,table2,"
                         "kernels,roofline,ablations,protocol,staleness,"
                         "faults,wire,serving,obs (default: all; "
                         "incompatible with --smoke)")
    ap.add_argument("--report", action="store_true",
                    help="print the accumulated BENCH_*.json perf "
                         "trajectories (dated, git-SHA-keyed) instead "
                         "of running anything")
    args = ap.parse_args()
    if args.report:
        if args.smoke or args.only:
            ap.error("--report only reads benchmarks/results/; drop "
                     "--smoke/--only")
        sys.exit(trajectory_report())
    which = set((args.only or
                 "figures,table2,kernels,roofline,ablations,protocol,"
                 "staleness,faults,wire,serving,obs,analysis")
                .split(","))
    if args.smoke:
        if args.only:
            ap.error("--smoke runs only the protocol + staleness + "
                     "faults + wire + serving + obs + analysis lanes; "
                     "drop --only")
        which = {"protocol", "staleness", "faults", "wire", "serving",
                 "obs", "analysis"}

    rows = []
    t0 = time.time()
    if "analysis" in which:
        # static-audit smoke: taint/deadness/retrace over the sync x
        # slice subset (the full grid is the CI `analysis` lane).  A
        # violation here is a correctness regression, not a perf one,
        # so it aborts the bench rather than printing a row quietly.
        from repro.analysis.audit import audit_combos
        ta = time.time()
        report = audit_combos(schedules=("sync",),
                              first_layers=("slice",),
                              lane_check=False)
        if not report.ok:
            print(report.summary(), file=sys.stderr)
            sys.exit(1)
        rows.append(("analysis/audit_smoke",
                     f"{(time.time()-ta)*1e6:.0f}",
                     f"combos={len(report.combos)}_traces="
                     f"{report.static_round_traces}"))
    if "protocol" in which:
        from benchmarks import protocol_bench
        rows += protocol_bench.run(smoke=args.smoke)
    if "staleness" in which:
        from benchmarks import staleness
        rows += staleness.run(smoke=args.smoke)
    if "faults" in which:
        from benchmarks import faults
        rows += faults.run(smoke=args.smoke)
    if "wire" in which:
        import os
        import tempfile

        from benchmarks import wire
        # the wire bench appends even under --smoke (its entry is the
        # deliverable); keep the smoke entry out of benchmarks/results/
        rows += wire.run(
            smoke=args.smoke,
            results_path=os.path.join(tempfile.mkdtemp(),
                                      "BENCH_wire.json")
            if args.smoke else None)
    if "serving" in which:
        from benchmarks import serving
        rows += serving.run(smoke=args.smoke)
    if "obs" in which:
        import os
        import tempfile

        from benchmarks import obs
        # like the wire lane: the obs bench appends even under --smoke
        # (its entry is the deliverable); keep smoke entries out of
        # benchmarks/results/
        rows += obs.run(
            smoke=args.smoke,
            results_path=os.path.join(tempfile.mkdtemp(),
                                      "BENCH_obs.json")
            if args.smoke else None)
    if "kernels" in which:
        from benchmarks import kernels_bench
        rows += kernels_bench.run()
    if "roofline" in which:
        from benchmarks import roofline_table
        rows += roofline_table.run()
    if "table2" in which:
        from benchmarks import table2
        rows += table2.run()
    if "figures" in which:
        from benchmarks import figures
        rows += figures.main(paper=args.paper)
    if "ablations" in which:
        from benchmarks import ablations
        rows += ablations.run()

    print("name,us_per_call,derived")
    for r in rows:
        print(",".join(str(x) for x in r))
    print(f"# total wall time: {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == '__main__':
    main()
