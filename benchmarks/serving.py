"""Federated serving under offered load: latency and throughput of
``Session.serve()``'s continuous-batched vertical inference as the
arrival rate and slot-pool size vary.

Each cell replays the SAME request stream (seeded entity draws from a
hot-entity pool, so repeat entities exercise the exchange cache)
against one :class:`repro.serving.FederatedServer` per slot count at a
wall-clock arrival schedule: requests are submitted when their arrival
time passes, the slot pool steps continuously, and per-request
telemetry (submit -> done) yields p50/p99 latency, throughput, and the
cache hit rate.  The server is REUSED across load levels so the cell
grid demonstrates the one-compile contract: ``step_traces == 1`` per
(max_slots, spec) configuration no matter how many cells ran through
it (recorded per slot count in the entry).

Results append to ``benchmarks/results/BENCH_serving.json`` (same
append-only rules as BENCH_protocol.json), one dated git-SHA-keyed
entry per run, spec_hash-stamped.

Run:    PYTHONPATH=src python -m benchmarks.serving
Smoke:  PYTHONPATH=src python -m benchmarks.serving --smoke
        (toy sizes, no result-file write unless --out is given; the
        scripts/ci.sh serving-smoke lane runs this with a throwaway
        --out)
"""
from __future__ import annotations

import datetime
import json
import os
import time

import jax
import numpy as np

from benchmarks.protocol_bench import RESULTS, _append_entry
from repro.api import ExperimentSpec, ServeRequest, build, git_sha, \
    split_features

FULL = dict(dataset="mnist", n_clients=3, rounds=3, epochs=2,
            n_samples=2000, n_requests=192, entity_pool=64,
            loads_rps=(200.0, 1000.0, 5000.0), slot_counts=(4, 16))
SMOKE = dict(dataset="mnist", n_clients=3, rounds=1, epochs=1,
             n_samples=512, n_requests=36, entity_pool=12,
             loads_rps=(200.0, 1000.0, 4000.0), slot_counts=(4,))


def make_stream(cfg, rng):
    """The request stream every cell replays: row indices and entity
    ids drawn from a bounded hot-entity pool (pool < stream length, so
    repeats exercise the cache)."""
    ents = rng.integers(0, cfg["entity_pool"], cfg["n_requests"])
    return [(int(e), f"entity-{e}") for e in ents]


def drive_cell(srv, layout, xte, stream, rate_rps, tag):
    """Replay ``stream`` against ``srv`` at ``rate_rps`` offered load
    (arrival time i/rate), stepping the pool continuously; return the
    cell's latency/throughput/cache metrics from the telemetry added
    during this cell only."""
    tele_start = len(srv.telemetry)
    hits0 = srv.cache.hits if srv.cache else 0
    miss0 = srv.cache.misses if srv.cache else 0
    arrivals = np.arange(len(stream)) / rate_rps
    t0 = time.perf_counter()
    i = 0
    while i < len(stream) or srv.queued or srv.occupancy:
        now = time.perf_counter() - t0
        while i < len(stream) and arrivals[i] <= now:
            row, entity = stream[i]
            srv.submit(ServeRequest(
                uid=f"{tag}-{i}", entity_id=f"{tag}:{entity}",
                slices=split_features(layout, xte[row])))
            i += 1
        if srv.step() == 0 and i < len(stream):
            # pool idle, next arrival not due yet: sleep toward it
            wait = arrivals[i] - (time.perf_counter() - t0)
            if wait > 0:
                time.sleep(min(wait, 1e-3))
    tele = srv.telemetry[tele_start:]
    lat = np.asarray([t["latency_s"] for t in tele])
    wall = max(t["t_done"] for t in tele) - min(t["t_submit"]
                                                for t in tele)
    hits = (srv.cache.hits - hits0) if srv.cache else 0
    misses = (srv.cache.misses - miss0) if srv.cache else 0
    return {
        "offered_rps": rate_rps,
        "n_requests": len(tele),
        "p50_ms": float(np.percentile(lat, 50) * 1e3),
        "p99_ms": float(np.percentile(lat, 99) * 1e3),
        "mean_ms": float(lat.mean() * 1e3),
        "throughput_rps": len(tele) / wall if wall > 0 else 0.0,
        "cache_hit_rate": hits / (hits + misses) if hits + misses
        else 0.0,
    }


def run(smoke=False, results_path=None):
    """Train the serving spec once, sweep offered load x slot count
    over the same seeded request stream, append the entry, return
    bench CSV rows.  smoke=True shrinks to toy sizes and (unless
    results_path is given) skips the file write."""
    cfg = SMOKE if smoke else FULL
    spec = ExperimentSpec(
        dataset=cfg["dataset"], mode="devertifl",
        n_clients=cfg["n_clients"], rounds=cfg["rounds"],
        epochs=cfg["epochs"], n_samples=cfg["n_samples"], eval_every=0)
    sess = build(spec)
    sess.run()
    layout = sess.federation.layout
    xte = np.asarray(sess.federation.xte)
    stream = make_stream(cfg, np.random.default_rng(0))

    cells, rows, traces = {}, [], {}
    for S in cfg["slot_counts"]:
        # ONE server (one compiled step) serves every load level at
        # this slot count; entity namespaces are per-cell so each
        # cell's hit rate reflects its own stream's repeats
        srv = sess.server(max_slots=S,
                          cache=2 * cfg["entity_pool"])
        for rate in cfg["loads_rps"]:
            tag = f"load{rate:g}/slots{S}"
            cells[tag] = drive_cell(srv, layout, xte, stream, rate,
                                    tag)
            c = cells[tag]
            rows.append((f"serving/{tag}", f"{c['p50_ms']*1e3:.0f}",
                         f"p99={c['p99_ms']:.2f}ms_thr="
                         f"{c['throughput_rps']:.0f}rps_hit="
                         f"{c['cache_hit_rate']:.2f}"))
        traces[str(S)] = srv.step_traces
        if srv.step_traces != 1:
            raise AssertionError(
                f"slot pool {S} retraced: step_traces="
                f"{srv.step_traces} (expected exactly 1 compile per "
                "(max_slots, spec) configuration)")

    entry = {
        "date": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "git_sha": git_sha(),
        "backend": jax.default_backend(),
        "config": {k: v for k, v in cfg.items()},
        "spec_hash": spec.spec_hash,
        "step_traces": traces,       # per slot count; all must be 1
        "cells": cells,
    }
    if results_path is None and not smoke:
        os.makedirs(RESULTS, exist_ok=True)
        results_path = os.path.join(RESULTS, "BENCH_serving.json")
    if results_path is not None:
        _append_entry(entry, results_path)
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(
        description="Offered-load x slot-count serving sweep (appends "
                    "to BENCH_serving.json)")
    ap.add_argument("--smoke", action="store_true",
                    help="toy sizes, no result-file write")
    ap.add_argument("--out", default=None,
                    help="write the entry to this path instead of "
                         "benchmarks/results/ (CI smoke uses a "
                         "throwaway file)")
    args = ap.parse_args()
    for r in run(smoke=args.smoke, results_path=args.out):
        print(",".join(str(x) for x in r))
