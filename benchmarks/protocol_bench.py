"""Protocol engine throughput: per-batch Python-loop dispatch vs the
fused lax.scan round (repro.core.protocol.make_round_fn), plus sweep
throughput (seed-vmapped federations from repro.core.sweep).

Emits benchmarks/results/BENCH_protocol.json so the perf trajectory is
recorded across PRs:

  {"loop_steps_per_sec": ..., "scan_steps_per_sec": ...,
   "scan_speedup": ..., "sweep": {...}}

Run:  PYTHONPATH=src python -m benchmarks.protocol_bench
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from repro.core.protocol import DeVertiFL, ProtocolConfig, train_keys
from repro.core.sweep import SweepConfig, run_cell

RESULTS = os.path.join(os.path.dirname(__file__), "results")

# the paper's MNIST configuration, sized so one round is ~100 steps
BENCH_CFG = dict(dataset="mnist", n_clients=3, epochs=2, n_samples=4000)


def _bench_engine(fed, run_round, n_steps, iters=3):
    def fresh():
        ik, _ = train_keys(jax.random.PRNGKey(0))
        p = fed.init_params(ik)
        return p, jax.vmap(fed.opt.init)(p)

    p, o = fresh()
    p, o, _, losses = run_round(p, o)       # warm-up / compile
    jax.block_until_ready(losses)
    t0 = time.perf_counter()
    for _ in range(iters):
        p, o, _, losses = run_round(p, o)
    jax.block_until_ready(losses)
    return iters * n_steps / (time.perf_counter() - t0)


def run():
    fed = DeVertiFL(ProtocolConfig(rounds=1, **BENCH_CFG))
    _, lk = train_keys(jax.random.PRNGKey(0))
    rkey = jax.random.fold_in(lk, 0)
    si = jnp.zeros((), jnp.int32)
    n_steps = fed.pcfg.epochs * fed.n_batches

    scan = _bench_engine(
        fed, lambda p, o: fed._round(p, o, si, rkey, fed._xtr, fed._ytr,
                                     fed.masks), n_steps)
    loop = _bench_engine(
        fed, lambda p, o: fed._python_round(p, o, si, rkey), n_steps)

    sweep_cell = run_cell("mnist", "devertifl", 3,
                          SweepConfig(seeds=(0, 1, 2, 3), rounds=2,
                                      epochs=2, n_samples=2000))
    report = {
        "config": BENCH_CFG,
        "steps_per_round": n_steps,
        "loop_steps_per_sec": loop,
        "scan_steps_per_sec": scan,
        "scan_speedup": scan / loop,
        "sweep": {
            "n_seeds": len(sweep_cell["seeds"]),
            "steps_per_sec": sweep_cell["steps_per_sec"],
            "wall_s": sweep_cell["wall_s"],
        },
    }
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "BENCH_protocol.json"), "w") as f:
        json.dump(report, f, indent=1)

    return [
        ("protocol/loop", 1e6 / loop, f"steps_per_sec={loop:.1f}"),
        ("protocol/scan", 1e6 / scan, f"steps_per_sec={scan:.1f}"),
        ("protocol/scan_speedup", 0.0, f"x{scan / loop:.2f}"),
        ("protocol/sweep4seeds", sweep_cell["wall_s"] * 1e6,
         f"steps_per_sec={sweep_cell['steps_per_sec']:.1f}"),
    ]


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
