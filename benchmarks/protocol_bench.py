"""Protocol engine throughput across first-layer strategies: the
paper-literal masked (zero-padded) scan, the slice-aware dynamic_slice
scan, the vfl_matmul Pallas scan, and the per-batch Python-loop
reference -- plus sweep throughput (seed-vmapped federations from
repro.core.sweep).

Appends one dated, git-SHA-keyed entry per run to
benchmarks/results/BENCH_protocol.json (a list), so the perf
trajectory accumulates across PRs instead of being overwritten:

  [{"date": ..., "git_sha": ..., "config": {...},
    "engines": {"loop": sps, "masked": sps, "slice": sps,
                "pallas": sps},
    "slice_speedup_vs_masked": ..., "scan_speedup_vs_loop": ...,
    "sweep": {...}}, ...]

Pre-slice-engine entries (a single dict with loop/scan keys) are
migrated into the list on first append.

Run:  PYTHONPATH=src python -m benchmarks.protocol_bench
Smoke (toy sizes, no file write): python -m benchmarks.run --smoke
"""
from __future__ import annotations

import datetime
import json
import os
import subprocess
import time

import jax
import jax.numpy as jnp

from repro.core.protocol import DeVertiFL, ProtocolConfig, train_keys
from repro.core.sweep import SweepConfig, run_cell

RESULTS = os.path.join(os.path.dirname(__file__), "results")

# the paper's MNIST configuration, sized so one round is ~100 steps
BENCH_CFG = dict(dataset="mnist", n_clients=3, epochs=2, n_samples=4000)
SMOKE_CFG = dict(dataset="mnist", n_clients=3, epochs=1, n_samples=640)


def _git_sha():
    try:
        return subprocess.check_output(
            ["git", "describe", "--always", "--dirty"],
            cwd=os.path.dirname(__file__), text=True).strip()
    except Exception:
        return "unknown"


def _append_entry(entry, path):
    """Append-only trajectory: never clobber previous runs.  An
    unreadable file is moved aside (.corrupt) rather than overwritten."""
    data = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                old = json.load(f)
            data = old if isinstance(old, list) else [old]
        except (json.JSONDecodeError, OSError):
            backup = path + ".corrupt"
            os.replace(path, backup)
            print(f"warning: unreadable {path} moved to {backup}")
    data.append(entry)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(data, f, indent=1)
    os.replace(tmp, path)       # atomic: a crash never truncates history
    return data


def _bench_engine(fed, run_round, n_steps, iters=3):
    def fresh():
        ik, _ = train_keys(jax.random.PRNGKey(0))
        p = fed.init_params(ik)
        return p, jax.vmap(fed.opt.init)(p)

    p, o = fresh()
    p, o, _, losses = run_round(p, o)       # warm-up / compile
    jax.block_until_ready(losses)
    t0 = time.perf_counter()
    for _ in range(iters):
        p, o, _, losses = run_round(p, o)
    jax.block_until_ready(losses)
    return iters * n_steps / (time.perf_counter() - t0)


def run(smoke=False, results_path=None, iters=None):
    """Bench all engine lanes.  smoke=True shrinks to toy sizes and
    (unless results_path is given) skips the trajectory file write, so
    it is safe inside tier-1 time budgets."""
    cfg = SMOKE_CFG if smoke else BENCH_CFG
    iters = iters if iters is not None else (1 if smoke else 3)
    _, lk = train_keys(jax.random.PRNGKey(0))
    rkey = jax.random.fold_in(lk, 0)
    si = jnp.zeros((), jnp.int32)

    engines = {}
    n_steps = None
    for fl in ("masked", "slice", "pallas"):
        fed = DeVertiFL(ProtocolConfig(rounds=1, first_layer=fl, **cfg))
        n_steps = fed.pcfg.epochs * fed.n_batches
        engines[fl] = _bench_engine(
            fed, lambda p, o: fed._round(p, o, si, rkey, fed._xtr,
                                         fed._ytr, fed._lay),
            n_steps, iters=iters)
        if fl == "masked":
            engines["loop"] = _bench_engine(
                fed, lambda p, o: fed._python_round(p, o, si, rkey),
                n_steps, iters=iters)

    sweep_scfg = (SweepConfig(seeds=(0, 1), rounds=2, epochs=1,
                              n_samples=512) if smoke else
                  SweepConfig(seeds=(0, 1, 2, 3), rounds=2, epochs=2,
                              n_samples=2000))
    sweep_cell = run_cell("mnist", "devertifl", 3, sweep_scfg)

    entry = {
        "date": datetime.datetime.now(datetime.timezone.utc).isoformat(
            timespec="seconds"),
        "git_sha": _git_sha(),
        # on non-TPU backends the pallas lane times the interpreter,
        # not the compiled kernel -- record the backend so trajectory
        # entries from different machines stay comparable
        "backend": jax.default_backend(),
        "config": dict(cfg, smoke=smoke, iters=iters),
        "steps_per_round": n_steps,
        "engines": engines,
        "slice_speedup_vs_masked": engines["slice"] / engines["masked"],
        # same first layer on both sides: comparable with PR 1's
        # scan_speedup trajectory entry
        "scan_speedup_vs_loop": engines["masked"] / engines["loop"],
        "sweep": {
            "n_seeds": len(sweep_cell["seeds"]),
            "steps_per_sec": sweep_cell["steps_per_sec"],
            "wall_s": sweep_cell["wall_s"],
        },
    }
    if results_path is None and not smoke:
        os.makedirs(RESULTS, exist_ok=True)
        results_path = os.path.join(RESULTS, "BENCH_protocol.json")
    if results_path is not None:
        _append_entry(entry, results_path)

    rows = [(f"protocol/{name}", 1e6 / sps, f"steps_per_sec={sps:.1f}")
            for name, sps in engines.items()]
    rows += [
        ("protocol/slice_vs_masked", 0.0,
         f"x{entry['slice_speedup_vs_masked']:.2f}"),
        ("protocol/sweep", sweep_cell["wall_s"] * 1e6,
         f"steps_per_sec={sweep_cell['steps_per_sec']:.1f}"),
    ]
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
