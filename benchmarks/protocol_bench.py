"""Protocol engine throughput across first-layer strategies: the
paper-literal masked (zero-padded) scan, the slice-aware dynamic_slice
scan, the vfl_matmul Pallas scan, and the per-batch Python-loop
reference -- plus the sweep lane comparing three executions of the
same multi-client-count grid slice:

  looped   one run_cell per client count (one compile EACH)
  padded   run_padded_cells: all counts on one padded lane axis,
           ONE compile, single device
  sharded  run_padded_cells with the lane axis shard_map'ed over
           the device mesh (== padded when only one device exists;
           the recorded "devices" field disambiguates)

Appends one dated, git-SHA-keyed entry per run to
benchmarks/results/BENCH_protocol.json (a list), so the perf
trajectory accumulates across PRs instead of being overwritten:

  [{"date": ..., "git_sha": ..., "spec_hash": ...,
    "spec_hashes": {lane: ...}, "config": {...},
    "engines": {"loop": sps, "masked": sps, "slice": sps,
                "pallas": sps},
    "slice_speedup_vs_masked": ..., "scan_speedup_vs_loop": ...,
    "schedules": {sched: {"steps_per_sec": ..., "f1": ...,
                          "spec_hash": ...}},
    "sweep": {"client_counts": [...], "spec_hashes": {n: ...},
              "n_seeds": ...,
              "looped_cells_per_sec": ..., "padded_cells_per_sec": ...,
              "sharded_cells_per_sec": ..., "devices": ...,
              "round_traces": ...}}, ...]

(docs/ARCHITECTURE.md documents the append-only schema contract.)
Pre-slice-engine entries (a single dict with loop/scan keys) are
migrated into the list on first append.

Run:  PYTHONPATH=src python -m benchmarks.protocol_bench
Smoke (toy sizes, no file write): python -m benchmarks.run --smoke
"""
from __future__ import annotations

import datetime
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.api import (ExperimentSpec, build, git_sha as _git_sha,
                       spec_grid, sweep_config_for_specs)
from repro.core.protocol import train_keys
from repro.core.sweep import run_cell, run_padded_cells

RESULTS = os.path.join(os.path.dirname(__file__), "results")

# the paper's MNIST configuration, sized so one round is ~100 steps
BENCH_CFG = dict(dataset="mnist", n_clients=3, epochs=2, n_samples=4000)
SMOKE_CFG = dict(dataset="mnist", n_clients=3, epochs=1, n_samples=640)


def _append_entry(entry, path):
    """Append-only trajectory: never clobber previous runs.  An
    unreadable file is moved aside (.corrupt) rather than overwritten."""
    data = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                old = json.load(f)
            data = old if isinstance(old, list) else [old]
        except (json.JSONDecodeError, OSError):
            backup = path + ".corrupt"
            os.replace(path, backup)
            print(f"warning: unreadable {path} moved to {backup}")
    data.append(entry)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(data, f, indent=1)
    os.replace(tmp, path)       # atomic: a crash never truncates history
    return data


def _bench_engine(fed, run_round, n_steps, iters=3):
    """run_round(params, opt_state, sched_state) must return
    (params, opt_state, sched_state, losses)."""
    def fresh():
        ik, _ = train_keys(jax.random.PRNGKey(0))
        p = fed.init_params(ik)
        return p, jax.vmap(fed.opt.init)(p), fed.init_sched_state()

    p, o, st = fresh()
    p, o, st, losses = run_round(p, o, st)      # warm-up / compile
    jax.block_until_ready(losses)
    t0 = time.perf_counter()
    for _ in range(iters):
        p, o, st, losses = run_round(p, o, st)
    jax.block_until_ready(losses)
    return iters * n_steps / (time.perf_counter() - t0)


def _scan_round(fed, rkey, si):
    """Adapter: the jitted scan round as a (p, o, st) -> ... callable."""
    def run_round(p, o, st):
        p, o, _, st, losses = fed._round(p, o, si, st, rkey, fed._xtr,
                                         fed._ytr, fed._lay)
        return p, o, st, losses
    return run_round


def run(smoke=False, results_path=None, iters=None):
    """Bench all engine lanes.  smoke=True shrinks to toy sizes and
    (unless results_path is given) skips the trajectory file write, so
    it is safe inside tier-1 time budgets."""
    cfg = SMOKE_CFG if smoke else BENCH_CFG
    iters = iters if iters is not None else (1 if smoke else 3)
    _, lk = train_keys(jax.random.PRNGKey(0))
    rkey = jax.random.fold_in(lk, 0)
    si = jnp.zeros((), jnp.int32)

    base_spec = ExperimentSpec(rounds=1, seeds=(0,), eval_every=0, **cfg)
    engines, spec_hashes = {}, {}
    n_steps = None
    for fl in ("masked", "slice", "pallas"):
        lane_spec = base_spec.replace(first_layer=fl)
        spec_hashes[fl] = lane_spec.spec_hash
        fed = build(lane_spec).federation
        n_steps = fed.pcfg.epochs * fed.n_batches
        engines[fl] = _bench_engine(fed, _scan_round(fed, rkey, si),
                                    n_steps, iters=iters)
        if fl == "masked":
            spec_hashes["loop"] = lane_spec.replace(
                engine="python").spec_hash

            def loop_round(p, o, st, fed=fed):
                p, o, _, st, losses = fed._python_round(p, o, si, st,
                                                        rkey)
                return p, o, st, losses
            engines["loop"] = _bench_engine(fed, loop_round, n_steps,
                                            iters=iters)

    # exchange-schedule lane: scan-round throughput + final F1 per
    # schedule, each stamped with the exact spec it timed.  "sync" is
    # the reference row (same engine as the slice lane above), so the
    # schedule overhead -- ring pushes, double-buffer swaps, the extra
    # data-copy forward -- is measured against it like-for-like.
    sched_rounds = 1 if smoke else 2
    schedules = {}
    for sname in ("sync", "stale_k:1", "double_buffer", "partial:0.8"):
        sspec = base_spec.replace(schedule=sname, rounds=sched_rounds)
        sess = build(sspec)
        sfed = sess.federation
        sps = _bench_engine(sfed, _scan_round(sfed, rkey, si),
                            sfed.pcfg.epochs * sfed.n_batches,
                            iters=iters)
        f1 = sess.run().metrics["f1"]
        schedules[sname] = {"steps_per_sec": sps, "f1": f1,
                            "spec_hash": sspec.spec_hash}

    # the sweep lane's config is DERIVED from its spec grid, so the
    # spec_hashes stamped below can never diverge from what is timed
    sweep_specs = spec_grid(
        datasets=("mnist",), modes=("devertifl",),
        **(dict(client_counts=(2, 3), seeds=(0, 1), rounds=2, epochs=1,
                n_samples=512)
           if smoke else
           dict(client_counts=(2, 3, 5), seeds=(0, 1, 2, 3), rounds=2,
                epochs=2, n_samples=2000)))
    _, _, sweep_scfg = sweep_config_for_specs(sweep_specs)
    counts = tuple(sweep_scfg.client_counts)
    # all three lanes are timed END-TO-END (data stacking + compiles +
    # training + eval): compile amortization is the padded engine's
    # win, so the walls must include it on every side
    t0 = time.perf_counter()
    looped_cells = [run_cell("mnist", "devertifl", nc, sweep_scfg)
                    for nc in counts]
    looped_wall = time.perf_counter() - t0
    # padded: every count on one lane axis, ONE round compile
    t0 = time.perf_counter()
    padded = run_padded_cells("mnist", "devertifl", sweep_scfg,
                              shard=False)
    padded_wall = time.perf_counter() - t0
    # sharded: same batch, lanes split over the device mesh.  With a
    # single device the shard_map is a no-op and the run would be
    # bitwise the padded one -- reuse it instead of paying a second
    # compile + train just to record noise.
    if jax.device_count() > 1:
        t0 = time.perf_counter()
        sharded = run_padded_cells("mnist", "devertifl", sweep_scfg,
                                   shard="auto")
        sharded_wall = time.perf_counter() - t0
    else:
        sharded, sharded_wall = padded, padded_wall
    sweep_entry = {
        "client_counts": list(counts),
        # spec ids of the per-count experiments this sweep covers,
        # keyed by n_clients (the very specs sweep_scfg was derived
        # from).  NOTE these identify the experiment CONFIGURATION:
        # the padded multi-count engine executes the gather-slice
        # first-layer lane, which is allclose -- not bitwise -- to
        # these specs' standalone runs (see repro.core.sweep docs)
        "spec_hashes": {str(s.n_clients): s.spec_hash
                        for s in sweep_specs},
        "n_seeds": len(sweep_scfg.seeds),
        "looped_cells_per_sec": len(counts) / max(looped_wall, 1e-9),
        "padded_cells_per_sec": len(counts) / max(padded_wall, 1e-9),
        "sharded_cells_per_sec": len(counts) / max(sharded_wall, 1e-9),
        # steady-state (post-compile) throughput of the padded batch
        "padded_steady_cells_per_sec": padded["cells_per_sec"],
        "devices": sharded["devices"],
        "round_traces": padded["round_traces"],
        # the SAME n_clients=3 run_cell measurement older trajectory
        # entries recorded, so the steps_per_sec series stays
        # comparable across PRs (if 3 ever leaves the count list, fall
        # back to the first count rather than aborting a finished run)
        "steps_per_sec": looped_cells[
            counts.index(3) if 3 in counts else 0]["steps_per_sec"],
    }

    entry = {
        "date": datetime.datetime.now(datetime.timezone.utc).isoformat(
            timespec="seconds"),
        "git_sha": _git_sha(),
        # joinability: spec_hash identifies the base bench experiment
        # (repro.api.ExperimentSpec.spec_hash); spec_hashes maps each
        # engine lane to the exact spec it timed
        "spec_hash": base_spec.spec_hash,
        "spec_hashes": spec_hashes,
        # on non-TPU backends the pallas lane times the interpreter,
        # not the compiled kernel -- record the backend so trajectory
        # entries from different machines stay comparable
        "backend": jax.default_backend(),
        "config": dict(cfg, smoke=smoke, iters=iters),
        "steps_per_round": n_steps,
        "engines": engines,
        "slice_speedup_vs_masked": engines["slice"] / engines["masked"],
        # same first layer on both sides: comparable with PR 1's
        # scan_speedup trajectory entry
        "scan_speedup_vs_loop": engines["masked"] / engines["loop"],
        # per-schedule scan throughput + final F1 (spec-hash-stamped):
        # the exchange-schedule lane added in PR 5
        "schedules": schedules,
        "sweep": sweep_entry,
    }
    # statically-verified compile-once contract (repro.analysis): the
    # retrace pass proves the benched round's carried avals close and
    # no captured scalar can drift -- 1 iff no unwaived hazard.  The
    # runtime sweep counter above measures one grid; this stamps the
    # structural claim the counter relies on.
    from repro.analysis.audit import audit as _static_audit
    entry["static_round_traces"] = _static_audit(
        base_spec, passes=("retrace",),
        lane_check=False).static_round_traces
    if results_path is None and not smoke:
        os.makedirs(RESULTS, exist_ok=True)
        results_path = os.path.join(RESULTS, "BENCH_protocol.json")
    if results_path is not None:
        _append_entry(entry, results_path)

    rows = [(f"protocol/{name}", 1e6 / sps, f"steps_per_sec={sps:.1f}")
            for name, sps in engines.items()]
    rows += [(f"protocol/sched_{name}", 1e6 / d["steps_per_sec"],
              f"steps_per_sec={d['steps_per_sec']:.1f} f1={d['f1']:.3f}")
             for name, d in schedules.items()]
    rows += [
        ("protocol/slice_vs_masked", 0.0,
         f"x{entry['slice_speedup_vs_masked']:.2f}"),
        ("protocol/sweep_looped", looped_wall * 1e6,
         f"cells_per_sec={sweep_entry['looped_cells_per_sec']:.2f}"),
        ("protocol/sweep_padded", padded_wall * 1e6,
         f"cells_per_sec={sweep_entry['padded_cells_per_sec']:.2f}"),
        ("protocol/sweep_sharded", sharded_wall * 1e6,
         f"cells_per_sec={sweep_entry['sharded_cells_per_sec']:.2f}"
         f" devices={sweep_entry['devices']}"),
    ]
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
