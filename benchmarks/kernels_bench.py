"""Kernel micro-benchmarks: per-call wall time of the XLA reference path
on CPU (the Pallas kernels target TPU; interpret-mode timings are not
meaningful, so we time the oracle path and report the kernel's derived
arithmetic/bandwidth characteristics from its block structure).

derived column: modelled VMEM working set + MXU utilization facts used
in EXPERIMENTS.md's kernel notes.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_ref
from repro.kernels.rwkv6_scan import rwkv6_scan_ref
from repro.kernels.vfl_matmul import vfl_matmul_ref


def _time(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run():
    rows = []
    key = jax.random.PRNGKey(0)

    # vfl_matmul: 1-of-4-clients slice of a 2048-wide feature space
    x = jax.random.normal(key, (512, 512), jnp.float32)
    w = jax.random.normal(key, (2048, 1024), jnp.float32)
    f = jax.jit(lambda a, b: vfl_matmul_ref(a, b, 512))
    us = _time(f, x, w)
    dense_flops = 512 * 2048 * 1024 * 2
    sparse_flops = 512 * 512 * 1024 * 2
    rows.append(("kernels/vfl_matmul_ref_512x2048x1024", us,
                 f"mxu_saving={dense_flops/sparse_flops:.1f}x"))

    # flash attention: 1k sequence, GQA 8:2
    q = jax.random.normal(key, (1, 8, 1024, 64), jnp.bfloat16)
    k = jax.random.normal(key, (1, 2, 1024, 64), jnp.bfloat16)
    v = jax.random.normal(key, (1, 2, 1024, 64), jnp.bfloat16)
    f = jax.jit(lambda a, b, c: flash_attention_ref(a, b, c, causal=True))
    us = _time(f, q, k, v)
    vmem_kb = (128 * 64 * 2 * 3 + 128 * 128 * 4) / 1024
    rows.append(("kernels/flash_attn_ref_b1h8s1024", us,
                 f"vmem_per_block={vmem_kb:.0f}KiB"))

    # mamba selective scan
    from repro.kernels.mamba_scan import mamba_scan_ref
    a = jax.nn.sigmoid(jax.random.normal(key, (1, 512, 256, 16))) * 0.5 + 0.4
    bxm = jax.random.normal(key, (1, 512, 256, 16)) * 0.2
    cm = jax.random.normal(key, (1, 512, 16))
    f = jax.jit(mamba_scan_ref)
    us = _time(f, a, bxm, cm)
    rows.append(("kernels/mamba_scan_ref_t512d256n16", us,
                 "vmem_state=32KiB_per_bd512_tile"))

    # fused MoE router (deepseek shape: 64 experts top-6)
    from repro.kernels.moe_router import moe_router_ref
    logits = jax.random.normal(key, (4096, 64), jnp.float32)
    f = jax.jit(lambda x: moe_router_ref(x, 6))
    us = _time(f, logits)
    rows.append(("kernels/moe_router_ref_t4096e64k6", us,
                 "tile=128x64=32KiB_vmem"))

    # rwkv6 scan
    r = jax.random.normal(key, (1, 512, 4, 64), jnp.float32)
    kk = jax.random.normal(key, (1, 512, 4, 64), jnp.float32) * 0.3
    vv = jax.random.normal(key, (1, 512, 4, 64), jnp.float32)
    ww = jax.nn.sigmoid(jax.random.normal(key, (1, 512, 4, 64))) * 0.5 + 0.4
    u = jax.random.normal(key, (4, 64)) * 0.2
    f = jax.jit(lambda *a: rwkv6_scan_ref(*a))
    us = _time(f, r, kk, vv, ww, u)
    rows.append(("kernels/rwkv6_scan_ref_t512h4", us,
                 "state_vmem=16KiB_fp32"))
    return rows


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
