"""Continuous-batching serving: a stream of requests with different
prompt lengths and budgets flows through a fixed slot pool; prefill
splices each new request into a running batch (vLLM-style, static
shapes for TPU).

  PYTHONPATH=src python examples/serving_engine.py --arch mixtral-8x22b
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.reduced import reduced_config
from repro.models import build_model
from repro.serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, max_batch=args.slots,
                           cache_len=96)

    rng = np.random.default_rng(0)
    total_prompt = total_new = 0
    for i in range(args.requests):
        n = int(rng.integers(3, 12))
        m = int(rng.integers(4, 10))
        engine.submit(Request(uid=i,
                              prompt=rng.integers(
                                  0, cfg.vocab_size, n).tolist(),
                              max_new_tokens=m,
                              temperature=0.7 if i % 2 else 0.0))
        total_prompt += n
        total_new += m

    t0 = time.time()
    out = engine.run()
    dt = time.time() - t0
    print(f"{cfg.name} (reduced): {args.requests} requests "
          f"({total_prompt} prompt + ~{total_new} new tokens) through "
          f"{args.slots} slots in {dt:.2f}s")
    for uid in sorted(out):
        print(f"  req {uid}: {out[uid]}")


if __name__ == "__main__":
    main()
