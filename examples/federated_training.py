"""The paper in one script: De-VertiFL vs non-federated training on the
synthetic MNIST stand-in with vertically partitioned features, driven
by the declarative repro.api front door.

  PYTHONPATH=src python examples/federated_training.py --clients 5

Each comparison side is one ExperimentSpec; ``build(spec).run()``
picks the engine -- a standalone scan-fused federation for one seed,
the seed-vmapped sweep cell (one compile per mode) for ``--seeds k``
> 1 -- and returns a RunResult with mean +/- std F1.

  --smoke runs the reduced CI configuration (titanic, 2 rounds) --
  the examples-smoke lane in scripts/ci.sh.
"""
import argparse

from repro.api import ExperimentSpec, build


def report(name, rr):
    m = rr.metrics
    if "f1_std" in m:
        print(f"  {name:14s} F1={m['f1']:.3f} +/- {m['f1_std']:.3f}  "
              f"({rr.timings['steps_per_sec']:.0f} steps/s across "
              f"{len(rr.spec.seeds)} federations)")
    else:
        for h in rr.history[:: max(1, rr.spec.rounds // 5)]:
            print(f"  round {h['round']:3d}  F1={h['f1']:.3f}  "
                  f"loss={h['loss']:.3f}")
        print(f"  {name:14s} final F1={m['f1']:.3f}  acc={m['acc']:.3f}")
    return m["f1"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=5)
    ap.add_argument("--dataset", default="mnist",
                    choices=["mnist", "fmnist", "titanic", "bank"])
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--epochs", type=int, default=5)
    ap.add_argument("--engine", default="scan",
                    choices=["scan", "python"],
                    help="scan = fused lax.scan rounds (default); "
                         "python = per-batch reference loop")
    ap.add_argument("--first-layer", default="auto",
                    choices=["auto", "pallas", "slice", "masked"],
                    help="first-layer strategy: slice/pallas read only "
                         "each client's contiguous feature slice; masked "
                         "is the paper-literal zero-padding reference; "
                         "auto = pallas on TPU, slice elsewhere")
    ap.add_argument("--seeds", type=int, default=1,
                    help=">1 runs the vmapped multi-seed sweep")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CI config: titanic, 3 clients, "
                         "2 rounds x 1 epoch, 2 seeds (~seconds)")
    args = ap.parse_args()
    if args.smoke:
        args.dataset, args.clients = "titanic", 3
        args.rounds, args.epochs, args.seeds = 2, 1, 2

    n = 6000 if args.dataset in ("mnist", "fmnist") else None
    try:
        spec = ExperimentSpec(
            dataset=args.dataset, mode="devertifl",
            n_clients=args.clients, rounds=args.rounds,
            epochs=args.epochs, n_samples=n, engine=args.engine,
            first_layer=args.first_layer,
            seeds=tuple(range(args.seeds)))
    except ValueError as e:
        ap.error(str(e))    # e.g. --seeds >1 with --engine python

    print(f"De-VertiFL: {args.clients} clients, {args.dataset}, "
          f"{args.rounds} rounds x {args.epochs} epochs "
          f"[engine={spec.engine}, seeds={spec.seeds}, "
          f"spec={spec.spec_hash}]")
    fed_f1 = report("devertifl", build(spec).run())

    print("non-federated baseline (no exchange, no FedAvg):")
    non_f1 = report("non-federated", build(spec.replace(
        mode="non_federated", fedavg=False)).run())

    gain = fed_f1 - non_f1
    print(f"collaboration gain: {gain:+.3f} F1 "
          f"({'matches' if gain > 0 else 'CONTRADICTS'} the paper's claim)")


if __name__ == "__main__":
    main()
