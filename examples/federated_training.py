"""The paper in one script: De-VertiFL vs non-federated training on the
synthetic MNIST stand-in with vertically partitioned features.

  PYTHONPATH=src python examples/federated_training.py --clients 5
"""
import argparse

from repro.core import train_federation


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=5)
    ap.add_argument("--dataset", default="mnist",
                    choices=["mnist", "fmnist", "titanic", "bank"])
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--epochs", type=int, default=5)
    args = ap.parse_args()

    n = 6000 if args.dataset in ("mnist", "fmnist") else None
    common = dict(dataset=args.dataset, n_clients=args.clients,
                  rounds=args.rounds, epochs=args.epochs, n_samples=n)

    print(f"De-VertiFL: {args.clients} clients, {args.dataset}, "
          f"{args.rounds} rounds x {args.epochs} epochs")
    fed = train_federation(**common)
    for h in fed["history"][:: max(1, args.rounds // 5)]:
        print(f"  round {h['round']:3d}  F1={h['f1']:.3f}  "
              f"loss={h['loss']:.3f}")
    print(f"  final F1={fed['final']['f1']:.3f}  "
          f"acc={fed['final']['acc']:.3f}")

    print("non-federated baseline (no exchange, no FedAvg):")
    non = train_federation(mode="non_federated", fedavg=False, **common)
    print(f"  final F1={non['final']['f1']:.3f}  "
          f"acc={non['final']['acc']:.3f}")
    gain = fed["final"]["f1"] - non["final"]["f1"]
    print(f"collaboration gain: +{gain:.3f} F1 "
          f"({'matches' if gain > 0 else 'CONTRADICTS'} the paper's claim)")


if __name__ == "__main__":
    main()
