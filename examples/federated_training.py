"""The paper in one script: De-VertiFL vs non-federated training on the
synthetic MNIST stand-in with vertically partitioned features, driven
by the scan-based federation engine.

  PYTHONPATH=src python examples/federated_training.py --clients 5

With --seeds k > 1 the comparison runs on the sweep engine instead:
k federations per mode are trained simultaneously (vmapped over the
seed axis, one compilation per mode) and mean +/- std F1 is reported.
"""
import argparse

from repro.core import train_federation
from repro.core.sweep import SweepConfig, run_cell


def run_single(args, common):
    print(f"De-VertiFL: {args.clients} clients, {args.dataset}, "
          f"{args.rounds} rounds x {args.epochs} epochs "
          f"[engine={args.engine}]")
    fed = train_federation(engine=args.engine, **common)
    for h in fed["history"][:: max(1, args.rounds // 5)]:
        print(f"  round {h['round']:3d}  F1={h['f1']:.3f}  "
              f"loss={h['loss']:.3f}")
    print(f"  final F1={fed['final']['f1']:.3f}  "
          f"acc={fed['final']['acc']:.3f}")

    print("non-federated baseline (no exchange, no FedAvg):")
    non = train_federation(mode="non_federated", fedavg=False,
                           engine=args.engine, **common)
    print(f"  final F1={non['final']['f1']:.3f}  "
          f"acc={non['final']['acc']:.3f}")
    return fed["final"]["f1"], non["final"]["f1"]


def run_sweep(args, common):
    seeds = tuple(range(args.seeds))
    print(f"De-VertiFL sweep: {args.clients} clients, {args.dataset}, "
          f"{args.rounds} rounds x {args.epochs} epochs, seeds {seeds}")
    scfg = SweepConfig(seeds=seeds, rounds=args.rounds,
                       epochs=args.epochs, n_samples=common["n_samples"],
                       first_layer=common["first_layer"])
    fed = run_cell(args.dataset, "devertifl", args.clients, scfg)
    non = run_cell(args.dataset, "non_federated", args.clients, scfg)
    for name, cell in (("devertifl", fed), ("non-federated", non)):
        print(f"  {name:14s} F1={cell['f1_mean']:.3f}"
              f" +/- {cell['f1_std']:.3f}"
              f"  ({cell['steps_per_sec']:.0f} steps/s across "
              f"{len(seeds)} federations)")
    return fed["f1_mean"], non["f1_mean"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=5)
    ap.add_argument("--dataset", default="mnist",
                    choices=["mnist", "fmnist", "titanic", "bank"])
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--epochs", type=int, default=5)
    ap.add_argument("--engine", default="scan",
                    choices=["scan", "python"],
                    help="scan = fused lax.scan rounds (default); "
                         "python = per-batch reference loop")
    ap.add_argument("--first-layer", default="auto",
                    choices=["auto", "pallas", "slice", "masked"],
                    help="first-layer strategy: slice/pallas read only "
                         "each client's contiguous feature slice; masked "
                         "is the paper-literal zero-padding reference; "
                         "auto = pallas on TPU, slice elsewhere")
    ap.add_argument("--seeds", type=int, default=1,
                    help=">1 runs the vmapped multi-seed sweep")
    args = ap.parse_args()
    if args.seeds > 1 and args.engine != "scan":
        ap.error("--seeds > 1 runs the vmapped sweep, which only "
                 "supports --engine scan")

    n = 6000 if args.dataset in ("mnist", "fmnist") else None
    common = dict(dataset=args.dataset, n_clients=args.clients,
                  rounds=args.rounds, epochs=args.epochs, n_samples=n,
                  first_layer=args.first_layer)

    if args.seeds > 1:
        fed_f1, non_f1 = run_sweep(args, common)
    else:
        fed_f1, non_f1 = run_single(args, common)
    gain = fed_f1 - non_f1
    print(f"collaboration gain: {gain:+.3f} F1 "
          f"({'matches' if gain > 0 else 'CONTRADICTS'} the paper's claim)")


if __name__ == "__main__":
    main()
