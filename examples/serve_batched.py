"""Batched serving demo: continuous greedy decoding for a batch of
requests against ring-buffer KV caches (SWA) or recurrent state (SSM),
tokens/s reported.

  PYTHONPATH=src python examples/serve_batched.py --arch rwkv6-1.6b
  PYTHONPATH=src python examples/serve_batched.py --arch mixtral-8x22b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.reduced import reduced_config
from repro.launch.serve import make_serve_step
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-1.6b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=48)
    ap.add_argument("--cache", type=int, default=64)
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    state = model.init_decode_state(args.batch, args.cache)
    if cfg.is_encoder_decoder:
        state["enc"] = jnp.zeros(
            (args.batch, cfg.num_prefix_embeddings, cfg.d_model),
            model.dtype)
    step_fn = jax.jit(make_serve_step(model), donate_argnums=(1,))

    toks = jnp.zeros((args.batch, 1), jnp.int32)
    # warmup/compile
    toks, state = step_fn(params, state, toks)
    t0 = time.time()
    outs = []
    for _ in range(args.tokens - 1):
        toks, state = step_fn(params, state, toks)
        outs.append(toks)
    dt = time.time() - t0
    total = args.batch * (args.tokens - 1)
    print(f"{cfg.name} (reduced): {total} tokens in {dt:.2f}s "
          f"= {total/dt:,.0f} tok/s on CPU")
    print("first request's tokens:", [int(t[0, 0]) for t in outs[:10]])


if __name__ == "__main__":
    main()
