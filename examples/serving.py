"""Federated serving demo: continuous-batched vertical inference
through ``Session.serve()``.

Each request's features arrive SPLIT ACROSS CLIENTS (the vertical
setting: every party owns a column slice of the same entity's row).
The server assembles per-client offers, batches admissible requests
into a fixed slot pool advanced by one jitted step, and keeps a
hot-entity cache of exchange activations -- a repeat entity is served
bitwise-identically with NO feature delivery from any client.

  PYTHONPATH=src python examples/serving.py
  PYTHONPATH=src python examples/serving.py --smoke     # CI sizes
  PYTHONPATH=src python examples/serving.py --slots 16 --requests 64
"""
import argparse

import numpy as np

from repro.api import ExperimentSpec, ServeRequest, build, \
    split_features


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="toy sizes (the scripts/ci.sh examples lane)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=24)
    args = ap.parse_args()
    n_req = 8 if args.smoke else args.requests

    spec = ExperimentSpec(
        dataset="mnist", mode="devertifl", n_clients=3,
        rounds=1 if args.smoke else 3, epochs=1,
        n_samples=512 if args.smoke else 2000, eval_every=0)
    sess = build(spec)
    print(f"training {spec.dataset}/{spec.mode} "
          f"({spec.n_clients} clients, spec {spec.spec_hash}) ...")
    res = sess.run()
    print(f"  trained: f1={res.metrics['f1']:.3f}")

    layout = sess.federation.layout
    xte = np.asarray(sess.federation.xte)[:n_req]

    # --- wave 1: features arrive split across clients, out of order
    srv = sess.server(max_slots=args.slots)
    offers = []
    for i in range(n_req):
        srv.submit(ServeRequest(uid=i, entity_id=f"entity-{i}"))
        slices = split_features(layout, xte[i])  # {client: [F_i]}
        offers += [(i, c, payload) for c, payload in slices.items()]
    rng = np.random.default_rng(0)
    rng.shuffle(offers)                     # arrival order is free
    for uid, client, payload in offers:
        srv.offer(uid, client, payload)
    report = srv.run()
    print(f"wave 1 (fresh): {report.counters['completed']}/{n_req} "
          f"served through {args.slots} slots in "
          f"{report.counters['steps']} steps "
          f"({report.counters['step_traces']} compile), "
          f"p50={report.latency_ms['p50']:.2f}ms "
          f"p99={report.latency_ms['p99']:.2f}ms "
          f"{report.throughput_rps:.0f} req/s")

    # --- wave 2: same entities -- cache hits, no slices needed at all
    for i in range(n_req):
        srv.submit(ServeRequest(uid=n_req + i, entity_id=f"entity-{i}"))
    report2 = srv.run()
    hit = report2.cache["hits"] / n_req
    print(f"wave 2 (hot):   {n_req}/{n_req} served from the "
          f"exchange cache (hit rate {hit:.0%}) -- no client sent "
          f"a single feature")

    # serving is predict, bit for bit
    ref = np.asarray(sess.predict(xte))
    ok = all(np.array_equal(report.results[i], ref[:, i])
             and np.array_equal(report2.results[n_req + i], ref[:, i])
             for i in range(n_req))
    print(f"parity with Session.predict(): "
          f"{'bitwise identical' if ok else 'MISMATCH'}")
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
