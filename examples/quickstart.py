"""Quickstart: the whole repo in one spec -> session -> metrics hop.

  PYTHONPATH=src python examples/quickstart.py

Declare the experiment as an ExperimentSpec (validated eagerly: typo a
dataset/mode/first_layer name and the error lists the registered
options), build a Session, run it.  The RunResult carries final
metrics, the per-round trajectory, a process-stable spec hash, and the
git SHA -- the same record the benches stamp their JSON with.  Runs in
seconds on CPU (it is the CI examples-smoke lane); for the LM
substrate demo see examples/quickstart_lm.py.
"""
from repro.api import ExperimentSpec, build

spec = ExperimentSpec(dataset="titanic", mode="devertifl", n_clients=3,
                      rounds=3, epochs=2, seeds=(0,))
result = build(spec).run()

print(f"spec {result.spec_hash}  git {result.git_sha}")
for h in result.history:
    print(f"  round {h['round']}  loss={h['loss']:.3f}  F1={h['f1']:.3f}")
print(f"final: F1={result.metrics['f1']:.3f} "
      f"acc={result.metrics['acc']:.3f} "
      f"({result.timings['steps_per_sec']:.0f} steps/s)")
