"""Exchange-schedule sweep in ~20 lines: one spec_grid over schedules
(sync vs stale vs stale+partial), one run_grid call, one compiled
round shared by every schedule lane (repro.schedule).

Run: PYTHONPATH=src python examples/staleness_sweep.py
"""
from repro.api import run_grid, spec_grid

SCHEDULES = ("sync", "stale_k:2", "stale_k:4+partial:0.8")


def main():
    specs = spec_grid(datasets=("titanic",), modes=("devertifl",),
                      client_counts=(3,), seeds=(0, 1),
                      schedules=SCHEDULES, rounds=2, epochs=2)
    grid = run_grid(specs)
    for sched in SCHEDULES:
        cell = grid["cells"][f"titanic/devertifl/{sched}/3"]
        print(f"{sched:24s} f1={cell['f1_mean']:.3f} "
              f"(spec {cell['spec_hash']})")


if __name__ == "__main__":
    main()
