"""End-to-end training driver: a ~100M-parameter qwen-family decoder
trained on the synthetic Markov LM stream with warmup+cosine schedule,
gradient clipping, periodic eval, and checkpointing -- the full
substrate stack in one script.

Defaults are CPU-budget friendly (~20M params, 60 steps). --preset 100m
trains the full ~100M model for 300 steps (hours on 1 CPU core; the
config is the point on this container, the wall time is not).

  PYTHONPATH=src python examples/train_lm_e2e.py
  PYTHONPATH=src python examples/train_lm_e2e.py --preset 100m --steps 300
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import load_checkpoint, latest_step, save_checkpoint
from repro.configs import get_config
from repro.data import markov_lm_batches
from repro.launch.train import make_train_step
from repro.models import build_model
from repro.optim import adam, linear_warmup_cosine

PRESETS = {
    # ~20M params: CI-fast
    "20m": dict(num_layers=6, d_model=384, num_heads=6, num_kv_heads=2,
                head_dim=64, d_ff=1536, vocab_size=8192),
    # ~100M params (the deliverable-b scale)
    "100m": dict(num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
                 head_dim=64, d_ff=3072, vocab_size=16384),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="20m", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_config("qwen1.5-0.5b").replace(
        remat=False, dtype="float32", **PRESETS[args.preset])
    model = build_model(cfg)
    n_params = None
    opt = adam(linear_warmup_cosine(args.lr, 20, args.steps))
    step_fn = jax.jit(make_train_step(model, opt), donate_argnums=(0, 1))

    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {n_params/1e6:.1f}M params "
          f"({cfg.num_layers}L d={cfg.d_model} vocab={cfg.vocab_size})")

    start = 0
    if latest_step(args.ckpt_dir) is not None:
        start = latest_step(args.ckpt_dir)
        params = load_checkpoint(args.ckpt_dir, start, params)
        print(f"resumed from checkpoint at step {start}")

    it = markov_lm_batches(cfg.vocab_size, args.batch, args.seq, seed=1)
    step = jnp.asarray(start, jnp.int32)
    t0 = time.time()
    first_loss = None
    for i in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt_state, step, m = step_fn(params, opt_state, step, batch)
        loss = float(m["loss"])
        if first_loss is None:
            first_loss = loss
        if i % 10 == 0 or i == args.steps - 1:
            tput = args.batch * args.seq * (i - start + 1) / \
                (time.time() - t0)
            print(f"step {i:4d}  loss {loss:.4f}  {tput:,.0f} tok/s")
        if args.ckpt_every and (i + 1) % args.ckpt_every == 0:
            path = save_checkpoint(args.ckpt_dir, i + 1, params)
            print(f"  checkpoint -> {path}")

    print(f"loss: {first_loss:.3f} -> {loss:.3f} "
          f"(uniform would be {jnp.log(cfg.vocab_size):.2f})")
    assert loss < first_loss, "training must reduce loss"


if __name__ == "__main__":
    main()
