"""Wire-transform tradeoff in ~20 lines: one spec_grid over exchange
transforms (raw vs int8 vs topk+int8+dp), one run_grid call, one
compiled round shared by every transform lane (repro.wire), bytes on
the wire read straight from the per-cell telemetry.

Run:   PYTHONPATH=src python examples/wire_tradeoff.py
Smoke: PYTHONPATH=src python examples/wire_tradeoff.py --smoke
"""
import argparse

from repro.api import run_grid, spec_grid

TRANSFORMS = ("none", "int8", "topk:0.5+int8+dp:0.1")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizes (1 round, 1 seed)")
    args = ap.parse_args()
    specs = spec_grid(datasets=("titanic",), modes=("devertifl",),
                      client_counts=(3,), transforms=TRANSFORMS,
                      seeds=(0,) if args.smoke else (0, 1),
                      rounds=1 if args.smoke else 3, epochs=2)
    grid = run_grid(specs)
    for t in TRANSFORMS:
        cell = grid["cells"][f"titanic/devertifl/{t}/none/sync/3"]
        w = cell["wire"]
        print(f"{t:24s} f1={cell['f1_mean']:.3f} bytes="
              f"{w['encoded_bytes']}/{w['raw_bytes']} "
              f"(spec {cell['spec_hash']})")


if __name__ == "__main__":
    main()
