"""Quickstart: build an assigned architecture (reduced), train a few
steps on the synthetic LM stream, then decode with a KV cache.

  PYTHONPATH=src python examples/quickstart_lm.py --arch gemma2-2b
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.reduced import reduced_config
from repro.data import markov_lm_batches
from repro.launch.train import make_train_step
from repro.models import build_model
from repro.optim import adam


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=20)
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    print(f"arch={cfg.name} family={cfg.family} "
          f"(reduced: {cfg.num_layers}L d={cfg.d_model})")
    model = build_model(cfg)
    opt = adam(1e-3)
    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(model, opt), donate_argnums=(0, 1))

    it = markov_lm_batches(cfg.vocab_size, 4, 64)
    step = jnp.zeros((), jnp.int32)
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        if cfg.modality != "text":
            batch["prefix_emb"] = jnp.zeros(
                (4, cfg.num_prefix_embeddings, cfg.d_model))
        params, opt_state, step, m = step_fn(params, opt_state, step, batch)
        if i % 5 == 0:
            print(f"  step {i:3d}  loss {float(m['loss']):.4f}")

    # decode 8 tokens
    state = model.init_decode_state(2, 32)
    if cfg.is_encoder_decoder:
        state["enc"] = jnp.zeros((2, cfg.num_prefix_embeddings,
                                  cfg.d_model), model.dtype)
    toks = jnp.zeros((2, 1), jnp.int32)
    out = []
    dec = jax.jit(model.decode_step)
    for _ in range(8):
        logits, state = dec(params, state, toks)
        toks = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(int(toks[0, 0]))
    print("greedy decode:", out)


if __name__ == "__main__":
    main()
