"""Federated serving: continuous-batched vertical inference.

De-VertiFL inference is inherently multi-party -- a prediction for one
entity needs EVERY client's feature slice plus the hidden-output
exchange -- so the serving path is built around three ideas:

  slot pool    a fixed pool of ``max_slots`` predict slots advanced by
               ONE jitted batched step.  Free slots run padding and
               are gated out by a traced ``slot_mask`` (client_mask
               style), so occupancy can vary every step while the step
               compiles exactly once per (max_slots, spec)
               configuration (``step_traces`` records it).
  assembly     a request's features *arrive split across clients*:
               ``submit`` announces the request, ``offer(uid, client,
               payload)`` delivers one client's canonical column slice
               (``Layout.sizes[i]`` wide; ``split_features`` produces
               them from raw rows).  The request becomes admissible
               only when every live client has delivered -- or the
               hot-entity cache already holds its exchange stack, in
               which case NO client needs to compute or send anything.
  hot cache    an LRU keyed by ``(spec_hash, entity_id)`` holding the
               [n_clients, W] exchange-point activation stack captured
               bitwise from a previous step.  A hit is spliced into
               the slot batch via an exact ``jnp.where`` select
               (``exchange.select_cached_exchange``), so cached and
               recomputed requests produce bit-identical predictions.

Admission is FIFO over readiness order and therefore deterministic for
a fixed call sequence.  The ready queue is bounded by ``queue_cap``;
under declared pressure (queue at cap -- never otherwise) the overflow
policy either rejects the incoming request or evicts the oldest queued
one.  Every request carries wall-clock telemetry (submit -> ready ->
admit -> done) and :meth:`FederatedServer.report` folds it into a
versioned :class:`ServeReport` (p50/p99 latency, throughput, cache and
scheduler counters).

The parity contract -- ``Session.serve()`` == ``Session.predict()``
bit for bit, invariant to arrival order, slot count, batch
composition, and cache state -- is pinned in tests/test_serving.py and
documented in docs/ARCHITECTURE.md section 10.
"""
from __future__ import annotations

import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.exchange import (hidden_output_exchange,
                                 select_cached_exchange)
from repro.core.protocol import (exchange_width, make_h_all_fn, rest)
from repro.wire import (WirePayload, get_wire_plan, pack, unpack,
                        wire_apply_static)

# 1: initial schema -- results/latency/throughput/cache/counters,
# spec_hash-stamped (the serving analog of RunResult's versioning)
# 2 (PR 10): reports carry an ``obs`` field -- the unified
# repro.obs.Telemetry record (serve counters + latency + tracer
# spans) as a JSON-safe dict; every PR-8 key is unchanged, so the
# change is additive.  (The record is named ``obs`` because
# ``telemetry`` has been the per-request timing log since schema 1.)
SERVE_SCHEMA_VERSION = 2


def split_features(layout, x) -> Dict[int, np.ndarray]:
    """Raw original-column-order features (``[F]`` or ``[B, F]``) ->
    per-client payloads ``{i: x[..., partition[i]]}`` for the LIVE
    clients -- exactly the slice each feature party owns, in the order
    the canonical layout concatenates them.  The serving harness, the
    bench, and the examples all build request payloads through this
    helper so a request is assembled from what clients would actually
    transmit."""
    x = np.asarray(x)
    return {i: x[..., np.asarray(p)]
            for i, p in enumerate(layout.partition[:layout.n_real])}


@dataclass
class ServeRequest:
    """One vertical inference request.

    uid        unique request id (results/telemetry key)
    entity_id  identity of the ROW being predicted -- the hot-entity
               cache key (with the spec hash).  Defaults to uid;
               repeat lookups of the same entity should share it.
    slices     optional per-client payloads ``{client: [F_i] slice}``
               (canonical column slices; ``split_features`` makes
               them).  Omitted slices arrive later via ``offer`` --
               or never, if the entity is already cached.
    """
    uid: Any
    entity_id: Any = None
    slices: Optional[Dict[int, Any]] = None

    def __post_init__(self):
        if self.entity_id is None:
            self.entity_id = self.uid


class ExchangeCache:
    """LRU cache of hot entities' exchange-point activation stacks.

    Keys are ``(spec_hash, entity_id)`` -- the spec hash is part of
    the key so a cache (which may be shared across servers) can never
    serve one experiment's activations under another's params.  Values
    are the bitwise [n_clients, W] stacks captured from the jitted
    serve step; ``lookup`` counts hits/misses and refreshes recency,
    ``put`` evicts least-recently-used entries beyond ``capacity``.
    """

    def __init__(self, capacity: int = 128):
        if capacity < 1:
            raise ValueError(f"cache capacity must be >= 1, got "
                             f"{capacity}")
        self.capacity = capacity
        self._store: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self):
        return len(self._store)

    def __contains__(self, key):
        return key in self._store

    def lookup(self, key) -> Optional[np.ndarray]:
        """The cached stack for ``key`` (refreshed to most-recent), or
        None; counts the hit/miss."""
        if key in self._store:
            self._store.move_to_end(key)
            self.hits += 1
            return self._store[key]
        self.misses += 1
        return None

    def put(self, key, value: np.ndarray):
        self._store[key] = value
        self._store.move_to_end(key)
        while len(self._store) > self.capacity:
            self._store.popitem(last=False)
            self.evictions += 1

    @property
    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "size": len(self._store),
                "capacity": self.capacity}


@dataclass
class ServeReport:
    """Versioned serving record -- the RunResult analog for
    ``Session.serve()``.  ``results`` maps uid -> the live per-client
    prediction vector (bitwise what ``Session.predict`` returns for
    that row); ``telemetry`` is the per-request timing log; ``obs``
    is the unified repro.obs.Telemetry record (JSON-safe dict: wall,
    serve counters, latency stats, tracer spans)."""
    spec_hash: str
    results: Dict[Any, np.ndarray]
    telemetry: List[dict] = field(default_factory=list)
    latency_ms: dict = field(default_factory=dict)
    throughput_rps: float = 0.0
    cache: Optional[dict] = None
    counters: dict = field(default_factory=dict)
    waiting: List[Any] = field(default_factory=list)
    rejected: List[Any] = field(default_factory=list)
    evicted: List[Any] = field(default_factory=list)
    obs: Optional[dict] = None
    schema_version: int = SERVE_SCHEMA_VERSION

    def to_dict(self) -> dict:
        """JSON-safe dict (BENCH_serving.json embeds this shape)."""
        return {
            "schema_version": self.schema_version,
            "spec_hash": self.spec_hash,
            "results": {str(k): np.asarray(v).tolist()
                        for k, v in self.results.items()},
            "telemetry": [{k: v for k, v in t.items()}
                          for t in self.telemetry],
            "latency_ms": dict(self.latency_ms),
            "throughput_rps": self.throughput_rps,
            "cache": None if self.cache is None else dict(self.cache),
            "counters": dict(self.counters),
            "waiting": [str(u) for u in self.waiting],
            "rejected": [str(u) for u in self.rejected],
            "evicted": [str(u) for u in self.evicted],
            "obs": None if self.obs is None else dict(self.obs),
        }


def make_serve_step_fn(model, pcfg, layout, first_layer_fn=None):
    """The ONE jitted batched predict step behind the slot pool.

    step(params, x, h_cached, use_cached, slot_mask, lay) ->
    (preds [n_clients, S], h_all [n_clients, S, W])

      x           [S, F] canonical-order slot batch (free / cached
                  slots hold zeros)
      h_cached    [n_clients, S, W] cached exchange stacks (zeros for
                  fresh slots)
      use_cached  [S] 0/1 gate: 1 = splice ``h_cached`` in place of
                  the freshly computed stack (exact select)
      slot_mask   [S] 0/1 gate: 0 = dead (free) slot; its prediction
                  is forced to -1 so stale reads are loud

    All gates are traced runtime values -- occupancy and cache state
    never retrace -- and every op after the per-client forward is
    per-row, so each slot's prediction equals predict()'s row bitwise
    regardless of what shares the batch (tests/test_serving.py).
    ``h_all`` returns the POST-select stack: what the cache should
    hold for each slot's entity (fresh slots' recompute, cached
    slots' unchanged cached bits).

    Under a non-none ``pcfg.transform`` (repro.wire) the fresh stack
    passes the deterministic codec components (topk/int8) before the
    cache select, so what crosses the serving wire -- and what the
    hot-entity cache stores -- is the encoded release, exactly as in
    training; dp noise is a training-time release control and is not
    applied at serving (docs/ARCHITECTURE.md section 11).  Codec
    idempotence keeps cached and recomputed requests bit-identical:
    a cached (already round-tripped) stack re-encodes to itself.
    """
    through = partial(rest, model, pcfg.exchange_at)
    h_all_fn = make_h_all_fn(model, pcfg, layout=layout,
                             first_layer_fn=first_layer_fn)
    exchange = pcfg.mode in ("devertifl", "verticomb")
    plan = get_wire_plan(getattr(pcfg, "transform", "none"))
    if plan.custom is not None:
        raise ValueError(
            f"custom transform {plan.spec!r} has no serving codec; "
            "serve with a built-in transform composition or "
            "transform='none'")

    def step(params, x, h_cached, use_cached, slot_mask, lay):
        h_fresh = h_all_fn(params, x, lay)
        if not plan.is_none:
            h_fresh = wire_apply_static(plan, h_fresh)
        h_all = select_cached_exchange(h_fresh, h_cached, use_cached)
        h_ex = hidden_output_exchange(
            h_all, differentiable=False,
            client_mask=lay.client_mask) if exchange else h_all
        logits = jax.vmap(through)(params, h_ex)   # [n, S, C]
        preds = jnp.argmax(logits, axis=-1)        # [n, S]
        preds = jnp.where(slot_mask[None, :] != 0, preds, -1)
        return preds, h_all

    return step


class FederatedServer:
    """Continuous-batched vertical inference over a fixed slot pool.

    Construct via :meth:`repro.api.Session.server` (or directly from a
    federation's model/pcfg/layout + trained param stack).  Drive it
    either as a batch -- ``submit`` everything, then ``run()`` -- or
    as a stream: interleave ``submit``/``offer`` with ``step()`` calls
    and collect ``report()`` at the end (the offered-load bench does
    this).
    """

    OVERFLOW = ("reject", "evict_oldest")

    def __init__(self, model, pcfg, layout, params, *, spec_hash="",
                 max_slots: int = 8, queue_cap: Optional[int] = None,
                 cache=128, overflow: str = "reject",
                 first_layer_fn=None, tracer=None):
        from repro.obs import NullTracer
        # request-lifecycle instants + step spans; the NullTracer
        # default keeps the pre-obs serving path instrument-free
        self.tracer = tracer if tracer is not None else NullTracer()
        if max_slots < 1:
            raise ValueError(f"max_slots must be >= 1, got {max_slots}")
        if queue_cap is not None and queue_cap < 1:
            raise ValueError(f"queue_cap must be >= 1 or None, got "
                             f"{queue_cap}")
        if overflow not in self.OVERFLOW:
            raise ValueError(f"unknown overflow policy {overflow!r}; "
                             f"pick one of {self.OVERFLOW}")
        self.params = params
        self.layout = layout
        self.spec_hash = spec_hash
        self.max_slots = max_slots
        self.queue_cap = queue_cap
        self.overflow = overflow
        self.n_live = layout.n_real
        self.n_clients = layout.n_clients      # padded client axis
        self.width = exchange_width(model, pcfg.exchange_at)
        # non-none wire plan: the step encodes the fresh exchange
        # stack and the cache stores the PACKED payload (WirePayload
        # -- sparse indices / int8 values / per-row scales), unpacked
        # on admission; codec idempotence makes the round trip bitwise
        self._plan = get_wire_plan(getattr(pcfg, "transform", "none"))
        self._lay = layout.arrays()
        self._sizes = tuple(layout.sizes)
        self._offsets = tuple(layout.offsets)
        self._F = layout.n_features

        if cache is None or cache is False or cache == 0:
            self.cache: Optional[ExchangeCache] = None
        elif isinstance(cache, ExchangeCache):
            self.cache = cache
        elif isinstance(cache, int) and not isinstance(cache, bool):
            self.cache = ExchangeCache(cache)
        elif cache is True:
            self.cache = ExchangeCache()
        else:
            raise TypeError(
                "cache must be an int capacity, an ExchangeCache, "
                f"True, or None/False/0 to disable; got {cache!r}")

        # host-side slot state: fixed-shape staging buffers the jitted
        # step consumes -- shapes never change, so it compiles once
        S = max_slots
        self._xbuf = np.zeros((S, self._F), np.float32)
        self._hbuf = np.zeros((self.n_clients, S, self.width),
                              np.float32)
        self._ubuf = np.zeros((S,), np.float32)     # use_cached gates
        self._mbuf = np.zeros((S,), np.float32)     # slot_mask gates
        self._slots: List[Optional[Any]] = [None] * S

        self._assembly: Dict[Any, dict] = {}   # uid -> request record
        self._ready: deque = deque()
        self._info: Dict[Any, dict] = {}
        self.results: Dict[Any, np.ndarray] = {}
        self.telemetry: List[dict] = []
        self.admission_log: List[Any] = []
        self.rejected: List[Any] = []
        self.evicted: List[Any] = []
        # queue length observed at each eviction/rejection -- the
        # "declared pressure" witness (property tests assert every
        # entry equals queue_cap)
        self.pressure_log: List[int] = []
        self.steps = 0
        self.submitted = 0
        self.completed = 0
        self.max_occupancy = 0
        self._t0: Optional[float] = None
        self._t_last: Optional[float] = None

        self._traces = 0
        raw_step = make_serve_step_fn(model, pcfg, layout,
                                      first_layer_fn=first_layer_fn)

        def counted(*args):
            self._traces += 1
            return raw_step(*args)

        self._step_fn = jax.jit(counted)

    # ------------------------------------------------------------------
    @property
    def step_traces(self) -> int:
        """Compile count of the batched step -- 1 after any number of
        steps at one (max_slots, spec) configuration."""
        return self._traces

    @property
    def occupancy(self) -> int:
        return sum(s is not None for s in self._slots)

    @property
    def queued(self) -> int:
        return len(self._ready)

    @property
    def pending(self) -> List[Any]:
        """Uids still assembling (not all clients delivered, entity
        not cached)."""
        return list(self._assembly)

    # ------------------------------------------------------------------
    def submit(self, req: ServeRequest):
        """Announce a request (optionally with some or all slices
        attached).  Probes the hot-entity cache ONCE, here: a hit
        makes the request admissible with no feature delivery at all
        -- the cached exchange stack stands in for every client's
        computation."""
        if not isinstance(req, ServeRequest):
            raise TypeError(f"submit() takes a ServeRequest, got "
                            f"{type(req).__name__}")
        if req.uid in self._info:
            raise ValueError(f"duplicate request uid {req.uid!r}")
        now = time.perf_counter()
        if self._t0 is None:
            self._t0 = now
        rec = {"uid": req.uid, "entity_id": req.entity_id,
               "t_submit": now, "status": "assembling",
               "cached": False, "slices": {}}
        self._info[req.uid] = rec
        self._assembly[req.uid] = rec
        self.submitted += 1
        self.tracer.instant("submit", cat="serve", uid=str(req.uid))
        if self.cache is not None:
            h = self.cache.lookup((self.spec_hash, req.entity_id))
            if h is not None:
                rec["cached"] = True
                rec["_h"] = h
                del self._assembly[req.uid]
                self._to_ready(rec)
                return req.uid
        for client, payload in (req.slices or {}).items():
            self.offer(req.uid, client, payload)
        return req.uid

    def offer(self, uid, client: int, payload):
        """Deliver one client's canonical column slice for a pending
        request.  Order is free -- readiness fires when the LAST live
        client delivers, whoever that is (arrival-order invariance is
        pinned in tests/test_serving.py)."""
        rec = self._info.get(uid)
        if rec is None:
            raise KeyError(f"offer() for unknown request uid {uid!r}; "
                           "submit() it first")
        if rec["status"] != "assembling":
            # cache-hit / queued / in-flight requests need no slices;
            # late deliveries are dropped silently (the federated
            # analog of a straggler's payload arriving after the
            # round already served the request)
            return
        if not 0 <= client < self.n_live:
            raise ValueError(f"client {client} out of range for "
                             f"{self.n_live} live clients")
        payload = np.asarray(payload, np.float32).reshape(-1)
        want = self._sizes[client]
        if payload.shape != (want,):
            raise ValueError(
                f"request {uid!r}: client {client}'s slice must have "
                f"{want} features (Layout.sizes[{client}]), got "
                f"{payload.shape}")
        rec["slices"][client] = payload
        self.tracer.instant("offer", cat="serve", uid=str(uid),
                            client=client)
        if len(rec["slices"]) == self.n_live:
            x = np.zeros((self._F,), np.float32)
            for i, sl in rec["slices"].items():
                x[self._offsets[i]:self._offsets[i]
                  + self._sizes[i]] = sl
            rec["_x"] = x
            del rec["slices"]
            del self._assembly[uid]
            self._to_ready(rec)

    def _to_ready(self, rec):
        """Move an assembled (or cache-hit) request to the bounded
        admission queue, applying the overflow policy under declared
        pressure (queue at cap) only."""
        rec["t_ready"] = time.perf_counter()
        if self.queue_cap is not None and \
                len(self._ready) >= self.queue_cap:
            self.pressure_log.append(len(self._ready))
            if self.overflow == "reject":
                rec["status"] = "rejected"
                self.rejected.append(rec["uid"])
                return
            old = self._ready.popleft()          # evict_oldest
            self._info[old]["status"] = "evicted"
            self.evicted.append(old)
        rec["status"] = "ready"
        self._ready.append(rec["uid"])
        self.tracer.instant("ready", cat="serve",
                            uid=str(rec["uid"]),
                            cached=bool(rec["cached"]))

    # ------------------------------------------------------------------
    def _admit(self):
        """FIFO-fill free slots from the ready queue."""
        for s in range(self.max_slots):
            if not self._ready:
                break
            if self._slots[s] is not None:
                continue
            uid = self._ready.popleft()
            rec = self._info[uid]
            rec["t_admit"] = time.perf_counter()
            rec["status"] = "in_flight"
            self.admission_log.append(uid)
            self.tracer.instant("admit", cat="serve", uid=str(uid),
                                slot=s)
            self._slots[s] = uid
            self._mbuf[s] = 1.0
            if rec["cached"]:
                self._ubuf[s] = 1.0
                self._xbuf[s] = 0.0
                h = rec.pop("_h")
                if isinstance(h, WirePayload):
                    h = unpack(h)
                self._hbuf[:, s, :] = h
            else:
                self._ubuf[s] = 0.0
                self._hbuf[:, s, :] = 0.0
                self._xbuf[s] = rec.pop("_x")
        self.max_occupancy = max(self.max_occupancy, self.occupancy)

    def step(self) -> int:
        """Admit what fits, advance every occupied slot by the one
        jitted batched step, complete and free them.  Returns the
        number of requests completed (0 when nothing was admissible).
        """
        self._admit()
        if self.occupancy == 0:
            return 0
        with self.tracer.span("serve_step", cat="serve",
                              occupancy=self.occupancy):
            preds, h_all = self._step_fn(
                self.params, jnp.asarray(self._xbuf),
                jnp.asarray(self._hbuf), jnp.asarray(self._ubuf),
                jnp.asarray(self._mbuf), self._lay)
            preds = np.asarray(preds)
            h_all = np.asarray(h_all)
        self.steps += 1
        done = 0
        now = time.perf_counter()
        for s, uid in enumerate(self._slots):
            if uid is None:
                continue
            rec = self._info[uid]
            self.results[uid] = preds[:self.n_live, s].copy()
            rec["t_done"] = now
            rec["latency_s"] = now - rec["t_submit"]
            rec["queue_s"] = rec["t_admit"] - rec["t_ready"]
            rec["status"] = "done"
            self.tracer.instant("complete", cat="serve",
                                uid=str(uid),
                                latency_ms=rec["latency_s"] * 1e3)
            if self.cache is not None and not rec["cached"]:
                h_slot = h_all[:, s, :].copy()
                if not self._plan.is_none:
                    h_slot = pack(self._plan, h_slot)
                self.cache.put((self.spec_hash, rec["entity_id"]),
                               h_slot)
            self.telemetry.append(rec)
            self.completed += 1
            done += 1
            self._slots[s] = None
            self._mbuf[s] = 0.0
            self._ubuf[s] = 0.0
            self._xbuf[s] = 0.0
            self._hbuf[:, s, :] = 0.0
        self._t_last = now
        return done

    def run(self) -> "ServeReport":
        """Drain every admissible request (ready or in flight) and
        return the report.  Requests still assembling -- a client
        never delivered and the entity is not cached -- are left
        pending and listed in ``report().waiting``."""
        while self._ready or self.occupancy:
            if self.step() == 0:
                break
        return self.report()

    # ------------------------------------------------------------------
    def report(self) -> ServeReport:
        lat = np.asarray([t["latency_s"] for t in self.telemetry])
        latency_ms = {}
        if lat.size:
            latency_ms = {
                "p50": float(np.percentile(lat, 50) * 1e3),
                "p99": float(np.percentile(lat, 99) * 1e3),
                "mean": float(lat.mean() * 1e3),
                "max": float(lat.max() * 1e3)}
        wall = (self._t_last - self._t0) if (
            self._t0 is not None and self._t_last is not None) else 0.0
        thr = self.completed / wall if wall > 0 else 0.0
        from repro.obs import Telemetry
        unified = Telemetry(
            wall_s=wall, steps=self.steps, steps_per_sec=(
                self.steps / wall if wall > 0 else 0.0),
            serve={"submitted": self.submitted,
                   "completed": self.completed,
                   "rejected": len(self.rejected),
                   "evicted": len(self.evicted),
                   "throughput_rps": thr, **{
                       f"latency_{k}_ms": v for k, v in (
                           latency_ms or {}).items()}},
            spans=(self.tracer.to_records()
                   if self.tracer.active else None))
        return ServeReport(
            spec_hash=self.spec_hash,
            results=dict(self.results),
            telemetry=[{k: v for k, v in t.items()
                        if not k.startswith("_") and k != "slices"}
                       for t in self.telemetry],
            latency_ms=latency_ms,
            throughput_rps=thr,
            cache=None if self.cache is None else self.cache.stats,
            counters={"submitted": self.submitted,
                      "completed": self.completed,
                      "rejected": len(self.rejected),
                      "evicted": len(self.evicted),
                      "waiting": len(self._assembly),
                      "steps": self.steps,
                      "step_traces": self.step_traces,
                      "max_occupancy": self.max_occupancy,
                      "max_slots": self.max_slots},
            waiting=list(self._assembly),
            rejected=list(self.rejected),
            evicted=list(self.evicted),
            obs=unified.to_dict())

    @property
    def stats(self) -> dict:
        return {"active": self.occupancy, "queued": self.queued,
                "assembling": len(self._assembly),
                "done": self.completed}
