"""Serving engines.

``federated`` is the De-VertiFL product path: continuous-batched
vertical inference over a fixed predict-slot pool with split-feature
assembly and a hot-entity exchange cache (behind
``repro.api.Session.serve()``).  ``engine`` is the legacy vLLM-style
token-decoding engine for the sequence-model substrate (prefill
splicing into running decode batches).
"""
from repro.serving.engine import Request, ServingEngine  # noqa: F401
from repro.serving.federated import (  # noqa: F401
    SERVE_SCHEMA_VERSION, ExchangeCache, FederatedServer, ServeReport,
    ServeRequest, make_serve_step_fn, split_features,
)
