"""Continuous-batching serving engine.

A fixed pool of `max_batch` decode slots advances one token per step
for every active slot (one jitted decode_step on the whole batch --
inactive slots run padding and are masked). New requests are admitted
by running the model's *prefill* path at B=1 and splicing the resulting
KV cache / recurrent state into the slot (`_insert_state`), so a long
prompt never stalls the running batch for more than one prefill, and a
finished slot is refilled immediately -- the standard
continuous-batching discipline (vLLM-style scheduling; static shapes
keep everything jit-compatible on TPU).

Greedy or temperature sampling per request.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    stop_token: Optional[int] = None


@dataclass
class _Slot:
    active: bool = False
    uid: int = -1
    remaining: int = 0
    stop_token: Optional[int] = None
    temperature: float = 0.0
    generated: list = field(default_factory=list)


class ServingEngine:
    def __init__(self, model, params, *, max_batch=8, cache_len=256,
                 seed=0):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.state = model.init_decode_state(max_batch, cache_len)
        if model.cfg.is_encoder_decoder:
            self.state["enc"] = jnp.zeros(
                (max_batch, model.cfg.num_prefix_embeddings,
                 model.cfg.d_model), model.dtype)
        self.slots = [_Slot() for _ in range(max_batch)]
        self.queue: deque = deque()
        self.done: Dict[int, list] = {}
        self.key = jax.random.PRNGKey(seed)
        self._last_tok = np.zeros((max_batch, 1), np.int32)

        self._decode = jax.jit(model.decode_step, donate_argnums=(1,))
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cache_len=cache_len))

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        assert len(req.prompt) >= 1
        assert len(req.prompt) + req.max_new_tokens <= self.cache_len
        self.queue.append(req)

    def _insert_state(self, slot_idx, single_state, first_tok):
        """Splice a B=1 prefill state into batch slot `slot_idx`.

        Scanned-layer cache leaves are stacked [n_groups, B, ...] --
        the batch axis is 1 there, 0 everywhere else (path-aware)."""
        def ins(path, batched, single):
            in_scanned = any(getattr(p, "key", None) == "scanned"
                             for p in path)
            if in_scanned:
                return batched.at[:, slot_idx].set(single[:, 0])
            return batched.at[slot_idx].set(single[0])
        self.state["cache"] = jax.tree_util.tree_map_with_path(
            ins, self.state["cache"], single_state["cache"])
        self.state["position"] = self.state["position"].at[
            slot_idx].set(single_state["position"][0])
        if "enc" in single_state:
            self.state["enc"] = self.state["enc"].at[slot_idx].set(
                single_state["enc"][0])
        self._last_tok[slot_idx, 0] = first_tok

    def _admit(self):
        for i, slot in enumerate(self.slots):
            if slot.active or not self.queue:
                continue
            req = self.queue.popleft()
            batch = {"tokens": jnp.asarray([req.prompt], jnp.int32)}
            if self.model.cfg.is_encoder_decoder or \
                    self.model.cfg.modality != "text":
                batch["prefix_emb"] = jnp.zeros(
                    (1, self.model.cfg.num_prefix_embeddings,
                     self.model.cfg.d_model))
            logits, st = self._prefill(self.params, batch)
            first = self._sample(logits[:, -1, :], req.temperature)
            self._insert_state(i, st, int(first[0]))
            self.slots[i] = _Slot(active=True, uid=req.uid,
                                  remaining=req.max_new_tokens - 1,
                                  stop_token=req.stop_token,
                                  temperature=req.temperature,
                                  generated=[int(first[0])])

    def _sample(self, logits, temperature):
        if temperature <= 0:
            return np.asarray(jnp.argmax(logits, axis=-1))
        self.key, sub = jax.random.split(self.key)
        return np.asarray(jax.random.categorical(
            sub, logits / temperature, axis=-1))

    # ------------------------------------------------------------------
    def step(self):
        """One decode step for every active slot."""
        toks = jnp.asarray(self._last_tok)
        logits, self.state = self._decode(self.params, self.state, toks)
        lg = logits[:, -1, :]
        for i, slot in enumerate(self.slots):
            if not slot.active:
                continue
            nxt = int(self._sample(lg[i:i + 1], slot.temperature)[0])
            slot.generated.append(nxt)
            self._last_tok[i, 0] = nxt
            slot.remaining -= 1
            if slot.remaining <= 0 or nxt == slot.stop_token:
                if nxt == slot.stop_token:
                    slot.generated.pop()
                self.done[slot.uid] = slot.generated
                self.slots[i] = _Slot()

    def run(self):
        """Drain the queue; returns {uid: generated tokens}."""
        while self.queue or any(s.active for s in self.slots):
            self._admit()
            if any(s.active for s in self.slots):
                self.step()
        return dict(self.done)

    @property
    def stats(self):
        return {"active": sum(s.active for s in self.slots),
                "queued": len(self.queue),
                "done": len(self.done)}
