"""Loop-aware cost extraction from post-SPMD-partitioning HLO text.

Why: ``compiled.cost_analysis()`` counts each while-loop body ONCE, but
our layer stacks are lax.scan'ed -- a 56-layer model's per-layer flops,
bytes, and collectives execute n_layers times while appearing once in
the HLO. This module rebuilds the call graph (ENTRY -> call / fusion /
while bodies), multiplies every computation's costs by its execution
count (XLA annotates ``known_trip_count`` on compiled while ops), and
returns loop-aware totals:

  flops            2*M*N*K for every dot, x execution count
  hbm_bytes        operand+output bytes of every top-level instruction
                   (fusion internals excluded: register/VMEM resident)
  collective wire bytes by kind (ring-algorithm factors)

All numbers are per-device (the partitioned program is per-device).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

from repro.analysis.ir import SHAPE_RE as _SHAPE_RE
from repro.analysis.ir import bytes_of as _bytes_of
from repro.analysis.ir import parse_shapes as _parse_shapes
from repro.analysis.ir import shape_elems as _shape_elems

_INSTR_RE = re.compile(r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_OP_RE = re.compile(r"^\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
                    r"([\w\-]+)\(")
_OPERANDS_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count\\?":\{\\?"n\\?":\\?"(\d+)')
_TRIP_RE2 = re.compile(r'known_trip_count[^0-9]*(\d+)')
_CALLS_RE = re.compile(r"(?:to_apply|body|condition|calls)=%?([\w.\-]+)")
# conditionals: both branches counted once (upper bound; a
# fedavg_every-style sync branch actually runs 1/E of steps -- callers
# that know the duty cycle can subtract, see launch/dryrun.py)
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")


@dataclass
class Instr:
    name: str
    type_str: str
    op: str
    line: str
    operands: list


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)
    shapes: dict = field(default_factory=dict)   # instr name -> type str


def split_computations(txt: str):
    comps = {}
    cur = None
    entry = None
    for line in txt.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line)
            if m:
                cur = Computation(m.group(2))
                if m.group(1):
                    entry = cur.name
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        om = _OP_RE.match(rest)
        if not om:
            continue
        type_str, op = om.group(1), om.group(2)
        after = rest[om.end():]
        # operands: %refs before the closing paren of the op call
        depth = 1
        end = 0
        for i, ch in enumerate(after):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = _OPERANDS_RE.findall(after[:end])
        instr = Instr(name, type_str, op, line, operands)
        cur.instrs.append(instr)
        cur.shapes[name] = type_str
    return comps, entry


def _dot_flops(instr: Instr, comp: Computation):
    """2 * prod(out dims) * prod(contracted dims of lhs)."""
    out_elems = sum(_shape_elems(dims)
                    for _, dims in _parse_shapes(instr.type_str))
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.line)
    if not m or not instr.operands:
        return 2 * out_elems  # fallback
    lhs_shape = comp.shapes.get(instr.operands[0])
    if lhs_shape is None:
        return 2 * out_elems
    shapes = _parse_shapes(lhs_shape)
    if not shapes:
        return 2 * out_elems
    dims = [int(d) for d in shapes[0][1].split(",")] if shapes[0][1] else []
    k = 1
    for idx in m.group(1).split(","):
        if idx and int(idx) < len(dims):
            k *= dims[int(idx)]
    return 2 * out_elems * k


_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _collective_wire(instr: Instr):
    kind = instr.op.replace("-start", "")
    if kind not in _COLL_KINDS:
        return None
    size = _bytes_of(instr.type_str)
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", instr.line)
    if m:
        g = int(m.group(2))
    else:
        m = re.search(r"replica_groups=\{\{([^}]*)\}", instr.line)
        g = len(m.group(1).split(",")) if m else 2
    if g <= 1:
        return kind, 0.0
    frac = (g - 1) / g
    wire = {"all-reduce": 2 * size * frac, "all-gather": size * frac,
            "reduce-scatter": size * frac, "all-to-all": size * frac,
            "collective-permute": float(size)}[kind]
    return kind, wire


def analyze(txt: str):
    """Loop-aware per-device costs from compiled HLO text."""
    comps, entry = split_computations(txt)

    # per-computation local costs and call edges
    local = {}
    for cname, comp in comps.items():
        flops = 0.0
        bytes_ = 0.0
        coll = defaultdict(float)
        coll_counts = defaultdict(float)
        calls = []   # (callee, multiplier)
        fused_callees = set()
        for ins in comp.instrs:
            if ins.op in ("dot", "convolution"):
                flops += _dot_flops(ins, comp)
            cw = _collective_wire(ins)
            if cw:
                coll[cw[0]] += cw[1]
                coll_counts[cw[0]] += 1
            # call edges
            bm = _BRANCHES_RE.search(ins.line)
            if bm:
                for br in bm.group(1).split(","):
                    calls.append((br.strip().lstrip("%"), 1.0))
            for callee in _CALLS_RE.findall(ins.line):
                mult = 1.0
                if ins.op == "while":
                    tm = _TRIP_RE.search(ins.line) or _TRIP_RE2.search(
                        ins.line)
                    mult = float(tm.group(1)) if tm else 1.0
                    if f"condition=%{callee}" in ins.line or \
                            f"condition={callee}" in ins.line:
                        continue  # cond: negligible
                calls.append((callee, mult))
                if ins.op == "fusion":
                    fused_callees.add(callee)
            # HBM bytes: top-level instruction outputs + operands
            # (fusion bodies excluded below via is_fused marker)
            if ins.op not in ("parameter", "constant", "tuple",
                              "get-tuple-element", "bitcast"):
                bytes_ += _bytes_of(ins.type_str)
                for opnd in ins.operands:
                    if opnd in comp.shapes:
                        bytes_ += _bytes_of(comp.shapes[opnd])
        local[cname] = dict(flops=flops, bytes=bytes_, coll=coll,
                            coll_counts=coll_counts, calls=calls,
                            fused=fused_callees)

    # propagate execution multipliers from ENTRY
    mult = defaultdict(float)
    bytes_enabled = {}  # fused computations contribute flops, not bytes

    def visit(cname, m, count_bytes):
        mult[cname] += m
        if cname in bytes_enabled:
            bytes_enabled[cname] = bytes_enabled[cname] or count_bytes
        else:
            bytes_enabled[cname] = count_bytes
        for callee, cm in local[cname]["calls"]:
            if callee not in local:
                continue
            inner_bytes = count_bytes and \
                callee not in local[cname]["fused"]
            visit(callee, m * cm, inner_bytes)

    if entry is None:
        entry = max(comps, key=lambda c: len(comps[c].instrs))
    visit(entry, 1.0, True)

    totals = dict(flops=0.0, hbm_bytes=0.0)
    coll = defaultdict(float)
    coll_counts = defaultdict(float)
    for cname, m in mult.items():
        lc = local[cname]
        totals["flops"] += lc["flops"] * m
        if bytes_enabled.get(cname):
            totals["hbm_bytes"] += lc["bytes"] * m
        for k, v in lc["coll"].items():
            coll[k] += v * m
            coll_counts[k] += lc["coll_counts"][k] * m
    coll = dict(coll)
    coll["total"] = sum(coll.values())
    coll["counts"] = {k: int(v) for k, v in coll_counts.items()}
    totals["collective_wire_bytes"] = coll
    return totals
