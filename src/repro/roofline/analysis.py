"""Roofline model from the compiled dry-run artifact (no real TPU):

  compute term    = per_chip_FLOPs / peak_FLOP/s
  memory term     = per_chip_HBM_bytes / HBM_bw
  collective term = per_chip_wire_bytes / ICI_bw

`compiled.cost_analysis()` on the SPMD-partitioned program reports
*per-device* flops / bytes accessed, so the terms divide by per-chip
peaks directly. Collective bytes are NOT in cost_analysis: we parse the
post-partitioning HLO text and sum operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute, converted
to ring-algorithm wire bytes:

  all-reduce       2 * size * (g-1)/g
  all-gather       size_out * (g-1)/g
  reduce-scatter   size_in  * (g-1)/g
  all-to-all       size * (g-1)/g
  collective-permute  size

where g is the replica-group size of that op. One active ICI link per
op is assumed (conservative; recorded in EXPERIMENTS.md).
"""
from __future__ import annotations

import re
from collections import defaultdict

from repro.analysis.ir import SHAPE_RE as _SHAPE_RE
from repro.analysis.ir import shape_bytes as _shape_bytes
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

# e.g.  %all-reduce.5 = bf16[8,128,3584] all-reduce(...), replica_groups=...
_COLL_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_TUPLE_COLL_RE = re.compile(
    r"=\s*\(([^)]*)\)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _group_size(line: str) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Per-device wire-byte totals by collective kind."""
    out = defaultdict(float)
    counts = defaultdict(int)
    for line in hlo_text.splitlines():
        if "-start(" not in line and not any(
                k in line for k in ("all-reduce(", "all-gather(",
                                    "reduce-scatter(", "all-to-all(",
                                    "collective-permute(")):
            continue
        m = _COLL_RE.search(line)
        shapes = []
        kind = None
        if m:
            kind = m.group(3)
            shapes = [(m.group(1), m.group(2))]
        else:
            mt = _TUPLE_COLL_RE.search(line)
            if mt:
                kind = mt.group(2)
                shapes = _SHAPE_RE.findall(mt.group(1))
        if not kind:
            continue
        size = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        g = _group_size(line)
        if g <= 1:
            continue
        frac = (g - 1) / g
        wire = {"all-reduce": 2 * size * frac,
                "all-gather": size * frac,
                "reduce-scatter": size * frac,
                "all-to-all": size * frac,
                "collective-permute": size}[kind]
        out[kind] += wire
        counts[kind] += 1
    out = dict(out)
    out["total"] = sum(out.values())
    out["counts"] = dict(counts)
    return out


def roofline_terms(per_chip_flops, per_chip_bytes, per_chip_wire_bytes,
                   model_flops_per_chip=None):
    """All inputs per chip; returns the three terms in seconds plus the
    dominant bottleneck."""
    t_c = per_chip_flops / PEAK_FLOPS_BF16
    t_m = per_chip_bytes / HBM_BW
    t_x = per_chip_wire_bytes / ICI_BW
    dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
              key=lambda kv: kv[1])[0]
    out = {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
           "bottleneck": dom,
           "bound_s": max(t_c, t_m, t_x)}
    if model_flops_per_chip is not None:
        out["model_flops_per_chip"] = model_flops_per_chip
        out["useful_flop_frac"] = (model_flops_per_chip / per_chip_flops
                                   if per_chip_flops else 0.0)
    return out


def summarize(record: dict) -> str:
    r = record
    t = r["roofline"]
    return (f"{r['arch']:22s} {r['shape']:12s} mesh={r['mesh']:9s} "
            f"compute={t['compute_s']*1e3:9.3f}ms "
            f"memory={t['memory_s']*1e3:9.3f}ms "
            f"coll={t['collective_s']*1e3:9.3f}ms "
            f"-> {t['bottleneck']}")
