"""repro.api -- the one front door to De-VertiFL experiments.

Declare WHAT to run as a frozen, hashable :class:`ExperimentSpec`
(validated eagerly against the dataset / mode / first-layer
registries), then :func:`build` it into a :class:`Session` and run::

    from repro.api import ExperimentSpec, build

    spec = ExperimentSpec(dataset="mnist", mode="devertifl",
                          n_clients=5, rounds=5)
    result = build(spec).run()          # -> RunResult
    print(result.metrics, result.spec_hash)

Grids ride the same spec type -- :func:`spec_grid` enumerates the
datasets x modes x client_counts cartesian, :func:`run_grid` trains it
with one compiled round per (dataset, mode) and the lanes sharded over
the device mesh (exactly ``repro.core.sweep``'s engine)::

    grid = run_grid(spec_grid(datasets=("mnist",), seeds=(0, 1)))

Extend any axis through the registries: :func:`register_dataset`,
:func:`register_mode`, :func:`register_first_layer`,
:func:`register_schedule` (the exchange-schedule axis: ``sync`` /
``stale_k:k`` / ``double_buffer`` / ``partial:p``).  Legacy entry
points (``train_federation``, ``ProtocolConfig``, ``SweepConfig``)
remain as thin internals underneath; spec-driven runs reproduce them
bit-for-bit (tests/test_api.py).  Contracts: docs/ARCHITECTURE.md
("Spec & registry contracts").
"""
from repro.analysis import AnalysisReport, audit  # noqa: F401
from repro.api.spec import ExperimentSpec, HASH_EXCLUDE  # noqa: F401
from repro.api.modes import (  # noqa: F401
    ModeEntry, get_mode, mode_names, register_mode,
)
from repro.api.session import (  # noqa: F401
    RESULT_SCHEMA_VERSION, RunResult, Session, build, git_sha, run_grid,
    spec_grid, sweep_config_for_specs,
)
from repro.core.protocol import register_first_layer  # noqa: F401
from repro.data.registry import (  # noqa: F401
    DatasetEntry, dataset_names, get_dataset, register_dataset,
)
from repro.schedule import (  # noqa: F401
    Schedule, get_schedule, register_schedule, schedule_names,
)
from repro.serving.federated import (  # noqa: F401
    ExchangeCache, FederatedServer, ServeReport, ServeRequest,
    split_features,
)


def first_layer_names() -> list:
    """Registered first-layer backend names."""
    from repro.core.protocol import FIRST_LAYERS
    return FIRST_LAYERS.names()
