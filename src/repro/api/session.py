"""``build(spec) -> Session`` -- the runnable side of the front door.

A Session wraps one mode implementation (resolved from the mode
registry) behind a uniform surface:

  session.run()      train, return a versioned :class:`RunResult`
  session.predict()  class predictions from the trained params
  session.resume()   continue from the latest checkpoint in
                     ``spec.checkpoint_dir`` (``latest_step``)

Parity contract (tests/test_api.py pins all of it bit-for-bit):

  * a single-seed federated Session reproduces
    ``DeVertiFL(ProtocolConfig(...)).train()`` exactly -- same key
    derivation (``train_keys`` / per-round ``fold_in``), same jitted
    round function, same history entries -- in every mode, every
    first-layer lane, padded or not;
  * a multi-seed Session reproduces ``sweep.run_cell``;
  * ``run_grid`` over a spec grid reproduces ``sweep.run_grid`` over
    the equivalent SweepConfig (plus a per-cell ``spec_hash``);
  * a ``resume()`` after a checkpoint matches the uninterrupted run
    (round r depends only on carried state and ``fold_in(loop_key, r)``).

``RunResult`` is the record the bench JSON schema reuses: metrics,
per-round trajectory, spec hash, git SHA, timings, and a
``schema_version`` so downstream tooling can detect shape changes.
"""
from __future__ import annotations

import dataclasses
import os
import subprocess
import time
from dataclasses import dataclass, field
from functools import lru_cache
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.modes import get_mode
from repro.api.spec import ExperimentSpec
from repro.checkpoint import (CheckpointCorruptError, checkpoint_steps,
                              load_checkpoint, load_entry,
                              save_checkpoint)
from repro.core import sweep as SW
from repro.core.baselines import SplitNN, SplitNNConfig
from repro.core.protocol import DeVertiFL, ProtocolConfig, train_keys
from repro.faults import (RESEED_TAG, DivergenceError, RetryPolicy,
                          diverged)
from repro.obs import NullTracer, SpanTracer, Telemetry

# 2 (PR 5): specs carry a ``schedule`` field; Session checkpoints grew
# a ``sched`` subtree (the exchange-schedule scan-carry state -- stale
# ring buffers / double-buffer slots; empty for sync) and a
# ``schedule_hash`` stamp that resume() verifies before loading, so a
# checkpoint written under one schedule cannot silently continue under
# another.  Both changes are additive.
# 3 (PR 7): specs carry a ``fault`` field; the checkpoint stamp folds
# non-none fault plans in (fault="none" keeps the PR 5 stamp, so older
# checkpoints stay resumable); ``timings`` gains a "fault" sub-dict
# (event counters + watchdog trips/retries) when a fault plan or a
# RetryPolicy is active.  All changes are additive.
# 4 (PR 9): specs carry a ``transform`` field (repro.wire exchange
# transforms); the checkpoint stamp folds non-none transforms in
# (transform="none" keeps the PR 7 stamp); ``timings`` gains a "wire"
# sub-dict (integer bytes-on-wire, raw vs encoded, cumulative and
# per-round) when a transform is active.  All changes are additive.
# 5 (PR 10): results carry a unified ``telemetry`` record
# (repro.obs.Telemetry: wall/steps/fault/wire/obs series/spans); the
# legacy ``timings`` dict is now DERIVED from it
# (``telemetry.to_timings()``) and kept as a deprecated alias with its
# exact historical keys.  The checkpoint stamp folds non-none obs
# levels in (obs="none" keeps the PR 9 stamp -- obs state rides the
# checkpointed scan carry, so the stream must match).  All changes
# are additive.
RESULT_SCHEMA_VERSION = 5
_CKPT_NAME = "session"


def _hash_array(hex_hash: str) -> np.ndarray:
    """16-hex-char hash -> uint8[8], checkpointable alongside params."""
    return np.frombuffer(bytes.fromhex(hex_hash), np.uint8)


def _copy_state(state):
    """Deep-copy a pytree of arrays.  The jitted round function donates
    its params/opt_state buffers, so rollback snapshots must not alias
    the live state -- jnp.array forces fresh buffers per leaf."""
    return jax.tree.map(jnp.array, state)


def _schedule_hash(schedule: str) -> str:
    """Process-stable 16-hex-char id of a canonical schedule spec
    string -- the checkpoint stamp resume() verifies."""
    import hashlib
    return hashlib.sha256(
        ("schedule:" + schedule).encode()).hexdigest()[:16]


def _stream_stamp(spec) -> str:
    """The schedule(+fault)(+wire)(+obs) identity stamped into
    checkpoints.  With ``fault="none"``, ``transform="none"`` and
    ``obs="none"`` this is exactly the PR 5 schedule stamp, so older
    checkpoints stay resumable; a non-none plan, transform or obs
    level extends the stamped string, so a checkpoint written under
    one stream can never silently continue under another (the carried
    fault / wire / obs state -- crash countdowns, straggler rings,
    byte counters, metric series -- belongs to its own stream)."""
    ident = spec.schedule if spec.fault == "none" else \
        f"{spec.schedule}|fault={spec.fault}"
    if spec.transform != "none":
        ident = f"{ident}|wire={spec.transform}"
    if spec.obs != "none":
        ident = f"{ident}|obs={spec.obs}"
    return _schedule_hash(ident)


# obs series slots in the carried sched state (ObsImpl sits outermost,
# so they live at the top level) -- all [rounds, ...]: their leading
# axis is the WRITING spec's rounds, which a resume may change
_OBS_SERIES = ("s_loss", "s_exn", "s_gn", "s_quar", "s_bytes",
               "s_stale")


def _obs_series_like(sched_like, directory, step):
    """A like-tree whose obs series leaves take the CHECKPOINT's
    round capacity (axis 0) so the structured load accepts them; any
    other shape difference is left for load_checkpoint's own error."""
    out = dict(sched_like)
    for k in _OBS_SERIES:
        if k not in out:
            continue
        saved = load_entry(directory, step, f"sched/{k}",
                           name=_CKPT_NAME)
        have = out[k]
        if saved is not None and saved.shape != tuple(have.shape) \
                and saved.shape[1:] == tuple(have.shape)[1:]:
            out[k] = jnp.zeros(saved.shape, have.dtype)
    return out


def _obs_series_refit(sched, sched_like):
    """Refit restored series rows to this spec's rounds: zero-pad the
    tail (rows the resumed run will write) or drop trailing rows that
    were never written (a checkpoint at round r has rows [0, r), and
    resume refuses r > spec.rounds)."""
    out = dict(sched)
    for k in _OBS_SERIES:
        if k not in out:
            continue
        arr, rows = out[k], sched_like[k].shape[0]
        if arr.shape[0] > rows:
            out[k] = arr[:rows]
        elif arr.shape[0] < rows:
            pad = [(0, rows - arr.shape[0])] + \
                [(0, 0)] * (arr.ndim - 1)
            out[k] = jnp.pad(arr, pad)
    return out


@lru_cache(maxsize=1)
def git_sha() -> str:
    """`git describe --always --dirty` of this checkout ("unknown"
    outside a repo; cached -- constant per process).  Stamped into
    RunResult and the bench entries."""
    try:
        return subprocess.check_output(
            ["git", "describe", "--always", "--dirty"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            text=True, stderr=subprocess.DEVNULL).strip()
    except Exception:
        return "unknown"


@dataclass
class RunResult:
    """Versioned result record.  ``params`` (the trained per-client
    param stack, or SplitNN param dict) is carried for programmatic
    use but excluded from ``to_dict()`` so results serialize small."""
    spec: ExperimentSpec
    spec_hash: str
    git_sha: str
    metrics: dict                   # final metrics ("f1", "acc", ...)
    history: List[dict] = field(default_factory=list)
    # DEPRECATED alias: derived from ``telemetry.to_timings()``, kept
    # with its exact historical keys ("wall_s", "steps_per_sec",
    # "fault", "wire") for pre-PR-10 consumers
    timings: dict = field(default_factory=dict)
    params: Any = None
    resumed_from: Optional[int] = None
    telemetry: Optional[Telemetry] = None
    schema_version: int = RESULT_SCHEMA_VERSION

    def to_dict(self) -> dict:
        """JSON-safe dict (the bench schema embeds this shape)."""
        def clean(v):
            if isinstance(v, dict):
                return {k: clean(x) for k, x in v.items()}
            if isinstance(v, (list, tuple)):
                return [clean(x) for x in v]
            if isinstance(v, (np.ndarray, jnp.ndarray)):
                return np.asarray(v).tolist()
            if isinstance(v, (np.floating, np.integer)):
                return v.item()
            return v
        return {
            "schema_version": self.schema_version,
            "spec": self.spec.to_dict(),
            "spec_hash": self.spec_hash,
            "git_sha": self.git_sha,
            "metrics": clean(self.metrics),
            "history": clean(self.history),
            "timings": clean(self.timings),
            "resumed_from": self.resumed_from,
            "telemetry": (None if self.telemetry is None
                          else self.telemetry.to_dict()),
        }


def _protocol_config(spec: ExperimentSpec, internal: str) -> ProtocolConfig:
    """The thin internal config a spec lowers to (field-for-field; the
    spec's extra knobs -- eval cadence, checkpointing, shard -- live at
    the Session layer)."""
    return ProtocolConfig(
        dataset=spec.dataset, n_clients=spec.n_clients,
        rounds=spec.rounds, epochs=spec.epochs,
        batch_size=spec.batch_size, lr=spec.lr,
        exchange_at=spec.exchange_at, mode=internal, fedavg=spec.fedavg,
        seed=spec.seed, n_samples=spec.n_samples, engine=spec.engine,
        first_layer=spec.first_layer, schedule=spec.schedule,
        fault=spec.fault, transform=spec.transform, obs=spec.obs,
        max_clients=spec.max_clients)


def _sweep_config(spec: ExperimentSpec, client_counts,
                  schedules=None, faults=None,
                  transforms=None) -> SW.SweepConfig:
    return SW.SweepConfig(
        client_counts=tuple(client_counts), seeds=spec.seeds,
        rounds=spec.rounds, epochs=spec.epochs,
        batch_size=spec.batch_size, lr=spec.lr,
        exchange_at=spec.exchange_at, fedavg=spec.fedavg,
        n_samples=spec.n_samples, first_layer=spec.first_layer,
        schedules=(tuple(schedules) if schedules is not None
                   else (spec.schedule,)),
        faults=(tuple(faults) if faults is not None
                else (spec.fault,)),
        transforms=(tuple(transforms) if transforms is not None
                    else (spec.transform,)),
        obs=(spec.obs,))


class Session:
    """One runnable experiment.  Construct via :func:`build`."""

    def __init__(self, spec: ExperimentSpec):
        if not isinstance(spec, ExperimentSpec):
            raise TypeError(f"build() takes an ExperimentSpec, got "
                            f"{type(spec).__name__}")
        self.spec = spec
        self.mode = get_mode(spec.mode)
        self._fed = None
        self._runner = None
        self._last_params = None
        # host-side span tracer: armed with the in-scan taps (obs !=
        # "none"), the zero-overhead NullTracer otherwise
        self.tracer = SpanTracer() if spec.obs != "none" \
            else NullTracer()

    # ------------------------------------------------------------------
    @property
    def federation(self) -> DeVertiFL:
        """The underlying DeVertiFL engine (federated modes only) --
        built lazily, shared by run/resume/predict."""
        if self.mode.kind != "federated":
            raise ValueError(f"mode {self.spec.mode!r} has no DeVertiFL "
                             "federation (it is not a federated mode)")
        if self._fed is None:
            with self.tracer.span("build", cat="setup",
                                  dataset=self.spec.dataset):
                self._fed = DeVertiFL(
                    _protocol_config(self.spec, self.mode.internal))
        return self._fed

    def _result(self, metrics, history, params, telemetry,
                resumed_from=None) -> RunResult:
        """The one RunResult construction path.  ``telemetry`` is the
        unified record; custom-mode runners may still hand over a
        legacy timings dict, which is lifted through
        ``Telemetry.from_timings``.  The deprecated ``timings`` alias
        is derived from the record, never built separately."""
        self._last_params = params
        if not isinstance(telemetry, Telemetry):
            telemetry = Telemetry.from_timings(telemetry)
        if self.tracer.active:
            telemetry.spans = self.tracer.to_records()
        return RunResult(spec=self.spec, spec_hash=self.spec.spec_hash,
                         git_sha=git_sha(), metrics=metrics,
                         history=history,
                         timings=telemetry.to_timings(), params=params,
                         resumed_from=resumed_from,
                         telemetry=telemetry)

    # ------------------------------------------------------------------
    def run(self, key=None, retry="auto") -> RunResult:
        """Train from scratch.  ``key`` overrides the spec-seed-derived
        PRNGKey (single-seed federated sessions only) -- an escape
        hatch for driving the engine on an external key stream.  NOTE
        the RunResult still carries the spec's hash (which identifies
        the spec-derived experiment), so key= is refused whenever
        checkpointing is on: a checkpoint of a custom-key run would
        pass the resume_hash guard and resume() on the wrong stream.

        ``retry`` is the divergence-watchdog policy (repro.faults):
        "auto" (default) arms a default :class:`RetryPolicy` when the
        spec carries a non-none fault plan and nothing otherwise --
        fault-free runs keep the untouched loop; pass a RetryPolicy to
        arm it explicitly, or None/False to disable.  On a trip the
        round is rolled back to the last good state and retried under
        a reseeded key (see repro.faults.recovery); trip/retry counts
        land in ``RunResult.timings["fault"]``.  Single-seed federated
        sessions only (multi-seed cells run the vmapped sweep engine,
        which has no per-round host watchdog)."""
        spec = self.spec
        if retry not in ("auto", None, False) and \
                (self.mode.kind != "federated" or len(spec.seeds) > 1):
            raise ValueError(
                "retry= applies to single-seed federated sessions: the "
                "divergence watchdog drives the per-round host loop")
        if key is not None and (self.mode.kind != "federated"
                                or len(spec.seeds) > 1):
            raise ValueError(
                "key= applies to single-seed federated sessions; other "
                "modes and multi-seed cells derive keys from the spec "
                "seeds")
        if key is not None and spec.checkpoint_every:
            raise ValueError(
                "key= cannot be combined with checkpointing: the "
                "custom key is not recorded, so resume() would "
                "continue the run on the spec-seed key stream instead "
                "-- a silent hybrid trajectory")
        if self.mode.kind == "custom":
            runner = self.mode.runner(spec)
            self._runner = runner
            return self._result(*runner.run())
        if self.mode.kind == "splitnn":
            return self._run_splitnn()
        if len(spec.seeds) > 1:
            return self._run_cell()
        return self._run_federated(key=key, retry=retry)

    def resume(self, retry="auto") -> RunResult:
        """Continue from the newest INTACT checkpoint in
        ``spec.checkpoint_dir`` (a fresh ``run()`` if none exists).
        Corrupt/truncated checkpoint files are skipped with a warning
        -- resume walks back to the newest one that loads
        (CheckpointCorruptError never kills a resume while an older
        intact step exists).  Rounds after the checkpoint are
        bit-for-bit the uninterrupted run's -- round r consumes only
        the carried state and ``fold_in(loop_key, r)``."""
        import warnings
        spec = self.spec
        if not spec.checkpoint_dir:
            raise ValueError("resume() needs spec.checkpoint_dir")
        if self.mode.kind != "federated" or len(spec.seeds) > 1:
            raise ValueError("resume() supports single-seed federated "
                             "sessions")
        steps = checkpoint_steps(spec.checkpoint_dir, name=_CKPT_NAME)
        if not steps:
            return self.run(retry=retry)
        fed = self.federation
        want_sched = _hash_array(_stream_stamp(spec))
        init_key, _ = train_keys(jax.random.PRNGKey(spec.seed))
        params_like = fed.init_params(init_key)
        like_base = {"params": params_like,
                     "opt_state": jax.vmap(fed.opt.init)(params_like),
                     "step_idx": jnp.zeros((), jnp.int32),
                     "sched": fed.init_sched_state(),
                     "resume_hash": _hash_array(spec.resume_hash)}
        state, step = None, None
        for cand in reversed(steps):
            try:
                if cand > spec.rounds:
                    raise ValueError(
                        f"latest intact checkpoint in "
                        f"{spec.checkpoint_dir!r} is at round {cand}, "
                        f"beyond spec.rounds={spec.rounds}: resuming "
                        "would return a longer run's params under "
                        "this spec's hash; raise rounds or point at a "
                        "different checkpoint_dir")
                # verify the stream stamp FIRST: a checkpoint written
                # under a different schedule or fault plan carries
                # differently-shaped scan state (stale ring buffers,
                # fault countdowns), and the structured load below
                # would fail with a misleading shape error instead of
                # naming the actual mismatch
                got_sched = load_entry(spec.checkpoint_dir, cand,
                                       "schedule_hash", name=_CKPT_NAME)
                if got_sched is None:
                    if spec.schedule != "sync" or \
                            spec.fault != "none" or \
                            spec.transform != "none" or \
                            spec.obs != "none":
                        raise ValueError(
                            f"checkpoint in {spec.checkpoint_dir!r} "
                            "carries no schedule stamp (written by a "
                            "pre-schedule writer, i.e. under "
                            "schedule='sync', fault='none', "
                            "transform='none', obs='none'); it cannot "
                            f"resume under schedule={spec.schedule!r} "
                            f"/ fault={spec.fault!r} / "
                            f"transform={spec.transform!r} / "
                            f"obs={spec.obs!r} -- the saved state has "
                            "no schedule, fault, wire or obs buffers "
                            "to restore")
                elif not np.array_equal(got_sched, want_sched):
                    raise ValueError(
                        f"checkpoint in {spec.checkpoint_dir!r} was "
                        "written under a different exchange schedule, "
                        "fault plan or wire transform (or obs level) "
                        f"than this spec's (schedule={spec.schedule!r}, "
                        f"fault={spec.fault!r}, "
                        f"transform={spec.transform!r}, "
                        f"obs={spec.obs!r}): resuming would splice "
                        "mismatched scan state (stale buffers / "
                        "participation stream / fault countdowns / "
                        "byte counters / metric series) into this "
                        "run; rebuild the spec with the original "
                        "schedule+fault+transform+obs or use a fresh "
                        "checkpoint_dir")
                like = dict(like_base)
                if got_sched is not None:
                    like["schedule_hash"] = want_sched
                if spec.obs != "none":
                    # obs series capacity equals the WRITER's rounds
                    # (the arrays are [rounds, ...]); resuming under a
                    # different rounds= only reshapes those rows, so
                    # load into the saved shape and refit below --
                    # unlike ring buffers, a series row per round is
                    # not trajectory state
                    like["sched"] = _obs_series_like(
                        like["sched"], spec.checkpoint_dir, cand)
                state = load_checkpoint(spec.checkpoint_dir, cand,
                                        like, name=_CKPT_NAME)
                if spec.obs != "none":
                    state["sched"] = _obs_series_refit(
                        state["sched"], like_base["sched"])
                step = cand
                break
            except CheckpointCorruptError as e:
                warnings.warn(
                    f"resume(): skipping corrupt checkpoint at round "
                    f"{cand} ({e}); falling back to the next older "
                    "step", RuntimeWarning, stacklevel=2)
        if state is None:
            warnings.warn(
                f"resume(): every checkpoint in "
                f"{spec.checkpoint_dir!r} is corrupt; training from "
                "scratch", RuntimeWarning, stacklevel=2)
            return self.run(retry=retry)
        if not np.array_equal(state["resume_hash"],
                              _hash_array(spec.resume_hash)):
            raise ValueError(
                f"checkpoint in {spec.checkpoint_dir!r} belongs to a "
                "different experiment (resume_hash mismatch): resuming "
                "it under this spec would splice another run's params "
                "into this spec's RunResult")
        state = jax.tree.map(jnp.asarray,
                             {k: v for k, v in state.items()
                              if k not in ("resume_hash",
                                           "schedule_hash")})
        return self._run_federated(
            start_round=step,
            state=(state["params"], state["opt_state"],
                   state["step_idx"], state["sched"]),
            resumed_from=step, retry=retry)

    def predict(self, x, params=None):
        """Class predictions on raw (original-column-order) inputs.
        Federated modes return the LIVE per-client [n_clients, B]
        stack (dead padded slots are trimmed -- their rows would be
        garbage); splitnn returns [B].  ``params`` defaults to the
        last run's."""
        params = params if params is not None else self._last_params
        if params is None:
            if len(self.spec.seeds) > 1:
                raise ValueError(
                    "multi-seed cells do not retain per-seed params; "
                    "run a single-seed session (seeds=(s,)) for "
                    "predict(), or pass params= explicitly")
            raise ValueError("predict() before run()/resume(): pass "
                             "params= or train first")
        if self.mode.kind == "federated":
            return self.federation.predict(params, x)[:self.spec.n_clients]
        if self.mode.kind == "splitnn":
            return self._splitnn().predict(params, x)
        if self._runner is None:    # predict with explicit params=
            self._runner = self.mode.runner(self.spec)
        return self._runner.predict(params, x)

    # ------------------------------------------------------------------
    def server(self, params=None, *, max_slots=8, queue_cap=None,
               cache=128, overflow="reject"):
        """A :class:`repro.serving.FederatedServer` over this spec's
        trained params: continuous-batched vertical inference where
        each request's features arrive split across clients
        (``submit``/``offer``), batched into ``max_slots`` predict
        slots advanced by one jitted step, with a hot-entity exchange
        cache (LRU of ``cache`` entries keyed by entity id +
        spec_hash; pass an ExchangeCache to share one across servers,
        or ``None`` to disable) and bounded-queue admission
        (``queue_cap`` + ``overflow``: "reject" | "evict_oldest").

        Serving is bit-for-bit ``predict()`` per request -- invariant
        to arrival order, slot count, batch composition, and cache
        state (tests/test_serving.py pins it; contracts in
        docs/ARCHITECTURE.md section 10).  Like ``evaluate``, serving
        always uses the synchronous evaluation exchange regardless of
        the training ``schedule``/``fault`` plan."""
        from repro.serving.federated import FederatedServer
        if self.mode.kind != "federated":
            raise ValueError(
                f"serve() runs federated modes; mode {self.spec.mode!r}"
                " has no multi-party inference path")
        params = params if params is not None else self._last_params
        if params is None:
            if len(self.spec.seeds) > 1:
                raise ValueError(
                    "multi-seed cells do not retain per-seed params; "
                    "run a single-seed session (seeds=(s,)) for "
                    "serve(), or pass params= explicitly")
            raise ValueError("serve() before run()/resume(): pass "
                             "params= or train first")
        fed = self.federation
        return FederatedServer(fed.model, fed.pcfg, fed.layout, params,
                               spec_hash=self.spec.spec_hash,
                               max_slots=max_slots, queue_cap=queue_cap,
                               cache=cache, overflow=overflow,
                               tracer=self.tracer)

    def serve(self, requests, params=None, **server_kw):
        """Batch convenience over :meth:`server`: submit every
        :class:`repro.serving.ServeRequest` in arrival order, drain the
        slot pool, and return the :class:`repro.serving.ServeReport`
        (per-request predictions + latency/cache/scheduler telemetry).
        """
        srv = self.server(params, **server_kw)
        for req in requests:
            srv.submit(req)
        return srv.run()

    # ------------------------------------------------------------------
    def _retry_policy(self, retry) -> Optional[RetryPolicy]:
        """Resolve the run()/resume() ``retry`` argument to a
        RetryPolicy or None.  "auto" arms the default policy exactly
        when the spec carries a fault plan -- fault-free runs keep the
        pre-watchdog loop (no snapshot copies, no host sync)."""
        if retry == "auto":
            return RetryPolicy() if self.spec.fault != "none" else None
        if retry is None or retry is False:
            return None
        if isinstance(retry, RetryPolicy):
            return retry
        raise TypeError(
            f"retry must be 'auto', None/False, or a RetryPolicy; got "
            f"{type(retry).__name__}")

    def _run_federated(self, key=None, start_round=0, state=None,
                       resumed_from=None, retry="auto") -> RunResult:
        spec = self.spec
        fed = self.federation
        policy = self._retry_policy(retry)
        key = key if key is not None else jax.random.PRNGKey(spec.seed)
        init_key, loop_key = train_keys(key)
        if state is None:
            params = fed.init_params(init_key)
            opt_state = jax.vmap(fed.opt.init)(params)
            step_idx = jnp.zeros((), jnp.int32)
            sched_state = fed.init_sched_state()
        else:
            params, opt_state, step_idx, sched_state = state
        history = []
        trips = retries = attempt = 0
        # the jitted round donates params/opt_state buffers, so the
        # rollback snapshot must be DEEP copies -- jnp.array per leaf
        snapshot = None if policy is None else _copy_state(
            (params, opt_state, step_idx, sched_state))
        t0 = time.perf_counter()
        r = start_round
        while r < spec.rounds:
            rkey = jax.random.fold_in(loop_key, r)
            if attempt > 0:
                # a retried round re-rolls its stochastic draws (fault
                # coins, participation, batch shuffles) on a reseeded
                # key; attempt=0 keeps the canonical stream, so runs
                # that never trip are bitwise the watchdog-free run
                rkey = jax.random.fold_in(
                    jax.random.fold_in(rkey, RESEED_TAG), attempt)
            with self.tracer.span("round", cat="train", round=r,
                                  attempt=attempt):
                if spec.engine == "scan":
                    params, opt_state, step_idx, sched_state, losses =\
                        fed._round(params, opt_state, step_idx,
                                   sched_state, rkey, fed._xtr,
                                   fed._ytr, fed._lay)
                else:
                    params, opt_state, step_idx, sched_state, losses =\
                        fed._python_round(params, opt_state, step_idx,
                                          sched_state, rkey)
            if policy is not None and \
                    diverged(losses, policy.loss_threshold):
                trips += 1
                if attempt >= policy.max_retries:
                    raise DivergenceError(
                        f"round {r} of spec {spec.spec_hash} "
                        f"(fault={spec.fault!r}, "
                        f"schedule={spec.schedule!r}) diverged "
                        f"(non-finite loss or |loss| > "
                        f"{policy.loss_threshold:g}) and stayed "
                        f"diverged after {policy.max_retries} reseeded "
                        "retries from the last good state; the run is "
                        "not recoverable under this plan -- lower the "
                        "fault rate / lr, raise "
                        "RetryPolicy(max_retries=...), or inspect the "
                        "exchange guard telemetry of a retry='none' "
                        "run")
                attempt += 1
                retries += 1
                s = policy.sleep_s(attempt)
                if s > 0:
                    time.sleep(s)
                # roll back: restore COPIES so the snapshot survives
                # donation by the next attempt's round call
                params, opt_state, step_idx, sched_state = \
                    _copy_state(snapshot)
                continue
            attempt = 0
            if policy is not None:
                snapshot = _copy_state(
                    (params, opt_state, step_idx, sched_state))
            if spec.eval_every and (r + 1) % spec.eval_every == 0:
                with self.tracer.span("eval", cat="eval", round=r):
                    ev = fed.evaluate(params)
                ev["round"] = r
                ev["loss"] = float(losses[-1])
                ev["round_losses"] = np.asarray(losses)
                history.append(ev)
            if spec.checkpoint_every and \
                    (r + 1) % spec.checkpoint_every == 0:
                with self.tracer.span("checkpoint", cat="ckpt",
                                      round=r):
                    save_checkpoint(
                        spec.checkpoint_dir, r + 1,
                        {"params": params, "opt_state": opt_state,
                         "step_idx": step_idx, "sched": sched_state,
                         "resume_hash": _hash_array(spec.resume_hash),
                         "schedule_hash":
                             _hash_array(_stream_stamp(spec))},
                        name=_CKPT_NAME)
            r += 1
        jax.block_until_ready(params)
        wall = time.perf_counter() - t0
        with self.tracer.span("eval", cat="eval", round=-1):
            final = fed.evaluate(params)
        rounds_run = spec.rounds - start_round
        steps = rounds_run * spec.epochs * fed.n_batches
        telemetry = Telemetry(wall_s=wall, steps=steps,
                              steps_per_sec=steps / max(wall, 1e-9))
        tel = fed.fault_telemetry(sched_state)
        if tel is not None or policy is not None:
            telemetry.fault = {
                **({k: int(v) for k, v in tel.items()} if tel else {}),
                "watchdog_trips": trips, "retries": retries}
        wtel = fed.wire_telemetry(sched_state)
        if wtel is not None:
            # cumulative integer bytes-on-wire; the counters ride the
            # scan carry, so a resumed run's totals cover every round
            # since round 0 (the checkpoint restores them)
            raw = int(wtel["raw_bytes"])
            enc = int(wtel["encoded_bytes"])
            telemetry.wire = {
                "raw_bytes": raw, "encoded_bytes": enc,
                "raw_bytes_per_round": raw // max(spec.rounds, 1),
                "encoded_bytes_per_round": enc // max(spec.rounds, 1)}
        # obs per-round series ride the same carry (and the same
        # checkpoint), so a resumed run's series cover rounds 0..R
        telemetry.series = fed.obs_series(sched_state)
        return self._result(final, history, params, telemetry,
                            resumed_from=resumed_from)

    def _run_cell(self) -> RunResult:
        spec = self.spec
        cell = SW.run_cell(spec.dataset, self.mode.internal,
                           spec.n_clients,
                           _sweep_config(spec, (spec.n_clients,)))
        metrics = {"f1": cell["f1_mean"], "acc": cell["acc_mean"],
                   "f1_std": cell["f1_std"],
                   "f1_per_seed": cell["f1_per_seed"],
                   "acc_per_seed": cell["acc_per_seed"],
                   "final_loss_mean": cell["final_loss_mean"],
                   "seeds": cell["seeds"]}
        telemetry = Telemetry(wall_s=cell["wall_s"],
                              steps_per_sec=cell["steps_per_sec"],
                              fault=cell.get("fault_telemetry"),
                              wire=cell.get("wire"),
                              series=cell.get("obs_series"))
        return self._result(metrics, [], None, telemetry)

    def _splitnn_config(self, seed) -> SplitNNConfig:
        spec = self.spec
        return SplitNNConfig(
            dataset=spec.dataset, n_clients=spec.n_clients,
            rounds=spec.rounds, epochs=spec.epochs,
            batch_size=spec.batch_size, lr=spec.lr, seed=seed,
            n_samples=spec.n_samples)

    def _splitnn(self) -> SplitNN:
        if self._runner is None:
            self._runner = SplitNN(self._splitnn_config(self.spec.seed))
        return self._runner

    def _run_splitnn(self) -> RunResult:
        spec = self.spec
        t0 = time.perf_counter()
        if len(spec.seeds) == 1:
            metrics, params = self._splitnn().train(return_state=True)
        else:
            # params stay None: like federated cells, a multi-seed run
            # keeps no single model for predict() to silently pick
            params = None
            f1s, accs = [], []
            for s in spec.seeds:
                m = SplitNN(self._splitnn_config(s)).train()
                f1s.append(m["f1"]), accs.append(m["acc"])
            metrics = {"f1": float(np.mean(f1s)),
                       "acc": float(np.mean(accs)),
                       "f1_std": float(np.std(f1s)),
                       "f1_per_seed": f1s, "acc_per_seed": accs,
                       "seeds": list(spec.seeds)}
        wall = time.perf_counter() - t0
        return self._result(metrics, [], params,
                            Telemetry(wall_s=wall))


def build(spec: ExperimentSpec) -> Session:
    """The front door: one validated spec -> one runnable Session."""
    return Session(spec)


# ---------------------------------------------------------------------------
# spec grids
# ---------------------------------------------------------------------------
# grid cells must agree on everything but (dataset, mode, transform,
# fault, schedule, n_clients): they share one compiled round function
# per (dataset, mode) group (transform, fault, schedule and count are
# vmapped lane axes)
_GRID_COMMON = ("seeds", "rounds", "epochs", "batch_size", "lr",
                "exchange_at", "fedavg", "engine", "first_layer",
                "n_samples", "shard", "obs")


def spec_grid(datasets=("mnist", "fmnist", "titanic", "bank"),
              modes=("devertifl", "non_federated", "verticomb"),
              client_counts=(2, 3, 5), seeds=(0, 1, 2),
              schedules=("sync",), faults=("none",),
              transforms=("none",), **common):
    """The cartesian datasets x modes x transforms x faults x
    schedules x client_counts spec grid (the axes the paper's Table 2
    varies, plus the PR 5 exchange-schedule axis, the PR 7 fault axis
    and the PR 9 wire-transform axis -- staleness-, fault- and
    compression-tolerance grids are spec grids too).  ``common``
    forwards to every ExperimentSpec (rounds=, epochs=,
    first_layer=, ...)."""
    return tuple(
        ExperimentSpec(dataset=ds, mode=mode, n_clients=nc, seeds=seeds,
                       schedule=sched, fault=f, transform=t, **common)
        for ds in datasets for mode in modes for t in transforms
        for f in faults for sched in schedules for nc in client_counts)


def _grid_groups(specs):
    """Group a spec sequence by (dataset, mode) preserving order, after
    validating grid homogeneity.  Returns [((ds, entry), [spec, ...])]."""
    specs = list(specs)
    if not specs:
        raise ValueError("empty spec grid")
    for s in specs:
        if not isinstance(s, ExperimentSpec):
            raise TypeError(f"spec grids hold ExperimentSpec items, got "
                            f"{type(s).__name__}")
        for f in _GRID_COMMON:
            if getattr(s, f) != getattr(specs[0], f):
                raise ValueError(
                    f"grid specs must agree on {f!r} (they share one "
                    f"compiled round per dataset x mode): "
                    f"{getattr(s, f)!r} != {getattr(specs[0], f)!r}")
        if s.engine != "scan":
            raise ValueError("grids run on the vmapped sweep engine "
                             "(engine='scan')")
        if s.max_clients is not None:
            raise ValueError("grids pad the client axis automatically; "
                             "leave max_clients=None")
        if get_mode(s.mode).kind != "federated":
            raise ValueError(f"mode {s.mode!r} is not a federated mode; "
                             "grids run federated cells (run splitnn "
                             "rows as standalone sessions)")
    groups = {}
    for s in specs:
        gk = (s.dataset, s.mode)
        g = groups.setdefault(gk, [])
        if any(p.n_clients == s.n_clients and p.schedule == s.schedule
               and p.fault == s.fault and p.transform == s.transform
               for p in g):
            raise ValueError(f"duplicate grid cell {s.dataset}/{s.mode}/"
                             f"{s.transform}/{s.fault}/{s.schedule}/"
                             f"{s.n_clients}")
        g.append(s)
    return list(groups.items())


def _group_axes(group):
    """Ordered-unique (client_counts, schedules, faults, transforms)
    of one (dataset, mode) spec group; the group must cover the full
    transform x fault x schedule x count cartesian (every lane reuses
    one padded count batch)."""
    counts, schedules, faults, transforms = [], [], [], []
    for s in group:
        if s.n_clients not in counts:
            counts.append(s.n_clients)
        if s.schedule not in schedules:
            schedules.append(s.schedule)
        if s.fault not in faults:
            faults.append(s.fault)
        if s.transform not in transforms:
            transforms.append(s.transform)
    want = {(t, f, sc, nc) for t in transforms for f in faults
            for sc in schedules for nc in counts}
    got = {(s.transform, s.fault, s.schedule, s.n_clients)
           for s in group}
    if got != want or len(group) != len(want):
        raise ValueError(
            f"spec grid group {group[0].dataset}/{group[0].mode} must "
            f"cover the full transform x fault x schedule x "
            f"client-count cartesian {sorted(want)}; got {sorted(got)}")
    return (tuple(counts), tuple(schedules), tuple(faults),
            tuple(transforms))


def sweep_config_for_specs(specs):
    """One (dataset, mode) spec group -> (dataset, internal_mode,
    SweepConfig) for ``sweep.run_padded_cells``."""
    groups = _grid_groups(specs)
    if len(groups) != 1:
        raise ValueError(
            f"expected one (dataset, mode) group, got "
            f"{[f'{ds}/{m}' for (ds, m), _ in groups]}; use "
            "repro.api.run_grid for multi-group spec grids")
    (ds, mode), group = groups[0]
    counts, schedules, faults, transforms = _group_axes(group)
    return ds, get_mode(mode).internal, _sweep_config(
        group[0], counts, schedules, faults, transforms)


def run_grid(specs, shard=None):
    """Run a spec grid: one padded, sharded lane batch per (dataset,
    mode) group -- exactly ``sweep.run_grid``'s execution and schema
    ({"cells": {"ds/mode/n": cell}, "compare": ...}), with each cell
    additionally stamped with the ``spec_hash`` of the spec that
    produced it.  A non-default schedule axis inserts the schedule
    into the keys ("ds/mode/sched/n"), a non-default fault axis
    prepends the fault plan ("ds/mode/fault/sched/n"), and a
    non-default transform axis prepends the wire spec on top
    ("ds/mode/transform/fault/sched/n"); sync-only fault-free
    transform-free grids keep the historical keys.  ``shard``
    overrides the specs' shard policy."""
    cells, compare = {}, {}
    for (ds, mode), group in _grid_groups(specs):
        counts, schedules, faults, transforms = _group_axes(group)
        out = SW.run_padded_cells(
            ds, get_mode(mode).internal,
            _sweep_config(group[0], counts, schedules, faults,
                          transforms),
            shard=group[0].shard if shard is None else shard)
        sync_only = schedules == ("sync",)
        none_only = faults == ("none",)
        wire_none = transforms == ("none",)
        for s in group:
            if not wire_none:
                ck = (f"{s.transform}/{s.fault}/{s.schedule}/"
                      f"{s.n_clients}")
            elif not none_only:
                ck = f"{s.fault}/{s.schedule}/{s.n_clients}"
            elif not sync_only:
                ck = f"{s.schedule}/{s.n_clients}"
            else:
                ck = s.n_clients
            cell = out["cells"][ck]
            cell["spec_hash"] = s.spec_hash
            cells[f"{ds}/{mode}/{ck}"] = cell
            compare.setdefault(f"{ds}/{ck}", {})[mode] = \
                cell["f1_mean"]
    return {"cells": cells, "compare": compare}
