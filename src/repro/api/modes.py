"""The mode registry behind ``repro.api``: which training topology an
``ExperimentSpec.mode`` selects.

Built-in modes:

  devertifl          the paper's protocol -- forward-pass
                     HiddenOutputExchange, local backward, P2P FedAvg
  non_federated      isolated per-client training (no exchange); the
                     paper's lower baseline
  verticomb          VertiComb-style backward exchange: gradients flow
                     to every contributor (alias: backward_exchange)
  splitnn            centralized split learning -- client bottoms, a
                     server top over concatenated embeddings (Table II
                     literature rows)

The federated modes are thin descriptors over
``repro.core.protocol.DeVertiFL`` (``internal`` is the ProtocolConfig
mode string); ``splitnn`` wraps ``repro.core.baselines.SplitNN``.
Register a custom mode with :func:`register_mode` by supplying a
``runner`` factory ``(spec) -> runner`` where the runner implements
``run() -> (metrics, history, params, timings)`` and optionally
``predict(params, x)`` -- see docs/ARCHITECTURE.md ("Spec & registry
contracts").
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

from repro.registry import Registry

MODES = Registry("mode")


@dataclass(frozen=True)
class ModeEntry:
    name: str
    kind: str                       # "federated" | "splitnn" | "custom"
    internal: Optional[str] = None  # ProtocolConfig.mode for federated
    runner: Optional[Callable] = None   # custom: (spec) -> runner


def register_mode(name, runner=None, *, kind="custom", internal=None,
                  aliases=(), overwrite=False) -> ModeEntry:
    """Register a mode for ``ExperimentSpec.mode=name``.  Custom modes
    pass a ``runner`` factory; the built-in kinds are registered by
    this module itself."""
    if kind == "custom" and runner is None:
        raise ValueError("custom modes need a runner factory "
                         "(spec) -> runner")
    entry = ModeEntry(name=name, kind=kind, internal=internal,
                      runner=runner)
    MODES.register(name, entry, overwrite=overwrite)
    for alias in aliases:
        MODES.register(alias, entry, overwrite=overwrite)
    return entry


def get_mode(name) -> ModeEntry:
    return MODES.get(name)


def mode_names() -> list:
    return MODES.names()


register_mode("devertifl", kind="federated", internal="devertifl")
register_mode("non_federated", kind="federated", internal="non_federated")
register_mode("verticomb", kind="federated", internal="verticomb",
              aliases=("backward_exchange",))
register_mode("splitnn", kind="splitnn")
