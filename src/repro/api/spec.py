"""``ExperimentSpec`` -- the one declarative record that names a
De-VertiFL experiment: dataset x mode x client count x seeds x engine
knobs, validated eagerly against the dataset / mode / first-layer
registries so a typo fails at construction time with the registered
options in the error.

Three properties the rest of the stack rides on (tests/test_api.py):

  frozen + hashable   specs are dataclass-frozen with tuple fields, so
                      they key caches and dedupe grids.
  pytree-static       ExperimentSpec is registered as a LEAFLESS pytree
                      whose treedef carries the spec itself: passing a
                      spec through ``jax.jit`` makes it part of the
                      trace signature, so equal specs NEVER retrace and
                      different specs always do.
  stable spec_hash    ``spec.spec_hash`` is a sha256 over the canonical
                      JSON of the RESULT-DETERMINING fields -- stable
                      across processes (unlike ``hash()``, which is
                      salted).  Observation/execution knobs that
                      provably do not change trajectories
                      (``eval_every``, ``checkpoint_dir``,
                      ``checkpoint_every``, ``shard`` -- sharded ==
                      single-device exactly) are excluded, so a bench
                      row stamped with the hash is joinable to every
                      run of the same experiment.  Backend-dependent
                      knobs canonicalize at construction: mode aliases
                      resolve to their registered name and
                      ``first_layer="auto"`` to the lane this backend
                      actually runs, so one hash never labels two
                      numerically different executions.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from dataclasses import dataclass
from typing import Optional, Tuple, Union

import jax

from repro.configs import get_config
from repro.data import registry as DR

# knobs that change what is *recorded*, not what is *computed* -- kept
# out of spec_hash so observation settings don't fork experiment ids.
# "obs" belongs here by construction: taps are observation-only
# (obs="full" trajectories are bitwise obs="none" trajectories,
# tests/test_obs.py pins it), so the level must not fork ids.
HASH_EXCLUDE = ("eval_every", "checkpoint_dir", "checkpoint_every",
                "shard", "obs")

ENGINES = ("scan", "python")


@dataclass(frozen=True)
class ExperimentSpec:
    """One experiment, declaratively.  ``build(spec)`` turns it into a
    runnable :class:`repro.api.Session`."""
    dataset: str = "mnist"
    mode: str = "devertifl"
    n_clients: int = 3
    seeds: Tuple[int, ...] = (0,)
    rounds: int = 5
    epochs: int = 5
    batch_size: int = 64
    lr: float = 1e-3
    exchange_at: int = -1           # -1 logits | 0 raw input | k hidden k
    fedavg: bool = True
    engine: str = "scan"            # scan | python (reference loop)
    first_layer: str = "auto"       # auto | pallas | slice | masked | custom
    # Exchange schedule (repro.schedule spec string, validated against
    # the schedule registry and canonicalized): "sync" | "stale_k:k" |
    # "double_buffer" | "partial:p[:det]" | "stale_k:k+partial:p" |
    # a register_schedule name.  Non-sync schedules run devertifl
    # federations only.  The default "sync" is EXCLUDED from
    # spec_hash so every pre-existing sync spec keeps its id.
    schedule: str = "sync"
    # Fault plan (repro.faults spec string, validated against the
    # fault registry and canonicalized): "none" | "crash:p[:dur]" |
    # "straggle:p:d" | "corrupt:p[:nan|scale]" | '+'-compositions |
    # a register_fault name.  Non-none plans run devertifl
    # federations only.  The default "none" is EXCLUDED from
    # spec_hash so every pre-existing spec keeps its id.
    fault: str = "none"
    # Exchange transform (repro.wire spec string, validated against
    # the transform registry and canonicalized): "none" | "int8" |
    # "topk:p" | "dp:sigma" | '+'-compositions | a register_transform
    # name.  Non-none transforms run devertifl federations only.  The
    # default "none" is EXCLUDED from spec_hash so every pre-existing
    # spec keeps its id.
    transform: str = "none"
    # Observability level (repro.obs spec string, validated against
    # the obs registry): "none" | "basic" | "full" | a register_obs
    # name.  Non-none levels arm in-scan metric taps + the host span
    # tracer under devertifl federations only.  Observation-only --
    # never changes a trajectory -- so it lives in HASH_EXCLUDE.
    obs: str = "none"
    max_clients: Optional[int] = None   # pad client axis with dead slots
    shard: Union[str, bool, int] = "auto"   # grid lanes: "auto"|False|int
    n_samples: Optional[int] = None     # dataset size override (speed)
    # eval cadence in rounds; 0 = final metrics only.  Single-seed
    # sessions only: multi-seed cells always record final metrics
    # (history stays empty)
    eval_every: int = 1
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 0       # rounds between checkpoints; 0 = off

    # ------------------------------------------------------------------
    def __post_init__(self):
        # normalize seeds for hashability/UX: int -> (int,), list -> tuple
        seeds = self.seeds
        if isinstance(seeds, int):
            seeds = (seeds,)
        object.__setattr__(self, "seeds", tuple(int(s) for s in seeds))
        self._validate()

    def _validate(self):
        from repro.api.modes import get_mode
        from repro.core.protocol import FIRST_LAYERS
        entry = DR.get_dataset(self.dataset)     # raises w/ options
        mode = get_mode(self.mode)               # raises w/ options
        # canonicalize aliases (backward_exchange -> verticomb) so the
        # alias cannot fork spec_hash: same experiment, same id
        object.__setattr__(self, "mode", mode.name)
        FIRST_LAYERS.get(self.first_layer)       # raises w/ options
        from repro.schedule import get_schedule
        sched = get_schedule(self.schedule)      # raises w/ options
        # canonicalize ("stale_k" -> "stale_k:1") so formatting cannot
        # fork spec_hash; degenerate members of non-sync families
        # (stale_k:0, partial:1.0) keep their literal identity -- they
        # run the schedule engine and are proven bitwise-equal to sync
        # by test, not collapsed by aliasing
        object.__setattr__(self, "schedule", sched.spec)
        if not sched.is_sync and mode.internal != "devertifl":
            raise ValueError(
                f"schedule {sched.spec!r} requires mode='devertifl' "
                f"(the scheduled dataflow is the forward "
                f"HiddenOutputExchange); mode {self.mode!r} supports "
                "schedule='sync' only")
        from repro.faults import get_fault_plan
        plan = get_fault_plan(self.fault)        # raises w/ options
        # canonicalize ("crash:0.2:1" -> "crash:0.2") so formatting
        # cannot fork spec_hash
        object.__setattr__(self, "fault", plan.spec)
        if not plan.is_none and mode.internal != "devertifl":
            raise ValueError(
                f"fault plan {plan.spec!r} requires mode='devertifl' "
                "(faults are injected into the forward "
                f"HiddenOutputExchange); mode {self.mode!r} supports "
                "fault='none' only")
        from repro.wire import get_wire_plan
        wire = get_wire_plan(self.transform)     # raises w/ options
        # canonicalize ("dp:0.10+topk:0.5" -> "topk:0.5+dp:0.1") so
        # formatting cannot fork spec_hash
        object.__setattr__(self, "transform", wire.spec)
        if not wire.is_none and mode.internal != "devertifl":
            raise ValueError(
                f"transform {wire.spec!r} requires mode='devertifl' "
                "(the transformed dataflow is the forward "
                f"HiddenOutputExchange); mode {self.mode!r} supports "
                "transform='none' only")
        from repro.obs import get_obs_plan
        op = get_obs_plan(self.obs)              # raises w/ options
        object.__setattr__(self, "obs", op.spec)
        if not op.is_none and mode.internal != "devertifl":
            raise ValueError(
                f"obs level {op.spec!r} requires mode='devertifl' "
                "(the taps ride the exchange engine's scan carry); "
                f"mode {self.mode!r} supports obs='none' only")
        if self.first_layer == "auto":
            # resolve backend-dependent "auto" NOW so the spec (and
            # its hash) records the lane that actually runs -- two
            # backends' auto lanes are allclose, not bitwise, so one
            # hash must not label both
            from repro.core.protocol import auto_first_layer
            object.__setattr__(self, "first_layer", auto_first_layer())
        if self.engine not in ENGINES:
            raise ValueError(f"unknown engine {self.engine!r}; pick one "
                             f"of {ENGINES}")
        for name in ("n_clients", "rounds", "epochs", "batch_size"):
            if getattr(self, name) < 1:
                raise ValueError(f"{name} must be >= 1, got "
                                 f"{getattr(self, name)}")
        if not self.seeds:
            raise ValueError("seeds must be a non-empty tuple of ints")
        if self.lr <= 0:
            raise ValueError(f"lr must be > 0, got {self.lr}")
        n_hidden = get_config(entry.arch).num_layers
        if not -1 <= self.exchange_at <= n_hidden:
            raise ValueError(
                f"exchange_at={self.exchange_at} out of range for "
                f"{self.dataset!r}: -1 (logits), 0 (raw input), or "
                f"1..{n_hidden} (after hidden layer k)")
        if self.max_clients is not None and \
                self.max_clients < self.n_clients:
            raise ValueError(f"max_clients={self.max_clients} < "
                             f"n_clients={self.n_clients}")
        if not (self.shard == "auto" or self.shard is False or
                (isinstance(self.shard, int)
                 and not isinstance(self.shard, bool)
                 and self.shard >= 1)):
            raise ValueError(f"shard must be 'auto', False, or a "
                             f"positive int, got {self.shard!r}")
        if self.eval_every < 0 or self.checkpoint_every < 0:
            raise ValueError("eval_every / checkpoint_every must be >= 0")
        if self.checkpoint_every and not self.checkpoint_dir:
            raise ValueError("checkpoint_every > 0 needs checkpoint_dir")
        if len(self.seeds) > 1:
            if self.engine != "scan":
                raise ValueError(
                    "multi-seed sessions run on the vmapped sweep "
                    "engine, which only supports engine='scan'")
            if self.max_clients is not None:
                raise ValueError(
                    "max_clients is a single-session / grid knob; "
                    "multi-seed cells pad automatically via "
                    "repro.api.run_grid")
            if self.checkpoint_every:
                raise ValueError("checkpointing is only supported for "
                                 "single-seed sessions")
        if mode.kind == "splitnn" and self.checkpoint_every:
            raise ValueError("checkpointing is only supported for "
                             "federated modes")

    # ------------------------------------------------------------------
    def replace(self, **kw) -> "ExperimentSpec":
        """A new validated spec with fields replaced."""
        return dataclasses.replace(self, **kw)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @property
    def seed(self) -> int:
        """The single-session seed (first of ``seeds``)."""
        return self.seeds[0]

    def _hash(self, extra_exclude=()) -> str:
        d = {k: v for k, v in self.to_dict().items()
             if k not in HASH_EXCLUDE and k not in extra_exclude}
        # the schedule axis arrived after spec_hash shipped: the
        # default "sync" is dropped from the hashed dict so every
        # pre-existing sync spec keeps its id (bench rows stay
        # joinable across the PR); non-sync schedules fork the hash
        if d.get("schedule") == "sync":
            del d["schedule"]
        # same contract for the fault axis (PR 7): fault="none" specs
        # hash identically to pre-fault specs; non-none plans fork
        if d.get("fault") == "none":
            del d["fault"]
        # and for the wire axis (PR 9): transform="none" specs hash
        # identically to pre-wire specs; non-none transforms fork
        if d.get("transform") == "none":
            del d["transform"]
        blob = json.dumps(d, sort_keys=True, default=list)
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    @property
    def spec_hash(self) -> str:
        """Process-stable 16-hex-char id of the result-determining
        fields (see module docstring for what is excluded)."""
        return self._hash()

    @property
    def resume_hash(self) -> str:
        """Identity of the training STREAM a checkpoint belongs to:
        ``spec_hash`` minus ``rounds``, because extending a run to
        more rounds is the one legitimate cross-spec resume.  Session
        checkpoints are stamped with it so a reused checkpoint_dir
        cannot silently splice another experiment's params into this
        spec's RunResult."""
        return self._hash(extra_exclude=("rounds",))


# Leafless pytree whose treedef IS the spec: jit treats a spec argument
# as static, so equal specs hit the trace cache and unequal ones miss.
jax.tree_util.register_pytree_node(
    ExperimentSpec,
    lambda spec: ((), spec),
    lambda spec, _: spec,
)
