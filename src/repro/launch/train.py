"""Training drivers.

make_train_step: standard synchronous data+tensor-parallel step (the
single-pod baseline; the De-VertiFL input exchange runs inside the
forward pass when cfg.vfl.enabled).

make_federated_train_step: the paper's protocol at pod scale -- each pod
is a "super-client" holding its own full replica of the weights
(leading pod axis, sharded over 'pod'); local steps touch no cross-pod
collective, and every `fedavg_every` steps the replicas are FedAvg'ed
(pmean over the pod axis), exactly Algorithm 1 lines 16-19 mapped onto
the slow DCI links. See DESIGN.md section 5.

Run as a script for a real (CPU-scale) training run:
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
      --steps 100 --batch 8 --seq 256 --reduced
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import sharding as sh
from repro.configs import get_config
from repro.models import build_model
from repro.optim import adam, linear_warmup_cosine


def make_train_step(model, opt):
    def train_step(params, opt_state, step, batch):
        (loss, met), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch)
        params, opt_state, om = opt.update(grads, opt_state, params, step)
        metrics = {"loss": loss, **{k: v for k, v in met.items()},
                   **om}
        return params, opt_state, step + 1, metrics
    return train_step


def make_federated_train_step(model, opt, n_pods, fedavg_every):
    """Params/opt-state carry a leading [n_pods] axis sharded over
    'pod'. Local steps are per-pod (vmap); at round boundaries the
    replicas are averaged (the cross-pod all-reduce is the ONLY DCI
    traffic, amortized over fedavg_every steps)."""

    def local_step(params, opt_state, step, batch):
        (loss, _), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch)
        params, opt_state, _ = opt.update(grads, opt_state, params, step)
        return params, opt_state, loss

    def train_step(params_f, opt_state_f, step, batch_f):
        # batch_f leaves: [n_pods, B/n_pods, ...]
        params_f, opt_state_f, losses = jax.vmap(
            local_step, in_axes=(0, 0, None, 0))(params_f, opt_state_f,
                                                 step, batch_f)

        def fedavg(p):
            return jax.tree.map(
                lambda l: jnp.broadcast_to(l.mean(0, keepdims=True),
                                           l.shape), p)

        do_avg = (step % fedavg_every) == (fedavg_every - 1)
        params_f = jax.lax.cond(do_avg, fedavg, lambda p: p, params_f)
        return params_f, opt_state_f, step + 1, {"loss": losses.mean()}

    return train_step


# ---------------------------------------------------------------------------
def shardings_for_train(model, opt, batch_spec_tree, mesh):
    """(params, opt_state, step, batch) NamedSharding trees."""
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = sh.param_specs(params_shape)
    opt_shape = jax.eval_shape(opt.init, params_shape)
    ospecs = sh.param_specs(opt_shape)
    bspecs = sh.batch_specs(batch_spec_tree)
    if model.cfg.is_encoder_decoder and "prefix_emb" in bspecs:
        # encoder consumes frames directly (no client sharding on D)
        bspecs["prefix_emb"] = sh.logical_spec("batch", None, None)
    ns = functools.partial(sh.named_sharding_tree, mesh=mesh)
    return (ns(pspecs), ns(ospecs), None, ns(bspecs)), params_shape, \
        opt_shape


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced variant (CPU-friendly)")
    ap.add_argument("--vocab", type=int, default=0)
    args = ap.parse_args()

    if args.reduced:
        from repro.configs.reduced import reduced_config
        cfg = reduced_config(args.arch)
    else:
        cfg = get_config(args.arch)
    if args.vocab:
        cfg = cfg.replace(vocab_size=args.vocab)
    model = build_model(cfg)
    opt = adam(linear_warmup_cosine(args.lr, 10, args.steps))
    step_fn = jax.jit(make_train_step(model, opt), donate_argnums=(0, 1))

    from repro.data import markov_lm_batches
    it = markov_lm_batches(cfg.vocab_size, args.batch, args.seq)
    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    step = jnp.zeros((), jnp.int32)
    t0 = time.time()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt_state, step, m = step_fn(params, opt_state, step, batch)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss={float(m['loss']):.4f} "
                  f"({time.time()-t0:.1f}s)")
    print("done")


if __name__ == "__main__":
    main()
