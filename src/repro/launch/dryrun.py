"""Multi-pod dry-run: prove every (architecture x input shape x mesh)
combination lowers AND compiles for the production meshes, and harvest
the roofline terms from the compiled artifact.

MUST set the placeholder device count before ANY other import -- jax
locks the device count on first init.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import sharding as sh                        # noqa: E402
from repro.configs import INPUT_SHAPES, get_config      # noqa: E402
from repro.launch import specs as SP                    # noqa: E402
from repro.launch.mesh import make_production_mesh      # noqa: E402
from repro.launch.serve import make_serve_step, shardings_for_serve  # noqa: E402
from repro.launch.train import (                        # noqa: E402
    make_train_step, shardings_for_train)
from repro.models import build_model                    # noqa: E402
from repro.optim import adam                            # noqa: E402
from repro.roofline import (                            # noqa: E402
    collective_bytes_from_hlo, roofline_terms, summarize)
from repro.roofline.hlo_costs import analyze as hlo_analyze  # noqa: E402

ARCHS = [
    "qwen2-7b", "rwkv6-1.6b", "jamba-v0.1-52b", "deepseek-moe-16b",
    "llava-next-34b", "qwen1.5-0.5b", "mixtral-8x22b", "qwen1.5-4b",
    "gemma2-2b", "seamless-m4t-medium",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../benchmarks/results/dryrun")


def skip_reason(cfg, shape_name):
    s = INPUT_SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.sub_quadratic_decode:
        return ("pure full-attention arch: long_500k requires "
                "sub-quadratic attention (DESIGN.md section 4)")
    if shape_name == "long_500k" and cfg.is_encoder_decoder:
        return ("enc-dec speech model: 500k-token text decode out of "
                "family scope (DESIGN.md section 4)")
    return None


def model_step_flops(cfg, shape_name):
    """MODEL_FLOPS: 6*N_active*tokens for training, 2*N_active*tokens
    for inference (global, not per-chip)."""
    s = INPUT_SHAPES[shape_name]
    n_active = cfg.param_counts()["active"]
    if s.kind == "train":
        return 6 * n_active * s.global_batch * s.seq_len
    if s.kind == "prefill":
        return 2 * n_active * s.global_batch * s.seq_len
    return 2 * n_active * s.global_batch  # decode: one token per seq


RULE_SETS = {
    "default": None,
    # beyond-paper perf variants (EXPERIMENTS.md section Perf):
    "ep": "EP_RULES",            # expert-parallel MoE over the model axis
    "no_fsdp": "NO_FSDP",        # replicate params (small models)
    "federated": "FEDERATED_RULES",
}


def resolve_rules(name):
    if name in (None, "default"):
        return None
    if name == "ep":
        return sh.EP_RULES
    if name == "federated":
        return sh.FEDERATED_RULES
    if name == "no_fsdp":
        return sh.DEFAULT_RULES.with_overrides(embed=None)
    raise KeyError(name)


def run_one(arch, shape_name, multi_pod=False, exchange=None,
            rules=None, lr=1e-4, cfg_overrides=None):
    """Lower + compile one (arch, shape, mesh); returns a record dict."""
    t0 = time.time()
    cfg = get_config(arch)
    if exchange:
        cfg = cfg.replace(vfl=cfg.vfl.__class__(enabled=True,
                                                exchange=exchange))
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    s = INPUT_SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "exchange": cfg.vfl.exchange if cfg.vfl.enabled else "off",
        "kind": s.kind,
    }
    reason = skip_reason(cfg, shape_name)
    if reason:
        record["status"] = "skipped"
        record["reason"] = reason
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    with sh.use_context(mesh, rules):
        model = build_model(cfg)
        if s.kind == "prefill":
            # forward-only: logits + populated decode caches
            batch = SP.train_batch_spec(cfg, shape_name)
            batch.pop("labels")
            params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            pspecs = sh.param_specs(params_shape)
            bspecs = sh.batch_specs(batch)
            if cfg.is_encoder_decoder and "prefix_emb" in bspecs:
                bspecs["prefix_emb"] = sh.logical_spec("batch", None, None)
            import functools as _ft
            ns = _ft.partial(sh.named_sharding_tree, mesh=mesh)
            jitted = jax.jit(model.prefill,
                             in_shardings=(ns(pspecs), ns(bspecs)))
            lowered = jitted.lower(params_shape, batch)
        elif s.kind == "train":
            opt = adam(lr)
            batch = SP.train_batch_spec(cfg, shape_name)
            (ps, os_, _, bs), params_shape, opt_shape = \
                shardings_for_train(model, opt, batch, mesh)
            step_fn = make_train_step(model, opt)
            jitted = jax.jit(step_fn, in_shardings=(ps, os_, None, bs),
                             donate_argnums=(0, 1))
            step0 = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = jitted.lower(params_shape, opt_shape, step0, batch)
        else:
            serve_fn = make_serve_step(model)
            (ps, ss, ts), params_shape, state_shape = shardings_for_serve(
                model, s.global_batch, s.seq_len, mesh)
            jitted = jax.jit(serve_fn, in_shardings=(ps, ss, ts),
                             donate_argnums=(1,))
            tokens = jax.ShapeDtypeStruct((s.global_batch, 1), jnp.int32)
            lowered = jitted.lower(params_shape, state_shape, tokens)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        cost = compiled.cost_analysis() or {}
        try:
            mem = compiled.memory_analysis()
            mem_info = {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            }
        except Exception:
            mem_info = {}

        hlo = compiled.as_text()
        # loop-aware costs (cost_analysis counts while bodies once --
        # see repro/roofline/hlo_costs.py); raw values kept as
        # cross-checks below
        la = hlo_analyze(hlo)
        coll = la["collective_wire_bytes"]
        flops = la["flops"]
        bytes_acc = la["hbm_bytes"]
        mf = model_step_flops(cfg, shape_name) / n_chips
        rl = roofline_terms(flops, bytes_acc, coll.get("total", 0.0),
                            model_flops_per_chip=mf)
        xla_raw = {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            "collectives_unrolled_once": collective_bytes_from_hlo(hlo),
        }

        n_params = cfg.param_counts()
        record.update({
            "status": "ok",
            "n_chips": n_chips,
            "per_chip_flops": flops,
            "per_chip_bytes": bytes_acc,
            "collective_wire_bytes": coll,
            "memory_analysis": mem_info,
            "xla_cost_analysis_raw": xla_raw,
            "roofline": rl,
            "params_total": n_params["total"],
            "params_active": n_params["active"],
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
        })
    return record


def result_path(record, out_dir):
    ex = record.get("exchange", "off")
    return os.path.join(
        out_dir, f"{record['arch']}__{record['shape']}__"
                 f"{record['mesh']}__{ex}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--exchange", default=None,
                    choices=[None, "zeropad_psum", "allgather"])
    ap.add_argument("--rules", default="default",
                    choices=list(RULE_SETS))
    ap.add_argument("--remat-policy", default=None,
                    choices=[None, "save_mixer_ffn"])
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    rule_set = resolve_rules(args.rules)

    archs = ARCHS if args.arch == "all" else args.arch.split(",")
    shapes = SHAPES if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                probe = {"arch": arch, "shape": shape,
                         "mesh": "2x16x16" if mp else "16x16",
                         "exchange": args.exchange or "zeropad_psum"}
                path = result_path(probe, args.out)
                if os.path.exists(path) and not args.force:
                    with open(path) as f:
                        rec = json.load(f)
                    status = rec.get("status")
                    print(f"[cached] {arch} {shape} {probe['mesh']}: "
                          f"{status}")
                    continue
                try:
                    ov = ({"remat_policy": args.remat_policy}
                          if args.remat_policy else None)
                    rec = run_one(arch, shape, multi_pod=mp,
                                  exchange=args.exchange, rules=rule_set,
                                  cfg_overrides=ov)
                    if rec["status"] == "ok":
                        print(f"[ok {rec['compile_s']:.0f}s] "
                              + summarize(rec))
                    else:
                        print(f"[skip] {arch} {shape} {probe['mesh']}: "
                              f"{rec['reason']}")
                except Exception as e:
                    failures += 1
                    rec = dict(probe)
                    rec["status"] = "error"
                    rec["error"] = f"{type(e).__name__}: {e}"
                    rec["traceback"] = traceback.format_exc()[-4000:]
                    print(f"[FAIL] {arch} {shape} {probe['mesh']}: "
                          f"{rec['error']}")
                with open(result_path(rec, args.out), "w") as f:
                    json.dump(rec, f, indent=1, default=str)
    print(f"done; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
