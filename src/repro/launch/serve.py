"""Serving driver: batched single-token decode against a KV cache /
recurrent state (the serve_step the decode_32k / long_500k dry-run
shapes lower).

Run as a script for a real (CPU-scale, reduced-config) serving demo:
  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b \
      --batch 4 --steps 32 --reduced
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp

from repro import sharding as sh
from repro.configs import get_config
from repro.models import build_model


def make_serve_step(model):
    def serve_step(params, state, tokens):
        logits, new_state = model.decode_step(params, state, tokens)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
        return next_tok.astype(jnp.int32), new_state
    return serve_step


def shardings_for_serve(model, batch_size, seq_len, mesh):
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = sh.param_specs(params_shape)
    state_shape = jax.eval_shape(
        lambda: model.init_decode_state(batch_size, seq_len))
    sspecs = sh.state_specs(state_shape)
    import jax.numpy as jnp2
    tok_spec = sh.batch_specs(
        {"tokens": jax.ShapeDtypeStruct((batch_size, 1), jnp2.int32)}
    )["tokens"]
    ns = functools.partial(sh.named_sharding_tree, mesh=mesh)
    from jax.sharding import NamedSharding
    return (ns(pspecs), ns(sspecs), NamedSharding(mesh, tok_spec)), \
        params_shape, state_shape


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--cache", type=int, default=128)
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args()

    if args.reduced:
        from repro.configs.reduced import reduced_config
        cfg = reduced_config(args.arch)
    else:
        cfg = get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    state = model.init_decode_state(args.batch, args.cache)
    if cfg.is_encoder_decoder:
        state["enc"] = jnp.zeros((args.batch, cfg.num_prefix_embeddings,
                                  cfg.d_model), model.dtype)
    step_fn = jax.jit(make_serve_step(model), donate_argnums=(1,))
    toks = jnp.zeros((args.batch, 1), jnp.int32)
    t0 = time.time()
    out = []
    for i in range(args.steps):
        toks, state = step_fn(params, state, toks)
        out.append(toks[:, 0])
    dt = time.time() - t0
    print(f"decoded {args.steps} tokens x batch {args.batch} in {dt:.2f}s "
          f"({args.steps*args.batch/dt:.1f} tok/s)")
    print("sample:", [int(t[0]) for t in out[:8]])


if __name__ == "__main__":
    main()
