"""Production meshes (TPU v5e): single pod = (data=16, model=16) = 256
chips; multi-pod = (pod=2, data=16, model=16) = 512 chips.

make_production_mesh is a FUNCTION so importing this module never
touches jax device state (the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax
init; smoke tests see the single real CPU device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_model=1, n_data=1):
    """Tiny mesh over however many (forced) host devices exist; used by
    sharding unit tests with --xla_force_host_platform_device_count=8."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


# TPU v5e hardware constants for the roofline model (per chip)
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # B/s
ICI_BW = 50e9                  # B/s per link (assumed one active link/op)
HBM_PER_CHIP = 16 * 1024 ** 3  # bytes
