"""Federated-mode dry-run: lower the De-VertiFL production protocol at
pod scale -- each pod is a super-client with its own weight replica;
local steps touch no cross-pod collective; every `fedavg_every` steps
the replicas are FedAvg'ed (Algorithm 1 lines 16-19 on the DCI links).

Records two lowerings per arch on the (pod=2, data=16, model=16) mesh:
  standard   -- synchronous data-parallel across pods (every step pays
                the cross-pod gradient all-reduce)
  federated  -- local steps + conditional FedAvg (pmean over pod)

and reports the cross-pod wire bytes of each, i.e. the measured DCI
saving of the paper's protocol.

  PYTHONPATH=src python -m repro.launch.dryrun_federated --arch qwen1.5-0.5b
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse      # noqa: E402
import json          # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import sharding as sh                        # noqa: E402
from repro.configs import INPUT_SHAPES, get_config      # noqa: E402
from repro.launch.mesh import make_production_mesh      # noqa: E402
from repro.launch.train import (                        # noqa: E402
    make_federated_train_step, make_train_step, shardings_for_train)
from repro.models import build_model                    # noqa: E402
from repro.optim import adam                            # noqa: E402
from repro.roofline.hlo_costs import analyze            # noqa: E402

RESULTS = os.path.join(os.path.dirname(__file__),
                       "../../../benchmarks/results/federated")


def crosspod_bytes(hlo_text):
    """Collective wire bytes whose replica groups span pods (group size
    > 256 on the 512-chip mesh means the op crosses the DCI)."""
    import re
    from repro.roofline.hlo_costs import (_collective_wire,
                                          split_computations, _CALLS_RE,
                                          _TRIP_RE, _TRIP_RE2,
                                          _BRANCHES_RE)
    comps, entry = split_computations(hlo_text)
    from collections import defaultdict
    local_calls = {}
    for cname, comp in comps.items():
        calls = []
        for ins in comp.instrs:
            bm = _BRANCHES_RE.search(ins.line)
            if bm:
                for br in bm.group(1).split(","):
                    calls.append((br.strip().lstrip("%"), 1.0))
            for callee in _CALLS_RE.findall(ins.line):
                mult = 1.0
                if ins.op == "while":
                    tm = _TRIP_RE.search(ins.line) or \
                        _TRIP_RE2.search(ins.line)
                    mult = float(tm.group(1)) if tm else 1.0
                    if f"condition=%{callee}" in ins.line or \
                            f"condition={callee}" in ins.line:
                        continue
                calls.append((callee, mult))
        local_calls[cname] = calls
    mult = defaultdict(float)

    def visit(c, m):
        mult[c] += m
        for callee, cm in local_calls.get(c, []):
            if callee in comps:
                visit(callee, m * cm)
    visit(entry, 1.0)

    import numpy as np

    def spans_pods(line, pod_stride=256):
        """Materialize iota-format replica groups and check whether any
        group mixes devices from different pods (ids differing across
        the pod_stride boundary)."""
        m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\]"
                      r"(?:T\(([\d,]+)\))?", line)
        if m:
            ng, gs = int(m.group(1)), int(m.group(2))
            dims = [int(d) for d in m.group(3).split(",")]
            ids = np.arange(int(np.prod(dims))).reshape(dims)
            if m.group(4):
                perm = [int(p) for p in m.group(4).split(",")]
                ids = ids.transpose(perm)
            groups = ids.reshape(ng, gs)
            pods = groups // pod_stride
            return bool((pods.min(axis=1) != pods.max(axis=1)).any())
        m = re.search(r"replica_groups=\{\{([^=]*?)\}\}", line)
        if m:
            for grp in m.group(1).split("},{"):
                ids = [int(x) for x in grp.replace("{", "").replace(
                    "}", "").split(",") if x.strip()]
                if ids and min(ids) // pod_stride != max(ids) // pod_stride:
                    return True
        return False

    total = 0.0
    for cname, comp in comps.items():
        for ins in comp.instrs:
            cw = _collective_wire(ins)
            if not cw or cw[1] <= 0:
                continue
            if spans_pods(ins.line):
                total += cw[1] * mult[cname]
    return total


def run(arch, fedavg_every=50):
    cfg = get_config(arch)
    s = INPUT_SHAPES["train_4k"]
    mesh = make_production_mesh(multi_pod=True)
    n_pods = 2
    out = {"arch": arch, "fedavg_every": fedavg_every}

    with sh.use_context(mesh, sh.FEDERATED_RULES):
        model = build_model(cfg)
        opt = adam(1e-4)

        # ---- standard synchronous step (cross-pod grad all-reduce) ----
        batch = {"tokens": jax.ShapeDtypeStruct((s.global_batch,
                                                 s.seq_len), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((s.global_batch,
                                                 s.seq_len), jnp.int32)}
        with sh.use_context(mesh, sh.DEFAULT_RULES):
            (ps, os_, _, bs), pshape, oshape = shardings_for_train(
                model, opt, batch, mesh)
            fn = jax.jit(make_train_step(model, opt),
                         in_shardings=(ps, os_, None, bs),
                         donate_argnums=(0, 1))
            txt = fn.lower(pshape, oshape,
                           jax.ShapeDtypeStruct((), jnp.int32),
                           batch).compile().as_text()
        la = analyze(txt)
        out["standard"] = {
            "collective_total_GB": la["collective_wire_bytes"]["total"]/1e9,
            "crosspod_GB": crosspod_bytes(txt) / 1e9,
        }

        # ---- federated step (local steps + conditional pod FedAvg) ----
        params_shape = jax.eval_shape(
            lambda k: jax.vmap(model.init)(jax.random.split(k, n_pods)),
            jax.random.PRNGKey(0))
        inner = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        ispecs = sh.param_specs(inner)
        pspecs = jax.tree.map(lambda sp: P(*(("pod",) + tuple(sp))),
                              ispecs, is_leaf=lambda x: isinstance(x, P))
        opt_shape = jax.eval_shape(
            lambda p: jax.vmap(opt.init)(p), params_shape)
        oispecs = sh.param_specs(jax.eval_shape(opt.init, inner))
        ospecs = jax.tree.map(lambda sp: P(*(("pod",) + tuple(sp))),
                              oispecs, is_leaf=lambda x: isinstance(x, P))
        batch_f = {"tokens": jax.ShapeDtypeStruct(
                       (n_pods, s.global_batch // n_pods, s.seq_len),
                       jnp.int32),
                   "labels": jax.ShapeDtypeStruct(
                       (n_pods, s.global_batch // n_pods, s.seq_len),
                       jnp.int32)}
        bspec = P("pod", "data", None)
        ns = lambda t: jax.tree.map(  # noqa: E731
            lambda sp: NamedSharding(mesh, sp), t,
            is_leaf=lambda x: isinstance(x, P))
        step_fn = make_federated_train_step(model, opt, n_pods,
                                            fedavg_every)
        fed = jax.jit(step_fn,
                      in_shardings=(ns(pspecs), ns(ospecs), None,
                                    {k: NamedSharding(mesh, bspec)
                                     for k in batch_f}),
                      donate_argnums=(0, 1))
        txt_f = fed.lower(params_shape, opt_shape,
                          jax.ShapeDtypeStruct((), jnp.int32),
                          batch_f).compile().as_text()
        la_f = analyze(txt_f)
        sync_crosspod = crosspod_bytes(txt_f)
        out["federated"] = {
            "collective_total_GB":
                la_f["collective_wire_bytes"]["total"] / 1e9,
            "crosspod_sync_GB": sync_crosspod / 1e9,
            # the sync branch runs every fedavg_every steps
            "crosspod_amortized_GB_per_step":
                sync_crosspod / 1e9 / fedavg_every,
        }
        std = out["standard"]["crosspod_GB"]
        amort = out["federated"]["crosspod_amortized_GB_per_step"]
        out["dci_reduction"] = (std / amort) if amort else float("inf")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--fedavg-every", type=int, default=50)
    args = ap.parse_args()
    rec = run(args.arch, args.fedavg_every)
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, f"{args.arch}.json"), "w") as f:
        json.dump(rec, f, indent=1)
    print(json.dumps(rec, indent=1))


if __name__ == "__main__":
    main()
