"""ShapeDtypeStruct stand-ins for every model input, per (arch x input
shape): weak-type-correct, shardable, no device allocation. The dry-run
lowers against these; train.py/serve.py use the same builders for real
arrays so shapes can never diverge between dry-run and execution.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import INPUT_SHAPES


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_spec(cfg, shape_name):
    """Inputs for one train/prefill step.

    vlm: seq = prefix image tokens + text tokens (anyres tiling);
    audio: decoder sees seq_len text tokens, encoder num_prefix frames.
    """
    s = INPUT_SHAPES[shape_name]
    B, S = s.global_batch, s.seq_len
    batch = {}
    if cfg.modality == "vision_text":
        P = min(cfg.num_prefix_embeddings, S // 2)
        batch["prefix_emb"] = sds((B, P, cfg.d_model), jnp.bfloat16)
        batch["tokens"] = sds((B, S - P), jnp.int32)
        batch["labels"] = sds((B, S - P), jnp.int32)
    elif cfg.modality == "audio_text":
        batch["prefix_emb"] = sds((B, cfg.num_prefix_embeddings,
                                   cfg.d_model), jnp.bfloat16)
        batch["tokens"] = sds((B, S), jnp.int32)
        batch["labels"] = sds((B, S), jnp.int32)
    else:
        batch["tokens"] = sds((B, S), jnp.int32)
        batch["labels"] = sds((B, S), jnp.int32)
    return batch


def decode_batch_spec(cfg, shape_name):
    s = INPUT_SHAPES[shape_name]
    return {"tokens": sds((s.global_batch, 1), jnp.int32)}


def input_specs(cfg, shape_name):
    s = INPUT_SHAPES[shape_name]
    if s.kind == "decode":
        return decode_batch_spec(cfg, shape_name)
    return train_batch_spec(cfg, shape_name)


def concretize(spec_tree, seed=0):
    """Turn ShapeDtypeStructs into real arrays (for smoke runs)."""
    key = jax.random.PRNGKey(seed)

    def one(s):
        if jnp.issubdtype(s.dtype, jnp.integer):
            return jnp.zeros(s.shape, s.dtype)
        return jnp.zeros(s.shape, s.dtype)

    return jax.tree.map(one, spec_tree)
