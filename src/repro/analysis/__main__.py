"""``python -m repro.analysis`` -- the static-audit CLI (CI lane).

Audits the registered mode x schedule x first-layer grid (or an
explicit subset), prints the JSON report to stdout (or ``--out``), a
human summary to stderr, and exits 1 on any unwaived violation.

    python -m repro.analysis                       # full grid
    python -m repro.analysis --smoke               # 3-combo subset
    python -m repro.analysis --modes devertifl \
        --schedules sync stale_k:2 --first-layers slice
    python -m repro.analysis --passes taint retrace
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis.audit import ALL_PASSES, audit_combos


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static privacy/deadness/retrace audit of the "
                    "traced round function (no execution).")
    p.add_argument("--modes", nargs="+", default=None,
                   help="modes to audit (default: every registered "
                        "federated mode, deduped through aliases)")
    p.add_argument("--schedules", nargs="+", default=None,
                   help="schedule specs (default: the shipped "
                        "sync/stale_k/double_buffer/partial family; "
                        "non-sync run under devertifl only)")
    p.add_argument("--first-layers", nargs="+", default=None,
                   help="first-layer lanes (default: masked slice "
                        "pallas)")
    p.add_argument("--faults", nargs="+", default=None,
                   help="fault plan specs (default: none plus a "
                        "crash+straggle+corrupt composite; non-none "
                        "plans run under devertifl only)")
    p.add_argument("--transforms", nargs="+", default=None,
                   help="wire transform specs (default: none plus the "
                        "hot int8+dp and topk compositions; non-none "
                        "transforms run under devertifl only)")
    p.add_argument("--passes", nargs="+", default=None,
                   choices=list(ALL_PASSES),
                   help="passes to run (default: all)")
    p.add_argument("--dataset", default="mnist",
                   help="dataset to trace against (structural "
                        "contracts are dataset-polymorphic; default "
                        "mnist)")
    p.add_argument("--n-clients", type=int, default=3)
    p.add_argument("--no-lane-check", action="store_true",
                   help="skip the sweep lane-structural retrace "
                        "comparison (the slowest single check)")
    p.add_argument("--smoke", action="store_true",
                   help="minimal subset: one combo per mode, sync "
                        "schedule, slice first layer, no lane check")
    p.add_argument("--out", default=None,
                   help="write the JSON report here instead of stdout")
    p.add_argument("-q", "--quiet", action="store_true",
                   help="suppress the stderr progress/summary")
    return p


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    kw = dict(modes=args.modes, schedules=args.schedules,
              first_layers=args.first_layers, faults=args.faults,
              transforms=args.transforms,
              passes=args.passes, dataset=args.dataset,
              n_clients=args.n_clients,
              lane_check=not args.no_lane_check)
    if args.smoke:
        kw["schedules"] = args.schedules or ("sync",)
        kw["first_layers"] = args.first_layers or ("slice",)
        kw["faults"] = args.faults or ("none",)
        kw["transforms"] = args.transforms or ("none",)
        kw["lane_check"] = False

    def progress(msg):
        if not args.quiet:
            print(msg, file=sys.stderr, flush=True)

    report = audit_combos(progress=progress, **kw)
    text = report.to_json()
    if args.out:
        with open(args.out, "w") as fh:
            fh.write(text + "\n")
    else:
        print(text)
    if not args.quiet:
        print(report.summary(), file=sys.stderr)
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
