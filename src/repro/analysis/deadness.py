"""The padded-lane deadness prover (pass 2 of three).

PR 3 pinned, by runtime test, that padding the client axis to
``max_clients`` changes nothing: dead ``client_mask`` slots contribute
exact zeros to the exchange sum, the FedAvg weighting, and the loss
mean.  This pass upgrades the pin to a *static proof* over the traced
round jaxpr: a maybe-nonzero abstract interpretation (one bool per
element, ``True`` = possibly nonzero) in which the Layout's masks and
``client_mask`` are concrete constants, so every mask multiply kills
the dead slots *in the abstract domain* -- no execution, no sampling.

The engine marks each mask-weighted per-client term with a
``kind="term"`` barrier tag (see ``analysis/barrier.py``); the prover
checks the tagged value's dead slots (client-axis indices >=
``n_real``) are all-False.  The default transfer function is TOP
(all maybe-nonzero): zero-breaking ops like ``exp`` are automatically
conservative, and precision flows only through the zero-preserving
structure (mul / dot_general / shape ops) that the invariant actually
rides on.  The proof is structural: it assumes finite arithmetic
(0 * finite == 0); NaN/Inf garbage in dead parameter slots is excluded
by the padded init contract and out of scope here.
"""
from __future__ import annotations

import numpy as np

from repro.analysis import ir
from repro.analysis.barrier import TAG_PRIM_NAME
from repro.analysis.report import Finding

# f(0) == 0 holds elementwise: pattern passes through
_ZERO_PRESERVING_1 = {
    "neg", "abs", "sign", "sqrt", "cbrt", "square", "tanh", "sin",
    "tan", "asin", "atan", "sinh", "erf", "erf_inv", "log1p",
    "expm1", "stop_gradient", "copy", "convert_element_type",
    "reduce_precision", "real", "imag", "floor", "round",
}


def _shape(aval):
    return getattr(aval, "shape", ())


class DeadnessInterpreter(ir.AbstractInterpreter):
    """Maybe-nonzero propagation with dead-slot checks at term tags."""

    def __init__(self, n_real: int, n_padded: int, combo: str):
        super().__init__()
        self.n_real = int(n_real)
        self.n_padded = int(n_padded)
        self.combo = combo
        self.findings = []
        self.terms_checked = 0

    # lattice: np bool arrays, full shape
    def top(self, aval):
        return np.ones(_shape(aval), bool)

    def bottom(self, aval):
        return np.zeros(_shape(aval), bool)

    def from_concrete(self, value):
        v = ir.as_np(value)
        if not isinstance(v, np.ndarray) or v.dtype == object:
            return np.ones(getattr(v, "shape", ()), bool)
        with np.errstate(invalid="ignore"):
            return np.asarray(v != 0)

    def join(self, a, b, aval=None):
        return np.logical_or(a, b)

    def equal(self, a, b):
        return a.shape == b.shape and bool((a == b).all())

    def default(self, eqn, in_abs):
        return [self.top(ov.aval) for ov in eqn.outvars]

    def _collapse_for_default(self, a):
        return np.asarray(a.any())

    def _retop(self, a, aval):
        return np.broadcast_to(np.asarray(a).any(),
                               _shape(aval)).copy()

    def enter_xs(self, a, aval):
        out = a.any(axis=0) if a.ndim else a
        return np.broadcast_to(out, _shape(aval)).copy()

    def stack_ys(self, a, aval):
        return np.broadcast_to(a, _shape(aval)).copy()

    # ------------------------------------------------------------------
    def rule(self, eqn, in_abs, in_conc):
        name = eqn.primitive.name
        out_shape = _shape(eqn.outvars[0].aval)

        if name == TAG_PRIM_NAME:
            self._check_tag(eqn, in_abs[0])
            return [in_abs[0]]

        if name in _ZERO_PRESERVING_1:
            return [in_abs[0]]
        if name == "integer_pow":
            return [in_abs[0]] if eqn.params.get("y", 1) > 0 else None
        if name == "mul":
            return [np.logical_and(in_abs[0], in_abs[1])]
        if name == "div":
            return [in_abs[0].copy()]
        if name in ("add", "sub", "add_any", "max", "min", "rem",
                    "atan2", "nextafter"):
            return [np.logical_or(in_abs[0], in_abs[1])]
        if name == "select_n":
            out = np.zeros(out_shape, bool)
            for a in in_abs[1:]:
                out |= a
            return [out]
        if name == "clamp":
            return [in_abs[0] | in_abs[1] | in_abs[2]]
        if name in ("reduce_sum", "reduce_max", "reduce_min",
                    "reduce_prod", "reduce_or", "reduce_and"):
            axes = eqn.params["axes"]
            return [np.asarray(in_abs[0].any(axis=tuple(axes)))]
        if name == "broadcast_in_dim":
            bdims = eqn.params["broadcast_dimensions"]
            mid = [1] * len(out_shape)
            for i, d in enumerate(bdims):
                mid[d] = in_abs[0].shape[i]
            return [np.broadcast_to(in_abs[0].reshape(mid),
                                    out_shape).copy()]
        if name == "reshape":
            if eqn.params.get("dimensions") is not None:
                return None
            return [in_abs[0].reshape(out_shape)]
        if name == "transpose":
            return [np.transpose(in_abs[0],
                                 eqn.params["permutation"]).copy()]
        if name in ("squeeze", "expand_dims"):
            return [in_abs[0].reshape(out_shape)]
        if name == "rev":
            return [np.flip(in_abs[0],
                            eqn.params["dimensions"]).copy()]
        if name == "slice":
            sl = tuple(slice(s, l, (st if st else 1)) for s, l, st in
                       zip(eqn.params["start_indices"],
                           eqn.params["limit_indices"],
                           eqn.params.get("strides")
                           or [1] * len(out_shape)))
            return [in_abs[0][sl].copy()]
        if name == "concatenate":
            return [np.concatenate(in_abs,
                                   axis=eqn.params["dimension"])]
        if name == "pad":
            return [self._pad(in_abs, eqn, out_shape)]
        if name == "dynamic_slice":
            return [self._dynamic_slice(in_abs, in_conc, eqn)]
        if name == "dynamic_update_slice":
            return [self._dynamic_update_slice(in_abs, in_conc, eqn)]
        if name == "dot_general":
            return [self._dot_general(in_abs, eqn)]
        if name == "gather":
            return self._via_bind(eqn, in_abs, in_conc)
        return None

    def _pad(self, in_abs, eqn, out_shape):
        a, padv = in_abs
        out = np.broadcast_to(np.asarray(padv).any(),
                              out_shape).copy()
        idx = []
        src = []
        for dim, (lo, hi, interior) in enumerate(
                eqn.params["padding_config"]):
            n = a.shape[dim]
            pos = lo + np.arange(n) * (interior + 1)
            keep = (pos >= 0) & (pos < out_shape[dim])
            idx.append(pos[keep])
            src.append(np.nonzero(keep)[0])
        out[np.ix_(*idx)] = a[np.ix_(*src)]
        return out

    def _dynamic_slice(self, in_abs, in_conc, eqn):
        a = in_abs[0]
        sizes = eqn.params["slice_sizes"]
        starts = in_conc[1:]
        if all(s is not None for s in starts):
            sl = tuple(
                slice(int(np.clip(int(s), 0, dim - sz)),
                      int(np.clip(int(s), 0, dim - sz)) + sz)
                for s, sz, dim in zip(starts, sizes, a.shape))
            return a[sl].copy()
        # unknown start: union over all windows per sliced axis
        out = a
        for k, sz in enumerate(sizes):
            if sz == a.shape[k]:
                continue
            windows = [np.take(out, range(s, s + sz), axis=k)
                       for s in range(a.shape[k] - sz + 1)]
            out = np.logical_or.reduce(windows)
        return out.copy()

    def _dynamic_update_slice(self, in_abs, in_conc, eqn):
        a, upd = in_abs[0], in_abs[1]
        starts = in_conc[2:]
        out = a.copy()
        if all(s is not None for s in starts):
            sl = tuple(
                slice(int(np.clip(int(s), 0, dim - usz)),
                      int(np.clip(int(s), 0, dim - usz)) + usz)
                for s, usz, dim in zip(starts, upd.shape, a.shape))
            out[sl] |= upd
            return out
        return np.logical_or(out, upd.any())

    def _dot_general(self, in_abs, eqn):
        (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
        lhs, rhs = in_abs[0], in_abs[1]
        letters = iter("abcdefghijklmnopqrstuvwxyz")
        l_sub = [None] * lhs.ndim
        r_sub = [None] * rhs.ndim
        for dl, dr in zip(lb, rb):
            c = next(letters)
            l_sub[dl] = r_sub[dr] = c
        for dl, dr in zip(lc, rc):
            c = next(letters)
            l_sub[dl] = r_sub[dr] = c
        for i in range(lhs.ndim):
            if l_sub[i] is None:
                l_sub[i] = next(letters)
        for i in range(rhs.ndim):
            if r_sub[i] is None:
                r_sub[i] = next(letters)
        out_sub = ([l_sub[d] for d in lb]
                   + [l_sub[d] for d in range(lhs.ndim)
                      if d not in lb and d not in lc]
                   + [r_sub[d] for d in range(rhs.ndim)
                      if d not in rb and d not in rc])
        spec = (f"{''.join(l_sub)},{''.join(r_sub)}"
                f"->{''.join(out_sub)}")
        counts = np.einsum(spec, lhs.astype(np.int64),
                           rhs.astype(np.int64))
        return counts > 0

    def _via_bind(self, eqn, in_abs, in_conc):
        """Execute the op on the bool pattern itself (int8-cast) when
        its non-pattern operands are concrete -- exact for gather."""
        if any(c is None for c in in_conc[1:]):
            return None
        try:
            vals = [in_abs[0].astype(np.int8)] + list(in_conc[1:])
            outs = ir.eval_eqn(eqn, vals)
            return [np.asarray(o) > 0 for o in outs]
        except Exception:
            return None

    # ------------------------------------------------------------------
    def _check_tag(self, eqn, pattern):
        if eqn.params["kind"] != "term":
            return
        ca = eqn.params.get("client_axis")
        if ca is None or ca >= pattern.ndim \
                or pattern.shape[ca] != self.n_padded:
            return
        self.terms_checked += 1
        if self.n_real >= self.n_padded:
            return
        dead = pattern.take(range(self.n_real, self.n_padded), axis=ca)
        if dead.any():
            bad = int(np.nonzero(dead.reshape(dead.shape[0], -1)
                                 .any(axis=1))[0][0]) + self.n_real
            path, e = self._path, eqn
            self.findings.append(Finding(
                "deadness", "unproven-dead-slot", self.combo,
                f"dead client slot {bad} of the tagged "
                f"{eqn.params['channel']!r} term is not provably zero",
                chain=(ir.eqn_line(e, path),)))


def run_deadness(closed_jaxpr, in_abs, combo, n_real, n_padded):
    """Prove dead-slot zeros over a traced round.  Returns findings."""
    interp = DeadnessInterpreter(n_real, n_padded, combo)
    interp.run(closed_jaxpr, in_abs)
    findings = list(interp.findings)
    if interp.terms_checked == 0:
        findings.append(Finding(
            "deadness", "no-terms-observed", combo,
            "no mask-weighted term tags were observed in the traced "
            "round; deadness instrumentation is not wired into this "
            "path", severity="warning"))
    return findings
