"""Trace-and-audit orchestration: build a federation, trace its round
function ONCE with ``jax.make_jaxpr`` (no execution), and drive the
taint / deadness / retrace passes over the IR.

The harness closes over everything the passes treat as *known* -- the
round key, the labels, and the LayoutArrays -- so they arrive as jaxpr
constants the interpreters can fold (concrete masks, offsets, and
permutations are what keep the per-slot taint refinement alive), while
the carried state (params, optimizer state, schedule state) and the
feature matrix stay arguments so they can be seeded per client slot.

Seeding encodes the induction hypothesis "round inputs are already
separated": client slot i's params/opt/schedule leaves carry taint bit
i, feature column c carries the bit of the client that owns it, and
the audited theorem is that one round preserves that separation --
slot j's outputs carry only bit j plus declassified channel content.
A clean round therefore composes to a clean training run.

Tracing uses a deliberately tiny dataset slice (the jaxpr is
data-size-polymorphic in everything the passes check; a 2-batch scan
exercises the same equations as a 200-batch one) so the full
mode x schedule x first-layer grid audits in seconds.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.analysis import deadness as DN
from repro.analysis import retrace as RT
from repro.analysis import taint as TA
from repro.analysis.barrier import audit_tracing
from repro.analysis.report import AnalysisReport, apply_waivers
from repro.core.protocol import (DeVertiFL, ProtocolConfig,
                                 make_round_fn, resolve_first_layer)

ALL_PASSES = ("taint", "deadness", "retrace")

# trace-size overrides: the audit proves structural contracts, which
# are invariant to dataset/batch size -- small sizes keep the grid fast
_TRACE_KW = dict(n_samples=32, batch_size=16, epochs=1, rounds=1)


def _as_pcfg(spec) -> ProtocolConfig:
    """Accept a ProtocolConfig or a repro.api ExperimentSpec."""
    if isinstance(spec, ProtocolConfig):
        return spec
    from repro.api.modes import get_mode          # lazy: api > analysis
    from repro.api.session import _protocol_config
    return _protocol_config(spec, get_mode(spec.mode).internal)


def combo_name(pcfg: ProtocolConfig) -> str:
    name = f"{pcfg.mode}/{pcfg.schedule}/{resolve_first_layer(pcfg)}"
    fault = getattr(pcfg, "fault", "none")
    if fault != "none":
        name = f"{name}/{fault}"
    transform = getattr(pcfg, "transform", "none")
    return name if transform == "none" else f"{name}/{transform}"


# ---------------------------------------------------------------------------
# the trace harness
# ---------------------------------------------------------------------------
class TracedRound:
    """One federation's round function as a ClosedJaxpr plus the
    leaf/aval bookkeeping the passes need."""

    def __init__(self, pcfg: ProtocolConfig):
        self.pcfg = pcfg
        self.combo = combo_name(pcfg)
        fed = DeVertiFL(pcfg)
        self.fed = fed
        self.n_train = len(fed.xtr)
        self.n_real = fed.layout.n_real
        self.n_padded = fed.layout.n_clients
        self.round_fn = make_round_fn(fed.model, fed.opt, pcfg,
                                      self.n_train, layout=fed.layout,
                                      sched_impl=fed._impl)
        params = fed.init_params(jax.random.PRNGKey(pcfg.seed))
        opt_state = jax.vmap(fed.opt.init)(params)
        sched_state = fed.init_sched_state()
        self.args = (params, opt_state, sched_state, fed._xtr)
        step0 = jnp.zeros((), jnp.int32)
        key = jax.random.fold_in(jax.random.PRNGKey(pcfg.seed), 1)
        ytr, lay = fed._ytr, fed._lay

        def harness(params, opt_state, sched_state, xtr):
            return self.round_fn(params, opt_state, step0, sched_state,
                                 key, xtr, ytr, lay)

        with audit_tracing():
            self.jaxpr, self.out_shape = jax.make_jaxpr(
                harness, return_shape=True)(*self.args)

    # -- leaf walks ----------------------------------------------------
    def _groups(self, tree):
        """Flatten a tuple-of-groups pytree into (group_idx, label,
        leaf) rows aligned with the jaxpr in/outvars."""
        rows = []
        for (path, leaf) in jax.tree_util.tree_flatten_with_path(
                tree)[0]:
            gi = path[0].idx
            rows.append((gi, jax.tree_util.keystr(path), leaf))
        return rows

    def _client_axis(self, shape) -> Optional[int]:
        """The stacked-client axis of a state leaf, by shape: params /
        opt leaves are [n, ...] (axis 0); schedule buffers are
        [n, B, W] (axis 0) or ring-stacked [depth, n, B, W] (axis
        ndim-3).  None for scalars / client-free leaves."""
        nd = len(shape)
        if nd >= 3 and shape[nd - 3] == self.n_padded:
            return nd - 3
        if nd >= 1 and shape[0] == self.n_padded:
            return 0
        return None

    def taint_seeds(self):
        """Input taints aligned with the jaxpr invars: state leaves
        per-slot on their client axis, features per-column by owner."""
        slot_bits = np.array([np.int64(1) << i
                              for i in range(self.n_padded)])
        in_abs = []
        for gi, label, leaf in self._groups(self.args):
            if gi == 3:       # xtr [n_train, F]: per-column ownership
                col = np.zeros(leaf.shape[1], np.int64)
                lo = self.fed.layout
                for i, (off, sz) in enumerate(zip(lo.offsets, lo.sizes)):
                    col[off:off + sz] |= np.int64(1) << i
                in_abs.append(TA.perslot(1, col))
                continue
            ax = self._client_axis(leaf.shape)
            if ax is None:
                in_abs.append(TA.EMPTY)
            else:
                in_abs.append(TA.perslot(ax, slot_bits))
        return in_abs

    def out_specs(self):
        """Per-outvar separation contract: carried state must stay
        per-slot on its client axis; the step counter and the scalar
        loss stream are aggregate telemetry, excluded by contract
        (docs/ARCHITECTURE.md section 8)."""
        specs = []
        names = ("params", "opt_state", "step_idx", "sched_state",
                 "losses")
        for gi, label, leaf in self._groups(self.out_shape):
            label = f"{names[gi]}{label[len(f'[{gi}]'):]}"
            if gi in (2, 4):
                specs.append(("skip", None, label))
                continue
            ax = self._client_axis(leaf.shape)
            if ax is None:
                specs.append(("skip", None, label))
            else:
                specs.append(("perslot", ax, label))
        return specs


# ---------------------------------------------------------------------------
# the audit
# ---------------------------------------------------------------------------
def audit(spec, passes: Optional[Sequence[str]] = None,
          lane_check: bool = True) -> AnalysisReport:
    """Statically audit one experiment's round function.

    ``spec`` is a repro.api ExperimentSpec or a ProtocolConfig; its
    training-size knobs are shrunk for tracing (the audited structure
    is size-polymorphic).  ``passes`` selects from
    ``("taint", "deadness", "retrace")`` (default: all).
    ``lane_check=False`` skips the retrace pass's lane-structural
    comparison (the expensive half; the CLI grid runs it once, not per
    combo).  Returns an :class:`AnalysisReport`; ``report.ok`` is the
    CI gate.
    """
    pcfg = _as_pcfg(spec).replace(**_TRACE_KW)
    passes = tuple(passes or ALL_PASSES)
    bad = set(passes) - set(ALL_PASSES)
    if bad:
        raise ValueError(f"unknown pass(es) {sorted(bad)}; "
                         f"choose from {ALL_PASSES}")
    report = AnalysisReport(combos=(combo_name(pcfg),),
                            passes_run=passes)
    tr = TracedRound(pcfg)

    if "taint" in passes:
        findings, channels = TA.run_taint(
            tr.jaxpr, tr.taint_seeds(), tr.out_specs(), tr.combo,
            tr.n_padded)
        report.findings.extend(findings)
        for ch, n in channels.items():
            report.channels[ch] = report.channels.get(ch, 0) + n

    if "deadness" in passes:
        # prove dead-slot zeros on a PADDED twin: an unpadded config
        # has no dead slots, so the proof obligation is the padded
        # variant every sweep lane actually runs
        if tr.n_real < tr.n_padded:
            twin = tr
        else:
            twin = TracedRound(
                pcfg.replace(max_clients=pcfg.n_clients + 1))
        in_abs = [np.ones(v.aval.shape, bool)
                  for v in twin.jaxpr.jaxpr.invars]
        report.findings.extend(DN.run_deadness(
            twin.jaxpr, in_abs, tr.combo, twin.n_real, twin.n_padded))

    if "retrace" in passes:
        report.findings.extend(RT.run_retrace(tr))
        if lane_check:
            report.findings.extend(RT.run_lane_check(pcfg.dataset))
        _stamp_traces(report)

    apply_waivers(report.findings)
    return report


def _stamp_traces(report: AnalysisReport):
    """static_round_traces == 1 iff the retrace pass ran and proved
    clean -- the static counterpart of the runtime ``round_traces``
    counter the sweep tests pin."""
    bad = any(f.pass_name == "retrace" and f.severity == "error"
              and not f.waived for f in report.findings)
    report.static_round_traces = 0 if bad else 1


def default_combos(modes=None, schedules=None, first_layers=None,
                   faults=None, transforms=None):
    """The registered mode x schedule x first-layer x fault x
    transform grid the CI lane audits: every federated mode (deduped
    through registry aliases), the shipped schedule families, the
    three built-in first-layer lanes ("auto" dedupes to its backend
    resolution), and -- for devertifl, the only mode faults and
    transforms inject into -- a composite fault plan exercising all
    three fault kinds plus the guard, and the hot wire transforms
    (repro.wire).  The fault and transform axes multiply schedules,
    not first layers (injection, guard and codec sit in the exchange,
    which is first-layer-agnostic), to keep the grid small; one
    combo per transform also chains the composite fault (the deepest
    engine chain: schedule -> fault -> wire)."""
    from repro.api.modes import MODES, get_mode
    if modes is None:
        seen = {}
        for name in MODES.names():
            m = get_mode(name)
            if m.kind == "federated" and m.internal not in seen:
                seen[m.internal] = m.internal
        modes = tuple(seen)
    if schedules is None:
        schedules = ("sync", "stale_k:2", "double_buffer",
                     "partial:0.5:det", "stale_k:1+partial:0.5")
    if first_layers is None:
        first_layers = ("masked", "slice", "pallas")
    if faults is None:
        faults = ("none", "crash:0.2:2+straggle:0.5:2+corrupt:0.05")
    if transforms is None:
        transforms = ("none", "int8+dp:0.1", "topk:0.5")
    combos = []
    for mode in modes:
        scheds = schedules if mode == "devertifl" else ("sync",)
        fts = faults if mode == "devertifl" else ("none",)
        wts = transforms if mode == "devertifl" else ("none",)
        fls, seen_fl = [], set()
        for fl in first_layers:
            r = resolve_first_layer(ProtocolConfig(mode=mode,
                                                   first_layer=fl))
            if r not in seen_fl:
                seen_fl.add(r)
                fls.append(fl)
        combos.extend((mode, sc, fl, "none", "none")
                      for sc in scheds for fl in fls)
        combos.extend((mode, sc, fls[0], ft, "none")
                      for ft in fts if ft != "none" for sc in scheds)
        combos.extend((mode, sc, fls[0], "none", t)
                      for t in wts if t != "none" for sc in scheds)
        combos.extend((mode, scheds[0], fls[0], ft, t)
                      for t in wts if t != "none"
                      for ft in fts if ft != "none")
    return combos


def audit_combos(modes=None, schedules=None, first_layers=None,
                 passes: Optional[Sequence[str]] = None,
                 dataset: str = "mnist", n_clients: int = 3,
                 lane_check: bool = True, faults=None,
                 transforms=None, progress=None) -> AnalysisReport:
    """Audit every registered mode x schedule x first-layer x fault x
    transform combination (the CI ``analysis`` lane).  The
    lane-structural retrace check runs ONCE for the grid (it compares
    sweep lane batches, which are per-dataset, not per-combo).
    Returns one merged report."""
    report = AnalysisReport()
    combos = default_combos(modes, schedules, first_layers, faults,
                            transforms)
    for i, (mode, sched, fl, fault, transform) in enumerate(combos):
        pcfg = ProtocolConfig(dataset=dataset, n_clients=n_clients,
                              mode=mode, schedule=sched, first_layer=fl,
                              fault=fault, transform=transform)
        if progress:
            progress(f"[{i + 1}/{len(combos)}] {combo_name(pcfg)}")
        report.merge(audit(pcfg, passes=passes, lane_check=False))
    if lane_check and "retrace" in (passes or ALL_PASSES):
        report.findings.extend(RT.run_lane_check(dataset))
        apply_waivers(report.findings)
    if "retrace" in (passes or ALL_PASSES):
        _stamp_traces(report)
    return report
