"""The privacy-flow taint audit (pass 1 of three).

Lattice.  A :class:`Taint` over-approximates which clients' RAW
features may have influenced each array:

  * ``Taint(None, bits)``   -- *uniform*: every element may carry the
    client sources in the ``bits`` bitmask (bit i = client i).
  * ``Taint(axis, bits[])`` -- *per-slot*: along one distinguished
    axis (the stacked client axis, or the canonical feature-column
    axis), slot s carries only ``bits[s]``.

Per-slot structure is what makes the audit decidable on this engine:
every client lives on one vmapped axis of the same stacked arrays, so
a taint domain without an axis-indexed refinement would collapse to
"everything touches everything" at the first stack.  Three mechanisms
keep the refinement alive through a real round trace:

  1. constant folding (ir.AbstractInterpreter): Layout offsets, masks,
     permutations, and PRNG keys are jaxpr constants, so
     ``dynamic_slice`` starts and gather indices are concrete;
  2. structural rules: dot_general preserves batch dims, slice/pad/
     concat/dynamic_update_slice move bits between slots explicitly;
  3. zero-pattern refinement: multiplying a uniform-per-column taint by
     a concrete block-diagonal client mask yields a PER-SLOT taint --
     the masked first layer's ``xb[None] * masks[:, None, :]`` is
     exactly this shape.

Declassification.  The engine marks its declared channels with the
:mod:`repro.analysis.barrier` tag primitive; a ``kind="declass"`` tag
clears client-source bits (the hidden-output exchange and the FedAvg
mean ARE the protocol -- the audit's theorem is that nothing else
crosses).  The audited contract per round output: client slot j's
parameters, optimizer state, and schedule state may carry only bit j
(its own raw features) plus declassified content.  One round suffices
by induction: inputs are seeded per-slot, so a clean round composes.

On violation the pass reports the offending equation chain, walked
backward through recorded def-sites following the leaking bit.
"""
from __future__ import annotations

import numpy as np

from jax import core as jcore

from repro.analysis import ir
from repro.analysis.barrier import TAG_PRIM_NAME
from repro.analysis.report import Finding


class Taint:
    """Client-source bitmask, uniform or refined along one axis."""
    __slots__ = ("axis", "bits")

    def __init__(self, axis, bits):
        self.axis = axis
        self.bits = bits if axis is None else np.asarray(bits, np.int64)

    def __repr__(self):
        if self.axis is None:
            return f"Taint({self.bits:#x})"
        return f"Taint(axis={self.axis}, bits={self.bits.tolist()})"


EMPTY = Taint(None, 0)


def uniform(bits: int) -> Taint:
    return EMPTY if bits == 0 else Taint(None, int(bits))


def perslot(axis: int, bits) -> Taint:
    return Taint(int(axis), bits)


def collapse(t: Taint) -> int:
    if t.axis is None:
        return t.bits
    return int(np.bitwise_or.reduce(t.bits)) if t.bits.size else 0


def is_empty(t: Taint) -> bool:
    return collapse(t) == 0


def is_mixed(t) -> bool:
    """True when some element carries MORE than one client bit -- the
    signature of cross-client mixing.  Per-slot taints with one owner
    bit per slot (a clean per-client stack, or per-column feature
    ownership) are not mixed."""
    if t is None or is_empty(t):
        return False
    bits = np.ravel(t.bits) if t.axis is not None else [t.bits]
    return any(int(b) & (int(b) - 1) for b in bits)


def _or_into(bits_arr, extra: int):
    return bits_arr if extra == 0 else bits_arr | np.int64(extra)


def join(a: Taint, b: Taint) -> Taint:
    if a.axis is None and b.axis is None:
        return uniform(a.bits | b.bits)
    if a.axis is None:
        return perslot(b.axis, _or_into(b.bits, a.bits))
    if b.axis is None:
        return perslot(a.axis, _or_into(a.bits, b.bits))
    if a.axis == b.axis and a.bits.shape == b.bits.shape:
        return perslot(a.axis, a.bits | b.bits)
    return uniform(collapse(a) | collapse(b))


# single-operand, shape-preserving: taint passes through untouched
_PASSTHROUGH = {
    "exp", "log", "log1p", "expm1", "tanh", "logistic", "sin", "cos",
    "tan", "asin", "acos", "atan", "sinh", "cosh", "erf", "erfc",
    "erf_inv", "neg", "sign", "floor", "ceil", "round", "abs", "sqrt",
    "rsqrt", "cbrt", "square", "integer_pow", "not", "is_finite",
    "convert_element_type", "stop_gradient", "copy", "real", "imag",
    "conj", "reduce_precision", "population_count", "clz",
    "logistic", "exp2",
}

# n-ary elementwise (equal shapes in jaxpr IR; scalars pre-broadcast)
_ELEMENTWISE_N = {
    "add", "sub", "mul", "div", "rem", "max", "min", "pow", "atan2",
    "and", "or", "xor", "shift_left", "shift_right_logical",
    "shift_right_arithmetic", "eq", "ne", "lt", "le", "gt", "ge",
    "nextafter", "add_any", "select_n", "clamp", "igamma", "igammac",
    "complex",
}

_REDUCES = {"reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
            "reduce_and", "reduce_or", "reduce_xor", "argmax", "argmin"}


class TaintInterpreter(ir.AbstractInterpreter):
    """Forward taint propagation with def-site provenance."""

    def __init__(self, n_slots_hint=0):
        super().__init__()
        self.all_bits = (1 << max(n_slots_hint, 1)) - 1
        self.channels = {}        # channel name -> tag count
        self.blame = {}           # var -> (path, eqn) that introduced
        #                           multi-client mixing in its lineage

    # lattice
    def top(self, aval):
        return uniform(self.all_bits)

    def bottom(self, aval):
        return EMPTY

    def from_concrete(self, value):
        return EMPTY

    def join(self, a, b, aval=None):
        return join(a, b)

    def equal(self, a, b):
        if a.axis is None and b.axis is None:
            return a.bits == b.bits
        if a.axis is None or b.axis is None:
            return False
        return (a.axis == b.axis and a.bits.shape == b.bits.shape
                and bool((a.bits == b.bits).all()))

    def _collapse_for_default(self, a):
        return uniform(collapse(a))

    # scan xs: one slice along the leading axis
    def enter_xs(self, a, aval):
        if a.axis is None:
            return a
        if a.axis == 0:
            return uniform(collapse(a))
        return perslot(a.axis - 1, a.bits)

    def stack_ys(self, a, aval):
        if a.axis is None:
            return a
        return perslot(a.axis + 1, a.bits)

    # ------------------------------------------------------------------
    def on_eqn(self, path, eqn, in_abs, out_abs):
        """Blame bookkeeping: remember, per var, the equation where
        multi-client mixing first entered its lineage.  An output that
        is mixed while no input was inherits nothing -- that equation
        IS the mixing point."""
        src, mixed_in = None, False
        for iv, a in zip(eqn.invars, in_abs):
            if isinstance(iv, jcore.Literal) or not is_mixed(a):
                continue
            mixed_in = True
            b = self.blame.get(iv)
            if b is not None:
                src = b
                break
        if src is None and not mixed_in:
            src = (path, eqn)
        if src is None:
            return
        for ov, a in zip(eqn.outvars, out_abs):
            if is_mixed(a):
                self.blame[ov] = src

    def rule(self, eqn, in_abs, in_conc):
        name = eqn.primitive.name
        out_aval = eqn.outvars[0].aval

        if name == TAG_PRIM_NAME:
            kind = eqn.params["kind"]
            ch = eqn.params["channel"]
            self.channels[ch] = self.channels.get(ch, 0) + 1
            if kind == "declass":
                return [EMPTY]
            return [in_abs[0]]

        if name in _PASSTHROUGH:
            return [in_abs[0]]

        if name in _ELEMENTWISE_N:
            if name == "mul":
                ref = self._mul_refine(in_abs, in_conc, out_aval)
                if ref is not None:
                    return [ref]
            out = EMPTY
            out_shape = getattr(out_aval, "shape", ())
            for a, v in zip(in_abs, eqn.invars):
                shape = getattr(v.aval, "shape", ())
                if a.axis is not None and shape != out_shape:
                    # numpy-style broadcast: axes right-align, so the
                    # slot axis survives iff its extent is unchanged
                    off = len(out_shape) - len(shape)
                    ax = a.axis + off
                    if (off >= 0 and 0 <= ax < len(out_shape)
                            and shape[a.axis] == out_shape[ax]):
                        a = a if ax == a.axis else perslot(ax, a.bits)
                    else:
                        a = uniform(collapse(a))
                out = join(out, a)
            return [out] * len(eqn.outvars)

        if name in _REDUCES:
            o = self._reduce_axes(in_abs[0] if in_abs else EMPTY,
                                  eqn.params.get("axes", ()))
            return [o] * len(eqn.outvars)

        if name == "broadcast_in_dim":
            return [self._broadcast(in_abs[0], eqn)]
        if name == "reshape":
            return [self._reshape(in_abs[0], eqn)]
        if name == "transpose":
            return [self._transpose(in_abs[0], eqn)]
        if name == "squeeze":
            return [self._squeeze(in_abs[0], eqn)]
        if name == "expand_dims":
            return [self._expand_dims(in_abs[0], eqn)]
        if name == "slice":
            return [self._slice(in_abs[0], eqn)]
        if name == "dynamic_slice":
            return [self._dynamic_slice(in_abs, in_conc, eqn)]
        if name == "dynamic_update_slice":
            return [self._dynamic_update_slice(in_abs, in_conc, eqn)]
        if name == "pad":
            return [self._pad(in_abs, eqn)]
        if name == "concatenate":
            return [self._concatenate(in_abs, eqn)]
        if name == "dot_general":
            return [self._dot_general(in_abs, eqn)]
        if name == "gather":
            return [self._gather(in_abs, in_conc, eqn)]
        if name in ("scatter-add", "scatter", "scatter-mul",
                    "scatter-min", "scatter-max", "scatter_add"):
            extra = collapse(in_abs[1]) | collapse(in_abs[2])
            return [join(in_abs[0], uniform(extra))]
        if name in ("rev",):
            a = in_abs[0]
            if a.axis is not None and a.axis in eqn.params["dimensions"]:
                return [perslot(a.axis, a.bits[::-1].copy())]
            return [a]
        if name == "iota":
            return [EMPTY]
        return None

    # -- structural rules ----------------------------------------------
    def _mul_refine(self, in_abs, in_conc, out_aval):
        """mul by a concrete mask: zero entries of the mask erase taint
        positionally, and may REFINE a taint onto a different axis --
        e.g. per-column(features) x block-diagonal client masks
        [n, 1, F] -> per-slot(clients)."""
        for (a, c) in ((in_abs[0], in_conc[1]), (in_abs[1], in_conc[0])):
            if c is None or is_empty(a):
                continue
            try:
                nz = np.broadcast_to(np.asarray(c) != 0, out_aval.shape)
            except Exception:
                continue
            ndim = len(out_aval.shape)
            if a.axis is None:
                if not nz.any():
                    return EMPTY
                return None     # uniform stays uniform
            k = a.axis
            if k >= ndim:
                return None
            # candidate result axes: keep k, or re-slot onto any axis
            best = None
            for cand in range(ndim):
                red = tuple(d for d in range(ndim) if d not in (cand, k))
                nz2 = nz.any(axis=red) if red else nz
                if cand == k:
                    nz2 = np.diag(nz2) if nz2.ndim == 2 else nz2
                    bits = np.where(nz2, a.bits[:nz2.shape[0]], 0)
                    t = perslot(k, bits.astype(np.int64))
                else:
                    if cand < k:
                        m = nz2          # [cand_dim, k_dim]
                    else:
                        m = nz2.T        # transpose to [cand_dim, k_dim]
                    bits = np.zeros(m.shape[0], np.int64)
                    for s in range(m.shape[0]):
                        sel = a.bits[np.nonzero(m[s])[0]]
                        bits[s] = (np.bitwise_or.reduce(sel)
                                   if sel.size else 0)
                    t = perslot(cand, bits)
                score = self._precision(t)
                if best is None or score < best[0]:
                    best = (score, t)
            return best[1] if best else None
        return None

    @staticmethod
    def _precision(t):
        """Lower = more precise: max popcount across slots."""
        if t.axis is None:
            return bin(t.bits).count("1") + 1000
        return max((bin(int(b)).count("1") for b in t.bits), default=0)

    def _reduce_axes(self, a, axes):
        if a.axis is None:
            return a
        if a.axis in axes:
            return uniform(collapse(a))
        return perslot(a.axis - sum(1 for x in axes if x < a.axis),
                       a.bits)

    def _broadcast(self, a, eqn):
        if a.axis is None:
            return a
        bdims = eqn.params["broadcast_dimensions"]
        if a.axis >= len(bdims):
            return uniform(collapse(a))
        out_axis = bdims[a.axis]
        out_dim = eqn.params["shape"][out_axis]
        bits = a.bits
        if bits.shape[0] != out_dim:    # size-1 dim expanded
            bits = np.repeat(bits[:1], out_dim)
        return perslot(out_axis, bits)

    def _reshape(self, a, eqn):
        if a.axis is None:
            return a
        if eqn.params.get("dimensions") is not None:
            return uniform(collapse(a))
        old = eqn.invars[0].aval.shape
        new = tuple(eqn.params["new_sizes"])
        k = a.axis
        pre = int(np.prod(old[:k], dtype=np.int64))
        post = int(np.prod(old[k + 1:], dtype=np.int64))
        run = 1
        for j, d in enumerate(new):
            if (run == pre and d == old[k]
                    and int(np.prod(new[j + 1:], dtype=np.int64)) == post):
                return perslot(j, a.bits)
            run *= d
        return uniform(collapse(a))

    def _transpose(self, a, eqn):
        if a.axis is None:
            return a
        perm = eqn.params["permutation"]
        return perslot(list(perm).index(a.axis), a.bits)

    def _squeeze(self, a, eqn):
        if a.axis is None:
            return a
        dims = eqn.params["dimensions"]
        if a.axis in dims:
            return uniform(collapse(a))
        return perslot(a.axis - sum(1 for d in dims if d < a.axis),
                       a.bits)

    def _expand_dims(self, a, eqn):
        if a.axis is None:
            return a
        dims = eqn.params["dimensions"]
        return perslot(a.axis + sum(1 for d in dims if d <= a.axis),
                       a.bits)

    def _slice(self, a, eqn):
        if a.axis is None:
            return a
        k = a.axis
        start = eqn.params["start_indices"][k]
        limit = eqn.params["limit_indices"][k]
        strides = eqn.params.get("strides")
        step = strides[k] if strides else 1
        return perslot(k, a.bits[start:limit:step].copy())

    def _dynamic_slice(self, in_abs, in_conc, eqn):
        a = in_abs[0]
        if a.axis is None:
            return a
        k = a.axis
        sizes = eqn.params["slice_sizes"]
        shape = eqn.invars[0].aval.shape
        start_c = in_conc[1 + k]
        if sizes[k] == shape[k]:
            return perslot(k, a.bits)
        if start_c is not None:
            s = int(np.clip(int(start_c), 0, shape[k] - sizes[k]))
            return perslot(k, a.bits[s:s + sizes[k]].copy())
        return uniform(collapse(a))

    def _dynamic_update_slice(self, in_abs, in_conc, eqn):
        x, upd = in_abs[0], in_abs[1]
        shape = eqn.outvars[0].aval.shape
        k = x.axis if x.axis is not None else (
            upd.axis if upd.axis is not None else None)
        if k is None:
            return join(x, upd)
        base = (x.bits.copy() if x.axis == k
                else np.full(shape[k], collapse(x), np.int64))
        u_shape = eqn.invars[1].aval.shape
        start_c = in_conc[2 + k]
        ubits = (upd.bits if upd.axis == k
                 else np.full(u_shape[k], collapse(upd), np.int64))
        if start_c is not None:
            s = int(np.clip(int(start_c), 0, shape[k] - u_shape[k]))
            base[s:s + u_shape[k]] |= ubits
        else:
            base |= np.int64(collapse(upd))
        return perslot(k, base)

    def _pad(self, in_abs, eqn):
        a, padv = in_abs[0], in_abs[1]
        cfg = eqn.params["padding_config"]
        out_shape = eqn.outvars[0].aval.shape
        in_shape = eqn.invars[0].aval.shape
        pb = np.int64(collapse(padv))

        def along(k, bits_at):
            lo, hi, interior = cfg[k]
            bits = np.full(out_shape[k], pb, np.int64)
            for i in range(in_shape[k]):
                pos = lo + i * (interior + 1)
                if 0 <= pos < out_shape[k]:
                    bits[pos] |= np.int64(bits_at(i))
            return perslot(k, bits)

        # pad is the transpose of ``slice``: it places one client's
        # cotangent chunk back into the stacked buffer, so the padded
        # axis is where slot structure is created -- the pad region
        # carries only the pad value's taint, never the operand's.
        padded = [k for k, c in enumerate(cfg)
                  if tuple(c) != (0, 0, 0)]
        if a.axis is not None and a.axis in padded:
            return along(a.axis, lambda i: a.bits[i])
        if a.axis is not None:
            # per-slot on an untouched axis: either keep that view or
            # re-slot onto the padded axis; choose the more precise.
            keep = perslot(a.axis, a.bits | pb)
            if not padded or collapse(a) == 0:
                return keep
            u = collapse(a)
            cand = along(padded[0], lambda i: u)
            return (cand if self._precision(cand)
                    <= self._precision(keep) else keep)
        if not padded or collapse(a) == 0:
            return join(a, uniform(pb))
        u = collapse(a)
        return along(padded[0], lambda i: u)

    def _concatenate(self, in_abs, eqn):
        dim = eqn.params["dimension"]
        shapes = [v.aval.shape for v in eqn.invars]
        axes = {a.axis for a in in_abs if a.axis is not None}
        if axes <= {dim}:
            # covers the all-uniform case too: stacking per-client
            # tensors (stack = broadcast + concat) yields per-slot
            # taint along the new axis, one operand's bits per span
            segs = []
            for a, sh in zip(in_abs, shapes):
                if a.axis == dim:
                    segs.append(a.bits)
                else:
                    segs.append(np.full(sh[dim], collapse(a), np.int64))
            return perslot(dim, np.concatenate(segs))
        if len(axes) == 1:
            ax = axes.pop()
            if ax != dim and all(sh[ax] == shapes[0][ax]
                                 for sh in shapes):
                bits = np.zeros(shapes[0][ax], np.int64)
                for a in in_abs:
                    if a.axis == ax:
                        bits |= a.bits
                    else:
                        bits |= np.int64(collapse(a))
                return perslot(ax, bits)
        return uniform(int(np.bitwise_or.reduce(
            [np.int64(collapse(a)) for a in in_abs])))

    def _dot_general(self, in_abs, eqn):
        (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
        lhs_aval, rhs_aval = (v.aval for v in eqn.invars[:2])
        lhs_free = [d for d in range(len(lhs_aval.shape))
                    if d not in lc and d not in lb]
        rhs_free = [d for d in range(len(rhs_aval.shape))
                    if d not in rc and d not in rb]

        def side(a, contract, batch, free, offset):
            if a.axis is None:
                return a
            k = a.axis
            if k in batch:
                return perslot(list(batch).index(k), a.bits)
            if k in contract:
                return uniform(collapse(a))
            return perslot(len(batch) + offset + free.index(k), a.bits)

        lt = side(in_abs[0], lc, lb, lhs_free, 0)
        rt = side(in_abs[1], rc, rb, rhs_free, len(lhs_free))
        return join(lt, rt)

    def _gather(self, in_abs, in_conc, eqn):
        a, idx_t = in_abs[0], in_abs[1]
        dn = eqn.params["dimension_numbers"]
        sizes = eqn.params["slice_sizes"]
        shape = eqn.invars[0].aval.shape
        extra = uniform(collapse(idx_t))
        if a.axis is None:
            return join(a, extra)
        k = a.axis
        collapsed = set(dn.collapsed_slice_dims)
        batching = set(getattr(dn, "operand_batching_dims", ()) or ())
        if sizes[k] == shape[k] and k not in collapsed \
                and k not in batching:
            kept = [d for d in range(len(shape))
                    if d not in collapsed and d not in batching]
            out_axis = dn.offset_dims[kept.index(k)]
            return join(perslot(out_axis, a.bits), extra)
        exact = self._gather_exact(a, in_conc, eqn, k, shape)
        if exact is not None:
            return join(exact, extra)
        return join(uniform(collapse(a)), extra)

    def _gather_exact(self, a, in_conc, eqn, k, shape):
        """Concrete-index gathers (``w[i]``, column takes) tracked
        exactly: gather an array of source-slot ids through the same
        equation, then read off which slots feed each output span."""
        if in_conc[1] is None:
            return None
        out_shape = eqn.outvars[0].aval.shape
        if (int(np.prod(shape, dtype=np.int64)) > 4_000_000
                or int(np.prod(out_shape, dtype=np.int64)) > 4_000_000):
            return None
        mid = [1] * len(shape)
        mid[k] = shape[k]
        ids = np.broadcast_to(
            np.arange(shape[k], dtype=np.int32).reshape(mid),
            shape)
        try:
            out_ids = np.asarray(
                ir.eval_eqn(eqn, [ids, in_conc[1]])[0])
        except Exception:
            return None
        if out_ids.ndim == 0:
            return uniform(int(a.bits[int(out_ids)]))
        best = None
        for cand in range(out_ids.ndim):
            bits = np.zeros(out_ids.shape[cand], np.int64)
            for s in range(out_ids.shape[cand]):
                uniq = np.unique(np.take(out_ids, s, axis=cand))
                bits[s] = np.bitwise_or.reduce(a.bits[uniq]) \
                    if uniq.size else 0
            t = perslot(cand, bits)
            score = self._precision(t)
            if best is None or score < best[0]:
                best = (score, t)
        return best[1] if best else None

    # -- provenance -----------------------------------------------------
    def _descend(self, v, eqn):
        """Hop from an outer outvar of a structured eqn (scan / while /
        cond / inlined call) to the aligned outvar of its sub-jaxpr.
        Def-sites are shared across scopes, so the walk continues
        inside the body where the offending equation actually lives."""
        name = eqn.primitive.name
        p = eqn.params
        if name == "scan":
            sub = p["jaxpr"]
        elif name == "while":
            sub = p["body_jaxpr"]
        elif name == "cond":
            sub = p["branches"][0]
        else:
            sub = ir.inline_jaxpr_of(eqn)
        if sub is None:
            return None
        jx = ir.closed(sub).jaxpr
        try:
            idx = eqn.outvars.index(v)
        except ValueError:
            return None
        # scan outvars = carry + ys and body outvars = carry + ys;
        # while/cond/call outvars align 1:1 -- same index either way
        if idx >= len(jx.outvars):
            return None
        inner = jx.outvars[idx]
        if isinstance(inner, jcore.Literal):
            return None
        return inner

    def explain(self, var, bit: int, limit=64):
        """Equation chain from ``var`` back toward the source of one
        leaking client bit (most recent def-sites, violating bit
        followed greedily, descending into scan/while/cond bodies)."""
        lines, seen, v = [], set(), var
        blame = None
        while v in self.def_site and v not in seen and \
                len(lines) < limit:
            seen.add(v)
            blame = self.blame.get(v, blame)
            path, eqn = self.def_site[v]
            lines.append(ir.eqn_line(eqn, path))
            nxt = self._descend(v, eqn)
            if nxt is not None:
                t = self.abs_env.get(nxt)
                if t is None or not (collapse(t) & bit) or \
                        nxt in seen:
                    nxt = None
            if nxt is None:
                fallback = None
                for iv in eqn.invars:
                    if isinstance(iv, jcore.Literal):
                        continue
                    t = self.abs_env.get(iv)
                    if t is None or not (collapse(t) & bit) or \
                            iv in seen:
                        continue
                    # prefer an operand the walk can keep following
                    # over a dead end (e.g. a loop-carry invar)
                    if iv in self.def_site:
                        nxt = iv
                        break
                    fallback = fallback or iv
                nxt = nxt or fallback
            if nxt is None:
                break
            v = nxt
        blame = self.blame.get(v, blame)
        if blame is not None:
            bpath, beqn = blame
            lines.append("<- mixing introduced at "
                         + ir.eqn_line(beqn, bpath))
        lines.append(f"<- carries client bit {bit:#x} "
                     "from a tainted source input")
        return lines


def check_round_outputs(interp, closed_jaxpr, out_abs, out_specs,
                        combo):
    """Verify per-slot separation on the round outputs.

    ``out_specs`` aligns with the jaxpr outvars: each entry is
    ``("perslot", client_axis, label)`` -- slot j may carry only bit
    j -- or ``("skip", None, label)`` for aggregate telemetry (the
    scalar loss stream, excluded by contract)."""
    findings = []
    outvars = closed_jaxpr.jaxpr.outvars
    for var, t, (check, axis, label) in zip(outvars, out_abs,
                                            out_specs):
        if check == "skip":
            continue
        if is_empty(t):
            continue
        if t.axis == axis:
            bad = [(s, int(b) & ~(1 << s))
                   for s, b in enumerate(t.bits)
                   if int(b) & ~(1 << s)]
            if not bad:
                continue
            s, leaked = bad[0]
            bit = leaked & -leaked
            findings.append(Finding(
                "taint", "cross-client-flow", combo,
                f"{label}: client slot {s} carries foreign client "
                f"bit(s) {leaked:#x} outside declared channels",
                chain=tuple(interp.explain(var, bit))))
        else:
            bits = collapse(t)
            bit = bits & -bits
            findings.append(Finding(
                "taint", "unseparable-flow", combo,
                f"{label}: taint could not be separated per client "
                f"slot (carries {bits:#x} uniformly; expected "
                f"per-slot on axis {axis})",
                chain=tuple(interp.explain(var, bit))))
    return findings


def run_taint(closed_jaxpr, in_abs, out_specs, combo, n_slots):
    """Drive the taint interpreter over a traced round and check the
    per-slot separation contract.  Returns (findings, channels)."""
    interp = TaintInterpreter(n_slots_hint=n_slots)
    out_abs = interp.run(closed_jaxpr, in_abs)
    findings = check_round_outputs(interp, ir.closed(closed_jaxpr),
                                   out_abs, out_specs, combo)
    if not interp.channels:
        findings.append(Finding(
            "taint", "no-channels-observed", combo,
            "no declared-channel tags were observed in the traced "
            "round; the audit instrumentation is not wired into this "
            "path", severity="warning"))
    return findings, interp.channels
