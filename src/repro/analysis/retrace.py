"""The retrace-hazard linter (pass 3 of three).

The engine's compile-once contracts (``round_traces == 1``, pinned at
runtime by tests/test_padded_engine.py and tests/test_schedule.py) fail
in practice through three statically-detectable hazards:

  carry-aval drift   a round output's aval differs from its input's
                     (dtype, shape, or weak_type): every round then
                     presents a new signature and jit retraces.  The
                     classic source is a captured Python scalar
                     promoting a carried float32 to weak float.
  captured scalars   a weak-typed scalar constant baked into the trace
                     (``0.5`` instead of ``jnp.float32(0.5)``): harmless
                     until it meets a carried value, then it drifts.
  lane divergence    the padded sweep vmaps ONE round body over lanes
                     that differ in client count / schedule / seed; if
                     the traced body secretly depends on a lane's
                     static value, the compile-once claim is false even
                     when a runtime counter on one grid happens to
                     read 1.

The lane check re-traces single-lane sweep batches that differ ONLY in
the lane's data (client count 2 vs 3 padded to the same width, seed 0
vs 1, under sync and under a mixed stale/partial schedule axis) and
demands bit-identical jaxpr text: values ride constvars/arguments, so
any textual difference is a structural specialization -- exactly what
would retrace.  ``static_round_traces == 1`` in the report means all
three hazards are absent.
"""
from __future__ import annotations

import difflib
import itertools
import re

import jax
import jax.numpy as jnp

from repro.analysis.report import Finding


def _aval_sig(aval):
    return (tuple(getattr(aval, "shape", ())),
            str(getattr(aval, "dtype", "?")),
            bool(getattr(aval, "weak_type", False)))


def _carried_labels(tr):
    """Labels for the carried leaves, aligned with the jaxpr's carried
    prefix (params, opt_state, step_idx, sched_state)."""
    params, opt_state, sched_state, _ = tr.args
    lab = []
    for name, tree in (("params", params), ("opt_state", opt_state)):
        for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
            lab.append(name + jax.tree_util.keystr(path))
    lab.append("step_idx")
    for path, _ in jax.tree_util.tree_flatten_with_path(
            tr.args[2])[0]:
        lab.append("sched_state" + jax.tree_util.keystr(path))
    return lab


def run_retrace(tr) -> list:
    """Per-combo checks on a PRODUCTION trace (no audit tags): the
    carried outputs must close over their input avals, and no captured
    weak-typed scalar constants may be baked into the round."""
    findings = []
    fed = tr.fed
    step0 = jnp.zeros((), jnp.int32)
    key = jax.random.PRNGKey(0)
    jx = jax.make_jaxpr(tr.round_fn)(
        tr.args[0], tr.args[1], step0, tr.args[2], key,
        fed._xtr, fed._ytr, fed._lay)

    labels = _carried_labels(tr)
    n_carried = len(labels)
    in_avals = [v.aval for v in jx.jaxpr.invars][:n_carried]
    out_avals = [v.aval for v in jx.jaxpr.outvars][:n_carried]
    for label, ia, oa in zip(labels, in_avals, out_avals):
        if _aval_sig(ia) != _aval_sig(oa):
            findings.append(Finding(
                "retrace", "carry-aval-drift", tr.combo,
                f"{label}: round output aval {oa} differs from its "
                f"input aval {ia}; every round would present a new "
                "signature and retrace"))

    for cv in jx.jaxpr.constvars:
        av = cv.aval
        if getattr(av, "weak_type", False) and \
                getattr(av, "shape", None) == ():
            findings.append(Finding(
                "retrace", "captured-weak-scalar", tr.combo,
                f"weak-typed scalar constant {av} captured in the "
                "round trace; promote it explicitly (jnp.asarray with "
                "a dtype) before it meets a carried value"))
    return findings


# ---------------------------------------------------------------------------
# lane-structural equality (the sweep's compile-once claim)
# ---------------------------------------------------------------------------
_ADDR_RE = re.compile(r"0x[0-9a-f]+")


def _normalize(text: str) -> str:
    """Erase memory addresses (function-object params like
    ``jvp_jaxpr_thunk=<function ... at 0x...>``) so only structural
    differences survive the comparison."""
    return _ADDR_RE.sub("0x", text)


def _lane_jaxpr(dataset, counts, schedules, seeds, max_clients, width,
                faults=("none",)):
    """Trace one single-config sweep lane batch (un-jitted, vmapped
    round) with the batch-wide padding/width statics pinned, so
    batches that should share a compile produce comparable jaxprs."""
    from repro.core.sweep import SweepConfig, build_lane_batch
    scfg = SweepConfig(
        datasets=(dataset,), modes=("devertifl",),
        client_counts=counts, seeds=seeds, rounds=1, epochs=1,
        batch_size=16, n_samples=32, first_layer="slice",
        schedules=schedules, faults=faults)
    lb = build_lane_batch(dataset, "devertifl", scfg,
                          max_clients=max_clients, width=width)
    step_idx = jnp.zeros((lb.n_lanes,), jnp.int32)
    return jax.make_jaxpr(jax.vmap(lb.round_fn))(
        lb.params, lb.opt_state, step_idx, lb.sched_state,
        lb.loop_keys, lb.xtr, lb.ytr, lb.lay)


def run_lane_check(dataset: str = "mnist") -> list:
    """Prove the padded sweep's round body is lane-polymorphic: trace
    lane batches differing only in client count / seed / schedule
    values (same padded max, same gather width, same lane count) and
    require bit-identical jaxpr text."""
    findings = []
    cases = [
        ("client-count (sync)",
         dict(counts=(2,), schedules=("sync",), seeds=(0,)),
         dict(counts=(3,), schedules=("sync",), seeds=(0,))),
        ("seed (sync)",
         dict(counts=(2,), schedules=("sync",), seeds=(0,)),
         dict(counts=(2,), schedules=("sync",), seeds=(1,))),
        ("client-count (stale_k+partial lanes)",
         dict(counts=(2,), schedules=("stale_k:1", "partial:0.5"),
              seeds=(0,)),
         dict(counts=(3,), schedules=("stale_k:1", "partial:0.5"),
              seeds=(0,))),
        # fault plans are traced per-lane state, so batches differing
        # only in rates / durations / corruption kind (and client
        # count) must share the round body.  Straggle presence must
        # MATCH across compared batches -- the ring depth is a static
        # -- so both sides carry a straggle leg here.
        ("client-count (fault lanes)",
         dict(counts=(2,), schedules=("sync",), seeds=(0,),
              faults=("crash:0.2", "corrupt:0.1")),
         dict(counts=(3,), schedules=("sync",), seeds=(0,),
              faults=("crash:0.4", "crash:0.3+corrupt:0.5:scale")),),
        ("fault-rate (straggle ring + stale_k lanes)",
         dict(counts=(2,), schedules=("sync", "stale_k:2"), seeds=(0,),
              faults=("straggle:0.5:2", "straggle:0.2:1+corrupt:0.1")),
         dict(counts=(2,), schedules=("sync", "stale_k:2"), seeds=(1,),
              faults=("straggle:0.9:1", "straggle:0.4:2+corrupt:0.6")),),
    ]
    # batch-wide statics shared by every compared trace: padded client
    # axis 3, gather width of the 2-client split (the widest involved)
    max_c, width = 3, None
    from repro.configs import get_config
    from repro.core import partition as PT
    from repro.core.protocol import arch_for
    from repro.models.mlp_model import PaperMLP
    n_feat = PaperMLP(get_config(arch_for(dataset))).in_features
    width = max(max(PT.make_layout(dataset, n_feat, nc, seed=s,
                                   max_clients=max_c).sizes)
                for nc, s in itertools.product((2, 3), (0, 1)))
    for name, kw_a, kw_b in cases:
        ja = _lane_jaxpr(dataset, max_clients=max_c, width=width, **kw_a)
        jb = _lane_jaxpr(dataset, max_clients=max_c, width=width, **kw_b)
        ta, tb = _normalize(str(ja.jaxpr)), _normalize(str(jb.jaxpr))
        if ta != tb:
            diff = list(itertools.islice(
                (ln for ln in difflib.unified_diff(
                    ta.splitlines(), tb.splitlines(), lineterm="")
                 if ln.startswith(("+", "-"))), 12))
            findings.append(Finding(
                "retrace", "lane-retrace-divergence",
                f"devertifl/sweep/{dataset}",
                f"sweep lane batches differing only in {name} trace "
                "to different round bodies; the padded batch would "
                "retrace per lane value", chain=tuple(diff)))
    return findings
