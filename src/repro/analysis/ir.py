"""Shared IR-walking helpers: HLO-text shape/dtype parsing (consumed by
``repro.roofline``) and jaxpr traversal / abstract interpretation
(consumed by the taint, deadness, and retrace passes).

Two IR families live here because both sides of the repo read program
text rather than running it:

  * HLO text   -- the roofline model parses post-partitioning HLO for
    operand shapes and collective sizes.  ``DTYPE_BYTES`` / ``SHAPE_RE``
    / ``parse_shapes`` / ``shape_bytes`` / ``bytes_of`` are the single
    copies of the regex shape logic that used to be duplicated across
    ``roofline/analysis.py`` and ``roofline/hlo_costs.py``.
  * jaxprs     -- the static auditor traces the round function once
    with ``jax.make_jaxpr`` (no execution) and interprets the IR.
    ``sub_jaxprs`` / ``all_eqns`` walk the call hierarchy;
    :class:`AbstractInterpreter` is the forward dataflow engine the
    taint and deadness lattices plug into.

The interpreter folds constants as it goes: any equation whose inputs
are all concretely known (jaxpr constvars -- the Layout arrays, keys,
schedule scalars -- plus literals) is *executed* via the canonical
``primitive.bind`` interpreter loop, so downstream rules see concrete
``dynamic_slice`` offsets, permutations, and masks instead of opaque
tracers.  That is what makes per-client separation decidable on an
engine that stacks every client on one vmapped axis.
"""
from __future__ import annotations

import re

import numpy as np

import jax
from jax import core as jcore

# ----------------------------------------------------------------------
# HLO text helpers (single source of truth for the roofline parsers)
# ----------------------------------------------------------------------

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0,
}

# e.g.  f32[8,128,3584]  -- dtype token + bracketed dims
SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def parse_shapes(type_str: str):
    """All ``(dtype, dims_str)`` pairs in an HLO type string (handles
    tuple types: every bracketed shape in the string is returned)."""
    return [(dt, dims) for dt, dims in SHAPE_RE.findall(type_str)]


def shape_elems(dims: str) -> int:
    """Element count of a comma-joined dims string ('' = scalar = 1)."""
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def shape_bytes(dtype: str, dims: str) -> int:
    """Byte size of one ``dtype[dims]`` shape (unknown dtypes: 4B)."""
    return shape_elems(dims) * DTYPE_BYTES.get(dtype, 4)


def bytes_of(type_str: str) -> int:
    """Total byte size of every shape in an HLO type string."""
    return sum(shape_bytes(dt, dims) for dt, dims in parse_shapes(type_str))


# ----------------------------------------------------------------------
# jaxpr traversal
# ----------------------------------------------------------------------

# call-like primitives whose sub-jaxpr the interpreter INLINES (the
# equation is transparent: map invars -> sub-jaxpr args, run, map back).
# scan / while / cond have their own drivers; anything else (notably
# pallas_call) falls to the conservative default rule, which is sound.
INLINE_CALLS = ("pjit", "closed_call", "core_call", "custom_jvp_call",
                "custom_vjp_call", "custom_vjp_call_jaxpr", "remat",
                "remat2", "checkpoint")


def closed(j):
    """Wrap an open Jaxpr as a ClosedJaxpr (no-op when already closed)."""
    if isinstance(j, jcore.ClosedJaxpr):
        return j
    return jcore.ClosedJaxpr(j, ())


def sub_jaxprs(eqn):
    """Yield every (ClosedJaxpr) nested in an equation's params --
    pjit/scan ``jaxpr``, cond ``branches``, while ``cond_jaxpr`` /
    ``body_jaxpr``, custom_jvp ``call_jaxpr`` -- uniformly closed."""
    for val in eqn.params.values():
        vals = val if isinstance(val, (tuple, list)) else (val,)
        for v in vals:
            if isinstance(v, (jcore.ClosedJaxpr, jcore.Jaxpr)):
                yield closed(v)


def all_eqns(jaxpr):
    """Every equation in a (Closed)Jaxpr, recursively, as
    ``(path, eqn)`` with ``path`` a '/'-joined primitive-name trail."""
    j = jaxpr.jaxpr if isinstance(jaxpr, jcore.ClosedJaxpr) else jaxpr

    def walk(jx, path):
        for eqn in jx.eqns:
            yield path, eqn
            for sub in sub_jaxprs(eqn):
                yield from walk(sub.jaxpr, f"{path}/{eqn.primitive.name}"
                                if path else eqn.primitive.name)

    yield from walk(j, "")


def inline_jaxpr_of(eqn):
    """The single inlinable sub-jaxpr of a transparent call equation
    (pjit's ``jaxpr``, custom_jvp's ``call_jaxpr``), or None."""
    for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
        v = eqn.params.get(key)
        if isinstance(v, (jcore.ClosedJaxpr, jcore.Jaxpr)):
            return closed(v)
    return None


def eqn_line(eqn, path=""):
    """One-line human rendering of an equation for reports: primitive,
    output avals, and the source location jax recorded at trace time."""
    outs = ", ".join(str(v.aval) for v in eqn.outvars)
    src = ""
    try:
        frame = jax._src.source_info_util.user_frame(eqn.source_info)
        if frame is not None:
            src = f"  [{frame.file_name.rsplit('/', 1)[-1]}:"\
                  f"{frame.start_line}]"
    except Exception:
        pass
    where = f"{path}/" if path else ""
    return f"{where}{eqn.primitive.name} -> {outs}{src}"


def eval_eqn(eqn, in_vals):
    """Execute one equation concretely (the canonical interpreter-loop
    bind).  Returns the list of output values.  Callers guard with
    try/except: anything that refuses to fold is simply not concrete."""
    subfuns, bind_params = eqn.primitive.get_bind_params(eqn.params)
    out = eqn.primitive.bind(*subfuns, *in_vals, **bind_params)
    return list(out) if eqn.primitive.multiple_results else [out]


def as_np(v):
    """np.asarray when possible; extended-dtype values (typed PRNG
    keys) stay raw -- they still fold through ``eval_eqn``, and the
    lattices' ``from_concrete`` must tolerate them."""
    try:
        return np.asarray(v)
    except Exception:
        return v


# folding guard: never materialize giant intermediates while folding
_FOLD_ELEM_LIMIT = 4_000_000
# primitives never folded (executing them is the training loop / has
# no cheap eager path)
_NO_FOLD = {"scan", "while", "cond", "pallas_call", "custom_partitioning"}


class AbstractInterpreter:
    """Forward abstract interpretation over a ClosedJaxpr with constant
    folding and structured control flow.

    Subclasses define the lattice:

      top(aval)              unknown abstract value for an aval
      from_concrete(value)   abstract value of a known constant
      join(a, b, aval)       least upper bound (monotone!)
      equal(a, b)            lattice equality (fixpoint termination)
      rule(eqn, in_abs, in_conc) -> list of out abstract values, or
                             None to take the conservative default
      default(eqn, in_abs) -> out values when no rule applies
      on_eqn(path, eqn, in_abs, out_abs)   observation hook (tags)

    plus the scan plumbing ``enter_xs(a, aval)`` (abstract of one
    scanned slice from the stacked abstract) and ``stack_ys(a, aval)``
    (stacked abstract of the per-step ys).  The engine handles env
    management, literals, concrete folding, transparent call inlining,
    and fixpoints for scan/while (lattices must have finite height).
    """

    max_fixpoint_iters = 64

    def __init__(self):
        self.abs_env = {}        # Var -> abstract value
        self.conc_env = {}       # Var -> concrete np/jax value
        self.def_site = {}       # Var -> (path, eqn) that produced it
        self._path = ""

    # -- lattice interface (subclass) ----------------------------------
    def top(self, aval):
        raise NotImplementedError

    def bottom(self, aval):
        """Least element (the default rule folds inputs into it)."""
        raise NotImplementedError

    def from_concrete(self, value):
        raise NotImplementedError

    def join(self, a, b, aval):
        raise NotImplementedError

    def equal(self, a, b) -> bool:
        raise NotImplementedError

    def rule(self, eqn, in_abs, in_conc):
        return None

    def default(self, eqn, in_abs):
        out = self.bottom(eqn.outvars[0].aval)
        for a in in_abs:
            out = self.join(out, self._collapse_for_default(a),
                            eqn.outvars[0].aval)
        return [self._retop(out, ov.aval) for ov in eqn.outvars]

    def _collapse_for_default(self, a):
        return a

    def _retop(self, a, aval):
        return a

    def on_eqn(self, path, eqn, in_abs, out_abs):
        pass

    # -- env -----------------------------------------------------------
    def read_abs(self, var):
        if isinstance(var, jcore.Literal):
            return self.from_concrete(np.asarray(var.val))
        return self.abs_env[var]

    def read_conc(self, var):
        """Concrete value of a var, or None when unknown."""
        if isinstance(var, jcore.Literal):
            return np.asarray(var.val)
        return self.conc_env.get(var)

    def write(self, var, abs_val, conc_val=None, eqn=None):
        if isinstance(var, jcore.DropVar):
            return
        self.abs_env[var] = abs_val
        if conc_val is not None:
            self.conc_env[var] = conc_val
        if eqn is not None:
            self.def_site[var] = (self._path, eqn)

    # -- driver --------------------------------------------------------
    def run(self, closed_jaxpr, in_abs, in_conc=None):
        """Interpret a ClosedJaxpr given abstract values (and optional
        concrete values, None-padded) for its invars.  Returns the
        output abstract values."""
        cj = closed(closed_jaxpr)
        jx = cj.jaxpr
        in_conc = in_conc or [None] * len(in_abs)
        for cv, const in zip(jx.constvars, cj.consts):
            cval = as_np(const)
            self.write(cv, self.from_concrete(cval), cval)
        for var, a, c in zip(jx.invars, in_abs, in_conc):
            self.write(var, a, c)
        self._run_eqns(jx)
        return [self.read_abs(v) for v in jx.outvars]

    def _run_eqns(self, jx):
        for eqn in jx.eqns:
            self._eqn(eqn)

    def _eqn(self, eqn):
        name = eqn.primitive.name
        in_abs = [self.read_abs(v) for v in eqn.invars]
        in_conc = [self.read_conc(v) for v in eqn.invars]

        # constant folding first: fully-known equations execute
        if (name not in _NO_FOLD and all(c is not None for c in in_conc)
                and all(np.prod(ov.aval.shape, dtype=np.int64)
                        <= _FOLD_ELEM_LIMIT for ov in eqn.outvars
                        if hasattr(ov.aval, "shape"))):
            try:
                outs = eval_eqn(eqn, in_conc)
            except Exception:
                outs = None
            if outs is not None:
                out_abs = []
                for ov, val in zip(eqn.outvars, outs):
                    cval = as_np(val)
                    a = self.from_concrete(cval)
                    self.write(ov, a, cval, eqn)
                    out_abs.append(a)
                self.on_eqn(self._path, eqn, in_abs, out_abs)
                return

        if name == "scan":
            out_abs = self._scan(eqn, in_abs, in_conc)
        elif name == "while":
            out_abs = self._while(eqn, in_abs, in_conc)
        elif name == "cond":
            out_abs = self._cond(eqn, in_abs, in_conc)
        elif name in INLINE_CALLS and inline_jaxpr_of(eqn) is not None:
            out_abs = self._inline(eqn, in_abs, in_conc)
        else:
            out_abs = self.rule(eqn, in_abs, in_conc)
            if out_abs is None:
                out_abs = self.default(eqn, in_abs)
        for ov, a in zip(eqn.outvars, out_abs):
            self.write(ov, a, None, eqn)
        self.on_eqn(self._path, eqn, in_abs, out_abs)

    def _nested(self, sub, eqn, in_abs, in_conc=None):
        """Run a sub-jaxpr in a child scope sharing the envs (vars are
        unique per trace, so sharing is safe) and the def-site map."""
        saved = self._path
        self._path = (f"{saved}/{eqn.primitive.name}" if saved
                      else eqn.primitive.name)
        try:
            return self.run(sub, in_abs, in_conc)
        finally:
            self._path = saved

    def _inline(self, eqn, in_abs, in_conc):
        sub = inline_jaxpr_of(eqn)
        n = len(sub.jaxpr.invars)
        # custom_jvp_call passes (primal args); pjit passes all invars
        return self._nested(sub, eqn, in_abs[:n], in_conc[:n])[:len(
            eqn.outvars)]

    # scan plumbing (subclasses refine)
    def enter_xs(self, a, aval):
        return self._collapse_for_default(a)

    def stack_ys(self, a, aval):
        return self._retop(a, aval)

    def _scan(self, eqn, in_abs, in_conc):
        p = eqn.params
        nc, ncarry = p["num_consts"], p["num_carry"]
        body = closed(p["jaxpr"])
        consts = in_abs[:nc]
        # consts keep their concrete values inside the body (Layout
        # masks etc.); carry and xs slices are abstract-only
        consts_conc = list(in_conc[:nc])
        carry = list(in_abs[nc:nc + ncarry])
        xs = in_abs[nc + ncarry:]
        n_body_in = len(body.jaxpr.invars)
        xs_avals = [v.aval for v in
                    body.jaxpr.invars[nc + ncarry:n_body_in]]
        xs_slice = [self.enter_xs(a, av) for a, av in zip(xs, xs_avals)]
        carry_avals = [v.aval for v in body.jaxpr.invars[nc:nc + ncarry]]
        body_conc = consts_conc + [None] * (ncarry + len(xs_slice))
        ys_abs = None
        for _ in range(self.max_fixpoint_iters):
            outs = self._nested(body, eqn, consts + carry + xs_slice,
                                body_conc)
            new_carry = [self.join(c, o, av) for c, o, av in
                         zip(carry, outs[:ncarry], carry_avals)]
            ys_abs = outs[ncarry:]
            if all(self.equal(c, n) for c, n in zip(carry, new_carry)):
                carry = new_carry
                break
            carry = new_carry
        else:
            carry = [self.top(av) for av in carry_avals]
            outs = self._nested(body, eqn, consts + carry + xs_slice,
                                body_conc)
            ys_abs = outs[ncarry:]
        ys_avals = [v.aval for v in eqn.outvars[ncarry:]]
        return carry + [self.stack_ys(a, av)
                        for a, av in zip(ys_abs, ys_avals)]

    def _while(self, eqn, in_abs, in_conc):
        p = eqn.params
        cn, bn = p["cond_nconsts"], p["body_nconsts"]
        body = closed(p["body_jaxpr"])
        cond = closed(p["cond_jaxpr"])
        cconsts = in_abs[:cn]
        bconsts = in_abs[cn:cn + bn]
        carry = list(in_abs[cn + bn:])
        ncarry = len(carry)
        cc = list(in_conc[:cn]) + [None] * ncarry
        bc = list(in_conc[cn:cn + bn]) + [None] * ncarry
        avals = [v.aval for v in eqn.outvars]
        for _ in range(self.max_fixpoint_iters):
            self._nested(cond, eqn, cconsts + carry, cc)
            outs = self._nested(body, eqn, bconsts + carry, bc)
            new_carry = [self.join(c, o, av) for c, o, av in
                         zip(carry, outs, avals)]
            if all(self.equal(c, n) for c, n in zip(carry, new_carry)):
                return new_carry
            carry = new_carry
        return [self.top(av) for av in avals]

    def _cond(self, eqn, in_abs, in_conc):
        branches = eqn.params["branches"]
        pred, ops = in_abs[0], in_abs[1:]
        avals = [v.aval for v in eqn.outvars]
        out = None
        for br in branches:
            bouts = self._nested(closed(br), eqn, list(ops),
                                 list(in_conc[1:]))
            if out is None:
                out = bouts
            else:
                out = [self.join(a, b, av) for a, b, av in
                       zip(out, bouts, avals)]
        # control-flow dependence on the predicate
        pc = self._collapse_for_default(pred)
        return [self.join(a, self._retop(pc, av), av)
                for a, av in zip(out, avals)]
