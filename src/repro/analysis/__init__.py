"""repro.analysis -- jaxpr-level static auditing of the protocol.

Traces the round function ONCE with ``jax.make_jaxpr`` (no execution)
and proves three contracts over the IR (docs/ARCHITECTURE.md section 8
"Static-analysis contracts" is the authoritative reference):

  taint      privacy flow: client i's raw features reach client j != i
             only through the declared channels (the first-layer
             hidden-output exchange and the FedAvg mean), marked in the
             IR by :mod:`repro.analysis.barrier` tags
  deadness   dead padded ``client_mask`` slots contribute structural
             zeros to every tagged exchange / FedAvg / loss term
  retrace    the round's carried outputs close over their input avals
             (dtype + weak_type), no captured-scalar drift, and the
             sweep's lane-stacked round traces identically across
             client counts x schedules x seeds -- the static side of
             the ``round_traces == 1`` contract

Entry points:

  audit(spec) -> AnalysisReport          one ExperimentSpec
  audit_combos(...) -> AnalysisReport    registered mode x schedule x
                                         first-layer grid
  python -m repro.analysis               CLI; JSON report; exit 1 on
                                         any unwaived violation (the
                                         CI ``analysis`` lane)

Violations can be waived -- justified, in code -- via
:func:`repro.analysis.report.register_waiver`; see the docs section
above for when that is (and is not) acceptable.

This module stays import-light: ``repro.core`` imports
:func:`repro.analysis.barrier.tag` at module load, so the heavy pass
machinery only loads when an audit actually runs.
"""
from repro.analysis.barrier import audit_tracing, auditing, tag  # noqa: F401
from repro.analysis.report import (AnalysisReport, Finding,  # noqa: F401
                                   register_waiver)


def audit(spec, passes=None, **kw):
    """Audit one ExperimentSpec (or ProtocolConfig); see
    :func:`repro.analysis.audit.audit`."""
    from repro.analysis.audit import audit as _audit
    return _audit(spec, passes=passes, **kw)


def audit_combos(**kw):
    """Audit the registered mode x schedule x first-layer grid; see
    :func:`repro.analysis.audit.audit_combos`."""
    from repro.analysis.audit import audit_combos as _ac
    return _ac(**kw)
