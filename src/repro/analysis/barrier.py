"""The audit barrier: an identity primitive that marks declared
cross-client channels (and maskable terms) in the traced jaxpr.

De-VertiFL's privacy claim is *relational*: client i's raw features may
reach client j only through the declared first-layer hidden-output
exchange (and the FedAvg parameter mean).  A dataflow auditor therefore
needs the declared channels to be visible IN the IR.  This module
provides :func:`tag` -- an identity function that the engine calls at
exactly those reductions (``core/exchange.py``,
``core/protocol.py``, ``schedule/engine.py``):

  tag(x, "declass", "exchange")   the masked hidden-output sum every
                                  client consumes (the paper's channel)
  tag(x, "declass", "fedavg")     the masked parameter mean
  tag(x, "term", channel, client_axis=0)
                                  a mask-weighted per-client term whose
                                  dead padded slots the deadness pass
                                  must prove structurally zero

Outside an :func:`audit_tracing` context ``tag`` returns its argument
untouched -- zero equations, zero overhead, so production traces (and
the ``round_traces == 1`` compile-once contract) are bit-identical to
a build without the auditor.  Inside the context it binds ``tag_p``, an
identity primitive registered as linear (its transpose re-tags the
cotangent: the transpose of the declared forward exchange is precisely
the declared backward exchange of the verticomb baseline) and
vectorized under vmap, so it survives ``jax.grad`` / ``jax.vmap``
tracing and lands in the jaxpr where the passes can see it.

The context is thread-local and must only wrap ``jax.make_jaxpr``
calls, never jitted *executions*: a cached compiled function traced
under the context would carry tag equations for its lifetime (they
lower to identity, so even that is harmless -- just wasteful).
"""
from __future__ import annotations

import threading
from contextlib import contextmanager

from jax import core as jcore
from jax.interpreters import ad, batching, mlir

TAG_PRIM_NAME = "repro_audit_tag"

tag_p = jcore.Primitive(TAG_PRIM_NAME)
tag_p.def_impl(lambda x, **_: x)
tag_p.def_abstract_eval(lambda aval, **_: aval)
# linear: jvp passes tangents through, and the transpose of a DECLARED
# CHANNEL re-tags the cotangent -- backward flows through the exchange
# stay declared (that is verticomb's backward exchange).  A "term" tag
# does NOT transpose to a term: the cotangent of a mask-weighted term
# is not itself mask-weighted, so re-tagging it would hand the deadness
# prover a value it never claimed was zero.


def _tag_transpose(ct, x, **params):
    if params.get("kind") == "declass":
        return [tag_p.bind(ct, **params)]
    return [ct]


ad.deflinear2(tag_p, _tag_transpose)
batching.defvectorized(tag_p)
mlir.register_lowering(tag_p, lambda ctx, x, **_: [x])

_STATE = threading.local()


def auditing() -> bool:
    """True inside an :func:`audit_tracing` context (this thread)."""
    return getattr(_STATE, "depth", 0) > 0


@contextmanager
def audit_tracing():
    """Enable tag emission for the duration (re-entrant, thread-local).
    Wrap ``jax.make_jaxpr(...)`` calls only -- see module docstring."""
    _STATE.depth = getattr(_STATE, "depth", 0) + 1
    try:
        yield
    finally:
        _STATE.depth -= 1


def tag(x, kind: str, channel: str, client_axis=None):
    """Identity, plus an IR marker when an audit trace is active.

    kind="declass"  x is a declared cross-client channel value: the
                    taint pass clears client-source taint here.
    kind="term"     x is a mask-weighted per-client term (client axis
                    ``client_axis``): the deadness pass proves its dead
                    padded slots are structural zeros.

    ``client_axis`` indexes an axis of ``x`` *at the call site*; call
    sites sit outside any vmap so the index survives into the jaxpr
    unshifted.
    """
    if not auditing():
        return x
    return tag_p.bind(x, kind=kind, channel=channel,
                      client_axis=client_axis)
