"""Findings, waivers, and the AnalysisReport the auditor returns.

A *finding* is one violation (or warning) from one pass over one
audited combination.  A *waiver* is a pinned, justified exception:
``register_waiver("taint", "cross-client-flow", "devertifl/sync/*",
reason=...)`` marks matching findings as waived so the CI lane stays
green while the justification stays in code review's face.  Waivers
never delete findings -- a waived finding still appears in the JSON
report with its reason attached (docs/ARCHITECTURE.md section 8).

The report is plain data (dataclasses -> dicts) so the CLI can dump it
as JSON and the bench harness can stamp ``static_round_traces`` into
append-only bench entries.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field, asdict
from fnmatch import fnmatch
from typing import List, Tuple

SEVERITIES = ("error", "warning", "info")


@dataclass
class Finding:
    """One issue from one pass over one audited combination.

    ``chain`` is the offending equation chain (taint violations walk
    the dataflow from the leaking output back to the tainted source;
    other passes attach whatever locates the problem)."""
    pass_name: str                   # taint | deadness | retrace
    code: str                        # stable machine-readable kind
    combo: str                       # e.g. "devertifl/stale_k:2/slice"
    message: str
    chain: Tuple[str, ...] = ()
    severity: str = "error"
    waived: str = ""                 # non-empty = waiver reason

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"severity must be one of {SEVERITIES}, "
                             f"got {self.severity!r}")
        self.chain = tuple(self.chain)


@dataclass
class Waiver:
    pass_name: str                   # exact pass, or "*"
    code: str                        # exact code, or "*"
    combo: str                       # fnmatch glob over combo strings
    reason: str

    def matches(self, f: Finding) -> bool:
        return (self.pass_name in ("*", f.pass_name)
                and self.code in ("*", f.code)
                and fnmatch(f.combo, self.combo))


# shipped waivers -- currently empty: every registered mode x schedule
# x first-layer combination audits clean (the acceptance bar for new
# engine code is to KEEP it that way, or pin a justified entry here).
WAIVERS: List[Waiver] = []


def register_waiver(pass_name: str, code: str, combo: str,
                    reason: str) -> Waiver:
    """Pin a justified exception.  ``reason`` is mandatory and lands in
    the JSON report next to every finding it waives."""
    if not reason or not reason.strip():
        raise ValueError("a waiver needs a non-empty justification")
    w = Waiver(pass_name, code, combo, reason.strip())
    WAIVERS.append(w)
    return w


def apply_waivers(findings: List[Finding]) -> List[Finding]:
    for f in findings:
        for w in WAIVERS:
            if w.matches(f):
                f.waived = w.reason
                break
    return findings


@dataclass
class AnalysisReport:
    """Everything the auditor proved (or failed to) in one run."""
    combos: Tuple[str, ...] = ()          # combinations audited
    findings: List[Finding] = field(default_factory=list)
    channels: dict = field(default_factory=dict)   # channel -> tag count
    static_round_traces: int = 0          # 1 iff retrace pass proved it
    passes_run: Tuple[str, ...] = ()

    @property
    def violations(self) -> List[Finding]:
        """Unwaived error-severity findings (what fails CI)."""
        return [f for f in self.findings
                if f.severity == "error" and not f.waived]

    @property
    def ok(self) -> bool:
        return not self.violations

    def merge(self, other: "AnalysisReport") -> "AnalysisReport":
        self.combos = tuple(dict.fromkeys(self.combos + other.combos))
        self.findings.extend(other.findings)
        for k, v in other.channels.items():
            self.channels[k] = self.channels.get(k, 0) + v
        self.passes_run = tuple(dict.fromkeys(self.passes_run
                                              + other.passes_run))
        if other.static_round_traces:
            self.static_round_traces = max(self.static_round_traces,
                                           other.static_round_traces)
        return self

    def to_dict(self) -> dict:
        return {
            "ok": self.ok,
            "combos": list(self.combos),
            "passes_run": list(self.passes_run),
            "channels": dict(self.channels),
            "static_round_traces": self.static_round_traces,
            "n_findings": len(self.findings),
            "n_violations": len(self.violations),
            "findings": [asdict(f) for f in self.findings],
        }

    def to_json(self, indent=2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    def summary(self) -> str:
        lines = [f"audited {len(self.combos)} combination(s); "
                 f"passes: {', '.join(self.passes_run) or '<none>'}; "
                 f"channels: "
                 + (", ".join(f"{k} x{v}" for k, v in
                              sorted(self.channels.items())) or "<none>")
                 + f"; static_round_traces={self.static_round_traces}"]
        for f in self.findings:
            mark = "WAIVED " if f.waived else ""
            lines.append(f"  [{f.severity}] {mark}{f.pass_name}/"
                         f"{f.code} {f.combo}: {f.message}")
            for c in f.chain:
                lines.append(f"      {c}")
        lines.append("OK" if self.ok
                     else f"{len(self.violations)} violation(s)")
        return "\n".join(lines)
