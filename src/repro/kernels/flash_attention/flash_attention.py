"""Flash attention (TPU Pallas): causal / sliding-window / GQA / logit
-softcap, with online softmax in VMEM scratch.

Grid: (B, H, Sq/bq, Skv/bk) -- the kv dim iterates fastest, so the
running (m, l, acc) state for one query block lives in VMEM scratch
across kv steps and is finalized on the last one. Causal and window
bounds skip whole kv blocks with pl.when (on TPU the block fetch is
still scheduled, but the MXU work and softmax update are skipped; a
production variant would also mask the prefetch via a scalar-prefetch
grid, which we note in EXPERIMENTS.md as future TPU work).

GQA is expressed through the k/v BlockSpec index_map (q head h reads kv
head h // group) -- kv heads are never replicated in memory.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.3819763e38


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, causal, window, softcap, bq, bk, n_kv):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    k_start = kj * bk

    # block-level skip: causal (kv block entirely in the future) and
    # window (kv block entirely before the window of every query row)
    conds = []
    if causal:
        conds.append(k_start <= q_start + bq - 1)
        if window is not None:
            conds.append(q_start - (k_start + bk - 1) < window)
    run = functools.reduce(jnp.logical_and, conds) if conds else None

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)            # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask = mask & (kpos <= qpos)
        if window is not None:
            mask = mask & (qpos - kpos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + p.sum(axis=1)
        acc_ref[...] = alpha[:, None] * acc_ref[...] + p @ v
        m_ref[...] = m_new

    if run is None:
        _compute()
    else:
        pl.when(run)(_compute)

    @pl.when(kj == n_kv - 1)
    def _finalize():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


def flash_attention_p(q, k, v, *, causal=True, window=None, softcap=0.0,
                      scale=None, bq=128, bk=128, interpret=False):
    """q: [B, H, Sq, hd]; k, v: [B, KV, Skv, hd]; H % KV == 0.
    Returns [B, H, Sq, hd]."""
    B, H, Sq, hd = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    group = H // KV
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0
    scale = scale if scale is not None else hd ** -0.5
    n_kv = Skv // bk

    grid = (B, H, Sq // bq, n_kv)
    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, bq=bq, bk=bk, n_kv=n_kv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # running max
            pltpu.VMEM((bq,), jnp.float32),       # running denom
            pltpu.VMEM((bq, hd), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
