"""jit'd public wrapper for flash attention."""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention_p


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, softcap=0.0,
                    scale=None, bq=128, bk=128, interpret=True):
    """Flash attention; interpret=True for CPU validation (TPU target
    uses interpret=False)."""
    return flash_attention_p(q, k, v, causal=causal, window=window,
                             softcap=softcap, scale=scale, bq=bq, bk=bk,
                             interpret=interpret)
