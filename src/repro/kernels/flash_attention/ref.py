"""Pure-jnp oracle for flash attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.3819763e38


def flash_attention_ref(q, k, v, *, causal=True, window=None, softcap=0.0,
                        scale=None):
    """q: [B, H, Sq, hd]; k, v: [B, KV, Skv, hd]."""
    B, H, Sq, hd = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    group = H // KV
    scale = scale if scale is not None else hd ** -0.5
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (qpos - kpos < window)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # rows with no valid key -> zero output (matches kernel's l==0 guard)
    any_valid = mask.any(axis=-1)
    p = jnp.where(any_valid[None, None, :, None], p, 0.0)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      vr.astype(jnp.float32)).astype(q.dtype)
