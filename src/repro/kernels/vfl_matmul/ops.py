"""jit'd public wrapper for the VFL block-sparse matmul."""
from __future__ import annotations

import functools

import jax

from repro.kernels.vfl_matmul.vfl_matmul import vfl_matmul_p


@functools.partial(jax.jit,
                   static_argnames=("offset", "bm", "bn", "bk", "interpret"))
def vfl_matmul(x_local, w_full, offset: int, *, bm=128, bn=128, bk=128,
               interpret=True):
    """y = zeropad(x_local) @ w_full without materializing the padding.

    interpret defaults to True because this container is CPU-only; on
    TPU pass interpret=False to run the compiled kernel.
    """
    return vfl_matmul_p(x_local, w_full, offset, bm=bm, bn=bn, bk=bk,
                        interpret=interpret)
