"""jit'd public wrapper + custom VJP for the VFL block-sparse matmul.

The forward is the Pallas kernel (vfl_matmul_p): y = zeropad(x_local)
@ w_full computed as x_local @ w_full[offset:offset+K_local] by
indexing W's row blocks, never materializing the padding.  The VJP
keeps the same block-sparse structure:

  dx = g @ w_full[offset:offset+K_local].T      (sliced, never padded)
  dW = scatter-add of x_local.T @ g into W's rows
       [offset, offset+K_local) -- all other rows get an exact zero
       gradient, the same zeros the dense zeropad formulation produces
       (rows outside the slice only ever meet zero inputs).

Both cotangents are accumulated in fp32 and cast back, matching the
kernel's fp32 VMEM accumulator.

Padded-client gating: ``vfl_matmul(..., gate=g)`` multiplies the
output by a traced scalar (a client_mask entry).  Because the gate is
applied *outside* the custom VJP, autodiff scales both cotangents by
it -- dx = (g_ct * gate) @ W_slice.T and dW = scatter(x.T @ (g_ct *
gate)) -- so a masked-out (dead) client lane produces an exact-zero dW
scatter and dx without a Python-level branch.  gate=1.0 is a bitwise
identity on y, dx, and dW.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.vfl_matmul.vfl_matmul import vfl_matmul_p


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6))
def _vfl_matmul(x_local, w_full, offset, bm, bn, bk, interpret):
    return vfl_matmul_p(x_local, w_full, offset, bm=bm, bn=bn, bk=bk,
                        interpret=interpret)


def _vfl_matmul_fwd(x_local, w_full, offset, bm, bn, bk, interpret):
    y = _vfl_matmul(x_local, w_full, offset, bm, bn, bk, interpret)
    return y, (x_local, w_full)


def _vfl_matmul_bwd(offset, bm, bn, bk, interpret, res, g):
    x_local, w_full = res
    k_local = x_local.shape[1]
    w_slice = jax.lax.slice_in_dim(w_full, offset, offset + k_local,
                                   axis=0)
    g32 = g.astype(jnp.float32)
    dx = (g32 @ w_slice.astype(jnp.float32).T).astype(x_local.dtype)
    dw_block = x_local.astype(jnp.float32).T @ g32
    dw = (jnp.zeros(w_full.shape, jnp.float32)
          .at[offset:offset + k_local].add(dw_block)
          .astype(w_full.dtype))
    return dx, dw


_vfl_matmul.defvjp(_vfl_matmul_fwd, _vfl_matmul_bwd)


@functools.partial(jax.jit,
                   static_argnames=("offset", "bm", "bn", "bk", "interpret"))
def vfl_matmul(x_local, w_full, offset: int, *, gate=None, bm=128, bn=128,
               bk=128, interpret=True):
    """y = zeropad(x_local) @ w_full without materializing the padding.

    Differentiable (custom VJP above). interpret defaults to True
    because this container is CPU-only; on TPU pass interpret=False to
    run the compiled kernel.

    gate: optional traced scalar (e.g. a LayoutArrays.client_mask
    entry) multiplied into the output; gate=0.0 zeroes y AND both
    gradients (the dW scatter rows come out exactly zero), gate=1.0 is
    a bitwise no-op.  This is how padded federations mask dead client
    lanes through the kernel path.
    """
    y = _vfl_matmul(x_local, w_full, offset, bm, bn, bk, interpret)
    if gate is not None:
        y = y * jnp.asarray(gate, y.dtype)
    return y
