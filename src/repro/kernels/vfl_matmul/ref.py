"""Pure-jnp oracle for the VFL block-sparse matmul: materialize the
zero-padding exactly as the paper does and use a dense matmul."""
from __future__ import annotations

import jax.numpy as jnp


def vfl_matmul_ref(x_local, w_full, offset: int):
    """zeropad(x_local) @ w_full, the literal Algorithm-1 computation."""
    M, K_local = x_local.shape
    K_full, _ = w_full.shape
    x_pad = jnp.zeros((M, K_full), x_local.dtype)
    x_pad = x_pad.at[:, offset:offset + K_local].set(x_local)
    return x_pad @ w_full
