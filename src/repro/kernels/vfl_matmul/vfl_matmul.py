"""Block-sparse VFL input matmul -- the TPU-native form of De-VertiFL's
zero-padding (DESIGN.md section 2).

The paper's client multiplies a zero-padded full-width input x' by the
first-layer weight W: y = zeropad(x_local) @ W. All rows of W outside
the client's feature slice meet zeros; a dense matmul wastes
(n_clients-1)/n_clients of the MXU work. This kernel computes
y = x_local @ W[offset:offset+F_local] by *indexing* the weight blocks
through the BlockSpec index_map -- the padding is never materialized
and no zero-block is ever loaded into VMEM.

Grid: (M/bm, N/bn, K_local/bk); the K grid walks only the client's
feature blocks; index_map offsets the W block row by the client's slice
start. Accumulation in fp32 VMEM scratch, written out on the last K
step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _out():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def vfl_matmul_p(x_local, w_full, offset: int, *, bm=128, bn=128, bk=128,
                 interpret=False):
    """x_local: [M, K_local] (client's features, contiguous slice);
    w_full: [K_full, N]; offset: slice start (static, multiple of bk).
    Returns zeropad(x_local) @ w_full == x_local @ w_full[offset:...]."""
    M, K_local = x_local.shape
    K_full, N = w_full.shape
    bm = min(bm, M)
    bn = min(bn, N)
    bk = min(bk, K_local)
    assert offset % bk == 0 and K_local % bk == 0, \
        "client slice must be block-aligned"
    assert offset + K_local <= K_full
    n_k = K_local // bk
    off_blocks = offset // bk

    grid = (pl.cdiv(M, bm), pl.cdiv(N, bn), n_k)
    kernel = functools.partial(_kernel, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            # the block-sparse trick: W's K-block index is offset by the
            # client's slice start -- zero blocks are never touched
            pl.BlockSpec((bk, bn), lambda i, j, k: (off_blocks + k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x_local.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x_local, w_full)
