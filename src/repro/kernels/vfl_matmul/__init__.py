from repro.kernels.vfl_matmul.ops import vfl_matmul  # noqa: F401
from repro.kernels.vfl_matmul.ref import vfl_matmul_ref  # noqa: F401
