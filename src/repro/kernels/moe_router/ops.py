"""jit'd public wrapper for the fused MoE router kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.moe_router.moe_router import moe_router_p


@functools.partial(jax.jit, static_argnames=("k", "bt", "interpret"))
def moe_router(logits, k, *, bt=128, interpret=True):
    """Fused softmax + top-k + renorm + aux stats; interpret=True for
    CPU validation (TPU target uses interpret=False)."""
    return moe_router_p(logits, k, bt=bt, interpret=interpret)
