"""Fused MoE router kernel (TPU Pallas): logits -> softmax -> top-k
selection with renormalized weights, in one VMEM pass.

DeepSeekMoE routes every token over 64 experts with top-6; the unfused
XLA path materializes [T, E] probabilities in HBM three times (softmax,
top_k values, one-hot aux stats). This kernel streams token tiles
through VMEM once: softmax on the [bt, E] tile, then k iterative
argmax+mask sweeps (k <= 8, E <= 128 -- VPU-friendly dims), emitting
packed [bt, k] weights + indices and the per-tile expert-load partial
sums the aux loss needs.

Grid: (T/bt,). Everything fits one VMEM tile: bt*E*4 = 128*64*4 = 32 KiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(logits_ref, w_ref, idx_ref, load_ref, *, k, E, bt):
    x = logits_ref[...].astype(jnp.float32)          # [bt, E]
    m = jnp.max(x, axis=1, keepdims=True)
    p = jnp.exp(x - m)
    p = p / jnp.sum(p, axis=1, keepdims=True)        # softmax

    probs = p
    wsum = jnp.zeros((bt,), jnp.float32)
    ws = []
    idxs = []
    for j in range(k):                                # k small: unrolled
        best = jnp.argmax(probs, axis=1)              # [bt]
        bw = jnp.max(probs, axis=1)
        ws.append(bw)
        idxs.append(best)
        wsum = wsum + bw
        onehot = jax.nn.one_hot(best, E, dtype=probs.dtype)
        probs = probs * (1.0 - onehot)                # mask selected

    w = jnp.stack(ws, axis=1) / wsum[:, None]         # renormalize
    idx = jnp.stack(idxs, axis=1).astype(jnp.int32)
    w_ref[...] = w.astype(w_ref.dtype)
    idx_ref[...] = idx
    # per-tile expert stats for the aux loss: routed count + prob mass
    sel = jax.nn.one_hot(idx, E, dtype=jnp.float32).sum(axis=(0, 1))
    load_ref[...] = (sel + jnp.sum(p, axis=0))[None, :]


def moe_router_p(logits, k, *, bt=128, interpret=False):
    """logits: [T, E] -> (weights [T,k] renormalized, indices [T,k],
    stats [T/bt, E] -- per-tile (routed_count + prob_mass) partials)."""
    T, E = logits.shape
    bt = min(bt, T)
    assert T % bt == 0
    grid = (T // bt,)
    kernel = functools.partial(_kernel, k=k, E=E, bt=bt)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bt, E), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bt, k), lambda i: (i, 0)),
            pl.BlockSpec((bt, k), lambda i: (i, 0)),
            pl.BlockSpec((1, E), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, k), jnp.float32),
            jax.ShapeDtypeStruct((T, k), jnp.int32),
            jax.ShapeDtypeStruct((T // bt, E), jnp.float32),
        ],
        interpret=interpret,
    )(logits)
