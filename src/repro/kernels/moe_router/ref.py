"""Pure-jnp oracle for the fused MoE router."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def moe_router_ref(logits, k, bt=128):
    """logits: [T, E] -> (weights [T,k], indices [T,k], stats [T/bt,E])."""
    T, E = logits.shape
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_w, top_i = jax.lax.top_k(p, k)
    w = top_w / top_w.sum(-1, keepdims=True)
    bt = min(bt, T)
    sel = jax.nn.one_hot(top_i, E, dtype=jnp.float32).sum(1)   # [T, E]
    stats = (sel + p).reshape(T // bt, bt, E).sum(1)
    return w, top_i.astype(jnp.int32), stats
