from repro.kernels.moe_router.ops import moe_router  # noqa: F401
from repro.kernels.moe_router.ref import moe_router_ref  # noqa: F401
