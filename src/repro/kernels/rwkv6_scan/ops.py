"""jit'd public wrapper for the RWKV6 WKV scan kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.rwkv6_scan.rwkv6_scan import rwkv6_scan_p


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(r, k, v, w, u, *, chunk=64, interpret=True):
    """RWKV6 recurrence; interpret=True for CPU validation."""
    return rwkv6_scan_p(r, k, v, w, u, chunk=chunk, interpret=interpret)
