"""Pure-jnp oracle for the RWKV6 WKV scan (lax.scan over time)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv6_scan_ref(r, k, v, w, u):
    """r,k,v,w: [B, T, H, hd]; u: [H, hd] -> o: [B, T, H, hd]."""
    B, T, H, hd = r.shape

    def step(S, inp):
        ri, ki, vi, wi = inp                         # [B, H, hd]
        kv = ki[..., :, None] * vi[..., None, :]     # [B, H, hd, hd]
        o = jnp.einsum("bhk,bhkv->bhv", ri, S + u[..., :, None] * kv)
        S = wi[..., :, None] * S + kv
        return S, o

    S0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    args = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0)
                 for t in (r, k, v, w))
    _, o = jax.lax.scan(step, S0, args)
    return jnp.moveaxis(o, 0, 1).astype(r.dtype)
