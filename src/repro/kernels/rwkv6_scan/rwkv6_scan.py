"""RWKV6 WKV recurrence (TPU Pallas) -- the Finch data-dependent-decay
linear-attention scan:

    o_t = r_t (S + u * k_t v_t^T)
    S  <- diag(w_t) S + k_t v_t^T

Grid: (B, H, T/chunk); the chunk dim iterates fastest so the [hd, hd]
state matrix lives in VMEM scratch across chunk steps -- the HBM
traffic is O(T*hd) for r/k/v/w plus a single state residency, never
O(T*hd^2). Within a chunk the recurrence is a fori_loop of rank-1
updates; on TPU these map to VPU ops with the r_t (S ...) contraction
hitting the MXU per step. A chunk-parallel formulation (materializing
per-chunk decay products) would trade VMEM for parallelism; we keep the
sequential-in-chunk form, which is exact, and note the trade in
EXPERIMENTS.md.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_ref, *, chunk):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0, :, 0, :].astype(jnp.float32)      # [chunk, hd]
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    w = w_ref[0, :, 0, :].astype(jnp.float32)
    u = u_ref[0, :].astype(jnp.float32)            # [hd]

    def step(i, carry):
        S, out = carry
        ri = jax.lax.dynamic_slice_in_dim(r, i, 1, 0)       # [1, hd]
        ki = jax.lax.dynamic_slice_in_dim(k, i, 1, 0)
        vi = jax.lax.dynamic_slice_in_dim(v, i, 1, 0)
        wi = jax.lax.dynamic_slice_in_dim(w, i, 1, 0)
        kv = ki.T @ vi                                       # [hd, hd]
        oi = ri @ (S + u[:, None] * kv)                      # [1, hd]
        S = wi.T * S + kv
        out = jax.lax.dynamic_update_slice_in_dim(out, oi, i, 0)
        return S, out

    S0 = s_ref[...]
    out0 = jnp.zeros((chunk, r.shape[1]), jnp.float32)
    S_fin, out = jax.lax.fori_loop(0, chunk, step, (S0, out0))
    s_ref[...] = S_fin
    o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


def rwkv6_scan_p(r, k, v, w, u, *, chunk=64, interpret=False):
    """r,k,v,w: [B, T, H, hd]; u: [H, hd]. w is the per-step decay in
    (0,1). Returns o: [B, T, H, hd] (fp32 accumulated)."""
    B, T, H, hd = r.shape
    chunk = min(chunk, T)
    assert T % chunk == 0
    grid = (B, H, T // chunk)
    spec = pl.BlockSpec((1, chunk, 1, hd), lambda b, h, t: (b, t, h, 0))
    kernel = functools.partial(_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec, spec, spec, spec,
                  pl.BlockSpec((1, hd), lambda b, h, t: (h, 0))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, T, H, hd), r.dtype),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
