"""Pure-jnp oracle for the Mamba selective scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mamba_scan_ref(a, bx, c):
    """a, bx: [B, T, D, N]; c: [B, T, N] -> y: [B, T, D]."""
    def step(h, inp):
        ai, bxi, ci = inp
        h = ai * h + bxi                              # [B, D, N]
        y = jnp.einsum("bdn,bn->bd", h, ci)
        return h, y

    B, T, D, N = a.shape
    h0 = jnp.zeros((B, D, N), jnp.float32)
    args = (jnp.moveaxis(a.astype(jnp.float32), 1, 0),
            jnp.moveaxis(bx.astype(jnp.float32), 1, 0),
            jnp.moveaxis(c.astype(jnp.float32), 1, 0))
    _, ys = jax.lax.scan(step, h0, args)
    return jnp.moveaxis(ys, 0, 1).astype(a.dtype)
