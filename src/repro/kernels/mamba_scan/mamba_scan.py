"""Mamba (S6) selective-scan kernel (TPU Pallas) -- the Jamba hot spot:

    h_t = a_t * h_{t-1} + b_t        (elementwise over [d, N])
    y_t = h_t @ c_t                  (contract state dim N)

Grid: (B, D/bd, T/chunk); the chunk dim iterates fastest so the [bd, N]
state block lives in VMEM scratch across chunk steps. The d_inner dim
is tiled (bd = 512 lanes) so each grid cell's working set is
chunk*bd*N*4B -- e.g. 64*512*16*4 = 2 MiB, well inside VMEM, and the
HBM traffic is O(T*d*N) streamed once, never re-read.

Within a chunk the recurrence is sequential (fori_loop of VPU
multiply-adds); a log-depth associative formulation would trade 2x the
VMEM for parallelism -- noted as future TPU work in EXPERIMENTS.md.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, bx_ref, c_ref, o_ref, h_ref, *, chunk):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0].astype(jnp.float32)      # [chunk, bd, N]
    bx = bx_ref[0].astype(jnp.float32)    # [chunk, bd, N]
    c = c_ref[0].astype(jnp.float32)      # [chunk, N]

    def step(i, carry):
        h, out = carry
        ai = jax.lax.dynamic_slice_in_dim(a, i, 1, 0)[0]     # [bd, N]
        bxi = jax.lax.dynamic_slice_in_dim(bx, i, 1, 0)[0]
        ci = jax.lax.dynamic_slice_in_dim(c, i, 1, 0)[0]     # [N]
        h = ai * h + bxi
        yi = jnp.sum(h * ci[None, :], axis=1)                # [bd]
        out = jax.lax.dynamic_update_slice_in_dim(
            out, yi[None, :], i, 0)
        return h, out

    h0 = h_ref[...]
    out0 = jnp.zeros((chunk, a.shape[1]), jnp.float32)
    h_fin, out = jax.lax.fori_loop(0, chunk, step, (h0, out0))
    h_ref[...] = h_fin
    o_ref[0] = out.astype(o_ref.dtype)


def mamba_scan_p(a, bx, c, *, bd=512, chunk=64, interpret=False):
    """a, bx: [B, T, d_inner, N]; c: [B, T, N]. Returns y: [B, T, d_inner].

    a is the per-step decay exp(dt*A); bx is dt*B_t*x_t; c is C_t.
    """
    B, T, D, N = a.shape
    bd = min(bd, D)
    chunk = min(chunk, T)
    assert D % bd == 0 and T % chunk == 0
    grid = (B, D // bd, T // chunk)
    spec_a = pl.BlockSpec((1, chunk, bd, N), lambda b, d, t: (b, t, d, 0))
    spec_c = pl.BlockSpec((1, chunk, N), lambda b, d, t: (b, t, 0))
    spec_o = pl.BlockSpec((1, chunk, bd), lambda b, d, t: (b, t, d))
    kernel = functools.partial(_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec_a, spec_a, spec_c],
        out_specs=spec_o,
        out_shape=jax.ShapeDtypeStruct((B, T, D), a.dtype),
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        interpret=interpret,
    )(a, bx, c)
