"""jit'd public wrapper for the Mamba selective-scan kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.mamba_scan.mamba_scan import mamba_scan_p


@functools.partial(jax.jit, static_argnames=("bd", "chunk", "interpret"))
def mamba_scan(a, bx, c, *, bd=512, chunk=64, interpret=True):
    """Selective scan; interpret=True for CPU validation."""
    return mamba_scan_p(a, bx, c, bd=bd, chunk=chunk, interpret=interpret)
