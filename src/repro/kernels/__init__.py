# Pallas TPU kernels for the compute hot spots:
#   vfl_matmul      -- block-sparse first-layer matmul implementing the
#                      paper's zero-padding without multiplying zeros
#   flash_attention -- causal/SWA/GQA/softcap flash attention
#   rwkv6_scan      -- RWKV6 WKV recurrence (data-dependent decay)
# Each package: kernel (pl.pallas_call + BlockSpec), ops.py (jit'd
# wrapper), ref.py (pure-jnp oracle). Validated with interpret=True on
# CPU; TPU is the deployment target.
