"""Tiny name -> entry registries with actionable unknown-name errors.

One class, three instances across the repo (the `repro.api` front door
validates every ``ExperimentSpec`` against them eagerly):

  * datasets      repro.data.registry.DATASETS
  * modes         repro.api.modes.MODES
  * first layers  repro.core.protocol.FIRST_LAYERS

The contract tests/test_api.py pins: looking up an unregistered name
raises ``ValueError`` whose message lists every registered option, so a
typo'd spec fails at construction time with the fix in the traceback.
"""
from __future__ import annotations


class Registry:
    """Ordered name -> entry mapping.

    ``register`` refuses silent shadowing unless ``overwrite=True``;
    ``get`` on an unknown name raises ValueError naming the registered
    options (the actionable-error contract the api layer rides on).
    """

    def __init__(self, kind: str):
        self.kind = kind
        self._entries: dict = {}

    def register(self, name: str, entry, overwrite: bool = False):
        if not isinstance(name, str) or not name:
            raise ValueError(f"{self.kind} name must be a non-empty "
                             f"string, got {name!r}")
        if name in self._entries and not overwrite:
            raise ValueError(
                f"{self.kind} {name!r} is already registered; pass "
                f"overwrite=True to replace it")
        self._entries[name] = entry
        return entry

    def get(self, name: str):
        try:
            return self._entries[name]
        except (KeyError, TypeError):
            opts = ", ".join(repr(n) for n in self.names()) or "<none>"
            raise ValueError(
                f"unknown {self.kind} {name!r}; registered "
                f"{self.kind}s: {opts}") from None

    def __contains__(self, name) -> bool:
        try:
            return name in self._entries
        except TypeError:
            return False

    def names(self) -> list:
        return sorted(self._entries)
