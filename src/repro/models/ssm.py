"""State-space mixers: Mamba (S6 selective scan, for Jamba) and RWKV6
"Finch" (data-dependent decay linear attention).

Both are implemented with chunked sequential scans: the sequence is cut
into chunks; a lax.scan over chunks carries the recurrent state and each
chunk body is rematerialized, bounding activation memory at
O(chunk * state) instead of O(seq * state). The Pallas kernel in
repro.kernels.rwkv6_scan implements the RWKV6 inner recurrence for TPU;
this module is the XLA/CPU path and oracle.

Decode paths carry explicit recurrent state pytrees (the SSM analogue of
a KV cache) -- this is what makes long_500k O(1) per token.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.sharding import constrain


def _chunk_count(S):
    for c in (256, 128, 64, 32, 16, 8, 4, 2, 1):
        if S % c == 0:
            return S // c
    return 1


# ===========================================================================
# Mamba (S6)
# ===========================================================================
def mamba_init(key, cfg, dtype):
    D = cfg.d_model
    d_in = cfg.ssm_expand * D
    N = cfg.ssm_state_dim
    dt_rank = max(1, D // 16)
    ks = jax.random.split(key, 6)
    s = D ** -0.5
    p = {"mamba": {
        "in_proj": (jax.random.normal(ks[0], (D, 2 * d_in), jnp.float32)
                    * s).astype(dtype),
        "conv": (jax.random.normal(ks[1], (cfg.ssm_conv_width, d_in),
                                   jnp.float32) * 0.1).astype(dtype),
        "x_proj": (jax.random.normal(ks[2], (d_in, dt_rank + 2 * N),
                                     jnp.float32) * d_in ** -0.5).astype(dtype),
        "dt_proj": (jax.random.normal(ks[3], (dt_rank, d_in), jnp.float32)
                    * dt_rank ** -0.5).astype(dtype),
        "dt_bias": jnp.zeros((d_in,), jnp.float32),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (d_in, N)).copy()),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": (jax.random.normal(ks[4], (d_in, D), jnp.float32)
                     * d_in ** -0.5).astype(dtype),
    }}
    return p


def _mamba_scan_chunk(h0, a, bx, c):
    """h0: [B, d_in, N]; a, bx: [B, Tc, d_in, N]; c: [B, Tc, N].
    Sequential within-chunk scan (chunk is small)."""
    def step(h, inp):
        ai, bxi, ci = inp
        h = ai * h + bxi
        y = jnp.einsum("bdn,bn->bd", h, ci)
        return h, y
    a_t = jnp.moveaxis(a, 1, 0)
    bx_t = jnp.moveaxis(bx, 1, 0)
    c_t = jnp.moveaxis(c, 1, 0)
    h, ys = jax.lax.scan(step, h0, (a_t, bx_t, c_t))
    return h, jnp.moveaxis(ys, 0, 1)   # [B, Tc, d_in]


def mamba_apply(params, x, cfg, *, return_state=False, init_state=None):
    """x: [B, S, D]. Full-sequence (train/prefill) path."""
    m = params["mamba"]
    B, S, D = x.shape
    d_in = cfg.ssm_expand * D
    N = cfg.ssm_state_dim
    dt_rank = max(1, D // 16)

    xz = x @ m["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_in = constrain(x_in, "batch", None, "ssm_inner")
    # causal depthwise conv
    w = m["conv"]                                     # [K, d_in]
    K = w.shape[0]
    xp = jnp.pad(x_in, ((0, 0), (K - 1, 0), (0, 0)))
    x_conv = sum(xp[:, i:i + S, :] * w[i] for i in range(K))
    x_conv = jax.nn.silu(x_conv)

    proj = x_conv @ m["x_proj"]                       # [B,S,dt_rank+2N]
    dt_raw, Bmat, Cmat = jnp.split(proj, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(dt_raw @ m["dt_proj"] + m["dt_bias"])  # [B,S,d_in]
    A = -jnp.exp(m["A_log"])                          # [d_in, N]
    a = jnp.exp(dt[..., None] * A)                    # [B,S,d_in,N]
    bx = (dt * x_conv)[..., None] * Bmat[:, :, None, :].astype(dt.dtype)

    n_chunks = _chunk_count(S)
    Tc = S // n_chunks
    h0 = init_state if init_state is not None else \
        jnp.zeros((B, d_in, N), dtype=jnp.float32)

    a_c = a.reshape(B, n_chunks, Tc, d_in, N).astype(jnp.float32)
    bx_c = bx.reshape(B, n_chunks, Tc, d_in, N).astype(jnp.float32)
    c_c = Cmat.reshape(B, n_chunks, Tc, N).astype(jnp.float32)

    def chunk_body(h, inp):
        ai, bxi, ci = inp
        return jax.remat(_mamba_scan_chunk)(h, ai, bxi, ci)

    h_final, ys = jax.lax.scan(
        chunk_body, h0,
        (jnp.moveaxis(a_c, 1, 0), jnp.moveaxis(bx_c, 1, 0),
         jnp.moveaxis(c_c, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, d_in).astype(x.dtype)
    y = y + m["D"].astype(x.dtype) * x_conv
    out = (y * jax.nn.silu(z)) @ m["out_proj"]
    if return_state:
        conv_state = xp[:, -(K - 1):, :] if K > 1 else \
            jnp.zeros((B, 0, d_in), x.dtype)
        return out, {"h": h_final, "conv": conv_state}
    return out


def mamba_init_state(cfg, batch, dtype):
    d_in = cfg.ssm_expand * cfg.d_model
    return {
        "h": jnp.zeros((batch, d_in, cfg.ssm_state_dim), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, d_in), dtype),
    }


def mamba_decode(params, x, state, cfg):
    """x: [B, 1, D]; state: {'h': [B,d_in,N], 'conv': [B,K-1,d_in]}."""
    m = params["mamba"]
    B = x.shape[0]
    N = cfg.ssm_state_dim
    dt_rank = max(1, cfg.d_model // 16)

    xz = x[:, 0] @ m["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)
    hist = jnp.concatenate([state["conv"], x_in[:, None, :]], axis=1)  # [B,K,d]
    x_conv = jax.nn.silu(jnp.einsum("bkd,kd->bd", hist, m["conv"]))
    proj = x_conv @ m["x_proj"]
    dt_raw, Bmat, Cmat = jnp.split(proj, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(dt_raw @ m["dt_proj"] + m["dt_bias"])
    A = -jnp.exp(m["A_log"])
    a = jnp.exp(dt[..., None] * A).astype(jnp.float32)
    bx = ((dt * x_conv)[..., None] * Bmat[:, None, :].astype(dt.dtype)
          ).astype(jnp.float32)
    h = a * state["h"] + bx
    y = jnp.einsum("bdn,bn->bd", h, Cmat.astype(jnp.float32)).astype(x.dtype)
    y = y + m["D"].astype(x.dtype) * x_conv
    out = ((y * jax.nn.silu(z)) @ m["out_proj"])[:, None, :]
    return out, {"h": h, "conv": hist[:, 1:, :]}


# ===========================================================================
# RWKV6 (Finch)
# ===========================================================================
def rwkv_init(key, cfg, dtype):
    D = cfg.d_model
    H = D // cfg.rwkv_head_dim
    hd = cfg.rwkv_head_dim
    F = cfg.d_ff
    lora = 64
    ks = jax.random.split(key, 12)
    s = D ** -0.5

    def mat(k, a, b, sc=None):
        return (jax.random.normal(k, (a, b), jnp.float32)
                * (sc or a ** -0.5)).astype(dtype)

    return {"rwkv": {
        "wr": {"kernel": mat(ks[0], D, D)},
        "wk": {"kernel": mat(ks[1], D, D)},
        "wv": {"kernel": mat(ks[2], D, D)},
        "wg": {"kernel": mat(ks[3], D, D)},
        "wo": {"kernel": mat(ks[4], D, D)},
        # data-dependent decay (the Finch novelty): w = f(x) via LoRA
        "decay_lora_a": mat(ks[5], D, lora),
        "decay_lora_b": mat(ks[6], lora, D, 0.01),
        "decay_base": jnp.full((D,), -4.0, jnp.float32),
        "bonus": jnp.full((H, hd), 0.5, jnp.float32),
        # token-shift lerp coefficients for r,k,v,g,w
        "mu": jnp.full((5, D), 0.5, jnp.float32),
        "ln_out": L.norm_init(D, "layernorm"),
        # channel mix
        "mu_cm": jnp.full((2, D), 0.5, jnp.float32),
        "cm_wk": {"kernel": mat(ks[7], D, F)},
        "cm_wv": {"kernel": mat(ks[8], F, D)},
        "cm_wr": {"kernel": mat(ks[9], D, D)},
    }}


def _wkv_chunk(S0, r, k, v, w, u):
    """Sequential WKV recurrence within a chunk.
    S0: [B,H,hd,hd]; r,k,v,w: [B,Tc,H,hd]; u: [H,hd].
    o_t = r_t @ (S + u * k_t^T v_t);  S <- diag(w_t) S + k_t^T v_t."""
    def step(S, inp):
        ri, ki, vi, wi = inp                          # [B,H,hd]
        kv = ki[..., :, None] * vi[..., None, :]      # [B,H,hd,hd]
        o = jnp.einsum("bhk,bhkv->bhv", ri, S + u[..., None] * kv)
        S = wi[..., :, None] * S + kv
        return S, o
    rt, kt, vt, wt = (jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    S, os = jax.lax.scan(step, S0, (rt, kt, vt, wt))
    return S, jnp.moveaxis(os, 0, 1)                  # [B,Tc,H,hd]


def rwkv_time_mix(params, x, cfg, *, x_prev=None, state=None,
                  return_state=False):
    """x: [B,S,D]. x_prev: [B,D] last token of previous segment (decode).
    state: [B,H,hd,hd] WKV state."""
    p = params["rwkv"]
    B, S, D = x.shape
    hd = cfg.rwkv_head_dim
    H = D // hd

    if x_prev is None:
        x_prev = jnp.zeros((B, D), x.dtype)
    shifted = jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)
    mu = p["mu"].astype(x.dtype)
    lerp = [x + (shifted - x) * mu[i] for i in range(5)]  # r,k,v,g,w

    r = (lerp[0] @ p["wr"]["kernel"]).reshape(B, S, H, hd)
    k = (lerp[1] @ p["wk"]["kernel"]).reshape(B, S, H, hd)
    v = (lerp[2] @ p["wv"]["kernel"]).reshape(B, S, H, hd)
    g = jax.nn.silu(lerp[3] @ p["wg"]["kernel"])
    # data-dependent decay in (0,1): exp(-exp(.))
    dd = jnp.tanh(lerp[4].astype(jnp.float32) @ p["decay_lora_a"].astype(
        jnp.float32)) @ p["decay_lora_b"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(p["decay_base"] + dd)).reshape(B, S, H, hd)

    r = constrain(r, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "heads", None)

    S0 = state if state is not None else \
        jnp.zeros((B, H, hd, hd), jnp.float32)
    n_chunks = _chunk_count(S)
    Tc = S // n_chunks
    u = p["bonus"]

    def reshape_c(t):
        return jnp.moveaxis(
            t.astype(jnp.float32).reshape(B, n_chunks, Tc, H, hd), 1, 0)

    def chunk_body(Sc, inp):
        ri, ki, vi, wi = inp
        return jax.remat(_wkv_chunk)(Sc, ri, ki, vi, wi, u)

    S_fin, os = jax.lax.scan(chunk_body, S0,
                             (reshape_c(r), reshape_c(k), reshape_c(v),
                              reshape_c(w)))
    o = jnp.moveaxis(os, 0, 1).reshape(B, S, D).astype(x.dtype)

    # per-head groupnorm
    of = o.reshape(B, S, H, hd).astype(jnp.float32)
    of = (of - of.mean(-1, keepdims=True)) * jax.lax.rsqrt(
        of.var(-1, keepdims=True) + 1e-5)
    o = L.apply_norm(p["ln_out"], of.reshape(B, S, D).astype(x.dtype),
                     "layernorm")
    out = (o * g) @ p["wo"]["kernel"]
    if return_state:
        return out, {"wkv": S_fin, "x_prev_tm": x[:, -1, :]}
    return out


def rwkv_channel_mix(params, x, cfg, *, x_prev=None, return_state=False):
    p = params["rwkv"]
    B, S, D = x.shape
    if x_prev is None:
        x_prev = jnp.zeros((B, D), x.dtype)
    shifted = jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)
    mu = p["mu_cm"].astype(x.dtype)
    xk = x + (shifted - x) * mu[0]
    xr = x + (shifted - x) * mu[1]
    kk = jnp.square(jax.nn.relu(xk @ p["cm_wk"]["kernel"]))
    kk = constrain(kk, "batch", None, "mlp")
    vv = kk @ p["cm_wv"]["kernel"]
    rr = jax.nn.sigmoid(xr @ p["cm_wr"]["kernel"])
    out = rr * vv
    if return_state:
        return out, x[:, -1, :]
    return out


def rwkv_init_state(cfg, batch, dtype):
    D = cfg.d_model
    hd = cfg.rwkv_head_dim
    H = D // hd
    return {
        "wkv": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "x_prev_tm": jnp.zeros((batch, D), dtype),
        "x_prev_cm": jnp.zeros((batch, D), dtype),
    }
