"""Composable decoder / encoder-decoder stacks over heterogeneous layer
kinds (attention, Mamba, RWKV6, dense FFN, MoE), assembled from a
ModelConfig.

Layer stacks are decomposed into (prefix, periodic-group) form and the
periodic part is lax.scan'ed over stacked params so HLO size is O(period)
not O(num_layers) -- essential for compiling 56-layer models for a
512-device mesh on one CPU. Each scan body is rematerialized.

The De-VertiFL input block (vertical feature partitioning + Hidden
OutputExchange) lives in embed_input()/exchange_features(): with a mesh,
the embedding's d_model dim is sharded over the client axis and the
exchange reconstitutes full hidden features either by the paper's
zero-pad + psum (Algorithm 2) or the optimized all-gather (see
DESIGN.md §2).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name as _checkpoint_name
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map

from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.sharding import constrain, current_mesh, current_rules


# ---------------------------------------------------------------------------
# layer-kind schedule
# ---------------------------------------------------------------------------
def layer_kinds(cfg):
    kinds = []
    for l in range(cfg.num_layers):
        if cfg.ssm_type == "rwkv6":
            mixer = "rwkv"
        elif cfg.ssm_type == "mamba" and (
                cfg.attn_layer_period == 0
                or l % cfg.attn_layer_period != cfg.attn_layer_offset):
            mixer = "mamba"
        else:
            mixer = "attn"
        window = A.layer_window_for(cfg, l) if mixer == "attn" else None
        if mixer == "rwkv":
            ffn = "rwkv_cm"
        elif l == 0 and cfg.first_layer_dense_ff:
            ffn = "dense0"
        elif cfg.num_experts and (l % cfg.moe_every) == cfg.moe_offset:
            ffn = "moe"
        else:
            ffn = "dense"
        kinds.append({
            "mixer": mixer, "ffn": ffn, "window": window,
            "cross": cfg.is_encoder_decoder, "causal": True,
        })
    return kinds


def encoder_kinds(cfg):
    return [{"mixer": "attn", "ffn": "dense", "window": None,
             "cross": False, "causal": False}
            for _ in range(cfg.num_encoder_layers)]


def periodic_split(kinds):
    """Return (prefix_len, period) decomposing kinds into an irregular
    prefix followed by a periodic tail."""
    n = len(kinds)
    for prefix in (0, 1, 2):
        rest = kinds[prefix:]
        if not rest:
            continue
        for period in range(1, min(16, len(rest)) + 1):
            if len(rest) % period:
                continue
            if all(rest[i] == rest[i % period] for i in range(len(rest))):
                return prefix, period
    return n, 1


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------
def block_init(key, cfg, kind, dtype):
    ks = jax.random.split(key, 6)
    D = cfg.d_model
    p = {"pre_norm": L.norm_init(D, cfg.norm_type)}
    if kind["mixer"] == "attn":
        p["attn"] = A.attn_init(ks[0], cfg, dtype)
    elif kind["mixer"] == "mamba":
        p.update(S.mamba_init(ks[0], cfg, dtype))
    elif kind["mixer"] == "rwkv":
        p.update(S.rwkv_init(ks[0], cfg, dtype))
    if kind["cross"]:
        p["cross_norm"] = L.norm_init(D, cfg.norm_type)
        p["cross"] = A.attn_init(ks[1], cfg, dtype)
    p["ffn_norm"] = L.norm_init(D, cfg.norm_type)
    if kind["ffn"] == "moe":
        p["moe"] = M.moe_init(ks[2], cfg, dtype)
    elif kind["ffn"] == "dense0":
        p["ffn"] = L.mlp_init(ks[2], D, cfg.first_layer_dense_ff, cfg.act,
                              dtype)
    elif kind["ffn"] == "dense":
        p["ffn"] = L.mlp_init(ks[2], D, cfg.d_ff, cfg.act, dtype)
    return p


def block_apply(p, x, positions, cfg, kind, enc=None):
    """Full-sequence block. Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    name = _checkpoint_name
    h = L.apply_norm(p["pre_norm"], x, cfg.norm_type)
    if kind["mixer"] == "attn":
        y = A.attn_apply(p["attn"], h, positions, cfg,
                         layer_window=kind["window"],
                         causal=kind.get("causal", True))
    elif kind["mixer"] == "mamba":
        y = S.mamba_apply(p, h, cfg)
    else:
        y = S.rwkv_time_mix(p, h, cfg)
    x = x + name(y, "mixer_out")
    if kind["cross"] and enc is not None:
        hc = L.apply_norm(p["cross_norm"], x, cfg.norm_type)
        x = x + A.attn_apply(p["cross"], hc, positions, cfg, causal=False,
                             kv_override=enc)
    h2 = L.apply_norm(p["ffn_norm"], x, cfg.norm_type)
    if kind["ffn"] == "rwkv_cm":
        x = x + name(S.rwkv_channel_mix(p, h2, cfg), "ffn_out")
        return x, aux
    if kind["ffn"] == "moe":
        y, aux = M.moe_apply(p["moe"], h2, cfg)
        x = x + name(y, "ffn_out")
    else:
        x = x + name(L.mlp_apply(p["ffn"], h2, cfg.act), "ffn_out")
    return x, aux


def block_prefill(p, x, positions, cfg, kind, batch, cache_len, dtype,
                  enc=None):
    """Full-sequence forward that also emits the decode cache for this
    block (forward-only: the inference-prefill path)."""
    h = L.apply_norm(p["pre_norm"], x, cfg.norm_type)
    cache = {}
    if kind["mixer"] == "attn":
        y, (k, v) = A.attn_apply(p["attn"], h, positions, cfg,
                                 layer_window=kind["window"],
                                 causal=kind.get("causal", True),
                                 return_kv=True)
        x = x + y
        empty = A.init_cache(cfg, batch,
                             min(cache_len, kind["window"])
                             if kind["window"] else cache_len,
                             kind["window"], dtype)
        cache["attn"] = A.fill_cache_from_prefill(empty, k, v, positions,
                                                  batch)
    elif kind["mixer"] == "mamba":
        y, st = S.mamba_apply(p, h, cfg, return_state=True)
        x = x + y
        cache["mamba"] = st
    else:
        y, tm = S.rwkv_time_mix(p, h, cfg, return_state=True)
        x = x + y
        cache["rwkv"] = {"wkv": tm["wkv"], "x_prev_tm": h[:, -1, :]}
    if kind["cross"] and enc is not None:
        hc = L.apply_norm(p["cross_norm"], x, cfg.norm_type)
        x = x + A.attn_apply(p["cross"], hc, positions, cfg, causal=False,
                             kv_override=enc)
    h2 = L.apply_norm(p["ffn_norm"], x, cfg.norm_type)
    if kind["ffn"] == "rwkv_cm":
        y, cm_prev = S.rwkv_channel_mix(p, h2, cfg, return_state=True)
        x = x + y
        cache["rwkv"]["x_prev_cm"] = h2[:, -1, :]
    elif kind["ffn"] == "moe":
        y, _ = M.moe_apply(p["moe"], h2, cfg)
        x = x + y
    else:
        x = x + L.mlp_apply(p["ffn"], h2, cfg.act)
    return x, cache


def block_init_cache(cfg, kind, batch, seq_len, dtype):
    if kind["mixer"] == "attn":
        c = {"attn": A.init_cache(cfg, batch, seq_len, kind["window"], dtype)}
    elif kind["mixer"] == "mamba":
        c = {"mamba": S.mamba_init_state(cfg, batch, dtype)}
    else:
        c = {"rwkv": S.rwkv_init_state(cfg, batch, dtype)}
    return c


def block_decode(p, x, position, cfg, kind, cache, enc=None):
    """One-token decode. Returns (x, new_cache)."""
    h = L.apply_norm(p["pre_norm"], x, cfg.norm_type)
    new_cache = dict(cache)
    if kind["mixer"] == "attn":
        y, new_cache["attn"] = A.attn_decode(
            p["attn"], h, position, cache["attn"], cfg,
            layer_window=kind["window"])
        x = x + y
    elif kind["mixer"] == "mamba":
        y, new_cache["mamba"] = S.mamba_decode(p, h, cache["mamba"], cfg)
        x = x + y
    else:
        st = cache["rwkv"]
        y, tm_state = S.rwkv_time_mix(
            p, h, cfg, x_prev=st["x_prev_tm"], state=st["wkv"],
            return_state=True)
        x = x + y
        new_st = dict(st)
        new_st["wkv"] = tm_state["wkv"]
        new_st["x_prev_tm"] = h[:, -1, :]
        new_cache["rwkv"] = new_st
    if kind["cross"] and enc is not None:
        hc = L.apply_norm(p["cross_norm"], x, cfg.norm_type)
        x = x + A.attn_apply(p["cross"], hc, position[:, None], cfg,
                             causal=False, kv_override=enc)
    if kind["ffn"] == "rwkv_cm":
        st = new_cache["rwkv"]
        h2 = L.apply_norm(p["ffn_norm"], x, cfg.norm_type)
        y, cm_prev = S.rwkv_channel_mix(p, h2, cfg,
                                        x_prev=st["x_prev_cm"],
                                        return_state=True)
        x = x + y
        st2 = dict(st)
        st2["x_prev_cm"] = h2[:, -1, :]
        new_cache["rwkv"] = st2
        return x, new_cache
    h2 = L.apply_norm(p["ffn_norm"], x, cfg.norm_type)
    if kind["ffn"] == "moe":
        y, _ = M.moe_apply(p["moe"], h2, cfg)
        x = x + y
    else:
        x = x + L.mlp_apply(p["ffn"], h2, cfg.act)
    return x, new_cache


# ---------------------------------------------------------------------------
# stacks (prefix + scanned periodic groups)
# ---------------------------------------------------------------------------
class StackLayout:
    def __init__(self, cfg, kinds):
        self.kinds = kinds
        if cfg.scan_layers:
            self.prefix, self.period = periodic_split(kinds)
        else:
            self.prefix, self.period = len(kinds), 1
        self.n_groups = (len(kinds) - self.prefix) // self.period \
            if self.prefix < len(kinds) else 0
        self.group_kinds = kinds[self.prefix:self.prefix + self.period] \
            if self.n_groups else []


def stack_init(key, cfg, kinds, dtype):
    layout = StackLayout(cfg, kinds)
    ks = jax.random.split(key, layout.prefix + 1)
    params = {}
    for i in range(layout.prefix):
        params[f"layer_{i}"] = block_init(ks[i], cfg, kinds[i], dtype)
    if layout.n_groups:
        def ginit(k):
            gks = jax.random.split(k, layout.period)
            return {f"sub_{j}": block_init(gks[j], cfg,
                                           layout.group_kinds[j], dtype)
                    for j in range(layout.period)}
        gkeys = jax.random.split(ks[-1], layout.n_groups)
        params["scanned"] = jax.vmap(ginit)(gkeys)
    return params


def stack_apply(params, x, positions, cfg, kinds, enc=None):
    layout = StackLayout(cfg, kinds)
    aux = jnp.zeros((), jnp.float32)

    policy = None
    if cfg.remat_policy == "save_mixer_ffn":
        policy = jax.checkpoint_policies.save_only_these_names(
            "mixer_out", "ffn_out")

    for i in range(layout.prefix):
        fn = block_apply
        if cfg.remat:
            fn = jax.remat(fn, static_argnums=(3, 4), policy=policy)
        x, a = fn(params[f"layer_{i}"], x, positions, cfg, kinds[i], enc)
        aux = aux + a

    if layout.n_groups:
        def body(carry, gparams):
            xc, auxc = carry
            for j, kind in enumerate(layout.group_kinds):
                xc, a = block_apply(gparams[f"sub_{j}"], xc, positions, cfg,
                                    kind, enc)
                auxc = auxc + a
            return (xc, auxc), None
        if cfg.remat:
            body = jax.remat(body, policy=policy)
        (x, aux), _ = jax.lax.scan(body, (x, aux), params["scanned"])
    return x, aux


def stack_init_cache(cfg, kinds, batch, seq_len, dtype):
    layout = StackLayout(cfg, kinds)
    cache = {}
    for i in range(layout.prefix):
        cache[f"layer_{i}"] = block_init_cache(cfg, kinds[i], batch, seq_len,
                                               dtype)
    if layout.n_groups:
        def one_group(_):
            return {f"sub_{j}": block_init_cache(cfg, layout.group_kinds[j],
                                                 batch, seq_len, dtype)
                    for j in range(layout.period)}
        groups = [one_group(g) for g in range(layout.n_groups)]
        cache["scanned"] = jax.tree.map(lambda *xs: jnp.stack(xs), *groups)
    return cache


def stack_prefill(params, x, positions, cfg, kinds, batch, cache_len,
                  dtype, enc=None):
    layout = StackLayout(cfg, kinds)
    cache = {}
    for i in range(layout.prefix):
        x, cache[f"layer_{i}"] = block_prefill(
            params[f"layer_{i}"], x, positions, cfg, kinds[i], batch,
            cache_len, dtype, enc)

    if layout.n_groups:
        def body(xc, gparams):
            newc = {}
            for j, kind in enumerate(layout.group_kinds):
                xc, newc[f"sub_{j}"] = block_prefill(
                    gparams[f"sub_{j}"], xc, positions, cfg, kind, batch,
                    cache_len, dtype, enc)
            return xc, newc
        if cfg.remat:
            body = jax.remat(body)
        x, cache["scanned"] = jax.lax.scan(body, x, params["scanned"])
    return x, cache


def stack_decode(params, x, position, cfg, kinds, cache, enc=None):
    layout = StackLayout(cfg, kinds)
    new_cache = {}
    for i in range(layout.prefix):
        x, new_cache[f"layer_{i}"] = block_decode(
            params[f"layer_{i}"], x, position, cfg, kinds[i],
            cache[f"layer_{i}"], enc)

    if layout.n_groups:
        def body(xc, inp):
            gparams, gcache = inp
            newc = {}
            for j, kind in enumerate(layout.group_kinds):
                xc, newc[f"sub_{j}"] = block_decode(
                    gparams[f"sub_{j}"], xc, position, cfg, kind,
                    gcache[f"sub_{j}"], enc)
            return xc, newc
        x, new_cache["scanned"] = jax.lax.scan(
            body, x, (params["scanned"], cache["scanned"]))
    return x, new_cache


# ---------------------------------------------------------------------------
# De-VertiFL input block
# ---------------------------------------------------------------------------
def _client_axis():
    mesh = current_mesh()
    if mesh is None:
        return None, 0
    ax = current_rules().to_mesh_axes("client")
    if ax is None or ax not in mesh.axis_names or mesh.shape[ax] == 1:
        return None, 0
    return ax, mesh.shape[ax]


def exchange_features(x_local, axis, n, mode, batch_axes):
    """HiddenOutputExchange over client-sharded features.

    x_local (inside shard_map): [B_local, S, D/n] -- this client's slice.
    mode 'zeropad_psum': paper Algorithm 2 -- zero-pad to full width and
        sum across clients (each client transmits the full-width tensor).
    mode 'allgather': exchange only owned slices (1/n bytes).
    """
    if mode == "zeropad_psum":
        d_local = x_local.shape[-1]
        idx = jax.lax.axis_index(axis)
        full = jnp.zeros(x_local.shape[:-1] + (d_local * n,), x_local.dtype)
        full = jax.lax.dynamic_update_slice_in_dim(
            full, x_local, idx * d_local, axis=x_local.ndim - 1)
        return jax.lax.psum(full, axis)          # the exchange
    return jax.lax.all_gather(x_local, axis, axis=x_local.ndim - 1,
                              tiled=True)


def embed_input(params, ids, cfg, prefix_emb=None):
    """Token embedding with optional De-VertiFL vertical input block.
    Returns full-width features [B, S_total, D]."""
    axis, n = _client_axis()
    emb_scale = cfg.d_model ** 0.5 if cfg.final_logit_softcap else 1.0
    key = "vfl_embedding" if cfg.vfl.enabled else "embedding"
    table = params[key]["table"]
    if not cfg.vfl.enabled or axis is None:
        h = L.embed(params[key], ids)
        if prefix_emb is not None:
            h = jnp.concatenate([prefix_emb.astype(h.dtype), h], axis=1)
        return h * jnp.asarray(emb_scale, h.dtype)

    mesh = current_mesh()
    rules = current_rules()
    batch_axes = rules.to_mesh_axes("batch")
    if not isinstance(batch_axes, (tuple, list)):
        batch_axes = (batch_axes,) if batch_axes else ()
    # keep only axes that exist in this mesh AND divide the batch evenly
    kept, prod = [], 1
    for a in batch_axes:
        if a in mesh.axis_names and ids.shape[0] % (prod * mesh.shape[a]) == 0:
            kept.append(a)
            prod *= mesh.shape[a]
    batch_axes = tuple(kept) if kept else None
    mode = cfg.vfl.exchange

    bspec = P(batch_axes, None)
    out_spec = P(batch_axes, None, None)

    if prefix_emb is None:
        def local_fn(table_local, ids_local):
            # table_local: [V, D/n] -- this client's vertical feature slice
            emb = jnp.take(table_local, ids_local, axis=0)  # [B_l,S,D/n]
            return exchange_features(emb, axis, n, mode, batch_axes)
        h = shard_map(local_fn, mesh=mesh,
                      in_specs=(P(None, axis), bspec),
                      out_specs=out_spec, check_vma=False)(table, ids)
    else:
        def local_fn(table_local, ids_local, prefix_local):
            emb = jnp.take(table_local, ids_local, axis=0)
            emb = jnp.concatenate(
                [prefix_local.astype(emb.dtype), emb], axis=1)
            return exchange_features(emb, axis, n, mode, batch_axes)
        h = shard_map(local_fn, mesh=mesh,
                      in_specs=(P(None, axis), bspec,
                                P(batch_axes, None, axis)),
                      out_specs=out_spec, check_vma=False)(
                          table, ids, prefix_emb)
    return h * jnp.asarray(emb_scale, h.dtype)


@jax.custom_vjp
def _tied_logits(h, table):
    return h @ table.T


def _tied_logits_fwd(h, table):
    return _tied_logits(h, table), (h, table)


def _tied_logits_bwd(res, dlogits):
    """The table is D-sharded (VFL client slices) while logits are
    vocab-sharded; without this VJP, GSPMD computes dtable by
    ALL-GATHERING the [B,S,V] activation grads over the model axis
    (37 GB/step for qwen1.5-0.5b). Instead: contract locally in the
    vocab-sharded layout, then reshard the [V, D] weight grad (~0.6 GB)
    -- EXPERIMENTS.md section Perf iter 5."""
    h, table = res
    dh = dlogits @ table                                  # psum over model
    dtable = jnp.einsum("bsv,bsd->vd", dlogits, h)
    dtable = constrain(dtable, "vocab", None)             # compute sharded
    dtable = constrain(dtable, None, "client")            # reshard to param
    return dh, dtable.astype(table.dtype)


_tied_logits.defvjp(_tied_logits_fwd, _tied_logits_bwd)


def logits_from_hidden(params, h, cfg):
    key = "vfl_embedding" if cfg.vfl.enabled else "embedding"
    if cfg.tie_embeddings:
        logits = _tied_logits(h, params[key]["table"])
    else:
        logits = L.dense(params["lm_head"], h)
    logits = L.softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)
    return constrain(logits, "batch", None, "vocab")
