"""Attention: GQA/MHA with RoPE, QKV bias, logit softcap, full / sliding
-window / local+global variants, bidirectional (encoder) and cross
attention, chunked-query prefill (flash-style memory behaviour in pure
XLA) and ring-buffer KV caches for windowed decode.

The Pallas flash kernel in repro.kernels.flash_attention implements the
same math for the TPU hot path; this module is the XLA reference path
used for dry-run lowering and CPU execution (see DESIGN.md §6).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.sharding import constrain

NEG_INF = -2.3819763e38  # large negative for bf16-safe masking


def attn_init(key, cfg, dtype):
    ks = jax.random.split(key, 4)
    H, KV, hd, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    return {
        "wq": L.dense_init(ks[0], D, H * hd, dtype, bias=cfg.qkv_bias),
        "wk": L.dense_init(ks[1], D, KV * hd, dtype, bias=cfg.qkv_bias),
        "wv": L.dense_init(ks[2], D, KV * hd, dtype, bias=cfg.qkv_bias),
        "wo": L.dense_init(ks[3], H * hd, D, dtype),
    }


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def _attend(q, k, v, qpos, kpos, *, causal, window, cap, scale):
    """q: [B,Q,H,hd]; k,v: [B,S,KV,hd]; qpos: [Q] or [B,Q]; kpos: [S] or [B,S].
    kpos < 0 marks invalid (unwritten ring slots / padding)."""
    B, Q, H, hd = q.shape
    KV = k.shape[2]
    g = H // KV
    qr = q.reshape(B, Q, KV, g, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qr, k,
                        preferred_element_type=jnp.float32) * scale
    scores = L.softcap(scores, cap)
    # keep positions 1-D when batch-invariant (train/prefill): the mask
    # stays [1,1,1,Q,S] instead of [B,1,1,Q,S] -- a B x smaller tensor
    # that XLA would otherwise materialize and carry through the layer
    # scan (EXPERIMENTS.md section Perf, iteration 1)
    if qpos.ndim == 1:
        qpos = qpos[None]               # [1, Q]
    if kpos.ndim == 1:
        kpos = kpos[None]               # [1, S]
    qp = qpos[:, None, None, :, None]   # [B|1,1,1,Q,1]
    kp = kpos[:, None, None, None, :]   # [B|1,1,1,1,S]
    mask = kp >= 0
    if causal:
        mask = mask & (kp <= qp)
    if window:
        mask = mask & (qp - kp < window)
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs.astype(v.dtype), v)
    return out.reshape(B, Q, H, hd)


def _chunked_attend(q, k, v, qpos, kpos, *, causal, window, cap, scale,
                    q_chunk):
    """Scan over query chunks so the [Q,S] score tensor never fully
    materializes. For windowed attention only the [chunk-window, chunk)
    key band is touched -> O(S*window) FLOPs instead of O(S^2)."""
    B, S, H, hd = q.shape
    n_chunks = S // q_chunk
    assert S % q_chunk == 0

    if window and causal:
        # pad keys on the left so every chunk reads a static-size band
        pad = ((0, 0), (window, 0), (0, 0), (0, 0))
        k_p = jnp.pad(k, pad)
        v_p = jnp.pad(v, pad)
        kpos_p = jnp.pad(kpos, (window, 0), constant_values=-1)

        def body(_, i):
            start = i * q_chunk
            qc = jax.lax.dynamic_slice_in_dim(q, start, q_chunk, axis=1)
            kc = jax.lax.dynamic_slice_in_dim(k_p, start, window + q_chunk, 1)
            vc = jax.lax.dynamic_slice_in_dim(v_p, start, window + q_chunk, 1)
            kpc = jax.lax.dynamic_slice_in_dim(kpos_p, start,
                                               window + q_chunk, 0)
            qpc = jax.lax.dynamic_slice_in_dim(qpos, start, q_chunk, 0)
            return None, _attend(qc, kc, vc, qpc, kpc, causal=True,
                                 window=window, cap=cap, scale=scale)
    else:
        def body(_, i):
            start = i * q_chunk
            qc = jax.lax.dynamic_slice_in_dim(q, start, q_chunk, axis=1)
            qpc = jax.lax.dynamic_slice_in_dim(qpos, start, q_chunk, 0)
            return None, _attend(qc, k, v, qpc, kpos, causal=causal,
                                 window=window, cap=cap, scale=scale)

    _, chunks = jax.lax.scan(body, None, jnp.arange(n_chunks))
    # chunks: [n_chunks, B, q_chunk, H, hd]
    out = jnp.moveaxis(chunks, 0, 1).reshape(B, S, H, hd)
    return out


def attn_apply(params, x, positions, cfg, *, layer_window=None, causal=True,
               kv_override=None, return_kv=False):
    """Full-sequence (train / prefill) attention.

    layer_window: None -> cfg-level behaviour; int -> sliding window.
    kv_override: (k_src,) tensor for cross-attention (keys/values computed
        from encoder output instead of x).
    return_kv: also return (k, v) post-rope for prefill cache population.
    """
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    B, S, _ = x.shape
    kv_src = x if kv_override is None else kv_override
    q = _split_heads(L.dense(params["wq"], x), H, hd)
    k = _split_heads(L.dense(params["wk"], kv_src), KV, hd)
    v = _split_heads(L.dense(params["wv"], kv_src), KV, hd)
    if kv_override is None:
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        kpos = positions
    else:
        kpos = jnp.arange(kv_src.shape[1])
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "heads", None)
    scale = hd ** -0.5
    window = layer_window
    Skv = kv_src.shape[1]
    q_chunk = _pick_chunk(S, Skv, window if causal else None)
    if q_chunk < S:
        out = _chunked_attend(q, k, v, positions, kpos, causal=causal,
                              window=window, cap=cfg.attn_logit_softcap,
                              scale=scale, q_chunk=q_chunk)
    else:
        out = _attend(q, k, v, positions, kpos, causal=causal, window=window,
                      cap=cfg.attn_logit_softcap, scale=scale)
    out = constrain(out, "batch", None, "heads", None)
    out = L.dense(params["wo"], out.reshape(B, S, H * hd))
    if return_kv:
        return out, (k, v)
    return out


def fill_cache_from_prefill(cache, k, v, positions, batch_size):
    """Scatter a full-sequence prefill's (k, v) into a (possibly ring)
    cache. positions: [S] absolute; ring slot = pos % size; only the
    last `size` positions survive (exactly what decode would have
    written)."""
    size = cache["k"].shape[1]
    S = k.shape[1]
    take = min(S, size)
    k_t = k[:, S - take:]
    v_t = v[:, S - take:]
    pos_t = positions[S - take:]
    slots = pos_t % size
    new_k = cache["k"].at[:, slots].set(k_t)
    new_v = cache["v"].at[:, slots].set(v_t)
    new_pos = cache["pos"].at[:, slots].set(
        jnp.broadcast_to(pos_t[None], (batch_size, take)))
    new_k = constrain(new_k, "batch", "kv_seq", "heads", None)
    new_v = constrain(new_v, "batch", "kv_seq", "heads", None)
    return {"k": new_k, "v": new_v, "pos": new_pos}


def _pick_chunk(S, Skv, window):
    """Choose a query-chunk so the score tensor stays ~O(chunk * band)."""
    if S <= 4096 and Skv <= 4096:
        return S
    for c in (1024, 512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if S % c == 0:
            return c
    return 1


# ---------------------------------------------------------------------------
# decode with KV cache (ring buffer for windowed layers)
# ---------------------------------------------------------------------------
def init_cache(cfg, batch, seq_len, layer_window, dtype):
    size = min(seq_len, layer_window) if layer_window else seq_len
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, size, KV, hd), dtype=dtype),
        "v": jnp.zeros((batch, size, KV, hd), dtype=dtype),
        "pos": jnp.full((batch, size), -1, dtype=jnp.int32),
    }


def attn_decode(params, x, position, cache, cfg, *, layer_window=None):
    """One-token decode. x: [B,1,D]; position: [B] int32 (absolute);
    cache: dict with ring-buffer k/v/pos. Returns (out, new_cache)."""
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    B = x.shape[0]
    q = _split_heads(L.dense(params["wq"], x), H, hd)
    k = _split_heads(L.dense(params["wk"], x), KV, hd)
    v = _split_heads(L.dense(params["wv"], x), KV, hd)
    q = L.apply_rope(q, position[:, None], cfg.rope_theta)
    k = L.apply_rope(k, position[:, None], cfg.rope_theta)

    size = cache["k"].shape[1]
    slot = position % size                              # [B]
    b = jnp.arange(B)
    new_k = cache["k"].at[b, slot].set(k[:, 0])
    new_v = cache["v"].at[b, slot].set(v[:, 0])
    new_pos = cache["pos"].at[b, slot].set(position)
    new_k = constrain(new_k, "batch", "kv_seq", "heads", None)
    new_v = constrain(new_v, "batch", "kv_seq", "heads", None)

    out = _attend(q, new_k, new_v, position[:, None], new_pos,
                  causal=True, window=layer_window,
                  cap=cfg.attn_logit_softcap, scale=hd ** -0.5)
    out = L.dense(params["wo"], out.reshape(B, 1, H * hd))
    return out, {"k": new_k, "v": new_v, "pos": new_pos}


def layer_window_for(cfg, layer_idx):
    """Resolve the attention window for a given layer index."""
    if cfg.attn_type == "swa":
        return cfg.window_size
    if cfg.attn_type == "local_global":
        # even layers local (windowed), odd layers global -- gemma2 style
        return cfg.window_size if layer_idx % 2 == 0 else None
    return None
