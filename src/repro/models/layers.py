"""Primitive layers as pure functions over dict params.

Params are plain nested dicts of jnp arrays so the whole model state is
a pytree that pjit/shard_map/checkpointing handle natively. Init
functions take explicit PRNG keys; apply functions are side-effect free.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding import constrain


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def dense_init(key, in_dim, out_dim, dtype, bias=False, scale=None):
    scale = scale if scale is not None else in_dim ** -0.5
    k = jax.random.normal(key, (in_dim, out_dim), dtype=jnp.float32) * scale
    p = {"kernel": k.astype(dtype)}
    if bias:
        p["bias"] = jnp.zeros((out_dim,), dtype=dtype)
    return p


def dense(params, x):
    y = x @ params["kernel"]
    if "bias" in params:
        y = y + params["bias"]
    return y


def embedding_init(key, vocab, dim, dtype):
    t = jax.random.normal(key, (vocab, dim), dtype=jnp.float32) * (dim ** -0.5)
    return {"table": t.astype(dtype)}


def embed(params, ids):
    return jnp.take(params["table"], ids, axis=0)


def norm_init(dim, kind="rmsnorm"):
    p = {"scale": jnp.ones((dim,), dtype=jnp.float32)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((dim,), dtype=jnp.float32)
    return p


def apply_norm(params, x, kind="rmsnorm", eps=1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------
def rope_freqs(head_dim, theta):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta):
    """x: [..., S, H, hd]; positions: [..., S] int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [...,S,hd/2]
    cos = jnp.cos(angles)[..., :, None, :]              # [...,S,1,hd/2]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def softcap(x, cap):
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# MLP blocks
# ---------------------------------------------------------------------------
def mlp_init(key, d_model, d_ff, act, dtype, prefix_bias=False):
    ks = jax.random.split(key, 3)
    if act == "swiglu":
        return {
            "w_gate": dense_init(ks[0], d_model, d_ff, dtype),
            "w_up": dense_init(ks[1], d_model, d_ff, dtype),
            "w_down": dense_init(ks[2], d_ff, d_model, dtype),
        }
    return {
        "wi": dense_init(ks[0], d_model, d_ff, dtype, bias=prefix_bias),
        "w_down": dense_init(ks[1], d_ff, d_model, dtype, bias=prefix_bias),
    }


def mlp_apply(params, x, act):
    if act == "swiglu":
        h = jax.nn.silu(dense(params["w_gate"], x)) * dense(params["w_up"], x)
    elif act == "gelu":
        h = jax.nn.gelu(dense(params["wi"], x), approximate=True)
    else:
        h = jax.nn.relu(dense(params["wi"], x))
    h = constrain(h, "batch", None, "mlp")
    return dense(params["w_down"], h)
