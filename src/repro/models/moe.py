"""Mixture-of-Experts with group-local capacity dispatch.

Tokens are reshaped into G groups (sharded over the data axis); each
group dispatches its own tokens into a per-group [E, C, D] buffer via
sort + scatter, so no cross-shard cumsum serializes, and expert FLOPs
are proportional to *active* parameters (top-k), which keeps the
roofline honest. Capacity overflow drops tokens (residual keeps them).

Supports Mixtral-style (8 routed, top-2, renormalized) and
DeepSeekMoE-style (64 fine-grained routed top-6 + shared experts that
every token visits, implemented as one fused dense FFN of width
n_shared * d_ff).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.sharding import constrain, current_mesh, current_rules


def moe_init(key, cfg, dtype):
    E = cfg.num_experts
    F = cfg.moe_d_ff or cfg.d_ff
    D = cfg.d_model
    ks = jax.random.split(key, 6)
    scale = D ** -0.5

    def stack(k, a, b):
        w = jax.random.normal(k, (E, a, b), dtype=jnp.float32) * (a ** -0.5)
        return w.astype(dtype)

    p = {
        "router": {"kernel": (jax.random.normal(ks[0], (D, E),
                              dtype=jnp.float32) * scale)},
        "experts": {
            "w_gate": stack(ks[1], D, F),
            "w_up": stack(ks[2], D, F),
            "w_down": stack(ks[3], F, D),
        },
    }
    if cfg.num_shared_experts:
        p["shared"] = L.mlp_init(ks[4], D, cfg.num_shared_experts * F,
                                 "swiglu", dtype)
    return p


def _pick_groups(total_tokens: int, batch: int) -> int:
    """Groups must divide total tokens; prefer ~>=256 tokens per group so
    capacity quantization stays small, while keeping G a multiple that
    the data axis can shard."""
    if total_tokens <= 256:
        return 1
    g = batch
    while g > 1 and total_tokens // g < 256:
        g //= 2
    return max(g, 1)


def _dispatch(xg, top_idx, E, C):
    """Group-batched dispatch, G-major so the group dim stays visible to
    the partitioner (a vmapped formulation loses the sharding of the
    internal scatter buffers and GSPMD reconstructs them with
    full-replica all-reduces -- see EXPERIMENTS.md section Perf iter 2).

    xg: [G, T, D]; top_idx: [G, T, k].
    Returns (buf [G, E, C, D], dest [G, T*k], keep, src, order).
    """
    G, T, D = xg.shape
    k = top_idx.shape[-1]
    flat_e = top_idx.reshape(G, T * k)
    order = jnp.argsort(flat_e, axis=1, stable=True)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    # position within expert run: index - index of run start (cummax of
    # run-start positions replaces a per-row searchsorted)
    idx = jnp.broadcast_to(jnp.arange(T * k)[None], (G, T * k))
    starts = jnp.concatenate(
        [jnp.ones((G, 1), bool), sorted_e[:, 1:] != sorted_e[:, :-1]],
        axis=1)
    run_start = jax.lax.cummax(jnp.where(starts, idx, 0), axis=1)
    pos = idx - run_start
    keep = pos < C
    dest = jnp.where(keep, sorted_e * C + pos, E * C)   # E*C = drop slot
    src = order // k
    g_idx = jnp.arange(G)[:, None]
    vals = jnp.take_along_axis(xg, src[..., None], axis=1) \
        * keep[..., None].astype(xg.dtype)
    buf = jnp.zeros((G, E * C + 1, D), dtype=xg.dtype)
    buf = buf.at[g_idx, dest].add(vals)
    buf = constrain(buf, "group", None, None)
    return (buf[:, :-1, :].reshape(G, E, C, D), dest, keep, src, order,
            g_idx)


def _ep_axis(E):
    """Return (mesh, expert_axis_name, n_shards) when explicit expert
    parallelism applies (rules map 'expert' to a mesh axis dividing E)."""
    mesh = current_mesh()
    if mesh is None:
        return None, None, 0
    ax = current_rules().to_mesh_axes("expert")
    if not isinstance(ax, str) or ax not in mesh.axis_names:
        return None, None, 0
    n = mesh.shape[ax]
    if n <= 1 or E % n:
        return None, None, 0
    return mesh, ax, n


def _moe_expert_compute_ep(params, xg, ig, wg, cfg, E, C, mesh, axis, n):
    """Explicit expert parallelism (shard_map over the expert axis):
    every chip holds E/n full experts, dispatches only the slots bound
    for ITS experts, runs dense local matmuls, and contributes a
    partial per-token output -- ONE bf16 psum of [G,T,D] per layer is
    the only cross-chip traffic (vs. full [G,E,C,D] buffer psums under
    plain GSPMD; EXPERIMENTS.md section Perf iter 4)."""
    G, Tg, D = xg.shape
    k = ig.shape[-1]
    rules = current_rules()
    batch_axes = rules.to_mesh_axes("group")
    if not isinstance(batch_axes, (tuple, list)):
        batch_axes = (batch_axes,) if batch_axes else ()
    kept, prod = [], 1
    for a in batch_axes:
        if a in mesh.axis_names and a != axis \
                and G % (prod * mesh.shape[a]) == 0:
            kept.append(a)
            prod *= mesh.shape[a]
    batch_axes = tuple(kept) if kept else None

    def local_fn(x_l, i_l, w_l, wg_l, wu_l, wd_l):
        # x_l: [G_l, Tg, D]; i_l/w_l: [G_l, Tg, k];
        # wg_l/wu_l: [E_l, D, F]; wd_l: [E_l, F, D]
        Gl = x_l.shape[0]
        E_l = wg_l.shape[0]
        me = jax.lax.axis_index(axis)
        lo = me * E_l
        flat_e = i_l.reshape(Gl, Tg * k)
        order = jnp.argsort(flat_e, axis=1, stable=True)
        sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
        idx = jnp.broadcast_to(jnp.arange(Tg * k)[None], (Gl, Tg * k))
        starts = jnp.concatenate(
            [jnp.ones((Gl, 1), bool), sorted_e[:, 1:] != sorted_e[:, :-1]],
            axis=1)
        run_start = jax.lax.cummax(jnp.where(starts, idx, 0), axis=1)
        pos = idx - run_start
        mine = (sorted_e >= lo) & (sorted_e < lo + E_l) & (pos < C)
        local_dest = jnp.where(mine, (sorted_e - lo) * C + pos, E_l * C)
        src = order // k
        g_idx = jnp.arange(Gl)[:, None]
        vals = jnp.take_along_axis(x_l, src[..., None], axis=1) \
            * mine[..., None].astype(x_l.dtype)
        buf = jnp.zeros((Gl, E_l * C + 1, D), x_l.dtype)
        buf = buf.at[g_idx, local_dest].add(vals)
        buf = buf[:, :-1, :].reshape(Gl, E_l, C, D)
        h = jnp.einsum("gecd,edf->gecf", buf, wg_l)
        u = jnp.einsum("gecd,edf->gecf", buf, wu_l)
        out = jnp.einsum("gecf,efd->gecd", jax.nn.silu(h) * u, wd_l)
        out_flat = jnp.concatenate(
            [out.reshape(Gl, E_l * C, D), jnp.zeros((Gl, 1, D),
                                                    out.dtype)], axis=1)
        slot = jnp.take_along_axis(out_flat, local_dest[..., None], axis=1)
        w_sorted = jnp.take_along_axis(w_l.reshape(Gl, Tg * k), order,
                                       axis=1)
        y = jnp.zeros((Gl, Tg, D), x_l.dtype)
        y = y.at[g_idx, src].add(
            slot * (w_sorted * mine.astype(w_sorted.dtype))[..., None])
        return jax.lax.psum(y, axis)

    bspec = P(batch_axes, None, None)
    espec = P(axis, None, None)
    y = shard_map(
        local_fn, mesh=mesh,
        in_specs=(bspec, bspec, bspec, espec, espec, espec),
        out_specs=bspec, check_vma=False)(
            xg, ig, wg, params["experts"]["w_gate"],
            params["experts"]["w_up"], params["experts"]["w_down"])
    return y


def moe_apply(params, x, cfg):
    """x: [B, S, D] -> (y [B, S, D], aux_loss scalar)."""
    B, S, D = x.shape
    E = cfg.num_experts
    k = cfg.num_experts_per_tok
    F = cfg.moe_d_ff or cfg.d_ff
    T = B * S
    xf = x.reshape(T, D)

    logits = (xf.astype(jnp.float32) @ params["router"]["kernel"])  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_idx = jax.lax.top_k(probs, k)
    top_w = top_w / jnp.clip(top_w.sum(-1, keepdims=True), 1e-9)

    # load-balance auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=0)                            # [E]
    one_hot = jax.nn.one_hot(top_idx, E, dtype=jnp.float32) # [T,k,E]
    ce = jnp.mean(one_hot.sum(1), axis=0)                   # frac routed
    aux = cfg.router_aux_weight * E * jnp.sum(me * ce) / k

    G = _pick_groups(T, B)
    Tg = T // G
    C = max(1, int(cfg.expert_capacity_factor * k * Tg / E))
    C = min(C, Tg * k)

    xg = constrain(xf.reshape(G, Tg, D), "group", None, None)
    ig = top_idx.reshape(G, Tg, k)
    wg = top_w.reshape(G, Tg, k).astype(x.dtype)

    mesh, ep_ax, ep_n = _ep_axis(E)
    if mesh is not None:
        y = _moe_expert_compute_ep(params, xg, ig, wg, cfg, E, C, mesh,
                                   ep_ax, ep_n).reshape(B, S, D)
        if "shared" in params:
            y = y + L.mlp_apply(params["shared"], x, "swiglu")
        return y, aux

    buf, dest, keep, src, order, g_idx = _dispatch(xg, ig, E, C)
    buf = constrain(buf, "group", "expert", None, None)
    h = jnp.einsum("gecd,edf->gecf", buf, params["experts"]["w_gate"])
    u = jnp.einsum("gecd,edf->gecf", buf, params["experts"]["w_up"])
    h = jax.nn.silu(h) * u
    h = constrain(h, "group", "expert", None, "expert_mlp")
    out = jnp.einsum("gecf,efd->gecd", h, params["experts"]["w_down"])
    out = constrain(out, "group", "expert", None, None)
    out_flat = jnp.concatenate(
        [out.reshape(G, E * C, D), jnp.zeros((G, 1, D), out.dtype)],
        axis=1)
    slot_out = jnp.take_along_axis(out_flat, dest[..., None], axis=1) \
        * keep[..., None].astype(out.dtype)               # [G, Tg*k, D]
    w_sorted = jnp.take_along_axis(wg.reshape(G, Tg * k), order, axis=1)
    y = jnp.zeros((G, Tg, D), dtype=x.dtype)
    y = y.at[g_idx, src].add(slot_out * w_sorted[..., None])
    y = constrain(y, "group", None, None).reshape(B, S, D)

    if "shared" in params:
        y = y + L.mlp_apply(params["shared"], x, "swiglu")
    return y, aux
