"""Top-level Model: config -> init / loss / decode, for every assigned
architecture family (dense, moe, ssm, hybrid, vlm, audio) plus the
paper's own MLPs (which the De-VertiFL core drives directly).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import transformer as T
from repro.sharding import constrain


def padded_vocab(v: int) -> int:
    return ((v + 127) // 128) * 128


class Model:
    """Decoder-only or encoder-decoder LM assembled from a ModelConfig."""

    def __init__(self, cfg):
        self.cfg = cfg
        self.dtype = L.dtype_of(cfg.dtype)
        self.kinds = T.layer_kinds(cfg)
        self.enc_kinds = T.encoder_kinds(cfg) if cfg.is_encoder_decoder \
            else []
        self.vocab = padded_vocab(cfg.vocab_size)

    # ------------------------------------------------------------------
    def init(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 5)
        emb_key = "vfl_embedding" if cfg.vfl.enabled else "embedding"
        params = {
            emb_key: L.embedding_init(ks[0], self.vocab, cfg.d_model,
                                      self.dtype),
            "stack": T.stack_init(ks[1], cfg, self.kinds, self.dtype),
            "final_norm": L.norm_init(cfg.d_model, cfg.norm_type),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = L.dense_init(ks[2], cfg.d_model, self.vocab,
                                             self.dtype)
        if cfg.is_encoder_decoder:
            params["encoder"] = {
                "stack": T.stack_init(ks[3], cfg, self.enc_kinds, self.dtype),
                "final_norm": L.norm_init(cfg.d_model, cfg.norm_type),
            }
        return params

    # ------------------------------------------------------------------
    def _encode(self, params, prefix_emb):
        """Encoder pass (audio family): frame embeddings -> memory."""
        F = prefix_emb.shape[1]
        pos = jnp.arange(F)
        h = prefix_emb.astype(self.dtype)
        h, _ = T.stack_apply(params["encoder"]["stack"], h, pos, self.cfg,
                             self.enc_kinds)
        return L.apply_norm(params["encoder"]["final_norm"], h,
                            self.cfg.norm_type)

    def forward_logits(self, params, batch):
        """batch: {'tokens': [B,S_text] (+ 'prefix_emb': [B,P,D])}.
        Returns logits aligned with tokens positions ([B,S_text,V])."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S_text = tokens.shape
        enc = None
        prefix = None
        if cfg.is_encoder_decoder:
            enc = self._encode(params, batch["prefix_emb"])
        elif cfg.modality != "text" and "prefix_emb" in batch:
            prefix = batch["prefix_emb"]

        h = T.embed_input(params, tokens, cfg, prefix_emb=prefix)
        h = constrain(h, "batch", None, "act_embed")
        S_total = h.shape[1]
        positions = jnp.arange(S_total)
        h, aux = T.stack_apply(params["stack"], h, positions, cfg,
                               self.kinds, enc=enc)
        h = L.apply_norm(params["final_norm"], h, cfg.norm_type)
        h = h[:, S_total - S_text:, :]
        logits = T.logits_from_hidden(params, h, cfg)
        return logits, aux

    def loss(self, params, batch):
        """Next-token CE. batch needs 'tokens' and 'labels' (same shape);
        labels < 0 are masked."""
        logits, aux = self.forward_logits(params, batch)
        labels = batch["labels"]
        mask = (labels >= 0).astype(jnp.float32)
        lab = jnp.clip(labels, 0)
        # CE without gathering the (vocab-sharded) logits: logsumexp is
        # a sharded-safe reduction and the label logit is a one-hot
        # contraction (psum of a [B,S] result) -- take_along_axis here
        # would all-gather the full [B,S,V] logits (EXPERIMENTS.md
        # section Perf iter 5)
        lse = jax.nn.logsumexp(logits, axis=-1)
        one_hot = jax.nn.one_hot(lab, logits.shape[-1],
                                 dtype=logits.dtype)
        label_logit = jnp.einsum("bsv,bsv->bs", logits, one_hot)
        ll = label_logit - lse
        ce = -(ll * mask).sum() / jnp.clip(mask.sum(), 1.0)
        loss = ce + aux
        return loss, {"ce": ce, "aux": aux,
                      "tokens": mask.sum()}

    # ------------------------------------------------------------------
    # prefill (forward-only; returns logits and a populated decode state)
    # ------------------------------------------------------------------
    def prefill(self, params, batch, cache_len=None):
        """batch as in forward_logits. Returns (last-token logits,
        decode state ready for decode_step at position seq_len)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S_text = tokens.shape
        enc = None
        prefix = None
        if cfg.is_encoder_decoder:
            enc = self._encode(params, batch["prefix_emb"])
        elif cfg.modality != "text" and "prefix_emb" in batch:
            prefix = batch["prefix_emb"]
        h = T.embed_input(params, tokens, cfg, prefix_emb=prefix)
        h = constrain(h, "batch", None, "act_embed")
        S_total = h.shape[1]
        cache_len = cache_len or S_total
        positions = jnp.arange(S_total)
        h, cache = T.stack_prefill(params["stack"], h, positions, cfg,
                                   self.kinds, B, cache_len, self.dtype,
                                   enc=enc)
        h = L.apply_norm(params["final_norm"], h, cfg.norm_type)
        logits = T.logits_from_hidden(params, h[:, -1:, :], cfg)
        state = {"cache": cache,
                 "position": jnp.full((B,), S_total, jnp.int32)}
        if cfg.is_encoder_decoder:
            state["enc"] = enc
        return logits, state

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    def init_decode_state(self, batch_size, seq_len, prefill_len=None):
        cfg = self.cfg
        state = {
            "cache": T.stack_init_cache(cfg, self.kinds, batch_size, seq_len,
                                        self.dtype),
            "position": jnp.full((batch_size,),
                                 prefill_len if prefill_len is not None
                                 else 0, jnp.int32),
        }
        if cfg.is_encoder_decoder:
            state["enc"] = jnp.zeros(
                (batch_size, cfg.num_prefix_embeddings, cfg.d_model),
                self.dtype)
        return state

    def decode_step(self, params, state, tokens):
        """tokens: [B,1] -> (logits [B,1,V], new_state)."""
        cfg = self.cfg
        enc = state.get("enc")
        h = T.embed_input(params, tokens, cfg)
        h = constrain(h, "batch", None, "act_embed")
        pos = state["position"]
        h, new_cache = T.stack_decode(params["stack"], h, pos, cfg,
                                      self.kinds, state["cache"], enc=enc)
        h = L.apply_norm(params["final_norm"], h, cfg.norm_type)
        logits = T.logits_from_hidden(params, h, cfg)
        new_state = dict(state)
        new_state["cache"] = new_cache
        new_state["position"] = pos + 1
        return logits, new_state


def build_model(cfg) -> Model:
    if cfg.family == "mlp":
        from repro.models.mlp_model import PaperMLP
        return PaperMLP(cfg)
    return Model(cfg)
