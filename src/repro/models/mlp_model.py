"""The paper's own model: an MLP with 3 hidden layers (10 neurons each)
and an output head, exactly as in De-VertiFL section IV. The De-VertiFL
protocol in repro.core drives this model; the zero-padding / active-node
semantics live in the protocol, not here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L


class PaperMLP:
    def __init__(self, cfg):
        from repro.configs import paper_mlp as pm
        self.cfg = cfg
        self.in_features = cfg.vocab_size
        self.hidden = cfg.d_model
        self.n_hidden = cfg.num_layers
        self.n_classes = pm.N_CLASSES.get(cfg.name, 10)
        self.dtype = jnp.float32

    def init(self, key):
        dims = ([self.in_features] + [self.hidden] * self.n_hidden
                + [self.n_classes])
        ks = jax.random.split(key, len(dims) - 1)
        return {f"layer_{i}": L.dense_init(ks[i], dims[i], dims[i + 1],
                                           jnp.float32, bias=True,
                                           scale=(2.0 / dims[i]) ** 0.5)
                for i in range(len(dims) - 1)}

    def forward_from(self, params, h, start=0, upto=None):
        """Hidden layers [start, upto): h is the input when start=0,
        else the post-ReLU output of hidden layer start-1. The protocol
        engine's slice-aware first-layer paths compute layer 0 per
        client slice and continue here with start=1."""
        n = self.n_hidden if upto is None else upto
        for i in range(start, n):
            h = jax.nn.relu(L.dense(params[f"layer_{i}"], h))
        return h

    def forward_hidden(self, params, x, upto=None):
        """Forward through hidden layers; returns pre-head hidden.
        upto=k stops after hidden layer k (used by the exchange)."""
        return self.forward_from(params, x, 0, upto)

    def head(self, params, h):
        return L.dense(params[f"layer_{self.n_hidden}"], h)

    def forward_logits(self, params, batch):
        h = self.forward_hidden(params, batch["x"])
        return self.head(params, h), jnp.zeros((), jnp.float32)

    def loss(self, params, batch):
        logits, _ = self.forward_logits(params, batch)
        labels = batch["labels"]
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
        ce = -ll.mean()
        return ce, {"ce": ce, "aux": jnp.zeros(()), "tokens": 1.0 * ll.size}
