"""The schedule-aware side of the protocol engine: schedule impls
(the scan-carry state machines) and the devertifl step builder that
consumes them.

Every impl implements the four-hook contract the round function
drives (docs/ARCHITECTURE.md section 7):

  init_state(sched) -> pytree
      The schedule's scan-carry slot.  Empty pytrees are legal (the
      sync lane carries ``{}``); buffers are float32 zeros, so the
      first consumed exchanges of a cold start are exact-zero "no
      peers yet" terms.
  round_start(state, lay, key, round_idx) -> (state, eff_mask)
      Called once per round with the ROUND key.  eff_mask is the
      effective participation mask for the round --
      ``lay.client_mask`` composed with the per-round participation
      draw -- and weights both the exchange sum and the FedAvg.
  select(state, h_now) -> (h_ref, state)
      Called once per step with the stop-gradient CURRENT hidden
      stack ``h_now [n, B, W]``.  Returns the reference stack whose
      masked sum peers consume this step (``h_now`` itself for
      synchronous families) and the advanced state (ring push /
      back-slot fill).
  round_end(state) -> state
      Called after the round's scan (double_buffer's front/back swap).

The step built by :func:`make_sched_step_fn` keeps devertifl
semantics: each client's gradient flows only through its OWN current
hidden output; everything consumed from peers -- current, stale, or
absent -- is data.  The masked and slice first-layer families keep
their historical reduction orders, which is what lets ``stale_k:0``
and ``partial:1.0`` reduce bit-for-bit to the sync engine
(tests/test_schedule.py pins this).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.exchange import scheduled_exchange

# fold_in tag deriving the per-round participation key from the round
# key (disjoint from the epoch-permutation split of the same key)
PARTICIPATION_TAG = 0x5EED


def participation_mask(sched_state, lay, key, round_idx):
    """The per-round effective participation mask: ``client_mask``
    composed with a Bernoulli(p) draw from the round key (or a
    deterministic rotating keep-set), guarded so at least one live
    client always participates.  With p == 1.0 every value is
    bit-for-bit ``lay.client_mask`` (x * 1.0 preserves bits; the
    uniform draw is strictly < 1.0)."""
    cm = lay.client_mask
    p, det = sched_state["p"], sched_state["det"]
    n = cm.shape[0]
    # per-client draws from fold_in(pkey, i), NOT one shaped draw:
    # client i's coin must depend only on (round key, i) so a padded
    # client axis leaves the live clients' participation stream
    # bit-for-bit unchanged (a single bernoulli(key, p, (n,)) call
    # changes every draw when n grows)
    pkey = jax.random.fold_in(key, PARTICIPATION_TAG)
    bern = jax.vmap(
        lambda i: jax.random.bernoulli(jax.random.fold_in(pkey, i), p)
    )(jnp.arange(n, dtype=jnp.int32)).astype(cm.dtype)
    n_live = cm.sum().astype(jnp.int32)
    keep = jnp.maximum(1, jnp.round(p * n_live.astype(cm.dtype))
                       .astype(jnp.int32))
    rank = jnp.mod(jnp.arange(n, dtype=jnp.int32)
                   + round_idx.astype(jnp.int32),
                   jnp.maximum(n_live, 1))
    rot = (rank < keep).astype(cm.dtype)
    part = jnp.where(det > 0, rot, bern)
    eff = cm * part
    return jnp.where(eff.sum() > 0, eff, cm)


class LaneScheduleImpl:
    """The sync / stale_k / partial family with the staleness depth
    ``k``, participation ``p``, and the deterministic flag riding the
    carried STATE as traced scalars -- so a sweep can stack lanes with
    different (k, p) values on one vmapped axis and compile the round
    ONCE across schedule values.  ``max_k`` (static) sizes the ring
    buffer; per-lane ``k <= max_k`` selects how far back to read.

    Ring semantics: ``select`` at step t sees ``buf[max_k - j]`` as
    the stack pushed j steps ago, consumes ``buf[max_k - k]`` (k = 0
    consumes ``h_now`` itself), then pushes ``h_now`` at the end."""

    def __init__(self, max_k, n_clients, batch_size, width):
        if max_k < 0:
            raise ValueError(f"max_k must be >= 0, got {max_k}")
        self.max_k = int(max_k)
        self.n_clients = int(n_clients)
        self.batch_size = int(batch_size)
        self.width = int(width)

    def init_state(self, sched):
        if sched.k > self.max_k:
            raise ValueError(f"schedule {sched.spec!r} needs a ring of "
                             f"{sched.k} slots but this impl holds "
                             f"{self.max_k}")
        st = {"k": jnp.asarray(sched.k, jnp.int32),
              "p": jnp.asarray(sched.p, jnp.float32),
              "det": jnp.asarray(float(sched.deterministic),
                                 jnp.float32)}
        if self.max_k > 0:
            st["buf"] = jnp.zeros(
                (self.max_k, self.n_clients, self.batch_size,
                 self.width), jnp.float32)
        return st

    def round_start(self, state, lay, key, round_idx):
        return state, participation_mask(state, lay, key, round_idx)

    def select(self, state, h_now):
        if self.max_k == 0:
            return h_now, state
        buf, k = state["buf"], state["k"]
        idx = jnp.clip(self.max_k - k, 0, self.max_k - 1)
        stale = jax.lax.dynamic_index_in_dim(buf, idx, keepdims=False)
        h_ref = jnp.where(k > 0, stale, h_now)
        return h_ref, {**state,
                       "buf": jnp.concatenate([buf[1:], h_now[None]])}

    def round_end(self, state):
        return state

    @property
    def identity_select(self):
        """True when ``select`` statically returns ``h_now`` itself
        (depth-0 ring): the step builder then skips the second
        forward pass the ring formulation needs (see
        make_sched_step_fn)."""
        return self.max_k == 0


class DoubleBufferImpl:
    """Round-granularity pipelining: every step of round t consumes
    the ``front`` slot -- the hidden stack captured at the end of
    round t-1 (zeros for round 0) -- while each step overwrites
    ``back`` with its current stack; ``round_end`` promotes back to
    front.  This is the two-slot schedule a real deployment would run
    to fully overlap the exchange with a round of local compute."""

    def __init__(self, n_clients, batch_size, width):
        self.n_clients = int(n_clients)
        self.batch_size = int(batch_size)
        self.width = int(width)

    def init_state(self, sched):
        z = jnp.zeros((self.n_clients, self.batch_size, self.width),
                      jnp.float32)
        return {"front": z, "back": z}

    def round_start(self, state, lay, key, round_idx):
        return state, lay.client_mask

    def select(self, state, h_now):
        return state["front"], {**state, "back": h_now}

    def round_end(self, state):
        return {"front": state["back"], "back": state["back"]}


def make_schedule_impl(sched, n_clients, batch_size, width, max_k=None):
    """Build the impl for a parsed Schedule.  ``max_k`` overrides the
    ring depth (sweeps size it to the largest k across their lanes)."""
    if sched.custom is not None:
        _, make, args = sched.custom
        return make(n_clients=n_clients, batch_size=batch_size,
                    width=width, args=args)
    if sched.double_buffer:
        return DoubleBufferImpl(n_clients, batch_size, width)
    return LaneScheduleImpl(sched.k if max_k is None else max_k,
                            n_clients, batch_size, width)


def make_sched_step_fn(model, opt, pcfg, impl, layout=None,
                       first_layer_fn=None):
    """One schedule-aware devertifl optimizer step:

      step(params, opt_state, lay, eff_mask, sstate, xb, yb, step_idx)
        -> (params, opt_state, sstate, loss)

    Per step: compute the current hidden stack ``h_now`` (data), let
    the impl pick the reference stack ``h_ref`` (current / stale /
    front-buffer), then train each client on its OWN differentiable
    hidden output plus the eff_mask-weighted sum of the reference
    stack excluding its own reference contribution.  The reported
    loss stays the mean over LIVE clients (dropped participants keep
    training locally); only the exchange sum and the FedAvg honor
    eff_mask.
    """
    from repro.core import protocol as P
    if pcfg.mode != "devertifl":
        raise ValueError(f"schedules beyond 'sync' require "
                         f"mode='devertifl', got {pcfg.mode!r}")
    fl = P.resolve_first_layer(pcfg)
    through = partial(P.rest, model, pcfg.exchange_at)

    def update(params, opt_state, grads, step_idx):
        params, opt_state, _ = jax.vmap(
            lambda g, s, p: opt.update(g, s, p, step_idx))(
                grads, opt_state, params)
        return params, opt_state

    # fifth (optional) impl hook: obs taps record the loss vector and
    # grads the step already computed; None for every tap-free impl,
    # so non-obs engines are textually unchanged
    tap = getattr(impl, "tap_step", None)

    if fl == "masked":
        hidden = partial(P.client_hidden, model, pcfg.exchange_at)

        def step(params, opt_state, lay, eff_mask, sstate, xb, yb,
                 step_idx):
            xm = xb[None] * lay.masks[:, None, :]
            h_now = jax.lax.stop_gradient(jax.vmap(hidden)(params, xm))
            h_ref, sstate = impl.select(sstate, h_now)
            # same reduction order as the sync masked step: client i
            # consumes h_i + (masked total) - (own reference term)
            h_sum = P._masked_hidden_sum(h_ref, eff_mask)
            own = h_ref * eff_mask[:, None, None]

            def client_loss(p, x_i, own_i):
                h = hidden(p, x_i) + h_sum - own_i
                return P._ce(through(p, h), yb)

            losses, grads = jax.vmap(jax.value_and_grad(client_loss))(
                params, xm, own)
            params, opt_state = update(params, opt_state, grads,
                                       step_idx)
            if tap is not None:
                sstate = tap(sstate, losses, grads, lay)
            return (params, opt_state, sstate,
                    P._masked_mean(losses, lay.client_mask))
    else:
        first = first_layer_fn or P.make_first_layer_fn(model, pcfg,
                                                        layout)
        hidden_from = partial(P.client_hidden_from, model,
                              pcfg.exchange_at)

        def h_all_fn(ps, lay, xb):
            return jax.vmap(hidden_from)(ps, first(ps, xb, lay))

        if getattr(impl, "identity_select", False):
            # depth-0 select statically returns h_now, so the
            # reference stack IS the stop-gradient of the forward the
            # loss needs anyway: compute it ONCE inside grad (the
            # legacy sync formulation -- scheduled_exchange with
            # h_ref == stop_gradient(h_all) is bitwise
            # hidden_output_exchange, see repro.core.exchange) and
            # run select afterwards purely for its observers (obs
            # taps).  The ring formulation below pays a second
            # forward pass to materialize h_now before grad.
            def step(params, opt_state, lay, eff_mask, sstate, xb, yb,
                     step_idx):
                def total(ps):
                    h_all = h_all_fn(ps, lay, xb)
                    h_now = jax.lax.stop_gradient(h_all)
                    h = scheduled_exchange(h_all, h_now, eff_mask)
                    logits = jax.vmap(through)(ps, h)
                    losses = jax.vmap(P._ce, in_axes=(0, None))(
                        logits, yb)
                    return ((losses * lay.client_mask).sum(),
                            (losses, h_now))

                grads, (losses, h_now) = jax.grad(
                    total, has_aux=True)(params)
                _, sstate = impl.select(sstate, h_now)
                params, opt_state = update(params, opt_state, grads,
                                           step_idx)
                if tap is not None:
                    sstate = tap(sstate, losses, grads, lay)
                return (params, opt_state, sstate,
                        P._masked_mean(losses, lay.client_mask))

            return step

        def step(params, opt_state, lay, eff_mask, sstate, xb, yb,
                 step_idx):
            h_now = jax.lax.stop_gradient(h_all_fn(params, lay, xb))
            h_ref, sstate = impl.select(sstate, h_now)

            def total(ps):
                h = scheduled_exchange(h_all_fn(ps, lay, xb), h_ref,
                                       eff_mask)
                logits = jax.vmap(through)(ps, h)
                losses = jax.vmap(P._ce, in_axes=(0, None))(logits, yb)
                return (losses * lay.client_mask).sum(), losses

            grads, losses = jax.grad(total, has_aux=True)(params)
            params, opt_state = update(params, opt_state, grads,
                                       step_idx)
            if tap is not None:
                sstate = tap(sstate, losses, grads, lay)
            return (params, opt_state, sstate,
                    P._masked_mean(losses, lay.client_mask))

    return step
