# repro.schedule -- the exchange-scheduling subsystem: WHICH exchange
# tensor each client consumes at each step of the fused scan round.
# Built-ins: sync (paper-literal), stale_k (ring-buffered stale
# exchanges), double_buffer (round-pipelined two-slot), partial
# (per-round participation masks).  See registry.py for the spec
# grammar and docs/ARCHITECTURE.md section 7 for the scan-carry and
# extension contracts.
from repro.schedule.registry import (  # noqa: F401
    SCHEDULES, Schedule, ScheduleEntry, get_schedule, register_schedule,
    schedule_names,
)
from repro.schedule.engine import (  # noqa: F401
    PARTICIPATION_TAG, DoubleBufferImpl, LaneScheduleImpl,
    make_sched_step_fn, make_schedule_impl, participation_mask,
)
