"""The exchange-schedule registry: which exchange tensor each client
consumes at each step of the fused scan round.

A schedule is named by a compact spec string -- ``name[:arg[:flag]]``
components joined with ``+`` -- parsed against the ``SCHEDULES``
registry into a frozen :class:`Schedule` record:

  sync             the paper-literal schedule: every client consumes
                   every live peer's CURRENT hidden outputs, fully
                   synchronously.  Bit-for-bit the legacy engine (the
                   protocol keeps its original code path for it).
  stale_k[:k]      clients consume exchange buffers k steps old (a
                   ring buffer carried as scan state; k defaults to 1,
                   k=0 is bitwise sync).  Models overlapping the
                   HiddenOutputExchange with local compute.
  double_buffer    round-granularity two-slot pipeline: every step of
                   round t consumes the hidden outputs captured at the
                   END of round t-1 (zeros in round 0) while filling
                   the back slot for round t+1.
  partial:p[:det]  per-round participation: each round a client takes
                   part with probability p (Bernoulli from the round
                   key; ``:det`` rotates a deterministic keep-set
                   instead).  Dropped clients contribute exact-zero
                   terms to the exchange sum and the FedAvg weighting
                   -- composed with the padded-axis ``client_mask`` --
                   but keep training locally and still receive the
                   broadcast (the straggler model: their update missed
                   the round, the round did not miss them).
                   ``partial:1.0`` is bitwise sync.

``stale_k`` and ``partial`` compose ("stale_k:4+partial:0.8"); ``sync``
and ``double_buffer`` stand alone.  Custom schedules register via
:func:`register_schedule` (see docs/ARCHITECTURE.md section 7 for the
impl contract) and, like custom first layers, are refused in
multi-schedule sweep lanes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Tuple

from repro.registry import Registry

SCHEDULES = Registry("schedule")


@dataclass(frozen=True)
class Schedule:
    """Parsed, canonical exchange schedule.  ``spec`` is the canonical
    string (components in stale-before-partial order, numbers
    normalized) -- the identity that spec hashes, checkpoint stamps,
    and sweep cell keys use."""
    spec: str
    stale_k: Optional[int] = None       # None = no stale component
    participation: Optional[float] = None   # None = no partial component
    deterministic: bool = False         # partial: rotate, don't draw
    double_buffer: bool = False
    custom: Optional[Tuple] = None      # (name, make_factory, args)

    @property
    def is_sync(self) -> bool:
        """True only for the literal "sync" spec.  Degenerate members
        of other families (stale_k:0, partial:1.0) run through the
        schedule engine and are proven bitwise-equal by test, not by
        aliasing."""
        return (self.stale_k is None and self.participation is None
                and not self.double_buffer and self.custom is None)

    @property
    def k(self) -> int:
        """Staleness depth in steps (0 = consume current outputs)."""
        return self.stale_k or 0

    @property
    def p(self) -> float:
        """Per-round participation probability (1.0 = everyone)."""
        return 1.0 if self.participation is None else self.participation


@dataclass(frozen=True)
class ScheduleEntry:
    """Registry entry: ``parse(args) -> dict`` of Schedule field
    updates for built-ins; ``make`` is the custom impl factory."""
    name: str
    parse: Callable
    make: Optional[Callable] = None


def _parse_sync(args):
    if args:
        raise ValueError(f"sync takes no arguments, got {args}")
    return {}


def _parse_stale(args):
    if len(args) > 1:
        raise ValueError(f"stale_k takes one argument (k), got {args}")
    try:
        k = int(args[0]) if args else 1
    except ValueError:
        raise ValueError(f"stale_k wants an int k, got {args[0]!r}") \
            from None
    if k < 0:
        raise ValueError(f"stale_k wants k >= 0, got {k}")
    return {"stale_k": k}


def _parse_double(args):
    if args:
        raise ValueError(f"double_buffer takes no arguments, got {args}")
    return {"double_buffer": True}


def _parse_partial(args):
    det = False
    if args and args[-1] == "det":
        det, args = True, args[:-1]
    if len(args) != 1:
        raise ValueError(
            "partial wants a participation probability, e.g. "
            f"'partial:0.8' or 'partial:0.8:det'; got args {args}")
    try:
        p = float(args[0])
    except ValueError:
        raise ValueError(f"partial wants a float p, got {args[0]!r}") \
            from None
    if not 0.0 < p <= 1.0:
        raise ValueError(f"partial wants 0 < p <= 1, got {p}")
    return {"participation": p, "deterministic": det}


SCHEDULES.register("sync", ScheduleEntry("sync", _parse_sync))
SCHEDULES.register("stale_k", ScheduleEntry("stale_k", _parse_stale))
SCHEDULES.register("double_buffer",
                   ScheduleEntry("double_buffer", _parse_double))
SCHEDULES.register("partial", ScheduleEntry("partial", _parse_partial))


def register_schedule(name, make, overwrite=False) -> ScheduleEntry:
    """Register a custom exchange schedule for
    ``ExperimentSpec.schedule = name`` (or ``"name:arg1:arg2"``).

    ``make(n_clients, batch_size, width, args)`` must return an impl
    providing the four-hook contract the scan round drives
    (docs/ARCHITECTURE.md section 7):

      init_state(sched) -> pytree           the scan-carry slot
      round_start(state, lay, key, round_idx) -> (state, eff_mask)
      select(state, h_now) -> (h_ref, state)    per-step buffer choice
      round_end(state) -> state

    Custom schedules stand alone (no ``+`` composition), run
    devertifl-mode federations only, and are refused in multi-schedule
    sweep lanes (same constraint as custom first layers)."""
    def parse(args, _name=name, _make=make):
        return {"custom": (_name, _make, tuple(args))}

    return SCHEDULES.register(name, ScheduleEntry(name, parse, make),
                              overwrite=overwrite)


def schedule_names() -> list:
    """Registered schedule family names."""
    return SCHEDULES.names()


def _canonical(fields, custom_spec=None) -> str:
    if custom_spec is not None:
        return custom_spec
    parts = []
    if fields.get("double_buffer"):
        parts.append("double_buffer")
    if fields.get("stale_k") is not None:
        parts.append(f"stale_k:{fields['stale_k']}")
    if fields.get("participation") is not None:
        parts.append(f"partial:{fields['participation']:g}"
                     + (":det" if fields.get("deterministic") else ""))
    return "+".join(parts) or "sync"


def get_schedule(spec) -> Schedule:
    """Parse a schedule spec string (or pass a Schedule through) into
    the canonical :class:`Schedule` record.  Unknown family names raise
    with the registered options listed."""
    if isinstance(spec, Schedule):
        return spec
    text = str(spec).strip()
    comps = [c.strip() for c in text.split("+")]
    if not all(comps):
        raise ValueError(f"malformed schedule spec {text!r}")
    fields, seen = {}, []
    for comp in comps:
        name, *args = comp.split(":")
        entry = SCHEDULES.get(name)     # unknown names raise w/ options
        if name in seen:
            raise ValueError(f"duplicate schedule component {name!r} "
                             f"in {text!r}")
        seen.append(name)
        upd = entry.parse(args)
        if (name in ("sync", "double_buffer") or entry.make is not None) \
                and len(comps) > 1:
            raise ValueError(
                f"schedule component {name!r} does not compose; only "
                "stale_k and partial may be '+'-joined")
        fields.update(upd)
    custom = fields.get("custom")
    canon = _canonical(fields, custom_spec=text if custom else None)
    return Schedule(spec=canon, **fields)
