"""Version-tolerance shims for jax API drift.

The repo targets the installed jax (0.4.x) but is written against the
modern spellings where possible. Two drifts matter today:

  * ``shard_map`` moved from ``jax.experimental.shard_map`` to the
    top-level ``jax`` namespace (jax >= 0.6).
  * its replication-check kwarg was renamed ``check_rep`` ->
    ``check_vma`` in the same move.

``repro.compat.shard_map`` accepts the modern ``check_vma=`` spelling
and routes it to whichever kwarg the installed jax understands, so
call sites never need a version branch.
"""
from __future__ import annotations

import functools

try:  # jax >= 0.6: top-level export, kwarg spelled check_vma
    from jax import shard_map as _shard_map
    _CHECK_KWARG = "check_vma"
except ImportError:  # jax 0.4/0.5: experimental module, kwarg check_rep
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KWARG = "check_rep"


@functools.wraps(_shard_map)
def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kw):
    if check_vma is not None:
        kw[_CHECK_KWARG] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kw)
