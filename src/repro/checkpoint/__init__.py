from repro.checkpoint.checkpoint import (  # noqa: F401
    CheckpointCorruptError, checkpoint_steps, latest_step,
    load_checkpoint, load_entry, save_checkpoint,
)
