from repro.checkpoint.checkpoint import (  # noqa: F401
    latest_step, load_checkpoint, load_entry, save_checkpoint,
)
