"""Pytree checkpointing to .npz with structure metadata.

Flattens any pytree of arrays to key->array pairs using '/'-joined tree
paths, saves atomically (tmp + rename), and restores into the same
structure. Works for params, optimizer state, and De-VertiFL per-client
model sets alike.
"""
from __future__ import annotations

import json
import os
import re
import tempfile

import jax
import numpy as np


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(directory, step, tree, name="state"):
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    path = os.path.join(directory, f"{name}_{step:08d}.npz")
    # suffix must be .npz or np.savez appends one and the rename misses
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp.npz")
    os.close(fd)
    np.savez(tmp, **flat)
    os.replace(tmp, path)
    return path


def latest_step(directory, name="state"):
    if not os.path.isdir(directory):
        return None
    pat = re.compile(rf"{name}_(\d+)\.npz$")
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := pat.match(f))]
    return max(steps) if steps else None


def load_checkpoint(directory, step, like_tree, name="state"):
    """Restore into the structure of like_tree (values replaced)."""
    path = os.path.join(directory, f"{name}_{step:08d}.npz")
    data = np.load(path)
    paths, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    leaves = []
    for path_keys, leaf in paths:
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx)
                       for p in path_keys)
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), \
            f"shape mismatch for {key}: {arr.shape} vs {leaf.shape}"
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
