"""Pytree checkpointing to .npz with structure metadata.

Flattens any pytree of arrays to key->array pairs using '/'-joined tree
paths, saves atomically (tmp + rename), and restores into the same
structure. Works for params, optimizer state, and De-VertiFL per-client
model sets alike -- including padded client axes (dead slots round-trip
unchanged, empty arrays included) and NamedTuple nodes like
``LayoutArrays`` (attribute path keys), which the old '/'-join crashed
on (``GetAttrKey`` has neither ``.key`` nor ``.idx``).
"""
from __future__ import annotations

import os
import re
import tempfile

import jax
import numpy as np


def _key_part(p) -> str:
    """One path entry -> its string key.  Covers every jax key type:
    DictKey/FlattenedIndexKey (.key), GetAttrKey (.name, NamedTuples
    and dataclass-like nodes), SequenceKey (.idx)."""
    for attr in ("key", "name", "idx"):
        if hasattr(p, attr):
            return str(getattr(p, attr))
    return str(p)


def _flat_with_paths(tree):
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        yield "/".join(_key_part(p) for p in path), leaf


def _flatten(tree):
    flat = {}
    for key, leaf in _flat_with_paths(tree):
        if key in flat:
            raise ValueError(f"duplicate flattened key {key!r}; tree "
                             "paths must be unique after '/'-joining")
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(directory, step, tree, name="state"):
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    path = os.path.join(directory, f"{name}_{step:08d}.npz")
    # suffix must be .npz or np.savez appends one and the rename misses
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp.npz")
    os.close(fd)
    np.savez(tmp, **flat)
    os.replace(tmp, path)
    return path


def latest_step(directory, name="state"):
    if not os.path.isdir(directory):
        return None
    pat = re.compile(rf"{name}_(\d+)\.npz$")
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := pat.match(f))]
    return max(steps) if steps else None


def load_entry(directory, step, key, name="state"):
    """Read ONE flattened entry from a saved checkpoint (None if the
    checkpoint has no such key).  Lets callers verify stamp entries --
    e.g. the Session schedule guard -- and fail with an actionable
    error BEFORE attempting a full structured load whose like_tree
    shapes would otherwise produce a misleading mismatch message."""
    path = os.path.join(directory, f"{name}_{step:08d}.npz")
    with np.load(path) as data:
        return data[key] if key in data.files else None


def load_checkpoint(directory, step, like_tree, name="state"):
    """Restore into the structure of like_tree (values replaced; leaves
    are cast to the like leaf's dtype, a no-op for same-dtype
    round-trips)."""
    path = os.path.join(directory, f"{name}_{step:08d}.npz")
    data = np.load(path)
    treedef = jax.tree_util.tree_structure(like_tree)
    leaves = []
    for key, leaf in _flat_with_paths(like_tree):
        if key not in data:
            raise ValueError(
                f"checkpoint {path} has no entry {key!r}; the like_tree "
                "structure does not match the saved tree "
                f"(saved keys: {sorted(data.files)[:8]}...)")
        arr = data[key]
        if arr.shape != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key!r}: checkpoint has "
                f"{arr.shape}, like_tree expects {tuple(leaf.shape)} "
                "(padded client axes must be restored into a like_tree "
                "of the same padded width)")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
