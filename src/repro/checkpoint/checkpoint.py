"""Pytree checkpointing to .npz with structure metadata.

Flattens any pytree of arrays to key->array pairs using '/'-joined tree
paths, saves atomically (tmp + rename), and restores into the same
structure. Works for params, optimizer state, and De-VertiFL per-client
model sets alike -- including padded client axes (dead slots round-trip
unchanged, empty arrays included) and NamedTuple nodes like
``LayoutArrays`` (attribute path keys), which the old '/'-join crashed
on (``GetAttrKey`` has neither ``.key`` nor ``.idx``).

Corrupt files -- a truncated write, disk corruption, something that is
not an npz at all -- raise :class:`CheckpointCorruptError` instead of
a raw zipfile/zlib traceback, from every read path (``load_entry``,
``load_checkpoint``); a MISSING file still raises FileNotFoundError.
``checkpoint_steps`` lists every step on disk so callers (e.g.
``Session.resume``) can walk back to the newest intact checkpoint.
"""
from __future__ import annotations

import os
import re
import tempfile
import zipfile
import zlib

import jax
import numpy as np


class CheckpointCorruptError(RuntimeError):
    """A checkpoint file exists but cannot be read back -- truncated
    write, disk corruption, or not an npz archive.  The message names
    the file; delete it (or let ``Session.resume()`` skip it) and fall
    back to an older step."""


def _open_npz(path):
    """np.load with corrupt-file detection.  Missing files raise
    FileNotFoundError untouched; unreadable ones raise
    CheckpointCorruptError naming the file."""
    if not os.path.exists(path):
        raise FileNotFoundError(path)
    try:
        data = np.load(path, allow_pickle=False)
        data.files     # force the zip central directory to parse
        return data
    except (zipfile.BadZipFile, zlib.error, ValueError, OSError,
            EOFError, KeyError) as e:
        raise CheckpointCorruptError(
            f"checkpoint {path} is corrupt or truncated "
            f"({type(e).__name__}: {e}); delete it and resume from an "
            "older step") from e


def _key_part(p) -> str:
    """One path entry -> its string key.  Covers every jax key type:
    DictKey/FlattenedIndexKey (.key), GetAttrKey (.name, NamedTuples
    and dataclass-like nodes), SequenceKey (.idx)."""
    for attr in ("key", "name", "idx"):
        if hasattr(p, attr):
            return str(getattr(p, attr))
    return str(p)


def _flat_with_paths(tree):
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        yield "/".join(_key_part(p) for p in path), leaf


def _flatten(tree):
    flat = {}
    for key, leaf in _flat_with_paths(tree):
        if key in flat:
            raise ValueError(f"duplicate flattened key {key!r}; tree "
                             "paths must be unique after '/'-joining")
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(directory, step, tree, name="state"):
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    path = os.path.join(directory, f"{name}_{step:08d}.npz")
    # suffix must be .npz or np.savez appends one and the rename misses
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp.npz")
    os.close(fd)
    np.savez(tmp, **flat)
    os.replace(tmp, path)
    return path


def checkpoint_steps(directory, name="state"):
    """All checkpoint steps present in ``directory``, ascending
    (``[]`` if none / no directory).  Presence only -- a listed step
    may still raise CheckpointCorruptError when read."""
    if not os.path.isdir(directory):
        return []
    pat = re.compile(rf"{name}_(\d+)\.npz$")
    return sorted(int(m.group(1)) for f in os.listdir(directory)
                  if (m := pat.match(f)))


def latest_step(directory, name="state"):
    steps = checkpoint_steps(directory, name=name)
    return steps[-1] if steps else None


def load_entry(directory, step, key, name="state"):
    """Read ONE flattened entry from a saved checkpoint (None if the
    checkpoint has no such key).  Lets callers verify stamp entries --
    e.g. the Session schedule guard -- and fail with an actionable
    error BEFORE attempting a full structured load whose like_tree
    shapes would otherwise produce a misleading mismatch message."""
    path = os.path.join(directory, f"{name}_{step:08d}.npz")
    with _open_npz(path) as data:
        try:
            return data[key] if key in data.files else None
        except (zipfile.BadZipFile, zlib.error, ValueError, OSError,
                EOFError) as e:
            raise CheckpointCorruptError(
                f"checkpoint {path} is corrupt or truncated "
                f"({type(e).__name__}: {e}); delete it and resume "
                "from an older step") from e


def load_checkpoint(directory, step, like_tree, name="state"):
    """Restore into the structure of like_tree (values replaced; leaves
    are cast to the like leaf's dtype, a no-op for same-dtype
    round-trips)."""
    path = os.path.join(directory, f"{name}_{step:08d}.npz")
    data = _open_npz(path)
    treedef = jax.tree_util.tree_structure(like_tree)
    leaves = []
    for key, leaf in _flat_with_paths(like_tree):
        if key not in data:
            raise ValueError(
                f"checkpoint {path} has no entry {key!r}; the like_tree "
                "structure does not match the saved tree "
                f"(saved keys: {sorted(data.files)[:8]}...)")
        try:
            # member decompression is lazy; a truncated/corrupt member
            # surfaces here, not at open
            arr = data[key]
        except (zipfile.BadZipFile, zlib.error, ValueError, OSError,
                EOFError) as e:
            raise CheckpointCorruptError(
                f"checkpoint {path} is corrupt or truncated "
                f"({type(e).__name__}: {e}); delete it and resume "
                "from an older step") from e
        if arr.shape != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key!r}: checkpoint has "
                f"{arr.shape}, like_tree expects {tuple(leaf.shape)} "
                "(padded client axes must be restored into a like_tree "
                "of the same padded width)")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
