"""Logical-axis -> mesh-axis sharding rules (MaxText-style).

Model code tags tensors with *logical* axis names via ``constrain``;
the launcher installs a mesh + rules context; rules resolve logical
names to mesh axes. Without a mesh everything is a no-op so the same
model code runs single-device (smoke tests) and multi-pod (dry-run).

Logical axes used by the substrate:
  batch      activation batch dim            -> (pod, data)
  seq        sequence dim (ctx-parallel KV)   -> data for huge caches
  embed      param d_model dim (FSDP)         -> data
  heads      flattened q/kv head dim          -> model
  mlp        ffn hidden dim                   -> model
  vocab      vocabulary dim                   -> model
  expert     MoE expert dim                   -> None (or data for EP)
  group      MoE dispatch group dim           -> (pod, data)
  client     De-VertiFL client axis           -> model (input block)
  layers     scanned-layer leading dim        -> None
  sweep_lane sweep (seed x client-count) lane -> (pod, data): every
             lane is an independent federation, so the sweep engine
             shard_maps the lane axis over the data-parallel devices
             with no cross-lane collectives
"""
from __future__ import annotations

import contextlib
import re
from dataclasses import dataclass, field, replace
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass
class AxisRules:
    rules: dict = field(default_factory=dict)

    def to_mesh_axes(self, logical: Optional[str]):
        if logical is None:
            return None
        return self.rules.get(logical, None)

    def spec(self, *logical) -> P:
        return P(*[self.to_mesh_axes(a) for a in logical])

    def with_overrides(self, **kw) -> "AxisRules":
        r = dict(self.rules)
        r.update(kw)
        return AxisRules(r)


DEFAULT_RULES = AxisRules({
    "batch": ("pod", "data"),
    "seq": None,
    # long-context decode: shard the KV cache on seq over every axis not
    # already consumed by batch (the dedup in _fix_spec drops reused
    # axes per-tensor, so decode_32k shards B over (pod,data) and S over
    # model, while long_500k's B=1 leaves all axes free for S)
    "kv_seq": ("pod", "data", "model"),
    "embed": ("pod", "data"),    # FSDP over params' d_model dim
    "heads": "model",
    "mlp": "model",
    "vocab": "model",
    "expert": None,          # EP mode: 'model' (experts spread over TP)
    "expert_mlp": "model",   # EP mode: None (each chip holds full experts)
    "group": ("pod", "data"),
    "client": "model",
    "layers": None,
    "sweep_lane": ("pod", "data"),
    "act_embed": None,           # activations replicated on d_model
    "ssm_inner": "model",
})

# Federated (De-VertiFL) production mode: the pod axis is the federated
# axis -- params are REPLICATED across pods (each "super-client" holds
# full weights, FedAvg pmean syncs them at round boundaries), FSDP only
# within a pod.
FEDERATED_RULES = DEFAULT_RULES.with_overrides(
    embed="data",
    kv_seq="data",
)

# Expert-parallel MoE (beyond-paper perf mode, see EXPERIMENTS.md §Perf):
# experts are spread over the model axis (each chip holds full experts
# with MXU-friendly [D, F] matmuls) instead of slicing every expert's
# hidden dim; kills the per-layer expert-weight all-gather.
EP_RULES = DEFAULT_RULES.with_overrides(
    expert="model",
    expert_mlp=None,
)


class _Ctx:
    mesh: Optional[Mesh] = None
    rules: AxisRules = DEFAULT_RULES


_ctx = _Ctx()


def set_context(mesh: Optional[Mesh], rules: Optional[AxisRules] = None):
    _ctx.mesh = mesh
    if rules is not None:
        _ctx.rules = rules


@contextlib.contextmanager
def use_context(mesh: Optional[Mesh], rules: Optional[AxisRules] = None):
    old = (_ctx.mesh, _ctx.rules)
    set_context(mesh, rules or _ctx.rules)
    try:
        yield
    finally:
        _ctx.mesh, _ctx.rules = old


def current_mesh() -> Optional[Mesh]:
    return _ctx.mesh


def current_rules() -> AxisRules:
    return _ctx.rules


def _filter_spec_for_mesh(spec: P, mesh: Mesh) -> P:
    """Drop mesh axes the current mesh does not have (e.g. 'pod' on the
    single-pod mesh) and axes that do not divide -- GSPMD supports uneven
    sharding but shard_map and some in_shardings paths do not, so we play
    safe for explicit constraints."""
    names = set(mesh.axis_names)
    out = []
    for entry in spec:
        if entry is None:
            out.append(None)
        elif isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in names)
            out.append(kept if kept else None)
        else:
            out.append(entry if entry in names else None)
    return P(*out)


def logical_spec(*logical) -> P:
    spec = _ctx.rules.spec(*logical)
    if _ctx.mesh is not None:
        spec = _filter_spec_for_mesh(spec, _ctx.mesh)
    return spec


def _mesh_axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


def _fix_spec(shape, spec, mesh):
    """Make a spec legal for a concrete shape: drop mesh axes that do
    not divide the dim, and axes already used by an earlier dim
    (earlier dims take priority -- e.g. batch wins over kv_seq and the
    cache seq dim picks up whatever remains)."""
    used = set()
    fixed = []
    for dim, entry in zip(shape, spec):
        axes = () if entry is None else (
            tuple(entry) if isinstance(entry, (tuple, list)) else (entry,))
        kept = []
        for a in axes:
            if a in used:
                continue
            n = mesh.shape[a]
            if dim % (n * int(np_prod([mesh.shape[x] for x in kept]))) != 0:
                continue
            kept.append(a)
        used.update(kept)
        fixed.append(tuple(kept) if len(kept) > 1 else
                     (kept[0] if kept else None))
    return P(*fixed)


def np_prod(xs):
    out = 1
    for x in xs:
        out *= x
    return out


def constrain(x, *logical):
    """with_sharding_constraint on logical axes; no-op without a mesh.
    Axes that don't divide the dim evenly are dropped (GSPMD would pad,
    but we prefer deterministic layouts). If NO logical axis maps to a
    mesh axis the call is a no-op -- an all-None spec would FORCE
    replication (inserting all-gathers) rather than leave layout to the
    partitioner, which is never what a hint should do."""
    mesh = _ctx.mesh
    if mesh is None:
        return x
    spec = _fix_spec(x.shape, logical_spec(*logical), mesh)
    if all(e is None for e in spec):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Parameter specs from path-based rules
# ---------------------------------------------------------------------------
# Patterns are matched against '/'-joined param tree paths. First match
# wins; value is the tuple of logical axes for the trailing dims (a
# leading 'layers' axis is added automatically for scanned params whose
# rank exceeds the pattern).
_PARAM_PATTERNS = [
    (r"embedding/table",        ("vocab", "embed")),
    (r"vfl_embedding/table",    ("vocab", "client")),   # VFL input block
    (r"lm_head/kernel",         ("embed", "vocab")),
    (r"(wq|wk|wv)/kernel",      ("embed", "heads")),
    (r"(wq|wk|wv)/bias",        ("heads",)),
    (r"wo/kernel",              ("heads", "embed")),
    (r"wo/bias",                (None,)),
    (r"experts/(w_gate|w_up)",  ("expert", "embed", "expert_mlp")),
    (r"experts/w_down",         ("expert", "expert_mlp", "embed")),
    (r"router/kernel",          ("embed", None)),
    (r"(w_gate|w_up|wi)/kernel", ("embed", "mlp")),
    (r"(w_down|wo_mlp)/kernel", ("mlp", "embed")),
    (r"(w_gate|w_up|wi|w_down|wo_mlp)/bias", (None,)),
    # mamba
    (r"mamba/in_proj",          ("embed", "ssm_inner")),
    (r"mamba/conv",             (None, "ssm_inner")),
    (r"mamba/(x_proj|dt_proj)", ("ssm_inner", None)),
    (r"mamba/dt_bias",          ("ssm_inner",)),
    (r"mamba/(A_log|D)",        ("ssm_inner", None)),
    (r"mamba/out_proj",         ("ssm_inner", "embed")),
    # rwkv6
    (r"rwkv/(wr|wk|wv|wg)/kernel", ("embed", "heads")),
    (r"rwkv/wo/kernel",         ("heads", "embed")),
    (r"rwkv/(decay_lora_a|gate_lora_a)", ("embed", None)),
    (r"rwkv/(decay_lora_b|gate_lora_b)", (None, "heads")),
    (r"rwkv/(mu|decay_base|bonus)", (None,)),
    (r"rwkv/cm_(wk)/kernel",    ("embed", "mlp")),
    (r"rwkv/cm_(wv)/kernel",    ("mlp", "embed")),
    (r"rwkv/cm_wr/kernel",      ("embed", "act_embed")),
    (r"norm|scale|bias",        (None,)),
]


# decode-state (KV cache / recurrent state) patterns
_STATE_PATTERNS = [
    (r"attn/(k|v)$",            ("batch", "kv_seq", "heads", None)),
    (r"attn/pos$",              ("batch", "kv_seq")),
    (r"mamba/h$",               ("batch", "ssm_inner", None)),
    (r"mamba/conv$",            ("batch", None, "ssm_inner")),
    (r"rwkv/wkv$|(^|/)wkv$",    ("batch", "heads", None, None)),
    (r"x_prev",                 ("batch", None)),
    (r"(^|/)position$",         ("batch",)),
    (r"(^|/)enc$",              ("batch", None, None)),
]

# training-batch patterns
_BATCH_PATTERNS = [
    (r"tokens|labels",          ("batch", None)),
    (r"prefix_emb",             ("batch", None, "client")),
]


def _logical_for_path(path: str, ndim: int, scanned: bool, patterns):
    for pat, axes in patterns:
        if re.search(pat, path):
            axes = tuple(axes)
            if scanned and ndim == len(axes) + 1:
                axes = ("layers",) + axes
            if len(axes) != ndim:
                axes = tuple([None] * (ndim - len(axes))) + axes \
                    if ndim > len(axes) else axes[-ndim:]
            return axes
    return tuple([None] * ndim)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _specs_for_tree(tree_shape, patterns, scanned: bool = True):
    def one(path, leaf):
        p = _path_str(path)
        axes = _logical_for_path(p, len(leaf.shape), scanned, patterns)
        spec = logical_spec(*axes)
        mesh = current_mesh()
        if mesh is not None:
            spec = _fix_spec(leaf.shape, spec, mesh)
        return spec

    return jax.tree_util.tree_map_with_path(one, tree_shape)


def param_specs(params_shape, scanned: bool = True):
    """Pytree of PartitionSpec matching a (possibly abstract) params tree."""
    return _specs_for_tree(params_shape, _PARAM_PATTERNS, scanned)


def state_specs(state_shape, scanned: bool = True):
    """Specs for decode state (KV caches, SSM states, positions)."""
    return _specs_for_tree(state_shape, _STATE_PATTERNS, scanned)


def batch_specs(batch_shape):
    """Specs for a training/serving input batch dict."""
    return _specs_for_tree(batch_shape, _BATCH_PATTERNS, scanned=False)


def named_sharding_tree(specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
