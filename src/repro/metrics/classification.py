"""Accuracy and F1 (the paper's evaluation metrics), numpy-only.

F1 is macro-averaged for multi-class (MNIST/FMNIST) and the positive
-class F1 for binary tasks when average='binary', matching sklearn's
conventions used by the paper's reference implementation.
"""
from __future__ import annotations

import numpy as np


def accuracy(y_true, y_pred) -> float:
    y_true = np.asarray(y_true)
    y_pred = np.asarray(y_pred)
    return float((y_true == y_pred).mean())


def f1_score(y_true, y_pred, average="macro") -> float:
    y_true = np.asarray(y_true)
    y_pred = np.asarray(y_pred)
    classes = np.unique(np.concatenate([y_true, y_pred]))
    if average == "binary":
        classes = np.array([1])
    f1s = []
    for c in classes:
        tp = np.sum((y_pred == c) & (y_true == c))
        fp = np.sum((y_pred == c) & (y_true != c))
        fn = np.sum((y_pred != c) & (y_true == c))
        prec = tp / (tp + fp) if tp + fp else 0.0
        rec = tp / (tp + fn) if tp + fn else 0.0
        f1s.append(2 * prec * rec / (prec + rec) if prec + rec else 0.0)
    return float(np.mean(f1s))
