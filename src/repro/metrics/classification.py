"""Accuracy and F1 (the paper's evaluation metrics), numpy-only.

F1 is macro-averaged for multi-class (MNIST/FMNIST) and the positive
-class F1 for binary tasks when average='binary', matching sklearn's
conventions used by the paper's reference implementation.

Both metrics refuse non-finite inputs: a NaN prediction row (e.g. an
argmax over NaN logits from a diverged or corrupted model that
slipped past the exchange guard) silently compares unequal to every
label, which would report a plausible-looking near-zero score instead
of the actual failure.  The guard names the offending argument and
count so the caller can trace it back to the run.
"""
from __future__ import annotations

import numpy as np


def _check_finite(name, arr):
    """Refuse NaN/Inf metric inputs with an actionable error (float
    arrays only -- integer label arrays cannot hold non-finite
    values)."""
    if np.issubdtype(arr.dtype, np.floating):
        bad = ~np.isfinite(arr)
        if bad.any():
            raise ValueError(
                f"{name} contains {int(bad.sum())} non-finite "
                f"value(s) (of {arr.size}): a NaN/Inf prediction "
                "compares unequal to every label and would score as "
                "silently-wrong instead of failing; this usually "
                "means a diverged model or a corrupted exchange -- "
                "check the run's fault telemetry / loss history")
    return arr


def accuracy(y_true, y_pred) -> float:
    y_true = _check_finite("y_true", np.asarray(y_true))
    y_pred = _check_finite("y_pred", np.asarray(y_pred))
    return float((y_true == y_pred).mean())


def f1_score(y_true, y_pred, average="macro") -> float:
    y_true = _check_finite("y_true", np.asarray(y_true))
    y_pred = _check_finite("y_pred", np.asarray(y_pred))
    classes = np.unique(np.concatenate([y_true, y_pred]))
    if average == "binary":
        classes = np.array([1])
    f1s = []
    for c in classes:
        tp = np.sum((y_pred == c) & (y_true == c))
        fp = np.sum((y_pred == c) & (y_true != c))
        fn = np.sum((y_pred != c) & (y_true == c))
        prec = tp / (tp + fp) if tp + fp else 0.0
        rec = tp / (tp + fn) if tp + fn else 0.0
        f1s.append(2 * prec * rec / (prec + rec) if prec + rec else 0.0)
    return float(np.mean(f1s))
