from repro.metrics.classification import accuracy, f1_score  # noqa: F401
