"""repro.wire -- registry-backed exchange transforms: what the
federation's hidden stacks look like on the (simulated) wire
(docs/ARCHITECTURE.md section 11).

Spec strings ("int8", "topk:0.25", "dp:0.1", "topk:0.5+int8+dp:0.1",
...) parse into :class:`WirePlan` records; :func:`make_wire_impl`
wraps the resolved schedule/fault impl so the encode-decode round
trip rides the scan carry as traced state (compile-once, sweepable as
a lane axis) and integer bytes-on-wire counters surface through
``RunResult.timings["wire"]``; the codecs themselves (and the packed
form the serving ExchangeCache stores) live in
:mod:`repro.wire.codecs`.  ``transform="none"`` never touches the
engine: the protocol returns its legacy code path unwrapped, bit for
bit.
"""
from repro.wire.codecs import (WIRE_TAG, WirePayload, dp_noise,
                               int8_roundtrip, pack, topk_select,
                               unpack, wire_apply, wire_apply_static,
                               wire_bytes)
from repro.wire.engine import WireImpl, make_wire_impl
from repro.wire.registry import (TRANSFORMS, WireEntry, WirePlan,
                                 get_wire_plan, register_transform,
                                 transform_names)

__all__ = [
    "TRANSFORMS", "WIRE_TAG", "WireEntry", "WireImpl", "WirePayload",
    "WirePlan", "dp_noise", "get_wire_plan", "int8_roundtrip",
    "make_wire_impl", "pack", "register_transform", "topk_select",
    "transform_names", "unpack", "wire_apply", "wire_apply_static",
    "wire_bytes",
]
