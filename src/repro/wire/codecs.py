"""Wire codecs: what actually happens to an exchanged hidden stack on
its way across the (simulated) wire, as pure jittable encode-decode
round trips plus the host-side packed form the serving cache stores.

Every codec treats the TRAILING axis as the unit that crosses the
wire -- one entity's W-wide hidden vector -- so the same functions
serve the training stack ``[n_clients, B, W]`` (per batch row) and the
serving slot stack ``[n_clients, S, W]`` (per slot), and a cached
per-slot payload is self-contained:

  topk    keep the ceil(p * W) largest-|.| entries of each row, send
          exact zeros for the rest.  Kept entries keep their float
          bits untouched (an exact ``where`` select, never a multiply
          by 1.0 masquerading as identity), so ``p = 1.0`` is a
          bitwise identity.
  int8    symmetric quantization with a per-row power-of-two scale:
          ``scale = 2^e / 128`` with ``2^(e-1) < max|row| <= 2^e``
          (via frexp), ``q = round(row / scale)`` clipped to
          [-127, 127], decode ``q * scale``.  Every multiply/divide is
          by a power of two -- exact float arithmetic -- so the
          round trip is idempotent bit-for-bit: a decoded stack
          re-encodes to the same wire bytes and decodes to the same
          floats (tests/test_wire.py pins this).  That idempotence is
          also what lets the serving cache re-derive the packed wire
          form from a decoded stack without drift.
  dp      Gaussian release noise ``sigma * N(0, 1)`` per entry, drawn
          from ``fold_in(fold_in(fold_in(round_key, WIRE_TAG), step),
          i)`` -- per-client derivation, disjoint from the
          participation (0x5EED) and fault (0xFA17) tags, so the noise
          stream is bitwise reproducible and padding-invariant.

Gating is always an exact ``jnp.where`` on a traced on/off scalar --
an off component returns the input's bits untouched (never ``h + 0.0``,
which would quietly rewrite -0.0) -- so a "none" lane inside a wire
sweep is bit-for-bit the transform-free engine.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

# fold_in tag deriving the wire-noise key from the round key (disjoint
# from PARTICIPATION_TAG = 0x5EED and FAULT_TAG = 0xFA17)
WIRE_TAG = 0xC0DE


def topk_select(h, p):
    """Per-row magnitude sparsification: keep the ceil(p * W) largest
    |.| entries of each trailing-axis row of ``h`` (ties at the
    threshold are all kept), exact zeros elsewhere.  ``p`` is a traced
    scalar -- a lane axis value -- so the keep count is data, not a
    trace constant."""
    w = h.shape[-1]
    k = jnp.clip(jnp.ceil(p * jnp.float32(w)).astype(jnp.int32), 1, w)
    mag = jnp.abs(h)
    srt = jnp.sort(mag, axis=-1)            # ascending
    thresh = jax.lax.dynamic_slice_in_dim(srt, w - k, 1,
                                          axis=h.ndim - 1)
    return jnp.where(mag >= thresh, h, jnp.zeros_like(h))


def int8_roundtrip(h):
    """Symmetric int8 quantize -> dequantize with a per-row
    power-of-two scale.  All scaling is exact float arithmetic, so
    applying this twice equals applying it once, bit-for-bit."""
    amax = jnp.abs(h).max(axis=-1, keepdims=True)
    _, e = jnp.frexp(amax)                  # amax <= 2^e < 2 * amax
    scale = jnp.ldexp(jnp.ones_like(amax), e - 7)   # 2^(e-7) = 2^e/128
    q = jnp.clip(jnp.round(h / scale), -127.0, 127.0)
    return q * scale


def dp_noise(key, n_clients, shape):
    """[n_clients, *shape] standard-normal draws, client i's slice from
    ``fold_in(key, i)`` -- the per-client derivation that keeps a
    padded federation's live noise bitwise the unpadded one's."""
    def one(i):
        return jax.random.normal(jax.random.fold_in(key, i), shape)
    return jax.vmap(one)(jnp.arange(n_clients, dtype=jnp.int32))


def wire_apply(h, key, *, topk_on, topk_p, int8_on, dp_on, dp_sigma):
    """The full encode-decode round trip over a per-client stack
    ``h [n, ..., W]``: sparsify, quantize, noise -- each component
    gated by its traced on/off scalar with an exact select, so any
    subset of components rides one trace (the sweep lane contract).
    ``key`` is the per-step wire key (round key folded with WIRE_TAG
    and the in-round step index)."""
    h1 = jnp.where(topk_on > 0, topk_select(h, topk_p), h)
    h2 = jnp.where(int8_on > 0, int8_roundtrip(h1), h1)
    noise = dp_sigma * dp_noise(key, h.shape[0], h.shape[1:])
    return jnp.where(dp_on > 0, h2 + noise, h2)


def wire_bytes(live_n, rows, width, *, topk_on, topk_p, int8_on):
    """Integer bytes-on-wire for one step's exchange: ``raw`` is the
    fp32 dense cost, ``encoded`` what the active components ship --
    per kept entry 1 byte (int8) or 4 (fp32), plus 4-byte indices for
    topk's kept entries and a 4-byte scale per quantized row.  The dp
    component is payload-size-neutral.  ``live_n`` is the round's
    effective sender count (a traced scalar)."""
    f32 = jnp.float32
    kept = jnp.where(topk_on > 0,
                     jnp.ceil(topk_p * f32(width)), f32(width))
    per_entry = jnp.where(int8_on > 0, f32(1.0), f32(4.0))
    per_row = (kept * per_entry
               + jnp.where(topk_on > 0, f32(4.0) * kept, f32(0.0))
               + jnp.where(int8_on > 0, f32(4.0), f32(0.0)))
    raw = live_n * f32(4.0 * rows * width)
    enc = live_n * f32(rows) * per_row
    return raw.astype(jnp.int32), enc.astype(jnp.int32)


def wire_apply_static(plan, h, key=None):
    """``wire_apply`` with the plan's components resolved statically --
    the serving / probe path, where one process runs one transform and
    nothing needs a lane axis.  ``key=None`` skips the dp component
    (serving releases codec-encoded payloads; the dp mechanism is a
    training-time release control -- docs/ARCHITECTURE.md section
    11)."""
    if plan.topk is not None:
        h = topk_select(h, jnp.float32(plan.topk))
    if plan.int8:
        h = int8_roundtrip(h)
    if plan.dp is not None and key is not None:
        h = h + jnp.float32(plan.dp) * dp_noise(key, h.shape[0],
                                                h.shape[1:])
    return h


# ---------------------------------------------------------------------------
# host-side packed form (the serving ExchangeCache entry)
# ---------------------------------------------------------------------------
class WirePayload(NamedTuple):
    """One encoded exchange stack as it would sit in a transport
    buffer: per-client entry tuples ``(idx, vals, scale)`` -- kept
    indices (or None when dense), int8 or fp32 values, and the
    per-row scale (or None when unquantized) -- plus the dense shape
    and the integer wire size."""
    entries: tuple
    shape: tuple
    nbytes: int


def pack(plan, h) -> WirePayload:
    """Encode a (already round-tripped) per-client stack ``h [n, W]``
    into its packed wire form.  Codec idempotence guarantees
    ``unpack(pack(plan, h)) == h`` bit-for-bit when ``h`` came out of
    :func:`wire_apply_static` for the same plan."""
    h = np.asarray(h, np.float32)
    flat = h.reshape(h.shape[0], -1)
    entries, nbytes = [], 0
    for row in flat:
        if plan.topk is not None:
            idx = np.nonzero(row)[0].astype(np.int32)
            vals = row[idx]
            nbytes += 4 * int(idx.size)
        else:
            idx, vals = None, row
        if plan.int8:
            amax = np.float32(np.abs(vals).max()) if vals.size \
                else np.float32(0.0)
            _, e = np.frexp(amax)
            scale = np.ldexp(np.float32(1.0), int(e) - 7)
            q = np.clip(np.round(vals / scale), -127, 127) \
                .astype(np.int8)
            entries.append((idx, q, np.float32(scale)))
            nbytes += int(q.size) + 4
        else:
            entries.append((idx, vals, None))
            nbytes += 4 * int(vals.size)
    return WirePayload(tuple(entries), h.shape, int(nbytes))


def unpack(payload: WirePayload) -> np.ndarray:
    """Decode a packed payload back to the dense fp32 stack."""
    n = len(payload.entries)
    width = int(np.prod(payload.shape[1:], dtype=np.int64))
    out = np.zeros((n, width), np.float32)
    for i, (idx, vals, scale) in enumerate(payload.entries):
        dense = vals.astype(np.float32) * scale if scale is not None \
            else vals
        if idx is None:
            out[i] = dense
        else:
            out[i, idx] = dense
    return out.reshape(payload.shape)
