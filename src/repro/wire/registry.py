"""The exchange-transform registry: what the exchanged hidden stacks
look like on the (simulated) wire.

A wire transform is named by a compact spec string -- ``name[:args]``
components joined with ``+`` -- parsed against the ``TRANSFORMS``
registry into a frozen :class:`WirePlan` record:

  none           payloads cross the wire as raw fp32; the engine runs
                 its untouched legacy code path, bit-for-bit (the
                 protocol never wraps the engine impl for it) and the
                 spec hash is unchanged.
  topk:p         magnitude sparsification: each client keeps the
                 ceil(p * B * W) largest-|.| entries of its exchanged
                 stack and sends exact zeros for the rest (plus the
                 kept entries' indices on the wire).  ``p = 1.0`` is a
                 bitwise identity -- proven by test, not aliased.
  int8           symmetric 8-bit quantization with a per-client
                 power-of-two scale (2^ceil(log2(max|h|)) / 128), so
                 the decode is exact float arithmetic and the
                 encode-decode pair is idempotent bit-for-bit: an
                 already round-tripped stack re-encodes to the same
                 wire bytes and decodes to the same floats.
  dp:sigma       Gaussian release noise, N(0, sigma^2) added to every
                 released entry.  Draws come from per-client/per-step
                 ``fold_in`` keys disjoint from the participation and
                 fault tags, so the noise stream is bitwise
                 reproducible and padding-invariant.

Components compose left-to-right in the canonical order
topk -> int8 -> dp ("topk:0.25+int8+dp:0.1": sparsify, quantize the
kept values, noise the released result); ``none`` stands alone.
Custom transforms register via :func:`register_transform` and, like
custom schedules and faults, are refused in multi-transform sweep
lanes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Tuple

from repro.registry import Registry

TRANSFORMS = Registry("transform")


@dataclass(frozen=True)
class WirePlan:
    """Parsed, canonical wire transform.  ``spec`` is the canonical
    string (components in topk/int8/dp order, numbers normalized) --
    the identity that spec hashes, checkpoint stamps, and sweep cell
    keys use."""
    spec: str
    topk: Optional[float] = None        # None = no sparsify component
    int8: bool = False                  # quantize component present
    dp: Optional[float] = None          # None = no noise component
    custom: Optional[Tuple] = None      # (name, make_factory, args)

    @property
    def is_none(self) -> bool:
        """True only for the literal "none" transform -- the engine
        keeps its transform-free code path for it.  Degenerate members
        of other families (topk:1.0 runs the wire engine and reduces
        bitwise; a "none" LANE inside a wire sweep runs it with every
        component gated off) are proven bitwise-equal by test, not by
        aliasing."""
        return (self.topk is None and not self.int8
                and self.dp is None and self.custom is None)

    @property
    def topk_p(self) -> float:
        return 1.0 if self.topk is None else self.topk

    @property
    def dp_sigma(self) -> float:
        return self.dp or 0.0


@dataclass(frozen=True)
class WireEntry:
    """Registry entry: ``parse(args) -> dict`` of WirePlan field
    updates for built-ins; ``make`` is the custom impl factory."""
    name: str
    parse: Callable
    make: Optional[Callable] = None


def _parse_none(args):
    if args:
        raise ValueError(f"none takes no arguments, got {args}")
    return {}


def _parse_topk(args):
    if len(args) != 1:
        raise ValueError(
            "topk wants a keep fraction, e.g. 'topk:0.25'; got args "
            f"{args}")
    try:
        p = float(args[0])
    except ValueError:
        raise ValueError(f"topk wants a float keep fraction, got "
                         f"{args[0]!r}") from None
    if not 0.0 < p <= 1.0:
        raise ValueError(f"topk wants 0 < p <= 1, got {p}")
    return {"topk": p}


def _parse_int8(args):
    if args:
        raise ValueError(f"int8 takes no arguments, got {args}")
    return {"int8": True}


def _parse_dp(args):
    if len(args) != 1:
        raise ValueError(
            "dp wants a noise scale, e.g. 'dp:0.1'; got args "
            f"{args}")
    try:
        sigma = float(args[0])
    except ValueError:
        raise ValueError(f"dp wants a float noise scale, got "
                         f"{args[0]!r}") from None
    if sigma <= 0.0:
        raise ValueError(f"dp wants sigma > 0, got {sigma}")
    return {"dp": sigma}


TRANSFORMS.register("none", WireEntry("none", _parse_none))
TRANSFORMS.register("topk", WireEntry("topk", _parse_topk))
TRANSFORMS.register("int8", WireEntry("int8", _parse_int8))
TRANSFORMS.register("dp", WireEntry("dp", _parse_dp))


def register_transform(name, make, overwrite=False) -> WireEntry:
    """Register a custom exchange transform for
    ``ExperimentSpec.transform = name`` (or ``"name:arg1:arg2"``).

    ``make(inner, n_clients, batch_size, width, args)`` must return an
    impl providing the schedule four-hook contract
    (docs/ARCHITECTURE.md section 11); ``inner`` is the resolved
    schedule/fault impl the wire layer wraps (never None -- literal
    sync is handed over as a depth-0 ring impl).  The impl may
    additionally provide ``fedavg_mask(state, eff_mask)``,
    ``telemetry(state)`` and ``wire_telemetry(state)`` hooks.

    Custom transforms stand alone (no ``+`` composition), run
    devertifl-mode federations only, and are refused in
    multi-transform sweep lanes (same constraint as custom schedules
    and faults)."""
    def parse(args, _name=name, _make=make):
        return {"custom": (_name, _make, tuple(args))}

    return TRANSFORMS.register(name, WireEntry(name, parse, make),
                               overwrite=overwrite)


def transform_names() -> list:
    """Registered transform family names."""
    return TRANSFORMS.names()


def _canonical(fields, custom_spec=None) -> str:
    if custom_spec is not None:
        return custom_spec
    parts = []
    if fields.get("topk") is not None:
        parts.append(f"topk:{fields['topk']:g}")
    if fields.get("int8"):
        parts.append("int8")
    if fields.get("dp") is not None:
        parts.append(f"dp:{fields['dp']:g}")
    return "+".join(parts) or "none"


def get_wire_plan(spec) -> WirePlan:
    """Parse a transform spec string (or pass a WirePlan through) into
    the canonical :class:`WirePlan` record.  Unknown family names
    raise with the registered options listed."""
    if isinstance(spec, WirePlan):
        return spec
    text = str(spec).strip()
    comps = [c.strip() for c in text.split("+")]
    if not all(comps):
        raise ValueError(f"malformed transform spec {text!r}")
    fields, seen = {}, []
    for comp in comps:
        name, *args = comp.split(":")
        entry = TRANSFORMS.get(name)    # unknown names raise w/ options
        if name in seen:
            raise ValueError(f"duplicate transform component {name!r} "
                             f"in {text!r}")
        seen.append(name)
        upd = entry.parse(args)
        if (name == "none" or entry.make is not None) and len(comps) > 1:
            raise ValueError(
                f"transform component {name!r} does not compose; only "
                "topk, int8 and dp may be '+'-joined")
        fields.update(upd)
    custom = fields.get("custom")
    canon = _canonical(fields, custom_spec=text if custom else None)
    return WirePlan(spec=canon, **fields)
