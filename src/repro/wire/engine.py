"""The exchange-transform side of the protocol engine: a wrapper impl
that rides the schedule four-hook contract, so every payload crossing
the (simulated) wire passes one encode-decode round trip inside the
scanned round -- no retrace, ``round_traces == 1`` preserved, and the
transform is a vmappable sweep lane axis exactly like staleness depth
and fault rate.

:class:`WireImpl` wraps any resolved schedule or fault impl (literal
sync is handed over as a depth-0
:class:`~repro.schedule.LaneScheduleImpl`) and sits OUTERMOST in the
engine chain -- ``schedule -> fault -> wire`` -- transforming the
CURRENT hidden stack before the inner machinery sees it:

  select(state, h_now):
      h_tx = decode(encode(h_now))        # topk -> int8 -> dp
      h_ref, inner = inner.select(inner_state, h_tx)

so stale rings buffer what was actually SENT, transport corruption
(repro.faults) poisons the encoded payload, and the exchange guard
screens what a receiver would actually decode.  Each client's own
differentiable hidden output in the loss is untouched -- only the
released stack is transformed, which is the whole privacy story.  The
transform output carries the declared ``wire`` channel's declass tag:
the static auditor (repro.analysis) proves hiddens leave a client
only through this release point.

Determinism contracts: dp noise comes from
``fold_in(fold_in(fold_in(round_key, WIRE_TAG), step), i)`` --
per-client, disjoint from the participation and fault tags -- so
transform realizations are bitwise reproducible and padding-invariant.
All plan parameters (keep fraction, quantize flag, noise scale) ride
the carried state as traced scalars; lanes with different transforms
share one trace.  Integer bytes-on-wire counters (raw vs encoded)
accumulate in the carried state and surface through
``wire_telemetry`` into ``RunResult.timings["wire"]``.
"""
from __future__ import annotations

import inspect

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.barrier import tag
from repro.wire.codecs import WIRE_TAG, wire_apply, wire_bytes


class WireImpl:
    """Wire transform layered over an inner schedule/fault impl,
    carried as traced scan state.  Per-lane plan scalars select
    behavior inside one trace."""

    def __init__(self, plan, inner, n_clients, batch_size, width):
        self.plan = plan
        self.inner = inner
        self.n_clients = int(n_clients)
        self.batch_size = int(batch_size)
        self.width = int(width)
        # FaultImpl.init_state takes plan=; LaneScheduleImpl's doesn't
        self._inner_takes_plan = "plan" in inspect.signature(
            inner.init_state).parameters

    def init_state(self, sched, plan=None, wire=None):
        wire = self.plan if wire is None else wire
        if wire.custom is not None:
            raise ValueError(
                f"custom transform {wire.spec!r} cannot ride a wire "
                "lane state; it provides its own impl")
        kw = {}
        if plan is not None:
            if not self._inner_takes_plan:
                raise ValueError(
                    "fault plan given but the inner impl is not a "
                    "fault impl")
            kw["plan"] = plan
        return {
            "inner": self.inner.init_state(sched, **kw),
            # traced plan scalars (lane axis; explicit dtypes keep the
            # retrace lint quiet and lane jaxprs identical)
            "topk_on": jnp.asarray(
                1.0 if wire.topk is not None else 0.0, jnp.float32),
            "topk_p": jnp.asarray(wire.topk_p, jnp.float32),
            "int8_on": jnp.asarray(1.0 if wire.int8 else 0.0,
                                   jnp.float32),
            "dp_on": jnp.asarray(1.0 if wire.dp is not None else 0.0,
                                 jnp.float32),
            "dp_sigma": jnp.asarray(wire.dp_sigma, jnp.float32),
            # per-round wire key + in-round step counter (the dp noise
            # stream; replaced every round_start)
            "wkey": jax.random.PRNGKey(0),
            "wstep": jnp.zeros((), jnp.int32),
            # effective sender count for byte accounting
            "live_n": jnp.zeros((), jnp.float32),
            # telemetry (cumulative integer bytes-on-wire; aggregate
            # scalars, excluded from the per-slot contract like the
            # loss stream)
            "raw_bytes": jnp.zeros((), jnp.int32),
            "enc_bytes": jnp.zeros((), jnp.int32),
        }

    def round_start(self, state, lay, key, round_idx):
        # the inner engine sees the untouched round key, so its
        # participation/fault streams are bit-for-bit the wire-free
        # ones
        inner, eff = self.inner.round_start(state["inner"], lay, key,
                                            round_idx)
        state = {**state, "inner": inner,
                 "wkey": jax.random.fold_in(key, WIRE_TAG),
                 "wstep": jnp.zeros((), jnp.int32),
                 "live_n": eff.sum().astype(jnp.float32)}
        return state, eff

    def select(self, state, h_now):
        st = dict(state)
        skey = jax.random.fold_in(st["wkey"], st["wstep"])
        h_tx = wire_apply(h_now, skey,
                          topk_on=st["topk_on"], topk_p=st["topk_p"],
                          int8_on=st["int8_on"], dp_on=st["dp_on"],
                          dp_sigma=st["dp_sigma"])
        # the declared release point: everything downstream of this tag
        # (rings, guards, the exchange sum) consumes wire data, never a
        # raw hidden -- the taint auditor's proof obligation
        h_tx = tag(h_tx, "declass", "wire")
        raw_b, enc_b = wire_bytes(
            st["live_n"], self.batch_size, self.width,
            topk_on=st["topk_on"], topk_p=st["topk_p"],
            int8_on=st["int8_on"])
        st["wstep"] = st["wstep"] + 1
        st["raw_bytes"] = st["raw_bytes"] + raw_b
        st["enc_bytes"] = st["enc_bytes"] + enc_b
        h_ref, st["inner"] = self.inner.select(st["inner"], h_tx)
        return h_ref, st

    def round_end(self, state):
        return {**state, "inner": self.inner.round_end(state["inner"])}

    def fedavg_mask(self, state, eff_mask):
        """Delegate to the inner impl's hook (the fault layer's
        quarantine drop); identity when the inner has none."""
        fam = getattr(self.inner, "fedavg_mask", None)
        return eff_mask if fam is None else fam(state["inner"],
                                                eff_mask)

    def telemetry(self, state):
        """The inner impl's counters (fault events), surfaced through
        the outermost layer so ``timings["fault"]`` is unchanged by
        wrapping; None when the inner has no telemetry hook."""
        tel = getattr(self.inner, "telemetry", None)
        return None if tel is None else tel(state["inner"])

    def wire_telemetry(self, state):
        """Cumulative integer bytes-on-wire from a (possibly
        lane-batched) carried state, as numpy arrays."""
        return {"raw_bytes": np.asarray(state["raw_bytes"]),
                "encoded_bytes": np.asarray(state["enc_bytes"])}


def make_wire_impl(plan, inner, n_clients, batch_size, width):
    """Build the wire layer for a parsed WirePlan over a resolved
    schedule/fault impl.  Custom plans delegate to their registered
    factory."""
    if plan.custom is not None:
        _, make, args = plan.custom
        return make(inner=inner, n_clients=n_clients,
                    batch_size=batch_size, width=width, args=args)
    return WireImpl(plan, inner, n_clients, batch_size, width)
