"""Divergence recovery: the watchdog policy Session.run drives when a
fault plan is active (or when handed an explicit policy).

The watchdog inspects each round's scanned loss stream on the host; a
non-finite value or a magnitude past ``loss_threshold`` trips it.  On
a trip the session rolls the carried training state back to its last
good snapshot (taken after every successful round -- checkpoint
granularity 1) and retries the round under a RESEEDED key:
``fold_in(fold_in(round_key, RESEED_TAG), attempt)``, so the retried
round's fault/participation draws and epoch shuffles are fresh but
deterministic -- the whole recovery trajectory is bitwise
reproducible.  Consecutive failures of one round back off
exponentially (``backoff * 2**(attempt-1)``, capped) and exhaust into
:class:`DivergenceError` with the knobs to turn.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

# fold_in tag deriving a retry's round key from the original round key
# (disjoint from PARTICIPATION_TAG = 0x5EED and FAULT_TAG = 0xFA17)
RESEED_TAG = 0x0DD5


class DivergenceError(RuntimeError):
    """A round kept diverging through every reseeded retry the policy
    allowed.  The message names the round, the trip condition, and the
    recovery knobs (RetryPolicy.max_retries / loss_threshold, the
    fault rate, the learning rate)."""


@dataclass(frozen=True)
class RetryPolicy:
    """Divergence-watchdog policy for ``Session.run(retry=...)``.

    ``max_retries`` bounds reseeded retries PER ROUND (consecutive
    failures; the counter resets on any successful round).
    ``backoff`` is the base sleep in seconds before retry ``a``
    (``backoff * 2**(a-1)``, capped at ``backoff_cap``; 0 disables
    sleeping -- the default, since simulated faults don't heal with
    time).  ``loss_threshold`` trips the watchdog on any round loss
    with magnitude above it; non-finite losses always trip."""
    max_retries: int = 2
    backoff: float = 0.0
    backoff_cap: float = 30.0
    loss_threshold: float = 1e4

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got "
                             f"{self.max_retries}")
        if self.backoff < 0 or self.backoff_cap < 0:
            raise ValueError("backoff and backoff_cap must be >= 0")
        if not self.loss_threshold > 0:
            raise ValueError(f"loss_threshold must be > 0, got "
                             f"{self.loss_threshold}")

    def sleep_s(self, attempt: int) -> float:
        """Backoff before retry ``attempt`` (1-based)."""
        if self.backoff <= 0:
            return 0.0
        return min(self.backoff * 2.0 ** (attempt - 1),
                   self.backoff_cap)


def diverged(losses, loss_threshold: float) -> bool:
    """Host-side watchdog predicate over a round's loss stream."""
    a = np.asarray(losses)
    return bool((~np.isfinite(a)).any()
                or (np.abs(a) > loss_threshold).any())
