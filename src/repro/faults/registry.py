"""The fault-plan registry: which deterministic adversity the
federation trains under.

A fault plan is named by a compact spec string -- ``name[:args]``
components joined with ``+`` -- parsed against the ``FAULTS`` registry
into a frozen :class:`FaultPlan` record:

  none               no injected faults; the engine runs its untouched
                     legacy code path, bit-for-bit (the protocol never
                     wraps the schedule impl for it) and the spec hash
                     is unchanged.
  crash:p[:dur]      fail-stop: each round every live client crashes
                     with probability p and stays down for ``dur``
                     rounds (default 1) before rejoining.  A down
                     client contributes exact-zero terms to the
                     exchange sum and the FedAvg weighting -- the same
                     structural zeros as a dead padded slot -- but
                     keeps its local state and receives the broadcast
                     when it rejoins.
  straggle:p:d       each round every live client straggles with
                     probability p: its hidden outputs arrive ``d``
                     steps late, served from a ring buffer of its own
                     past stacks (cold start = exchange-free zeros,
                     the stale_k idiom).
  corrupt:p[:kind]   transport corruption: each round every live
                     client's exchanged payload is poisoned with
                     probability p -- ``kind`` is ``nan`` (default,
                     non-finite payload) or ``scale`` (finite but
                     magnitude-exploded).  The exchange guard screens
                     and quarantines these (repro.core.exchange
                     ``screen_exchange``).

All draws come from per-client/per-round ``fold_in`` keys disjoint
from the participation tag, so fault realizations are bitwise
reproducible and padding-invariant (a padded federation crashes the
same live clients as its unpadded twin).  ``crash``, ``straggle`` and
``corrupt`` compose ("crash:0.2+corrupt:0.05"); ``none`` stands alone.
Custom fault impls register via :func:`register_fault` and, like
custom schedules, are refused in multi-fault sweep lanes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Tuple

from repro.registry import Registry

FAULTS = Registry("fault")


@dataclass(frozen=True)
class FaultPlan:
    """Parsed, canonical fault plan.  ``spec`` is the canonical string
    (components in crash/straggle/corrupt order, numbers normalized)
    -- the identity that spec hashes, checkpoint stamps, and sweep
    cell keys use."""
    spec: str
    crash: Optional[float] = None       # None = no crash component
    crash_dur: int = 1                  # rounds a crashed client is down
    straggle: Optional[float] = None    # None = no straggle component
    straggle_d: int = 0                 # delay in steps
    corrupt: Optional[float] = None     # None = no corrupt component
    corrupt_kind: str = "nan"           # "nan" | "scale"
    custom: Optional[Tuple] = None      # (name, make_factory, args)

    @property
    def is_none(self) -> bool:
        """True only for the literal "none" plan -- the engine keeps
        its fault-free code path for it.  Degenerate members of other
        families (crash:0 is refused by the parser; a "none" LANE
        inside a fault sweep runs the fault engine with p=0 traced and
        is proven bitwise-equal by test, not by aliasing)."""
        return (self.crash is None and self.straggle is None
                and self.corrupt is None and self.custom is None)

    @property
    def crash_p(self) -> float:
        return self.crash or 0.0

    @property
    def straggle_p(self) -> float:
        return self.straggle or 0.0

    @property
    def corrupt_p(self) -> float:
        return self.corrupt or 0.0

    @property
    def max_dur(self) -> int:
        """Crash outage length in rounds (0 = no crash component)."""
        return self.crash_dur if self.crash is not None else 0

    @property
    def max_delay(self) -> int:
        """Straggler delay in steps = the ring depth this plan needs."""
        return self.straggle_d if self.straggle is not None else 0


@dataclass(frozen=True)
class FaultEntry:
    """Registry entry: ``parse(args) -> dict`` of FaultPlan field
    updates for built-ins; ``make`` is the custom impl factory."""
    name: str
    parse: Callable
    make: Optional[Callable] = None


def _prob(name, text):
    try:
        p = float(text)
    except ValueError:
        raise ValueError(f"{name} wants a float probability, got "
                         f"{text!r}") from None
    if not 0.0 < p <= 1.0:
        raise ValueError(f"{name} wants 0 < p <= 1, got {p}")
    return p


def _parse_none(args):
    if args:
        raise ValueError(f"none takes no arguments, got {args}")
    return {}


def _parse_crash(args):
    if not 1 <= len(args) <= 2:
        raise ValueError(
            "crash wants a probability and an optional outage length, "
            f"e.g. 'crash:0.2' or 'crash:0.2:3'; got args {args}")
    p = _prob("crash", args[0])
    try:
        dur = int(args[1]) if len(args) > 1 else 1
    except ValueError:
        raise ValueError(f"crash wants an int dur, got {args[1]!r}") \
            from None
    if dur < 1:
        raise ValueError(f"crash wants dur >= 1, got {dur}")
    return {"crash": p, "crash_dur": dur}


def _parse_straggle(args):
    if len(args) != 2:
        raise ValueError(
            "straggle wants a probability and a delay in steps, e.g. "
            f"'straggle:0.5:2'; got args {args}")
    p = _prob("straggle", args[0])
    try:
        d = int(args[1])
    except ValueError:
        raise ValueError(f"straggle wants an int delay, got "
                         f"{args[1]!r}") from None
    if d < 1:
        raise ValueError(f"straggle wants delay >= 1, got {d}")
    return {"straggle": p, "straggle_d": d}


def _parse_corrupt(args):
    if not 1 <= len(args) <= 2:
        raise ValueError(
            "corrupt wants a probability and an optional kind, e.g. "
            f"'corrupt:0.05' or 'corrupt:0.05:scale'; got args {args}")
    p = _prob("corrupt", args[0])
    kind = args[1] if len(args) > 1 else "nan"
    if kind not in ("nan", "scale"):
        raise ValueError(f"corrupt kind must be 'nan' or 'scale', "
                         f"got {kind!r}")
    return {"corrupt": p, "corrupt_kind": kind}


FAULTS.register("none", FaultEntry("none", _parse_none))
FAULTS.register("crash", FaultEntry("crash", _parse_crash))
FAULTS.register("straggle", FaultEntry("straggle", _parse_straggle))
FAULTS.register("corrupt", FaultEntry("corrupt", _parse_corrupt))


def register_fault(name, make, overwrite=False) -> FaultEntry:
    """Register a custom fault impl for ``ExperimentSpec.fault = name``
    (or ``"name:arg1:arg2"``).

    ``make(inner, n_clients, batch_size, width, args)`` must return an
    impl providing the schedule four-hook contract
    (docs/ARCHITECTURE.md section 9); ``inner`` is the resolved
    schedule impl the fault layer wraps (never None -- literal sync is
    handed over as a depth-0 ring impl).  The impl may additionally
    provide ``fedavg_mask(state, eff_mask)`` (post-scan averaging
    mask) and ``telemetry(state)`` (counter dict) hooks.

    Custom faults stand alone (no ``+`` composition), run
    devertifl-mode federations only, and are refused in multi-fault
    sweep lanes (same constraint as custom schedules)."""
    def parse(args, _name=name, _make=make):
        return {"custom": (_name, _make, tuple(args))}

    return FAULTS.register(name, FaultEntry(name, parse, make),
                           overwrite=overwrite)


def fault_names() -> list:
    """Registered fault family names."""
    return FAULTS.names()


def _canonical(fields, custom_spec=None) -> str:
    if custom_spec is not None:
        return custom_spec
    parts = []
    if fields.get("crash") is not None:
        dur = fields.get("crash_dur", 1)
        parts.append(f"crash:{fields['crash']:g}"
                     + (f":{dur}" if dur != 1 else ""))
    if fields.get("straggle") is not None:
        parts.append(f"straggle:{fields['straggle']:g}"
                     f":{fields['straggle_d']}")
    if fields.get("corrupt") is not None:
        kind = fields.get("corrupt_kind", "nan")
        parts.append(f"corrupt:{fields['corrupt']:g}"
                     + (f":{kind}" if kind != "nan" else ""))
    return "+".join(parts) or "none"


def get_fault_plan(spec) -> FaultPlan:
    """Parse a fault spec string (or pass a FaultPlan through) into
    the canonical :class:`FaultPlan` record.  Unknown family names
    raise with the registered options listed."""
    if isinstance(spec, FaultPlan):
        return spec
    text = str(spec).strip()
    comps = [c.strip() for c in text.split("+")]
    if not all(comps):
        raise ValueError(f"malformed fault spec {text!r}")
    fields, seen = {}, []
    for comp in comps:
        name, *args = comp.split(":")
        entry = FAULTS.get(name)        # unknown names raise w/ options
        if name in seen:
            raise ValueError(f"duplicate fault component {name!r} "
                             f"in {text!r}")
        seen.append(name)
        upd = entry.parse(args)
        if (name == "none" or entry.make is not None) and len(comps) > 1:
            raise ValueError(
                f"fault component {name!r} does not compose; only "
                "crash, straggle and corrupt may be '+'-joined")
        fields.update(upd)
    custom = fields.get("custom")
    canon = _canonical(fields, custom_spec=text if custom else None)
    return FaultPlan(spec=canon, **fields)
