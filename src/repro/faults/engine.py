"""The fault-injection side of the protocol engine: a wrapper impl
that rides the schedule four-hook contract, so injected adversity is
carried as traced scan state -- no retrace, ``round_traces == 1``
preserved, and fault rate is a vmappable sweep lane axis exactly like
staleness depth.

:class:`FaultImpl` wraps any resolved schedule impl (literal sync is
handed over as a depth-0 :class:`~repro.schedule.LaneScheduleImpl`)
and layers, per round:

  crash      fail-stop outages drawn at ``round_start`` from
             per-client fold_in coins; a down client is removed from
             the round's eff_mask (exact-zero exchange + FedAvg terms,
             the dead-padded-slot idiom) and rejoins after ``dur``
             rounds via a carried countdown.
  straggle   drawn clients' consumed hiddens are served ``d`` steps
             late from a ring of their own past stacks (cold start =
             exchange-free zeros).
  corrupt    drawn clients' payloads are poisoned per-step (NaN or a
             magnitude explosion) BEFORE the guard screen -- which is
             the point: the screen must catch them.

After injection every consumed stack passes
:func:`repro.core.exchange.screen_exchange`: non-finite or
over-magnitude slices are replaced with that client's last-good stack
and the client is quarantined out of the round's FedAvg weighting via
the ``fedavg_mask`` hook.  Event counters (crash / straggle /
corruption / quarantine client-rounds) accumulate in the carried
state and surface through ``telemetry``.

Determinism contracts: all coins come from
``fold_in(fold_in(fold_in(round_key, FAULT_TAG), kind), i)`` --
disjoint from the participation tag and per-client, so fault
realizations are bitwise reproducible and padding-invariant.  All
plan parameters (rates, durations, delay, corruption kind) ride the
carried state as traced scalars; lanes with different plans share one
trace.  The two all-dead fallbacks declassify only a scalar
"is anyone left" bit through the declared ``fault`` channel, keeping
the taint auditor's per-slot separation proof intact
(docs/ARCHITECTURE.md section 9).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.barrier import tag
from repro.core.exchange import screen_exchange

# fold_in tag deriving the fault key from the round key (disjoint from
# PARTICIPATION_TAG = 0x5EED and the epoch-permutation split)
FAULT_TAG = 0xFA17
_CRASH, _STRAGGLE, _CORRUPT = 1, 2, 3

# exchange-guard magnitude threshold: hidden stacks in every shipped
# config sit orders of magnitude below this, scale-corrupted ones
# orders of magnitude above
GUARD_MAX = 1e6
# the "scale" corruption factor -- finite, but far past GUARD_MAX
CORRUPT_SCALE = 1e9


def _fault_coins(key, kind, n, p):
    """[n] float32 Bernoulli(p) coins, one per client slot, each from
    ``fold_in(fold_in(fold_in(key, FAULT_TAG), kind), i)`` -- per-client
    derivation for padding invariance (the participation_mask idiom)."""
    fkey = jax.random.fold_in(jax.random.fold_in(key, FAULT_TAG), kind)
    return jax.vmap(
        lambda i: jax.random.bernoulli(jax.random.fold_in(fkey, i), p)
    )(jnp.arange(n, dtype=jnp.int32)).astype(jnp.float32)


def _alive_or(masked, fallback):
    """``masked`` unless it kills every client, else ``fallback``.  The
    scalar liveness bit aggregates every slot's fate, so it crosses the
    per-slot taint boundary -- declassified through the declared
    ``fault`` channel (identity outside an audit trace)."""
    pred = tag(masked.sum(), "declass", "fault") > 0
    return jnp.where(pred, masked, fallback)


class FaultImpl:
    """Fault layers over an inner schedule impl, carried as traced
    scan state.  ``max_delay`` (static) sizes the straggler ring;
    per-lane plan scalars select behavior inside one trace."""

    def __init__(self, plan, inner, n_clients, batch_size, width,
                 max_delay=None):
        self.plan = plan
        self.inner = inner
        self.n_clients = int(n_clients)
        self.batch_size = int(batch_size)
        self.width = int(width)
        self.max_delay = max(plan.max_delay, int(max_delay or 0))

    def init_state(self, sched, plan=None):
        plan = self.plan if plan is None else plan
        if plan.max_delay > self.max_delay:
            raise ValueError(f"fault plan {plan.spec!r} needs a "
                             f"straggler ring of {plan.max_delay} "
                             f"slots but this impl holds "
                             f"{self.max_delay}")
        n, b, w = self.n_clients, self.batch_size, self.width
        st = {
            "inner": self.inner.init_state(sched),
            # traced plan scalars (lane axis; explicit dtypes keep the
            # retrace lint quiet and lane jaxprs identical)
            "crash_p": jnp.asarray(plan.crash_p, jnp.float32),
            "crash_dur": jnp.asarray(plan.max_dur, jnp.int32),
            "strag_p": jnp.asarray(plan.straggle_p, jnp.float32),
            "strag_d": jnp.asarray(plan.max_delay, jnp.int32),
            "corrupt_p": jnp.asarray(plan.corrupt_p, jnp.float32),
            "corrupt_nan": jnp.asarray(
                1.0 if plan.corrupt_kind == "nan" else 0.0, jnp.float32),
            # per-client carried fate
            "crash_left": jnp.zeros((n,), jnp.int32),
            "strag_mask": jnp.zeros((n,), jnp.float32),
            "corrupt_mask": jnp.zeros((n,), jnp.float32),
            "quar": jnp.zeros((n,), jnp.float32),
            "live": jnp.zeros((n,), jnp.float32),
            "last_good": jnp.zeros((n, b, w), jnp.float32),
            # telemetry (client-round event counts; aggregate scalars,
            # excluded from the per-slot contract like the loss stream)
            "crash_events": jnp.zeros((), jnp.int32),
            "strag_events": jnp.zeros((), jnp.int32),
            "corrupt_events": jnp.zeros((), jnp.int32),
            "quar_events": jnp.zeros((), jnp.int32),
        }
        if self.max_delay > 0:
            st["ring"] = jnp.zeros((self.max_delay, n, b, w),
                                   jnp.float32)
        return st

    def round_start(self, state, lay, key, round_idx):
        # the inner schedule sees the untouched round key, so its
        # participation stream is bit-for-bit the fault-free one
        inner, eff = self.inner.round_start(state["inner"], lay, key,
                                            round_idx)
        cm = lay.client_mask
        n = self.n_clients
        # crash countdowns: tick down, then draw fresh outages among
        # clients currently up
        left = jnp.maximum(state["crash_left"] - 1, 0)
        up = (left == 0).astype(jnp.float32)
        new_crash = _fault_coins(key, _CRASH, n, state["crash_p"]) * up
        left = jnp.where(new_crash > 0, state["crash_dur"], left)
        down = (left > 0).astype(cm.dtype)
        eff = _alive_or(eff * (1.0 - down), eff)
        strag = _fault_coins(key, _STRAGGLE, n, state["strag_p"]) * cm
        corrupt = _fault_coins(key, _CORRUPT, n, state["corrupt_p"]) * cm
        state = {
            **state, "inner": inner, "crash_left": left,
            "strag_mask": strag, "corrupt_mask": corrupt,
            "quar": jnp.zeros_like(state["quar"]), "live": cm,
            "crash_events": state["crash_events"]
            + (new_crash * cm).sum().astype(jnp.int32),
            "strag_events": state["strag_events"]
            + strag.sum().astype(jnp.int32),
            "corrupt_events": state["corrupt_events"]
            + corrupt.sum().astype(jnp.int32),
        }
        return state, eff

    def select(self, state, h_now):
        h_ref, inner = self.inner.select(state["inner"], h_now)
        st = {**state, "inner": inner}
        if self.max_delay > 0:
            # stragglers' consumed stacks are their own, d steps old
            # (ring read before push, the LaneScheduleImpl idiom)
            ring, d = st["ring"], st["strag_d"]
            idx = jnp.clip(self.max_delay - d, 0, self.max_delay - 1)
            old = jax.lax.dynamic_index_in_dim(ring, idx,
                                               keepdims=False)
            sm = st["strag_mask"] * (d > 0)
            h_ref = jnp.where(sm[:, None, None] > 0, old, h_ref)
            st["ring"] = jnp.concatenate([ring[1:], h_now[None]])
        # transport corruption of the consumed payload (pre-screen)
        poison = jnp.where(st["corrupt_nan"] > 0,
                           jnp.full_like(h_ref, jnp.nan),
                           h_ref * jnp.float32(CORRUPT_SCALE))
        h_ref = jnp.where(st["corrupt_mask"][:, None, None] > 0,
                          poison, h_ref)
        # the guard: screen every consumed stack, quarantine bad slots
        h_ref, bad = screen_exchange(h_ref, st["last_good"], GUARD_MAX)
        st["last_good"] = h_ref
        st["quar"] = jnp.maximum(st["quar"],
                                 bad.astype(jnp.float32))
        return h_ref, st

    def round_end(self, state):
        return {**state,
                "inner": self.inner.round_end(state["inner"]),
                "quar_events": state["quar_events"]
                + (state["quar"] * state["live"]).sum()
                .astype(jnp.int32)}

    def fedavg_mask(self, state, eff_mask):
        """Drop this round's quarantined clients from the FedAvg
        weighting -- exact-zero terms, like dead padded slots."""
        return _alive_or(eff_mask * (1.0 - state["quar"]), eff_mask)

    def telemetry(self, state):
        """Cumulative client-round event counts from a (possibly
        lane-batched) carried state, as numpy arrays."""
        return {"crashes": np.asarray(state["crash_events"]),
                "straggles": np.asarray(state["strag_events"]),
                "corruptions": np.asarray(state["corrupt_events"]),
                "quarantined": np.asarray(state["quar_events"])}


def make_fault_impl(plan, inner, n_clients, batch_size, width,
                    max_delay=None):
    """Build the fault layer for a parsed FaultPlan over a resolved
    schedule impl.  ``max_delay`` overrides the straggler ring depth
    (sweeps size it to the largest delay across their lanes).  Custom
    plans delegate to their registered factory."""
    if plan.custom is not None:
        _, make, args = plan.custom
        return make(inner=inner, n_clients=n_clients,
                    batch_size=batch_size, width=width, args=args)
    return FaultImpl(plan, inner, n_clients, batch_size, width,
                     max_delay=max_delay)
