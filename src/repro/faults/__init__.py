"""repro.faults -- deterministic fault injection, exchange guards,
and divergence recovery for the federation (docs/ARCHITECTURE.md
section 9).

Spec strings ("crash:0.2+corrupt:0.05", "straggle:0.5:2", ...) parse
into :class:`FaultPlan` records; :func:`make_fault_impl` wraps the
resolved schedule impl so injected adversity rides the scan carry as
traced state (compile-once, sweepable as a lane axis); the guard
screen lives in :func:`repro.core.exchange.screen_exchange`;
:class:`RetryPolicy` drives Session.run's rollback-and-reseed
watchdog.  ``fault="none"`` never touches the engine: the protocol
returns its legacy code path unwrapped, bit for bit.
"""
from repro.faults.engine import (CORRUPT_SCALE, FAULT_TAG, GUARD_MAX,
                                 FaultImpl, make_fault_impl)
from repro.faults.recovery import (RESEED_TAG, DivergenceError,
                                   RetryPolicy, diverged)
from repro.faults.registry import (FAULTS, FaultEntry, FaultPlan,
                                   fault_names, get_fault_plan,
                                   register_fault)

__all__ = [
    "CORRUPT_SCALE", "FAULT_TAG", "GUARD_MAX", "RESEED_TAG",
    "DivergenceError", "FAULTS", "FaultEntry", "FaultImpl",
    "FaultPlan", "RetryPolicy", "diverged", "fault_names",
    "get_fault_plan", "make_fault_impl", "register_fault",
]
