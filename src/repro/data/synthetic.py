"""Structured synthetic stand-ins for the paper's datasets.

The container is offline, so MNIST/FMNIST/Titanic/Bank-Marketing cannot
be downloaded. These generators match each dataset's shape, class
cardinality, and -- critically for De-VertiFL -- its *information
geometry*: class-discriminative signal is spread across ALL features
(MNIST prototypes span every image row; tabular labels depend on every
column), so a vertical slice held by one client carries only partial
information and the paper's qualitative claims (federated >>
non-federated, gap grows with participants) are reproducible.

Shapes/cardinalities:
  mnist   70000 x 784, 10 classes (paper uses 60k train / 10k test)
  fmnist  70000 x 784, 10 classes (harder: more within-class variance)
  titanic 891 x 9 (post-preprocessing feature count), binary
  bank    ~45211 x 51 (post one-hot), binary (we scale n down for CI)
"""
from __future__ import annotations

import numpy as np


def _image_like(n, n_classes, side, noise, proto_scale, seed, blobs=6):
    """Class prototypes made of smooth Gaussian blobs covering the whole
    image; samples = prototype + pixel noise, quantized to [0,255]."""
    rng = np.random.default_rng(seed)
    yy, xx = np.mgrid[0:side, 0:side].astype(np.float64)
    protos = np.zeros((n_classes, side, side))
    for c in range(n_classes):
        for _ in range(blobs):
            cx, cy = rng.uniform(2, side - 2, 2)
            sx, sy = rng.uniform(1.5, 5.0, 2)
            amp = rng.uniform(0.4, 1.0) * rng.choice([-1, 1])
            protos[c] += amp * np.exp(-(((xx - cx) / sx) ** 2
                                        + ((yy - cy) / sy) ** 2))
    protos = protos / np.abs(protos).max(axis=(1, 2), keepdims=True)
    labels = rng.integers(0, n_classes, n)
    imgs = protos[labels] * proto_scale + rng.normal(0, noise,
                                                     (n, side, side))
    imgs = np.clip((imgs + 1) * 127.5, 0, 255).astype(np.float32)
    return imgs.reshape(n, side * side) / 255.0, labels.astype(np.int32)


def synthetic_mnist(n=8000, seed=0):
    # noise calibrated so a single client's row-slice is weakly
    # informative but the union of slices is highly separable -- the
    # regime where the paper's collaboration gain appears (Fig. 3).
    return _image_like(n, 10, 28, noise=1.2, proto_scale=1.0, seed=seed)


def synthetic_fmnist(n=8000, seed=1):
    # harder: weaker prototypes, more noise (paper's FMNIST F1 < MNIST F1)
    return _image_like(n, 10, 28, noise=1.6, proto_scale=0.9,
                       seed=seed + 100, blobs=9)


def _tabular(n, n_features, seed, flip=0.08, sparsity=1.0):
    """Binary labels from a dense logistic ground truth over ALL features
    (every vertical slice is informative but insufficient alone)."""
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (n, n_features))
    w = rng.normal(0, 1, n_features) * sparsity
    logits = x @ w / np.sqrt(n_features)
    p = 1 / (1 + np.exp(-2.5 * logits))
    y = (rng.uniform(size=n) < p).astype(np.int32)
    noise_mask = rng.uniform(size=n) < flip
    y = np.where(noise_mask, 1 - y, y)
    return x.astype(np.float32), y


def synthetic_titanic(n=891, seed=2):
    return _tabular(n, 9, seed, flip=0.10)


def synthetic_bank(n=8000, seed=3):
    return _tabular(n, 51, seed, flip=0.12)


_GENS = {
    "mnist": synthetic_mnist,
    "fmnist": synthetic_fmnist,
    "titanic": synthetic_titanic,
    "bank": synthetic_bank,
}

N_CLASSES = {"mnist": 10, "fmnist": 10, "titanic": 2, "bank": 2}


def split_train_test(x, y, test_frac=0.2):
    """THE train/test split rule for every dataset (registry-routed
    custom loaders included): the first ``test_frac`` of the draw is
    the test set.  Single implementation so the bit-for-bit parity
    between registry and direct loads cannot drift."""
    n_test = int(len(x) * test_frac)
    return x[n_test:], y[n_test:], x[:n_test], y[:n_test]


def stack_splits(make_fn, seeds, n=None, test_frac=0.2):
    """Per-seed ``make_fn(n, seed=s, test_frac=...)`` 4-tuples stacked
    on a leading seed axis (rectangular), for seed-vmapped sweeps."""
    splits = [make_fn(n, seed=s, test_frac=test_frac) for s in seeds]
    return tuple(np.stack(parts) for parts in zip(*splits))


def make_dataset(name, n=None, seed=None, test_frac=0.2):
    """Returns (x_train, y_train, x_test, y_test)."""
    kw = {}
    if n is not None:
        kw["n"] = n
    if seed is not None:
        kw["seed"] = seed
    return split_train_test(*_GENS[name](**kw), test_frac=test_frac)


def make_dataset_stack(name, seeds, n=None, test_frac=0.2):
    """Per-seed dataset draws stacked on a leading seed axis, for
    seed-vmapped sweeps: (x_train, y_train, x_test, y_test), each
    [n_seeds, ...]. Every seed is an independent draw of the same
    (shape, cardinality) generator, so the stack is rectangular."""
    def mk(n, seed=None, test_frac=0.2):
        return make_dataset(name, n, seed=seed, test_frac=test_frac)
    return stack_splits(mk, seeds, n=n, test_frac=test_frac)
