from repro.data.synthetic import (  # noqa: F401
    make_dataset, synthetic_mnist, synthetic_fmnist, synthetic_titanic,
    synthetic_bank,
)
from repro.data.vertical import (  # noqa: F401
    round_robin_rows, round_robin_features, random_features, zeropad,
    client_view,
)
from repro.data.lm import markov_lm_batches, MarkovLM  # noqa: F401
from repro.data.registry import (  # noqa: F401
    DatasetEntry, dataset_names, get_dataset, register_dataset,
)
