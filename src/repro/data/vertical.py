"""Vertical (feature-wise) data partitioning -- De-VertiFL section III.

MNIST-style: image rows are dealt to participants round-robin (Fig. 2).
Tabular: features are distributed randomly (Titanic) or round-robin.
client_view() applies the paper's zero-padding: every client sees the
full-width feature vector with the features it does not own set to 0.
"""
from __future__ import annotations

import numpy as np


def round_robin_rows(n_clients, side=28):
    """Deal image rows round-robin; returns list of flat feature indices
    per client (paper Fig. 2: client i gets rows i, i+n, i+2n, ...)."""
    out = []
    for c in range(n_clients):
        rows = np.arange(c, side, n_clients)
        idx = (rows[:, None] * side + np.arange(side)[None, :]).reshape(-1)
        out.append(np.sort(idx))
    return out


def round_robin_features(n_features, n_clients):
    return [np.arange(c, n_features, n_clients) for c in range(n_clients)]


def random_features(n_features, n_clients, seed=0):
    """Random disjoint assignment (paper: Titanic features 'randomly
    distributed among the participants')."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n_features)
    return [np.sort(perm[c::n_clients]) for c in range(n_clients)]


def zeropad(x, idx, n_features):
    """Zero-padded full-width view of client features (Algorithm 1 l.8)."""
    out = np.zeros((x.shape[0], n_features), dtype=x.dtype)
    out[:, idx] = x[:, idx] if x.shape[1] == n_features else x
    return out


def client_view(x, idx):
    """x: [N, F] full data; idx: this client's feature indices.
    Returns the zero-padded [N, F] view the client trains on."""
    mask = np.zeros(x.shape[1], dtype=x.dtype)
    mask[idx] = 1
    return x * mask


def feature_mask(idx, n_features, dtype=np.float32):
    m = np.zeros(n_features, dtype=dtype)
    m[idx] = 1
    return m
