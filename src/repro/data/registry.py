"""The dataset registry behind the `repro.api` front door.

Every place the engine needs a dataset by name -- ``DeVertiFL``, the
sweep lanes, the SplitNN baseline, ``partition.make_partition`` --
resolves it here instead of switching on hard-coded strings, so a
dataset registered once is usable everywhere (standalone sessions,
vmapped sweeps, benches) with no further wiring.

A ``DatasetEntry`` bundles what those consumers need:

  make        (n=None, seed=None, test_frac=0.2)
              -> (x_train, y_train, x_test, y_test)
  n_classes   label cardinality (binary -> F1 average="binary")
  arch        repro.configs model-config name for the PaperMLP built
              on this dataset (vocab_size == feature count)
  partition   how features are dealt to clients: "image_rows" (Fig. 2
              row round-robin), "random", "round_robin", or a callable
              (n_features, n_clients, seed) -> list of per-client
              sorted feature-index arrays

The four paper datasets are pre-registered with ``make`` delegating to
``repro.data.synthetic.make_dataset`` verbatim, so registry-routed
loads are bit-for-bit the historical draws (the engine parity tests
ride on this).  Register your own with :func:`register_dataset`; see
docs/ARCHITECTURE.md ("Spec & registry contracts").
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable, Union

from repro.data import synthetic as SD
from repro.registry import Registry

PARTITION_KINDS = ("image_rows", "random", "round_robin")


@dataclass(frozen=True)
class DatasetEntry:
    name: str
    make: Callable          # (n=None, seed=None, test_frac=0.2) -> 4-tuple
    n_classes: int
    arch: str               # repro.configs config name
    partition: Union[str, Callable] = "round_robin"


DATASETS = Registry("dataset")


def register_dataset(name, loader=None, *, n_classes, arch,
                     partition="round_robin", make=None,
                     overwrite=False) -> DatasetEntry:
    """Register a dataset for spec-driven experiments.

    Provide EITHER ``loader`` -- ``(n=None, seed=None) -> (x, y)`` with
    x [N, F] float32 and y [N] int labels, wrapped in the standard
    head-is-test split -- or ``make`` for full control of the
    train/test split (same signature/return as ``DatasetEntry.make``).
    ``arch`` names the repro.configs model config whose ``vocab_size``
    matches the feature count.
    """
    if (loader is None) == (make is None):
        raise ValueError("register_dataset needs exactly one of "
                         "loader= or make=")
    if isinstance(partition, str) and partition not in PARTITION_KINDS:
        raise ValueError(f"unknown partition kind {partition!r}; pick "
                         f"one of {PARTITION_KINDS} or pass a callable")
    if make is None:
        make = partial(_split, loader)
    entry = DatasetEntry(name=name, make=make, n_classes=int(n_classes),
                         arch=arch, partition=partition)
    return DATASETS.register(name, entry, overwrite=overwrite)


def _split(loader, n=None, seed=None, test_frac=0.2):
    """Wrap a raw (x, y) loader in the repo-wide train/test split rule
    (``synthetic.split_train_test``)."""
    kw = {}
    if n is not None:
        kw["n"] = n
    if seed is not None:
        kw["seed"] = seed
    return SD.split_train_test(*loader(**kw), test_frac=test_frac)


def get_dataset(name) -> DatasetEntry:
    return DATASETS.get(name)


def dataset_names() -> list:
    return DATASETS.names()


def make_dataset(name, n=None, seed=None, test_frac=0.2):
    """Registry-routed (x_train, y_train, x_test, y_test)."""
    return get_dataset(name).make(n, seed=seed, test_frac=test_frac)


def make_dataset_stack(name, seeds, n=None, test_frac=0.2):
    """Per-seed draws stacked on a leading seed axis (rectangular),
    for seed-vmapped sweeps -- the registry-routed twin of
    ``repro.data.synthetic.make_dataset_stack`` (same stacking
    helper)."""
    entry = get_dataset(name)

    def mk(n, seed=None, test_frac=0.2):
        return entry.make(n, seed=seed, test_frac=test_frac)
    return SD.stack_splits(mk, seeds, n=n, test_frac=test_frac)


# the paper's four datasets: make= delegates to the historical loader so
# registry-routed draws are bitwise the pre-registry ones
register_dataset("mnist", make=partial(SD.make_dataset, "mnist"),
                 n_classes=10, arch="paper-mlp-mnist",
                 partition="image_rows")
register_dataset("fmnist", make=partial(SD.make_dataset, "fmnist"),
                 n_classes=10, arch="paper-mlp-fmnist",
                 partition="image_rows")
register_dataset("titanic", make=partial(SD.make_dataset, "titanic"),
                 n_classes=2, arch="paper-mlp-titanic",
                 partition="random")
register_dataset("bank", make=partial(SD.make_dataset, "bank"),
                 n_classes=2, arch="paper-mlp-bank",
                 partition="round_robin")
