"""Synthetic language-model data: a sparse random Markov chain over the
vocabulary. The chain has low per-state entropy, so next-token loss has
real learnable structure (loss drops well below ln(V) within a few
hundred steps) -- used by the end-to-end ~100M-param training example
and the LM integration tests.
"""
from __future__ import annotations

import numpy as np


class MarkovLM:
    def __init__(self, vocab_size, branching=4, seed=0):
        rng = np.random.default_rng(seed)
        self.vocab = vocab_size
        self.next_states = rng.integers(0, vocab_size,
                                        (vocab_size, branching))
        probs = rng.dirichlet(np.ones(branching) * 0.5, vocab_size)
        self.cum_probs = np.cumsum(probs, axis=1)

    def sample(self, rng, batch, seq_len):
        toks = np.empty((batch, seq_len + 1), dtype=np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, batch)
        for t in range(seq_len):
            u = rng.uniform(size=batch)
            cur = toks[:, t]
            choice = (u[:, None] > self.cum_probs[cur]).sum(axis=1)
            toks[:, t + 1] = self.next_states[cur, choice]
        return toks


def markov_lm_batches(vocab_size, batch, seq_len, seed=0, branching=4):
    """Infinite iterator of {'tokens', 'labels'} next-token batches."""
    lm = MarkovLM(vocab_size, branching, seed)
    rng = np.random.default_rng(seed + 1)
    while True:
        toks = lm.sample(rng, batch, seq_len)
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
