"""Learning-rate schedules as pure functions of the step counter."""
from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(lr):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(peak_lr, total_steps, final_frac=0.1):
    def fn(step):
        frac = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return peak_lr * (final_frac + (1 - final_frac) * cos)
    return fn


def linear_warmup_cosine(peak_lr, warmup_steps, total_steps,
                         final_frac=0.1):
    def fn(step):
        warm = peak_lr * jnp.minimum(1.0, (step + 1) / max(warmup_steps, 1))
        frac = jnp.clip((step - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup_steps, warm, cos)
    return fn
