from repro.optim.optimizers import (  # noqa: F401
    Optimizer, adam, adamw, sgd, clip_by_global_norm,
)
from repro.optim.schedule import (  # noqa: F401
    constant_schedule, cosine_schedule, linear_warmup_cosine,
)
