"""Pure-pytree optimizers (Adam/AdamW/SGD) with the optax-style
(init, update) interface, written in-house so the framework has no
dependencies beyond jax/numpy.

Moments are kept in float32 regardless of param dtype (mixed-precision
training: bf16 params, fp32 optimizer state), and the sharding layer
gives moments the same specs as their params (plus optional ZeRO-1
data-axis sharding at the launcher level).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params, step) -> (new_params, state)


def _tree_zeros_like_f32(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def clip_by_global_norm(grads, max_norm):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


def adam(lr, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
         max_grad_norm: Optional[float] = 1.0):
    """lr: float or schedule fn step->float."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {"mu": _tree_zeros_like_f32(params),
                "nu": _tree_zeros_like_f32(params)}

    def update(grads, state, params, step):
        if max_grad_norm:
            grads, gn = clip_by_global_norm(grads, max_grad_norm)
        else:
            gn = jnp.zeros(())
        t = step.astype(jnp.float32) + 1.0
        lr_t = lr_fn(step)

        def upd(g, mu, nu, p):
            g = g.astype(jnp.float32)
            mu = b1 * mu + (1 - b1) * g
            nu = b2 * nu + (1 - b2) * g * g
            mu_hat = mu / (1 - b1 ** t)
            nu_hat = nu / (1 - b2 ** t)
            step_v = mu_hat / (jnp.sqrt(nu_hat) + eps)
            if weight_decay:
                step_v = step_v + weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr_t * step_v
            return new_p.astype(p.dtype), mu, nu

        flat = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
        new_params = jax.tree.map(lambda x: x[0], flat,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_mu = jax.tree.map(lambda x: x[1], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
        new_nu = jax.tree.map(lambda x: x[2], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"mu": new_mu, "nu": new_nu}, {"grad_norm": gn}

    return Optimizer(init, update)


def adamw(lr, weight_decay=0.01, **kw):
    return adam(lr, weight_decay=weight_decay, **kw)


def sgd(lr, momentum=0.0):
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        if momentum:
            return {"v": _tree_zeros_like_f32(params)}
        return {}

    def update(grads, state, params, step):
        lr_t = lr_fn(step)
        if momentum:
            new_v = jax.tree.map(
                lambda v, g: momentum * v + g.astype(jnp.float32),
                state["v"], grads)
            new_p = jax.tree.map(
                lambda p, v: (p.astype(jnp.float32) - lr_t * v
                              ).astype(p.dtype), params, new_v)
            return new_p, {"v": new_v}, {}
        new_p = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - lr_t * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return new_p, {}, {}

    return Optimizer(init, update)
