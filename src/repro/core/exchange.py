"""HiddenOutputExchange (Algorithm 2) -- the paper's knowledge-exchange
novelty: during the forward pass, every participant broadcasts its
hidden-layer outputs and each participant SUMS the received tensors with
its own.

Two implementations with identical semantics:

  * hidden_output_exchange: the literal simulation used by the MLP
    reproduction -- per-client hidden outputs are stacked on a leading
    client axis and summed; other clients' contributions are
    stop-gradient'ed, because in the real deployment a client receives
    peers' activations as data and the backward pass is local
    (Algorithm 1 line 12 updates only theta_i).

  * the SPMD form for production models lives in
    repro.models.transformer.exchange_features (psum over the client
    mesh axis inside shard_map); tests assert the two agree.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def hidden_output_exchange(h_all, differentiable=False):
    """h_all: [n_clients, B, H] per-client hidden outputs.

    Returns [n_clients, B, H]: for client i, h_i + sum of peers' hiddens.
    With differentiable=False (De-VertiFL), peers' terms carry no
    gradient; with True, gradients flow to every contributor (this is
    the VertiComb-style backward exchange used as a baseline).
    """
    total = h_all.sum(axis=0, keepdims=True)        # [1, B, H]
    if differentiable:
        return jnp.broadcast_to(total, h_all.shape)
    peers = jax.lax.stop_gradient(total - h_all)    # const contribution
    return h_all + peers


def fedavg(stacked_params):
    """P2P weight exchange + FedAvg (Algorithm 1 lines 16-19): every
    client receives every peer's weights and averages. stacked_params
    has a leading client axis on every leaf; returns the same structure
    with every client's slot set to the mean."""
    def avg(leaf):
        m = leaf.mean(axis=0, keepdims=True)
        return jnp.broadcast_to(m, leaf.shape)
    return jax.tree.map(avg, stacked_params)
