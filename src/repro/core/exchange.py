"""HiddenOutputExchange (Algorithm 2) -- the paper's knowledge-exchange
novelty: during the forward pass, every participant broadcasts its
hidden-layer outputs and each participant SUMS the received tensors with
its own.

Two implementations with identical semantics:

  * hidden_output_exchange: the literal simulation used by the MLP
    reproduction -- per-client hidden outputs are stacked on a leading
    client axis and summed; other clients' contributions are
    stop-gradient'ed, because in the real deployment a client receives
    peers' activations as data and the backward pass is local
    (Algorithm 1 line 12 updates only theta_i).

  * the SPMD form for production models lives in
    repro.models.transformer.exchange_features (psum over the client
    mesh axis inside shard_map); tests assert the two agree.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# identity outside an audit trace; marks the declared cross-client
# channels / maskable terms for the static auditor (repro.analysis)
from repro.analysis.barrier import tag


def hidden_output_exchange(h_all, differentiable=False, client_mask=None):
    """h_all: [n_clients, B, H] per-client hidden outputs.

    Returns [n_clients, B, H]: for client i, h_i + sum of peers' hiddens.
    With differentiable=False (De-VertiFL), peers' terms carry no
    gradient; with True, gradients flow to every contributor (this is
    the VertiComb-style backward exchange used as a baseline).

    client_mask ([n_clients], 1.0 = live) excludes dead padding slots
    from the broadcast sum: a dead client contributes an exact +0.0
    term, so the live clients' exchanged sum is bit-for-bit the
    unpadded sum (adding trailing zeros to an XLA reduction preserves
    every bit -- pinned in tests/test_padded_engine.py).  Dead rows of
    the *output* are garbage; the protocol masks them out of every
    loss/metric downstream.
    """
    hm = h_all if client_mask is None else \
        tag(h_all * client_mask[:, None, None], "term", "exchange",
            client_axis=0)
    total = tag(hm.sum(axis=0, keepdims=True),      # [1, B, H]
                "declass", "exchange")
    if differentiable:
        return jnp.broadcast_to(total, h_all.shape)
    peers = jax.lax.stop_gradient(total - hm)       # const contribution
    return h_all + peers


def scheduled_exchange(h_all, h_ref, eff_mask):
    """Exchange where the broadcast tensors come from a schedule's
    reference stack (repro.schedule): client i consumes its OWN
    current ``h_all[i]`` plus the eff_mask-weighted sum of ``h_ref``
    excluding its own reference contribution.  ``h_ref`` is data (a
    stop-gradient current stack, a stale ring slot, or a
    double-buffer front), so gradients flow only through ``h_all`` --
    devertifl semantics by construction.

    ``eff_mask`` composes liveness with per-round participation: a
    dropped client's reference term is an exact +0.0 in the sum (it
    sends nothing) while its own row still receives the participants'
    total (it missed the round; the round did not miss it).

    With ``h_ref == stop_gradient(h_all)`` and an all-live eff_mask
    this is the same reduction order as ``hidden_output_exchange(...,
    differentiable=False)`` -- bit-for-bit, which is how the
    degenerate schedules (stale_k:0, partial:1.0) reduce to sync
    (tests/test_schedule.py)."""
    hm = tag(h_ref * eff_mask[:, None, None], "term", "exchange",
             client_axis=0)
    total = tag(hm.sum(axis=0, keepdims=True),      # [1, B, H]
                "declass", "exchange")
    return h_all + (total - hm)


def screen_exchange(payload, last_good, max_abs):
    """Non-finite/magnitude screen over a per-client exchange stack.

    ``payload`` is [n_clients, B, H] about to enter the exchange sum;
    a client's slice is BAD when it contains any non-finite value or
    its magnitude exceeds ``max_abs`` (a NaN maximum compares False
    against the threshold, so both tests catch it independently).  Bad
    slices are replaced with that client's ``last_good`` slice (zeros
    before its first clean round -- the exchange-free cold-start
    idiom), which keeps NaN/Inf out of the reduction entirely: masking
    AFTER the sum would still poison it, since NaN * 0.0 is NaN.

    Returns ``(screened, bad)`` with ``bad`` a [n_clients] bool mask of
    quarantined slots.  The caller (repro.faults.FaultImpl) drops
    quarantined clients from the round's FedAvg weighting exactly like
    dead padded slots and counts the events into telemetry.  Every op
    here (is_finite / reduce_and / reduce_max / select_n) is handled
    by the static auditor's taint and deadness interpreters, and
    ``bad[i]`` derives only from client i's payload, so the per-slot
    separation contract is preserved."""
    red = tuple(range(1, payload.ndim))
    ok = jnp.isfinite(payload).all(axis=red) & \
        (jnp.abs(payload).max(axis=red) <= jnp.float32(max_abs))
    bad = ~ok
    sel = bad.reshape((-1,) + (1,) * (payload.ndim - 1))
    return jnp.where(sel, last_good, payload), bad


def select_cached_exchange(h_fresh, h_cached, use_cached):
    """Serving-path cache splice (repro.serving.federated): per-slot
    SELECT between a freshly computed exchange-point stack and one
    served from the hot-entity cache.

    ``h_fresh``/``h_cached`` are [n_clients, S, W] slot stacks;
    ``use_cached`` is a [S] 0/1 gate (client_mask-style: a traced
    runtime value, never a python branch, so the slot count and cache
    state can vary per step without retracing).  ``jnp.where`` is an
    exact element select -- a slot with gate 0 gets ``h_fresh``'s bits
    untouched and a slot with gate 1 gets the cached bits untouched --
    which is the whole bitwise-parity story for the serving cache: a
    cached stack was itself captured from this select's output on an
    earlier step, and everything downstream (exchange sum, rest-of-
    network, argmax) is per-row, so cache on/off cannot change a
    single bit of any request's prediction."""
    sel = use_cached[None, :, None] != 0
    return jnp.where(sel, h_cached, h_fresh)


def fedavg(stacked_params, client_mask=None):
    """P2P weight exchange + FedAvg (Algorithm 1 lines 16-19): every
    client receives every peer's weights and averages. stacked_params
    has a leading client axis on every leaf; returns the same structure
    with every client's slot set to the mean.

    client_mask weights the average so dead padding slots contribute
    nothing (live mean is broadcast to every slot, dead ones included,
    keeping the all-clients-synced invariant).  The masked mean is
    computed as ``sum * (1/n_live)`` -- a multiply, exactly how XLA
    lowers ``mean`` -- so the unpadded all-ones mask reproduces
    ``leaf.mean(axis=0)`` bit for bit."""
    if client_mask is None:
        def avg(leaf):
            m = tag(leaf.mean(axis=0, keepdims=True),
                    "declass", "fedavg")
            return jnp.broadcast_to(m, leaf.shape)
    else:
        inv_live = 1.0 / client_mask.sum()

        def avg(leaf):
            cm = client_mask.reshape((-1,) + (1,) * (leaf.ndim - 1))
            term = tag(leaf * cm, "term", "fedavg", client_axis=0)
            m = tag(term.sum(axis=0, keepdims=True) * inv_live,
                    "declass", "fedavg")
            return jnp.broadcast_to(m, leaf.shape)
    return jax.tree.map(avg, stacked_params)
