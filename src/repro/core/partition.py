"""Vertical partitioning (Algorithm 1 line 3): distribute dataset
features across participants. Image datasets are dealt row-by-row
round-robin (Fig. 2); tabular datasets round-robin or random."""
from __future__ import annotations

import numpy as np

from repro.data import vertical as V


def make_partition(dataset: str, n_features: int, n_clients: int, seed=0):
    """Returns list of per-client sorted feature-index arrays."""
    if dataset in ("mnist", "fmnist"):
        side = int(round(n_features ** 0.5))
        return V.round_robin_rows(n_clients, side)
    if dataset == "titanic":
        return V.random_features(n_features, n_clients, seed)
    return V.round_robin_features(n_features, n_clients)


def masks_for(partition, n_features, dtype=np.float32):
    """[n_clients, n_features] 0/1 masks (the zero-padding operators)."""
    return np.stack([V.feature_mask(idx, n_features, dtype)
                     for idx in partition])


def stacked_masks(dataset, n_features, n_clients, seeds, dtype=np.float32):
    """[n_seeds, n_clients, n_features] masks -- one vertical partition
    per seed, for seed-vmapped sweeps. Only seeded partitioners
    (titanic's random_features) actually vary across seeds; the
    round-robin datasets yield the same partition in every lane."""
    return np.stack([
        masks_for(make_partition(dataset, n_features, n_clients, seed=s),
                  n_features, dtype)
        for s in seeds])
