"""Vertical partitioning (Algorithm 1 line 3) and the canonical
slice-aware layout the protocol engine trains on.

Partitioning distributes dataset features across participants: image
datasets are dealt row-by-row round-robin (Fig. 2); tabular datasets
round-robin or random.

The column-permutation trick
----------------------------
The paper's zero-padding makes every client's first-layer matmul
full-width: zeropad(x_local) @ W touches all F rows of W even though
only F_i of them meet non-zero inputs.  ``canonicalize`` removes that
waste *once at setup* instead of on every step: it permutes the dataset
columns so client i owns the contiguous slice ``[offset_i, offset_i +
F_i)`` of the reordered feature axis.  Reordering columns of x while
keeping W's row init order is semantics-preserving -- the first layer
is a sum over feature columns, and which physical column a feature
lives in is arbitrary -- so random partitions (titanic) remain the same
experiment, just expressed in an engine-friendly order.  The recorded
``perm`` maps canonical column j back to original feature ``perm[j]``,
and ``Layout.apply`` re-expresses any raw [..., F] array in canonical
order.

On the canonical layout the zero-padding masks become contiguous slabs,
the XLA engine path can ``dynamic_slice`` instead of masking, and the
``vfl_matmul`` Pallas kernel can walk only the client's weight-row
blocks.  ``Layout.block`` is the largest block size (capped at 128)
that divides every slice size -- and therefore every offset -- so all
slices are block-aligned for the kernel's BlockSpec index_map.

Padded client axes
------------------
``Layout.pad(max_clients)`` appends *dead* client slots (empty feature
slice, all-zero mask) so federations with different participant counts
ride arrays of one static client-axis length and can share a single
compiled round function (repro.core.sweep stacks client-count lanes
this way).  ``LayoutArrays.client_mask`` is the runtime 0/1 view of
which slots are live; the protocol engine multiplies it into the
HiddenOutputExchange sum, the FedAvg weighting, and every loss mean,
so dead slots contribute exact zeros and a padded federation's live
clients train bit-for-bit identically to the unpadded run
(tests/test_padded_engine.py pins this).

See docs/ARCHITECTURE.md for the full Layout/LayoutArrays contract.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import NamedTuple, Sequence, Tuple

import numpy as np

from repro.data import registry as DR
from repro.data import vertical as V


def make_partition(dataset: str, n_features: int, n_clients: int, seed=0):
    """Returns list of per-client sorted feature-index arrays.

    The partition strategy comes from the dataset registry entry
    (``repro.data.registry``): "image_rows" deals whole image rows
    round-robin (Fig. 2), "random" assigns features randomly
    (Titanic), "round_robin" interleaves feature columns, and a
    callable entry is invoked as ``(n_features, n_clients, seed)``.
    Unknown dataset names raise with the registered options."""
    kind = DR.get_dataset(dataset).partition
    if callable(kind):
        return kind(n_features, n_clients, seed)
    if kind == "image_rows":
        side = int(round(n_features ** 0.5))
        return V.round_robin_rows(n_clients, side)
    if kind == "random":
        return V.random_features(n_features, n_clients, seed)
    return V.round_robin_features(n_features, n_clients)


def skewed_partition(n_features: int, sizes: Sequence[int], seed=0):
    """A partition with EXPLICIT unequal per-client feature counts: a
    seeded permutation of the feature ids split at the cumulative
    ``sizes`` (each client's ids sorted, like the registry
    strategies).  ``sizes`` must be positive and sum to
    ``n_features``.  The sizes -- and therefore the canonical
    offsets -- are seed-independent, so skewed layouts satisfy the
    sweep engine's cross-seed static-offset requirement just like the
    registry partitions."""
    sizes = tuple(int(s) for s in sizes)
    if not sizes or any(s < 1 for s in sizes):
        raise ValueError(f"sizes must be positive ints, got {sizes}")
    if sum(sizes) != n_features:
        raise ValueError(f"sizes {sizes} sum to {sum(sizes)}, not "
                         f"n_features={n_features}")
    ids = np.random.default_rng(seed).permutation(n_features)
    return [np.sort(p) for p in
            np.split(ids, np.cumsum(sizes)[:-1])]


def masks_for(partition, n_features, dtype=np.float32):
    """[n_clients, n_features] 0/1 masks (the zero-padding operators)."""
    return np.stack([V.feature_mask(idx, n_features, dtype)
                     for idx in partition])


# ---------------------------------------------------------------------------
# canonical slice-aware layout
# ---------------------------------------------------------------------------
class LayoutArrays(NamedTuple):
    """The device-array view of a Layout, threaded through the jitted
    step/round/predict functions (and vmapped over a seed axis -- and
    now a (seed x client-count) lane axis -- by repro.core.sweep,
    exactly like masks used to be):

      masks        [n_clients, n_features] contiguous-slab zeropad
                   masks (canonical column order) -- the masked
                   reference path; dead (padded) clients are all-zero
      offsets      [n_clients] int32 slice starts -- the dynamic_slice
                   path; dead clients hold 0
      sizes        [n_clients] int32 slice lengths -- runtime view of
                   Layout.sizes for shape-uniform (padded-sweep) first
                   layers; dead clients hold 0
      client_mask  [n_clients] float 1.0 = live participant, 0.0 =
                   dead padding slot.  Multiplied into the exchange
                   sum, FedAvg weights, and loss means so dead slots
                   contribute exact zeros.
    """
    masks: object
    offsets: object
    sizes: object
    client_mask: object


@dataclass(frozen=True, eq=False)
class Layout:
    """Canonical block-aligned feature layout for one federation.

    partition   per-client ORIGINAL feature ids (what each client owns)
    perm        [F] canonical column j holds original feature perm[j]
    inv_perm    [F] original feature f lives at canonical column
                inv_perm[f]
    offsets     per-client canonical slice starts (python ints: static
                under jit, usable in Pallas BlockSpec index_maps)
    sizes       per-client slice lengths F_i (0 for dead padding slots)
    block       largest bk <= 128 dividing every live size (hence
                every offset)
    n_real      number of LIVE participants; clients [n_real,
                n_clients) are dead padding slots added by ``pad``
    """
    partition: Tuple[np.ndarray, ...]
    perm: np.ndarray
    inv_perm: np.ndarray
    offsets: Tuple[int, ...]
    sizes: Tuple[int, ...]
    block: int
    n_features: int
    n_real: int

    @property
    def n_clients(self) -> int:
        """Padded client-axis length (== n_real for unpadded layouts)."""
        return len(self.sizes)

    def apply(self, x):
        """Re-express raw [..., F] data in canonical column order."""
        return x[..., self.perm]

    def masks(self, dtype=np.float32):
        """Contiguous-slab zeropad masks in canonical column order.
        Dead (padded) clients get all-zero rows."""
        m = np.zeros((self.n_clients, self.n_features), dtype)
        for i, (off, sz) in enumerate(zip(self.offsets, self.sizes)):
            m[i, off:off + sz] = 1
        return m

    def client_mask(self, dtype=np.float32):
        """[n_clients] 1.0 for live participants, 0.0 for padding."""
        return (np.arange(self.n_clients) < self.n_real).astype(dtype)

    def pad(self, max_clients: int) -> "Layout":
        """Append dead client slots until the client axis has length
        ``max_clients``.  Dead slots own no features (empty slice at
        offset 0, all-zero mask); the protocol engine excludes them
        from the exchange and FedAvg via ``client_mask``."""
        if max_clients < self.n_clients:
            raise ValueError(f"max_clients={max_clients} < existing "
                             f"client axis {self.n_clients}")
        k = max_clients - self.n_clients
        if k == 0:
            return self
        import dataclasses
        empty = tuple(np.empty((0,), self.partition[0].dtype)
                      for _ in range(k))
        return dataclasses.replace(
            self, partition=self.partition + empty,
            offsets=self.offsets + (0,) * k,
            sizes=self.sizes + (0,) * k)

    def arrays(self) -> LayoutArrays:
        import jax.numpy as jnp
        return LayoutArrays(masks=jnp.asarray(self.masks()),
                            offsets=jnp.asarray(self.offsets, jnp.int32),
                            sizes=jnp.asarray(self.sizes, jnp.int32),
                            client_mask=jnp.asarray(self.client_mask()))


def _block_of(sizes: Sequence[int], cap: int = 128) -> int:
    g = 0
    for s in sizes:
        g = math.gcd(g, int(s))
    if g == 0:
        return 1
    return max(d for d in range(1, min(g, cap) + 1) if g % d == 0)


def canonicalize(partition, n_features: int) -> Layout:
    """Build the canonical contiguous layout for a partition: column j
    of the canonical order is original feature ``perm[j]``, client i's
    features occupy ``[offset_i, offset_i + F_i)``."""
    parts = tuple(np.asarray(p) for p in partition)
    perm = np.concatenate(parts).astype(np.int64)
    if perm.size != n_features or np.unique(perm).size != n_features:
        raise ValueError("partition must be disjoint and cover all "
                         f"{n_features} features (got {perm.size} ids, "
                         f"{np.unique(perm).size} unique)")
    inv_perm = np.empty_like(perm)
    inv_perm[perm] = np.arange(n_features)
    sizes = tuple(int(len(p)) for p in parts)
    offsets = tuple(int(o) for o in
                    np.concatenate([[0], np.cumsum(sizes)[:-1]]))
    return Layout(partition=parts, perm=perm, inv_perm=inv_perm,
                  offsets=offsets, sizes=sizes,
                  block=_block_of(sizes), n_features=n_features,
                  n_real=len(parts))


def make_layout(dataset: str, n_features: int, n_clients: int,
                seed=0, max_clients=None, sizes=None) -> Layout:
    """Partition + canonicalize (+ optional padding) in one call.
    ``sizes`` overrides the registry partition strategy with a skewed
    split of explicit per-client feature counts
    (:func:`skewed_partition`); every engine lane -- masked, slice,
    pallas, padded or not -- trains identically on skewed and equal
    splits (tests/test_wire.py pins it)."""
    if sizes is not None:
        if len(sizes) != n_clients:
            raise ValueError(f"sizes has {len(sizes)} entries for "
                             f"n_clients={n_clients}")
        part = skewed_partition(n_features, sizes, seed=seed)
    else:
        part = make_partition(dataset, n_features, n_clients, seed=seed)
    lay = canonicalize(part, n_features)
    return lay if max_clients is None else lay.pad(max_clients)
