"""Seed-vmapped grid sweeps over De-VertiFL federations.

Grid semantics
--------------
A sweep is the cartesian grid  datasets x modes x client_counts, and
every grid **cell** is a *batch of federations*: one federation per
seed, all trained simultaneously by ``jax.vmap`` over a leading seed
axis of (params, opt_state, step_idx, round keys, data, layout).  Per
cell there is exactly ONE compilation -- the jitted, vmapped round
function from ``repro.core.protocol.make_round_fn`` -- reused for
every round and every seed lane of that cell (the seed count is part
of the traced shape, so a different number of seeds, like a different
dataset/mode/n_clients, is a fresh compile).  Each seed lane is an
independent federation end to end: its own synthetic dataset draw,
its own vertical partition (independently random where the dataset's
partitioner is seeded, i.e. titanic; the round-robin datasets
partition identically at every seed), its own parameter init, its
own epoch shuffles (all derived from ``PRNGKey(seed)`` exactly as
``DeVertiFL.train`` derives them, so a sweep lane reproduces the
corresponding standalone run bit-for-bit).

Every lane trains on its own canonical column layout
(``repro.core.partition.canonicalize``): each seed's data is permuted
at setup by that seed's layout, and the per-seed ``LayoutArrays``
(slab masks + slice offsets) ride the vmapped seed axis exactly like
masks used to.  Canonical offsets/sizes are deterministic per
(dataset, n_clients) -- only the column *assignment* varies across
seeds -- which is what lets the pallas first-layer path close over
static offsets even under the seed vmap.

``run_cell`` trains one cell and reports per-seed and mean/std F1/acc;
``run_grid`` walks the whole grid -- reproducing the paper's
Table-2-style comparison (devertifl vs. non_federated vs. verticomb)
in one call -- and returns ``{"cells": {"ds/mode/n": {...}}}`` plus a
per-(dataset, n_clients) mode comparison in ``"compare"``.
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import partition as PT
from repro.core.protocol import (ARCH_FOR, ProtocolConfig, make_perm_fn,
                                 make_predict_fn, make_round_fn, train_keys)
from repro.data import synthetic as SD
from repro.metrics import accuracy, f1_score
from repro.models.mlp_model import PaperMLP
from repro.optim import adam


@dataclass(frozen=True)
class SweepConfig:
    datasets: Sequence[str] = ("mnist", "fmnist", "titanic", "bank")
    modes: Sequence[str] = ("devertifl", "non_federated", "verticomb")
    client_counts: Sequence[int] = (2, 3, 5)
    seeds: Sequence[int] = (0, 1, 2)
    rounds: int = 5
    epochs: int = 5
    batch_size: int = 64
    lr: float = 1e-3
    exchange_at: int = -1
    fedavg: bool = True
    n_samples: Optional[int] = None     # dataset size override (speed)
    first_layer: str = "auto"           # auto | pallas | slice | masked


def _stacked_federations(dataset, n_clients, seeds, n_samples):
    """Per-seed datasets, canonical layouts and keys stacked on axis 0.
    Data is permuted into each seed's canonical column order; the
    LayoutArrays (masks + offsets) carry the per-seed layout through
    the vmapped round."""
    xtr, ytr, xte, yte = SD.make_dataset_stack(dataset, seeds, n=n_samples)
    layouts = [PT.make_layout(dataset, xtr.shape[-1], n_clients, seed=s)
               for s in seeds]
    # canonical offsets/sizes are seed-independent (only the column
    # assignment varies); the pallas path relies on this to close over
    # static offsets under the seed vmap
    if any(l.offsets != layouts[0].offsets or l.sizes != layouts[0].sizes
           for l in layouts):
        raise ValueError("per-seed canonical layouts disagree on "
                         "offsets/sizes; the static-offset pallas path "
                         "cannot be vmapped over such lanes")
    xtr = jnp.asarray(np.stack([l.apply(x) for x, l in zip(xtr, layouts)]))
    xte = jnp.asarray(np.stack([l.apply(x) for x, l in zip(xte, layouts)]))
    ytr, yte = jnp.asarray(ytr), jnp.asarray(yte)
    lay = jax.tree.map(lambda *a: jnp.stack(a),
                       *[l.arrays() for l in layouts])
    keys = jnp.stack([jax.random.PRNGKey(s) for s in seeds])
    return xtr, ytr, xte, yte, lay, keys, layouts[0]


def run_cell(dataset, mode, n_clients, scfg: SweepConfig):
    """Train len(scfg.seeds) federations of one (dataset, mode,
    n_clients) cell in a single vmapped computation."""
    pcfg = ProtocolConfig(
        dataset=dataset, n_clients=n_clients, rounds=scfg.rounds,
        epochs=scfg.epochs, batch_size=scfg.batch_size, lr=scfg.lr,
        exchange_at=scfg.exchange_at, mode=mode, fedavg=scfg.fedavg,
        n_samples=scfg.n_samples, first_layer=scfg.first_layer)
    model = PaperMLP(get_config(ARCH_FOR[dataset]))
    opt = adam(pcfg.lr, max_grad_norm=None)

    xtr, ytr, xte, yte, lay, keys, layout = _stacked_federations(
        dataset, n_clients, scfg.seeds, scfg.n_samples)
    n_seeds, n_train = xtr.shape[0], xtr.shape[1]

    def init_one(key):
        init_key, loop_key = train_keys(key)
        ks = jax.random.split(init_key, n_clients)
        params = jax.vmap(model.init)(ks)
        return params, jax.vmap(opt.init)(params), loop_key

    params, opt_state, loop_keys = jax.jit(jax.vmap(init_one))(keys)

    round_fn = make_round_fn(model, opt, pcfg, n_train, layout=layout)
    vround = jax.jit(jax.vmap(round_fn), donate_argnums=(0, 1))
    vpred = jax.jit(jax.vmap(make_predict_fn(model, pcfg, layout=layout)))
    vfold = jax.jit(jax.vmap(jax.random.fold_in, in_axes=(0, None)))

    step_idx = jnp.zeros((n_seeds,), jnp.int32)
    # round 0 triggers the jit compile; time the steady-state rounds
    # only (matching benchmarks/protocol_bench's warmed-up timings).
    # With rounds == 1 the compile is unavoidably included.
    t0 = time.perf_counter()
    losses = None
    timed_rounds = pcfg.rounds
    for r in range(pcfg.rounds):
        params, opt_state, step_idx, losses = vround(
            params, opt_state, step_idx, vfold(loop_keys, r),
            xtr, ytr, lay)
        if r == 0 and pcfg.rounds > 1:
            jax.block_until_ready(losses)
            t0 = time.perf_counter()
            timed_rounds = pcfg.rounds - 1
    jax.block_until_ready(losses)
    wall = time.perf_counter() - t0

    preds = np.asarray(vpred(params, xte, lay))      # [S, n, B_test]
    yte_np, ytr_np = np.asarray(yte), np.asarray(ytr)
    f1s, accs = [], []
    for s in range(n_seeds):
        avg = "macro" if len(np.unique(ytr_np[s])) > 2 else "binary"
        f1s.append(float(np.mean([f1_score(yte_np[s], preds[s, i], average=avg)
                                  for i in range(n_clients)])))
        accs.append(float(np.mean([accuracy(yte_np[s], preds[s, i])
                                   for i in range(n_clients)])))
    steps = timed_rounds * pcfg.epochs * make_perm_fn(pcfg,
                                                      n_train).n_batches
    return {
        "dataset": dataset, "mode": mode, "n_clients": n_clients,
        "seeds": list(scfg.seeds),
        "f1_per_seed": f1s, "acc_per_seed": accs,
        "f1_mean": float(np.mean(f1s)), "f1_std": float(np.std(f1s)),
        "acc_mean": float(np.mean(accs)),
        "final_loss_mean": float(np.asarray(losses)[:, -1].mean()),
        "wall_s": wall,
        "steps_per_sec": steps * n_seeds / max(wall, 1e-9),
    }


def run_grid(scfg: SweepConfig = SweepConfig()):
    """Walk the full datasets x modes x client_counts grid.  Returns
    {"cells": {key: cell}, "compare": {ds/n: {mode: f1_mean}}} where
    key = "dataset/mode/n_clients"."""
    cells, compare = {}, {}
    for ds, mode, nc in itertools.product(scfg.datasets, scfg.modes,
                                          scfg.client_counts):
        cell = run_cell(ds, mode, nc, scfg)
        cells[f"{ds}/{mode}/{nc}"] = cell
        compare.setdefault(f"{ds}/{nc}", {})[mode] = cell["f1_mean"]
    return {"cells": cells, "compare": compare}
