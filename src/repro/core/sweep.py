"""Vmapped, sharded grid sweeps over De-VertiFL federations.

Grid semantics
--------------
A sweep is the cartesian grid  datasets x modes x schedules x
client_counts x seeds.  Since PR 3 the engine stacks BOTH the seed
axis and the client-count axis on one leading **lane** axis: every
(n_clients, seed) pair is a lane, all client counts are padded to
``max(client_counts)`` dead slots (``Layout.pad`` -- see
repro.core.partition), and one jitted, vmapped round function from
``repro.core.protocol.make_round_fn`` trains every lane of a
(dataset, mode) cell group simultaneously.  A dataset x mode grid
therefore compiles ONCE across all client counts
(tests/test_padded_engine.py pins the trace count), where previously
every n_clients value was a separate compile.  Since PR 5 the
exchange SCHEDULE (repro.schedule) is a lane axis too: staleness
depth k and participation p ride the traced per-lane schedule state,
so a staleness-tolerance grid (sync / stale_k / partial lanes) also
shares that single compile (tests/test_schedule.py pins it; see
``SweepConfig.schedules`` for the family constraints).

Each lane is an independent federation end to end: its own synthetic
dataset draw, its own vertical partition, its own parameter init
(live clients' init keys are exactly the unpadded derivation -- see
``protocol.init_padded_params``), its own epoch shuffles, all derived
from ``PRNGKey(seed)`` exactly as ``DeVertiFL.train`` derives them.
A masked-lane padded sweep reproduces the corresponding standalone
runs bit-for-bit; the shape-uniform gather-slice first layer (below)
is allclose instead, because its contraction length is padded.

Device scale-out
----------------
Lanes have no cross-lane dataflow, so ``run_padded_cells`` distributes
them over the device mesh with ``repro.compat.shard_map`` under the
``repro.sharding`` rules ("sweep_lane" -> the data-parallel mesh
axes).  The lane axis is split over the largest device count that
divides it; on a single device the shard_map is skipped.  Sharded and
single-device sweeps produce identical results (pinned in
tests/test_padded_engine.py).

First layer under the lane vmap
-------------------------------
Canonical offsets/sizes are static per (dataset, n_clients), so the
per-federation slice/pallas paths close over them -- which is exactly
what a cross-client-count trace cannot do.  The padded sweep instead
uses ``make_uniform_first_layer_fn``: a gather-slice of static width
``max(F_i)`` whose offsets AND sizes ride the traced LayoutArrays,
with out-of-slice columns masked to exact zeros.  first_layer="masked"
keeps the fully-traced zeropad reference (and bitwise standalone
equivalence); "slice"/"pallas"/"auto" resolve to the gather-slice
variant under the lane vmap (a pallas lane needs the scalar-prefetch
offset from the ROADMAP before it can vary offsets per lane).

``run_cell`` (per-count, seed-vmapped only) is retained for
single-cell use -- benchmarks/table2.py and examples drive it --
and as the "looped" baseline the sweep benchmark compares against.
``run_grid`` walks datasets x modes, one padded multi-count batch
each, and returns the same {"cells": {"ds/mode/n": ...}} schema as
before.

See docs/ARCHITECTURE.md for the Layout/LayoutArrays and key
derivation contracts this engine rides on.
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding as sh
from repro.compat import shard_map
from repro.configs import get_config
from repro.core import partition as PT
from repro.core.protocol import (FIRST_LAYERS, ProtocolConfig, arch_for,
                                 init_padded_params, make_perm_fn,
                                 make_predict_fn, make_round_fn,
                                 resolve_first_layer, train_keys)
from repro.data import registry as DR
from repro.metrics import accuracy, f1_score
from repro.models.mlp_model import PaperMLP
from repro.optim import adam


@dataclass(frozen=True)
class SweepConfig:
    datasets: Sequence[str] = ("mnist", "fmnist", "titanic", "bank")
    modes: Sequence[str] = ("devertifl", "non_federated", "verticomb")
    client_counts: Sequence[int] = (2, 3, 5)
    seeds: Sequence[int] = (0, 1, 2)
    rounds: int = 5
    epochs: int = 5
    batch_size: int = 64
    lr: float = 1e-3
    exchange_at: int = -1
    fedavg: bool = True
    n_samples: Optional[int] = None     # dataset size override (speed)
    first_layer: str = "auto"           # auto | pallas | slice | masked
    # Exchange-schedule lane axis (repro.schedule spec strings).  The
    # sync/stale_k/partial family rides ONE compiled round -- k and p
    # are traced per-lane scalars in the schedule state -- so a
    # staleness-tolerance grid compiles once (round_traces == 1).
    # Non-sync schedules run devertifl mode only; double_buffer and
    # custom schedules cannot share a lane axis with other schedules.
    schedules: Sequence[str] = ("sync",)
    # Fault-plan lane axis (repro.faults spec strings).  Rates,
    # durations and corruption kind ride the traced fault state, so a
    # fault-tolerance grid (none / crash / corrupt lanes) shares the
    # one compiled round too; the straggler ring is sized to the
    # largest delay across lanes.  Non-none plans run devertifl mode
    # only; custom plans cannot ride a lane axis.
    faults: Sequence[str] = ("none",)
    # Exchange-transform lane axis (repro.wire spec strings).  Keep
    # fraction, quantize flag and noise scale ride the traced wire
    # state, so a compression-tradeoff grid (none / topk / int8 / dp
    # lanes) shares the one compiled round as well.  Non-none
    # transforms run devertifl mode only; custom transforms cannot
    # ride a lane axis.
    transforms: Sequence[str] = ("none",)
    # Observability lane axis (repro.obs spec strings).  The level
    # gates ride the traced obs state, so an obs x transform x fault
    # x schedule grid shares the one compiled round too.  Taps are
    # observation-only: a non-none obs lane's trajectory is bitwise
    # its none lane's.  Non-none levels run devertifl mode only;
    # custom obs impls cannot ride a lane axis.
    obs: Sequence[str] = ("none",)


# ---------------------------------------------------------------------------
# shape-uniform first layer for the (seed x client-count) lane vmap
# ---------------------------------------------------------------------------
def make_uniform_first_layer_fn(width: int):
    """first(params, xb, lay) -> [n_clients, B, H] layer-0 activations
    where offsets AND sizes are read from the traced LayoutArrays, so
    a single trace serves lanes with different client counts.

    Client i's slice is gathered as the ``width`` columns starting at
    lay.offsets[i]; columns past lay.sizes[i] are masked to exact
    zeros before the matmul, so they contribute +0.0 terms.  width is
    the max live slice length across all lanes (static).  Because the
    contraction runs over ``width`` terms instead of F_i, results are
    allclose -- not bitwise -- to the per-federation dynamic_slice
    path.  Dead slots (size 0) produce relu(bias), matching the
    per-federation engines' dead_h1."""
    iota = jnp.arange(width)

    def first(params, xb, lay):
        w = params["layer_0"]["kernel"]     # [n, F, H]
        b = params["layer_0"]["bias"]       # [n, H]

        def one(w_i, b_i, off, size):
            valid = (iota < size)
            cols = jnp.where(valid, off + iota, 0)
            x_i = xb[:, cols] * valid.astype(xb.dtype)[None, :]
            return jax.nn.relu(x_i @ w_i[cols] + b_i)

        return jax.vmap(one)(w, b, lay.offsets, lay.sizes)
    return first


def _sweep_first_layer(pcfg, width):
    """Resolve the first layer for a lane-vmapped sweep: masked stays
    masked (fully traced already); slice/pallas/auto take the uniform
    gather-slice (static pallas offsets cannot vary across lanes).
    Registered custom backends close over per-federation statics the
    lane vmap cannot vary, so they are refused here, not mis-traced."""
    fl = resolve_first_layer(pcfg)
    if FIRST_LAYERS.get(fl) is not None:
        raise ValueError(
            f"custom first_layer {fl!r} is not supported in padded "
            "multi-count sweeps (its offsets/sizes cannot vary per "
            "lane); use 'masked', 'slice', 'pallas', or 'auto'")
    if fl == "masked":
        return None
    return make_uniform_first_layer_fn(width)


# ---------------------------------------------------------------------------
# exchange-schedule lanes
# ---------------------------------------------------------------------------
def _sweep_schedules(scfg, mode, model, n_clients, n_train):
    """Parse scfg.schedules into (scheds, impl, sync_only) for a lane
    batch of one (dataset, mode).  sync-only sweeps get impl=None (the
    untouched legacy round).  Mixed schedule lanes must all belong to
    the sync/stale_k/partial family: k and p ride the traced schedule
    state, so ONE ring impl (sized to the largest k) serves every
    lane under a single trace.  double_buffer is vmappable but carries
    a differently-shaped state, so it cannot share an axis with other
    schedules; custom schedules (like custom first layers) may close
    over per-federation statics and are refused outright."""
    from repro.schedule import get_schedule, make_schedule_impl
    if not scfg.schedules:
        raise ValueError("schedules must name at least one schedule")
    scheds = tuple(get_schedule(s) for s in scfg.schedules)
    if len(scheds) == 1 and scheds[0].is_sync:
        return scheds, None, True
    if mode != "devertifl":
        raise ValueError(
            f"schedules beyond 'sync' require mode='devertifl' sweep "
            f"cells, got mode {mode!r}")
    if any(s.custom is not None for s in scheds):
        raise ValueError(
            "custom schedules are not supported in sweep lanes (their "
            "impls may close over per-federation statics the lane "
            "vmap cannot vary); run them as standalone sessions")
    if any(s.double_buffer for s in scheds) and len(scheds) > 1:
        raise ValueError(
            "double_buffer carries a differently-shaped schedule "
            "state and cannot share a lane axis with other schedules; "
            "sweep it as its own single-schedule batch")
    from repro.core.protocol import exchange_width
    impl = make_schedule_impl(
        scheds[0], n_clients, min(scfg.batch_size, n_train),
        exchange_width(model, scfg.exchange_at),
        max_k=max(s.k for s in scheds))
    return scheds, impl, False


def _stacked_sched_state(impl, scheds, n_base):
    """Per-lane initial schedule states, schedule-major over a base
    lane batch of n_base (count x seed) lanes."""
    if impl is None:
        return {}
    per = [jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_base,) + a.shape),
        impl.init_state(sc)) for sc in scheds]
    return jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *per)


# ---------------------------------------------------------------------------
# fault-plan lanes
# ---------------------------------------------------------------------------
def _sweep_faults(scfg, mode, model, n_clients, n_train, impl):
    """Parse scfg.faults into (plans, impl, none_only) for a lane batch
    of one (dataset, mode).  A none-only axis hands the schedule impl
    back untouched -- the fault-free sweep is bit-for-bit the pre-fault
    one.  Mixed fault lanes share ONE FaultImpl: rates / durations /
    corruption kind are traced per-lane state, and the straggler ring
    is sized to the largest delay across lanes.  Literal sync under a
    fault axis is promoted to the depth-0 ring impl (proven
    bitwise-sync) so the fault layer has four-hook state to ride;
    custom plans (like custom schedules) may close over per-federation
    statics and are refused."""
    from repro.faults import get_fault_plan, make_fault_impl
    if not scfg.faults:
        raise ValueError("faults must name at least one fault plan")
    plans = tuple(get_fault_plan(f) for f in scfg.faults)
    if len(plans) == 1 and plans[0].is_none:
        return plans, impl, True
    if mode != "devertifl":
        raise ValueError(
            f"fault plans beyond 'none' require mode='devertifl' sweep "
            f"cells, got mode {mode!r}")
    if any(p.custom is not None for p in plans):
        raise ValueError(
            "custom fault plans are not supported in sweep lanes "
            "(their impls may close over per-federation statics the "
            "lane vmap cannot vary); run them as standalone sessions")
    from repro.core.protocol import exchange_width
    bs = min(scfg.batch_size, n_train)
    width = exchange_width(model, scfg.exchange_at)
    if impl is None:
        from repro.schedule import LaneScheduleImpl
        impl = LaneScheduleImpl(0, n_clients, bs, width)
    impl = make_fault_impl(plans[0], impl, n_clients, bs, width,
                           max_delay=max(p.max_delay for p in plans))
    return plans, impl, False


def _stacked_fault_state(impl, plans, scheds, n_base, none_only):
    """Per-lane initial carry states, fault-major over the
    schedule-major base ((plan, sched) blocks of n_base lanes each).
    A none-only fault axis reduces to :func:`_stacked_sched_state`."""
    if none_only:
        return _stacked_sched_state(impl, scheds, n_base)
    per = [jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_base,) + a.shape),
        impl.init_state(sc, plan=pl))
        for pl in plans for sc in scheds]
    return jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *per)


# ---------------------------------------------------------------------------
# exchange-transform (wire) lanes
# ---------------------------------------------------------------------------
def _sweep_transforms(scfg, mode, model, n_clients, n_train, impl):
    """Parse scfg.transforms into (wires, impl, none_only) for a lane
    batch of one (dataset, mode).  A none-only axis hands the
    schedule/fault impl back untouched -- the transform-free sweep is
    bit-for-bit the pre-wire one.  Mixed transform lanes share ONE
    WireImpl: keep fraction, quantize flag and noise scale are traced
    per-lane state, so transform x fault x schedule grids ride the
    single compiled round.  Like the fault layer, literal sync under a
    wire axis is promoted to the depth-0 ring impl so the wire layer
    has four-hook state to wrap; custom transforms may close over
    per-federation statics and are refused."""
    from repro.wire import get_wire_plan, make_wire_impl
    if not scfg.transforms:
        raise ValueError("transforms must name at least one transform")
    wires = tuple(get_wire_plan(t) for t in scfg.transforms)
    if len(wires) == 1 and wires[0].is_none:
        return wires, impl, True
    if mode != "devertifl":
        raise ValueError(
            f"transforms beyond 'none' require mode='devertifl' sweep "
            f"cells, got mode {mode!r}")
    if any(w.custom is not None for w in wires):
        raise ValueError(
            "custom transforms are not supported in sweep lanes (their "
            "impls may close over per-federation statics the lane "
            "vmap cannot vary); run them as standalone sessions")
    from repro.core.protocol import exchange_width
    bs = min(scfg.batch_size, n_train)
    width = exchange_width(model, scfg.exchange_at)
    if impl is None:
        from repro.schedule import LaneScheduleImpl
        impl = LaneScheduleImpl(0, n_clients, bs, width)
    impl = make_wire_impl(wires[0], impl, n_clients, bs, width)
    return wires, impl, False


def _stacked_wire_state(impl, wires, plans, scheds, n_base,
                        fault_none_only, wire_none_only):
    """Per-lane initial carry states, transform-major over the
    fault-major-over-schedule-major base ((wire, plan, sched) blocks of
    n_base lanes each).  A none-only wire axis reduces to
    :func:`_stacked_fault_state`."""
    if wire_none_only:
        return _stacked_fault_state(impl, plans, scheds, n_base,
                                    fault_none_only)
    per = []
    for wp in wires:
        for pl in plans:
            kw = {"wire": wp}
            if not fault_none_only:
                kw["plan"] = pl
            for sc in scheds:
                per.append(jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (n_base,) + a.shape),
                    impl.init_state(sc, **kw)))
    return jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *per)


# ---------------------------------------------------------------------------
# observability (obs) lanes
# ---------------------------------------------------------------------------
def _sweep_obs(scfg, mode, model, n_clients, n_train, impl):
    """Parse scfg.obs into (obss, impl, none_only) for a lane batch of
    one (dataset, mode).  A none-only axis hands the
    schedule/fault/wire impl back untouched -- the obs-free sweep is
    bit-for-bit the pre-obs one.  Mixed obs lanes share ONE ObsImpl:
    the level gates are traced per-lane state, so obs x transform x
    fault x schedule grids ride the single compiled round.  Like the
    fault and wire layers, literal sync under an obs axis is promoted
    to the depth-0 ring impl so the taps have four-hook state to
    wrap; custom obs impls may close over per-federation statics and
    are refused."""
    from repro.obs import get_obs_plan, make_obs_impl
    if not scfg.obs:
        raise ValueError("obs must name at least one obs level")
    obss = tuple(get_obs_plan(o) for o in scfg.obs)
    if len(obss) == 1 and obss[0].is_none:
        return obss, impl, True
    if mode != "devertifl":
        raise ValueError(
            f"obs levels beyond 'none' require mode='devertifl' sweep "
            f"cells, got mode {mode!r}")
    if any(o.custom is not None for o in obss):
        raise ValueError(
            "custom obs impls are not supported in sweep lanes (their "
            "impls may close over per-federation statics the lane "
            "vmap cannot vary); run them as standalone sessions")
    from repro.core.protocol import exchange_width
    bs = min(scfg.batch_size, n_train)
    width = exchange_width(model, scfg.exchange_at)
    if impl is None:
        from repro.schedule import LaneScheduleImpl
        impl = LaneScheduleImpl(0, n_clients, bs, width)
    # build at the HIGHEST stacked level: tap work above the impl's
    # static level is not traced at all, and every lane must share
    # one trace -- lower-level lanes gate it off with traced zeros
    top = max(obss, key=lambda o: o.level)
    impl = make_obs_impl(top, impl, n_clients, bs, width,
                         rounds=scfg.rounds)
    return obss, impl, False


def _stacked_obs_state(impl, obss, wires, plans, scheds, n_base,
                       fault_none_only, wire_none_only,
                       obs_none_only):
    """Per-lane initial carry states, obs-major over the
    transform-major-over-fault-major-over-schedule-major base ((obs,
    wire, plan, sched) blocks of n_base lanes each).  A none-only obs
    axis reduces to :func:`_stacked_wire_state`."""
    if obs_none_only:
        return _stacked_wire_state(impl, wires, plans, scheds, n_base,
                                   fault_none_only, wire_none_only)
    per = []
    for op in obss:
        for wp in wires:
            for pl in plans:
                kw = {"obs": op}
                if not wire_none_only:
                    kw["wire"] = wp
                if not fault_none_only:
                    kw["plan"] = pl
                for sc in scheds:
                    per.append(jax.tree.map(
                        lambda a: jnp.broadcast_to(
                            a, (n_base,) + a.shape),
                        impl.init_state(sc, **kw)))
    return jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *per)


# ---------------------------------------------------------------------------
# lane stacking
# ---------------------------------------------------------------------------
def _stacked_federations(dataset, n_clients, seeds, n_samples):
    """Per-seed datasets, canonical layouts and keys stacked on axis 0.
    Data is permuted into each seed's canonical column order; the
    LayoutArrays (masks/offsets/sizes/client_mask) carry the per-seed
    layout through the vmapped round."""
    xtr, ytr, xte, yte = DR.make_dataset_stack(dataset, seeds, n=n_samples)
    layouts = [PT.make_layout(dataset, xtr.shape[-1], n_clients, seed=s)
               for s in seeds]
    # canonical offsets/sizes are seed-independent (only the column
    # assignment varies); the pallas path relies on this to close over
    # static offsets under the seed vmap
    if any(l.offsets != layouts[0].offsets or l.sizes != layouts[0].sizes
           for l in layouts):
        raise ValueError("per-seed canonical layouts disagree on "
                         "offsets/sizes; the static-offset pallas path "
                         "cannot be vmapped over such lanes")
    xtr = jnp.asarray(np.stack([l.apply(x) for x, l in zip(xtr, layouts)]))
    xte = jnp.asarray(np.stack([l.apply(x) for x, l in zip(xte, layouts)]))
    ytr, yte = jnp.asarray(ytr), jnp.asarray(yte)
    lay = jax.tree.map(lambda *a: jnp.stack(a),
                       *[l.arrays() for l in layouts])
    keys = jnp.stack([jax.random.PRNGKey(s) for s in seeds])
    return xtr, ytr, xte, yte, lay, keys, layouts[0]


def _stacked_lanes(dataset, client_counts, seeds, n_samples,
                   max_clients=None):
    """Stack every (n_clients, seed) pair on one lane axis, padded to
    max(client_counts) (or an explicit wider ``max_clients``).
    Returns (xtr, ytr, xte, yte, lay, keys, lanes, width): lanes is
    the [(n_clients, seed), ...] order (count-major), width the max
    live slice length."""
    max_c = max_clients or max(client_counts)
    xtr, ytr, xte, yte = DR.make_dataset_stack(dataset, seeds, n=n_samples)
    xs_tr, xs_te, lays, lanes, width = [], [], [], [], 1
    for nc in client_counts:
        for si, s in enumerate(seeds):
            lo = PT.make_layout(dataset, xtr.shape[-1], nc, seed=s,
                                max_clients=max_c)
            lanes.append((nc, s))
            width = max(width, max(lo.sizes))
            xs_tr.append(lo.apply(xtr[si]))
            xs_te.append(lo.apply(xte[si]))
            lays.append(lo.arrays())
    n_rep = len(client_counts)
    lay = jax.tree.map(lambda *a: jnp.stack(a), *lays)
    keys = jnp.stack([jax.random.PRNGKey(s) for _, s in lanes])
    return (jnp.asarray(np.stack(xs_tr)),
            jnp.asarray(np.concatenate([ytr] * n_rep)),
            jnp.asarray(np.stack(xs_te)),
            jnp.asarray(np.concatenate([yte] * n_rep)),
            lay, keys, lanes, width)


def _lane_metrics(preds, yte, ytr, lanes):
    """Per-lane mean-over-live-clients F1/acc from padded predictions
    [L, max_clients, B_test]."""
    f1s, accs = [], []
    for li, (nc, _) in enumerate(lanes):
        avg = "macro" if len(np.unique(ytr[li])) > 2 else "binary"
        f1s.append(float(np.mean([f1_score(yte[li], preds[li, i],
                                           average=avg)
                                  for i in range(nc)])))
        accs.append(float(np.mean([accuracy(yte[li], preds[li, i])
                                   for i in range(nc)])))
    return f1s, accs


def _train_rounds(vround, vfold, params, opt_state, sched_state,
                  loop_keys, xtr, ytr, lay, rounds):
    """Drive `rounds` vmapped rounds and time STEADY STATE only: round
    0 triggers the jit compile, so the clock restarts after it (with
    rounds == 1 the compile is unavoidably included -- matching
    benchmarks/protocol_bench's warmed-up timings).  Shared by
    run_cell and run_padded_cells so the looped-vs-padded benchmark
    comparison can never diverge on timing protocol.  sched_state is
    the per-lane exchange-schedule(+fault) carry ({} for sync).
    Returns (params, opt_state, sched_state, losses, wall,
    timed_rounds) -- the final carry is returned so fault telemetry
    counters can be read back per lane."""
    step_idx = jnp.zeros((loop_keys.shape[0],), jnp.int32)
    t0 = time.perf_counter()
    losses = None
    timed_rounds = rounds
    for r in range(rounds):
        params, opt_state, step_idx, sched_state, losses = vround(
            params, opt_state, step_idx, sched_state,
            vfold(loop_keys, r), xtr, ytr, lay)
        if r == 0 and rounds > 1:
            jax.block_until_ready(losses)
            t0 = time.perf_counter()
            timed_rounds = rounds - 1
    jax.block_until_ready(losses)
    return (params, opt_state, sched_state, losses,
            time.perf_counter() - t0, timed_rounds)


# ---------------------------------------------------------------------------
# single-cell (per-count) runner -- the pre-padding engine, retained
# ---------------------------------------------------------------------------
def run_cell(dataset, mode, n_clients, scfg: SweepConfig):
    """Train len(scfg.seeds) federations of one (dataset, mode,
    n_clients) cell in a single vmapped computation.  One compile per
    (dataset, mode, n_clients): the looped baseline the padded
    multi-count engine (run_padded_cells) is benchmarked against."""
    if len(scfg.schedules) != 1:
        raise ValueError(
            "run_cell takes exactly one schedule; use "
            "run_padded_cells(schedules=...) for schedule grids")
    if len(scfg.faults) != 1:
        raise ValueError(
            "run_cell takes exactly one fault plan; use "
            "run_padded_cells(faults=...) for fault grids")
    if len(scfg.transforms) != 1:
        raise ValueError(
            "run_cell takes exactly one transform; use "
            "run_padded_cells(transforms=...) for wire grids")
    if len(scfg.obs) != 1:
        raise ValueError(
            "run_cell takes exactly one obs level; use "
            "run_padded_cells(obs=...) for obs grids")
    pcfg = ProtocolConfig(
        dataset=dataset, n_clients=n_clients, rounds=scfg.rounds,
        epochs=scfg.epochs, batch_size=scfg.batch_size, lr=scfg.lr,
        exchange_at=scfg.exchange_at, mode=mode, fedavg=scfg.fedavg,
        n_samples=scfg.n_samples, first_layer=scfg.first_layer,
        schedule=scfg.schedules[0], fault=scfg.faults[0],
        transform=scfg.transforms[0], obs=scfg.obs[0])
    model = PaperMLP(get_config(arch_for(dataset)))
    opt = adam(pcfg.lr, max_grad_norm=None)

    xtr, ytr, xte, yte, lay, keys, layout = _stacked_federations(
        dataset, n_clients, scfg.seeds, scfg.n_samples)
    n_seeds, n_train = xtr.shape[0], xtr.shape[1]
    scheds, impl, _ = _sweep_schedules(scfg, mode, model, n_clients,
                                       n_train)
    plans, impl, none_only = _sweep_faults(scfg, mode, model, n_clients,
                                           n_train, impl)
    wires, impl, wire_none = _sweep_transforms(scfg, mode, model,
                                               n_clients, n_train, impl)
    obss, impl, obs_none = _sweep_obs(scfg, mode, model, n_clients,
                                      n_train, impl)
    sched_state = _stacked_obs_state(impl, obss, wires, plans, scheds,
                                     n_seeds, none_only, wire_none,
                                     obs_none)

    def init_one(key):
        init_key, loop_key = train_keys(key)
        ks = jax.random.split(init_key, n_clients)
        params = jax.vmap(model.init)(ks)
        return params, jax.vmap(opt.init)(params), loop_key

    params, opt_state, loop_keys = jax.jit(jax.vmap(init_one))(keys)

    round_fn = make_round_fn(model, opt, pcfg, n_train, layout=layout,
                             sched_impl=impl)
    vround = jax.jit(jax.vmap(round_fn), donate_argnums=(0, 1))
    vpred = jax.jit(jax.vmap(make_predict_fn(model, pcfg, layout=layout)))
    vfold = jax.jit(jax.vmap(jax.random.fold_in, in_axes=(0, None)))

    params, opt_state, sched_state, losses, wall, timed_rounds = \
        _train_rounds(vround, vfold, params, opt_state, sched_state,
                      loop_keys, xtr, ytr, lay, pcfg.rounds)

    preds = np.asarray(vpred(params, xte, lay))      # [S, n, B_test]
    yte_np, ytr_np = np.asarray(yte), np.asarray(ytr)
    f1s, accs = _lane_metrics(preds, yte_np, ytr_np,
                              [(n_clients, s) for s in scfg.seeds])
    steps = timed_rounds * pcfg.epochs * make_perm_fn(pcfg,
                                                      n_train).n_batches
    cell = {
        "dataset": dataset, "mode": mode, "n_clients": n_clients,
        "seeds": list(scfg.seeds),
        "f1_per_seed": f1s, "acc_per_seed": accs,
        "f1_mean": float(np.mean(f1s)), "f1_std": float(np.std(f1s)),
        "acc_mean": float(np.mean(accs)),
        "final_loss_mean": float(np.asarray(losses)[:, -1].mean()),
        "wall_s": wall,
        "steps_per_sec": steps * n_seeds / max(wall, 1e-9),
    }
    if not none_only:
        cell["fault"] = plans[0].spec
        tel = impl.telemetry(sched_state)
        cell["fault_telemetry"] = {k: int(np.sum(v))
                                   for k, v in tel.items()}
    if not wire_none:
        cell["transform"] = wires[0].spec
        wtel = impl.wire_telemetry(sched_state)
        cell["wire"] = {k: int(np.sum(v)) for k, v in wtel.items()}
    if not obs_none:
        cell["obs"] = obss[0].spec
        # per-round series with a leading seed axis [S, R, ...]
        cell["obs_series"] = impl.obs_series(sched_state)
    return cell


# ---------------------------------------------------------------------------
# padded multi-count engine: one compile per (dataset, mode), lanes
# sharded over the device mesh
# ---------------------------------------------------------------------------
def _lane_shards(n_lanes: int, shard) -> int:
    """How many devices to split the lane axis over: the largest
    available device count dividing n_lanes (1 = no shard_map).
    shard=False forces single-device; an int requests that many."""
    if shard is False:
        return 1
    avail = jax.device_count()
    if isinstance(shard, int) and not isinstance(shard, bool):
        if n_lanes % shard or shard > avail:
            raise ValueError(f"cannot shard {n_lanes} lanes over "
                             f"{shard} of {avail} devices")
        return shard
    return max(d for d in range(1, avail + 1) if n_lanes % d == 0)


def _coerce_sweep_config(dataset, mode, scfg):
    """Let run_padded_cells take a spec grid in place of a SweepConfig:
    a sequence of ``repro.api.ExperimentSpec`` (one per client count,
    same dataset/mode) is translated via the api layer.  Returns the
    (dataset, internal_mode, SweepConfig) triple."""
    if isinstance(scfg, SweepConfig):
        return dataset, mode, scfg
    from repro.api.modes import get_mode        # lazy: api > core
    from repro.api.session import sweep_config_for_specs
    ds, internal, cfg = sweep_config_for_specs(scfg)
    if dataset is not None and dataset != ds:
        raise ValueError(f"dataset argument {dataset!r} does not match "
                         f"the specs' dataset {ds!r}")
    # resolve the caller's mode through the registry so aliases
    # (backward_exchange == verticomb) compare equal
    if mode is not None and get_mode(mode).internal != internal:
        raise ValueError(f"mode argument {mode!r} does not match the "
                         f"specs' mode {internal!r}")
    return ds, internal, cfg


class LaneBatch(NamedTuple):
    """One fully-assembled sweep lane batch: the vmappable round and
    every per-lane tensor it consumes.  ``build_lane_batch`` is the
    single assembly path shared by :func:`run_padded_cells` (which
    trains it) and the static auditor's retrace pass
    (``repro.analysis.retrace``, which re-traces sub-batches and
    compares jaxprs -- the static side of the compile-once claim)."""
    pcfg: ProtocolConfig
    model: object
    opt: object
    round_fn: object            # un-jitted, per-lane; vmap to train
    first: object               # shape-uniform first layer (or None)
    params: object
    opt_state: object
    sched_state: object
    loop_keys: object
    xtr: object
    ytr: object
    xte: object
    yte: object
    lay: object
    lanes: tuple                # [(n_clients, seed), ...] wire-major
    scheds: tuple               # then fault- then sched-major blocks
    sync_only: bool
    n_train: int
    n_base: int                 # lanes per (wire, fault, sched) block
    width: int
    plans: tuple = ()           # parsed FaultPlans (fault lane axis)
    none_only: bool = True      # fault axis is the default ("none",)
    impl: object = None         # the resolved lane impl (None = sync)
    wires: tuple = ()           # parsed WirePlans (transform lane axis)
    wire_none_only: bool = True  # wire axis is the default ("none",)
    obss: tuple = ()            # parsed ObsPlans (obs lane axis)
    obs_none_only: bool = True  # obs axis is the default ("none",)

    @property
    def n_lanes(self) -> int:
        return len(self.lanes)


def build_lane_batch(dataset, mode, scfg: SweepConfig,
                     max_clients=None, width=None) -> LaneBatch:
    """Assemble the transforms x faults x schedules x client_counts x
    seeds lane batch of one (dataset, mode) pair: stacked
    data/layouts/keys, per-count padded inits,
    wire-major-over-fault-major-over-schedule-major tiling, and the
    single un-jitted round function every lane shares.
    ``max_clients`` widens the padded client axis beyond
    max(client_counts) and ``width`` widens the gather-slice first
    layer -- the auditor pins both so sub-batches that must share a
    compile stay shape-identical."""
    counts = tuple(scfg.client_counts)
    max_c = max_clients or max(counts)
    if max_c < max(counts):
        raise ValueError(f"max_clients={max_c} < max client count "
                         f"{max(counts)}")
    # n_clients=min(counts) keeps ProtocolConfig's padded/unpadded
    # distinction truthful (lanes carry n_real in [min, max]), so
    # make_round_fn's mask-blind-aggregator guard stays armed whenever
    # any lane actually has dead slots
    pcfg = ProtocolConfig(
        dataset=dataset, n_clients=min(counts), max_clients=max_c,
        rounds=scfg.rounds, epochs=scfg.epochs,
        batch_size=scfg.batch_size, lr=scfg.lr,
        exchange_at=scfg.exchange_at, mode=mode, fedavg=scfg.fedavg,
        n_samples=scfg.n_samples, first_layer=scfg.first_layer)
    model = PaperMLP(get_config(arch_for(dataset)))
    opt = adam(pcfg.lr, max_grad_norm=None)

    xtr, ytr, xte, yte, lay, keys, base_lanes, data_width = \
        _stacked_lanes(dataset, counts, scfg.seeds, scfg.n_samples,
                       max_clients=max_c)
    width = max(width or 0, data_width)
    n_base, n_train = xtr.shape[0], xtr.shape[1]
    first = _sweep_first_layer(pcfg, width)
    scheds, impl, sync_only = _sweep_schedules(scfg, mode, model,
                                               max_c, n_train)
    plans, impl, none_only = _sweep_faults(scfg, mode, model, max_c,
                                           n_train, impl)
    wires, impl, wire_none = _sweep_transforms(scfg, mode, model,
                                               max_c, n_train, impl)
    obss, impl, obs_none = _sweep_obs(scfg, mode, model, max_c,
                                      n_train, impl)
    n_sched, n_fault = len(scheds), len(plans)
    n_wire, n_obs = len(wires), len(obss)

    # per-count init (live keys must be split(init_key, nc) -- a
    # count-static derivation -- so init compiles once per count;
    # only the ROUND is the compile-once claim)
    ps, os_, lks = [], [], []
    for ci, nc in enumerate(counts):
        def init_one(key, nc=nc):
            init_key, loop_key = train_keys(key)
            params = init_padded_params(model, init_key, nc, max_c)
            return params, jax.vmap(opt.init)(params), loop_key
        s = len(scfg.seeds)
        p, o, lk = jax.jit(jax.vmap(init_one))(keys[ci * s:(ci + 1) * s])
        ps.append(p), os_.append(o), lks.append(lk)
    params = jax.tree.map(lambda *a: jnp.concatenate(a), *ps)
    opt_state = jax.tree.map(lambda *a: jnp.concatenate(a), *os_)
    loop_keys = jnp.concatenate(lks)

    # obs-major-over-wire-major-over-fault-major-over-schedule-major
    # lane tiling: every (obs, wire, fault, schedule) tuple reuses the
    # SAME (count x seed) base batch -- same data, same layouts, same
    # inits, same key streams -- and differs only in the per-lane
    # carry state (traced k / p / rates / keep fractions / level
    # gates + buffers)
    n_tile = n_obs * n_wire * n_fault * n_sched
    if n_tile > 1:
        def tile(a):
            return jnp.concatenate([a] * n_tile, 0)
        xtr, ytr, xte, yte = map(tile, (xtr, ytr, xte, yte))
        lay = jax.tree.map(tile, lay)
        loop_keys = tile(loop_keys)
        params = jax.tree.map(tile, params)
        opt_state = jax.tree.map(tile, opt_state)
    sched_state = _stacked_obs_state(impl, obss, wires, plans, scheds,
                                     n_base, none_only, wire_none,
                                     obs_none)
    lanes = tuple((nc, s) for _ in obss for _ in wires for _ in plans
                  for _ in scheds for (nc, s) in base_lanes)

    round_fn = make_round_fn(model, opt, pcfg, n_train,
                             first_layer_fn=first, sched_impl=impl)
    return LaneBatch(pcfg=pcfg, model=model, opt=opt,
                     round_fn=round_fn, first=first, params=params,
                     opt_state=opt_state, sched_state=sched_state,
                     loop_keys=loop_keys, xtr=xtr, ytr=ytr, xte=xte,
                     yte=yte, lay=lay, lanes=lanes, scheds=scheds,
                     sync_only=sync_only, n_train=n_train,
                     n_base=n_base, width=width, plans=plans,
                     none_only=none_only, impl=impl, wires=wires,
                     wire_none_only=wire_none, obss=obss,
                     obs_none_only=obs_none)


def run_padded_cells(dataset, mode, scfg, shard="auto"):
    """Train the FULL schedules x client_counts x seeds lane batch of
    one (dataset, mode) pair under a single compiled round function,
    distributing lanes over the device mesh.  ``scfg`` is a
    SweepConfig, or a sequence of ``repro.api.ExperimentSpec`` sharing
    one (dataset, mode) whose n_clients / schedule values form the
    count and schedule axes.

    Returns {"cells": {key: cell_dict}, "round_traces": int,
    "lanes": int, "devices": int, "wall_s": float, "cells_per_sec":
    float, "steps_per_sec": float}.  For the default sync-only
    schedule axis the cell keys stay the historical bare ``n_clients``
    ints; a non-default schedule axis keys cells as
    ``"{schedule}/{n_clients}"`` (e.g. ``"stale_k:2/3"``); a
    non-default fault axis prepends the plan
    (``"{fault}/{schedule}/{n_clients}"``); a non-default transform
    axis prepends the wire spec on top
    (``"{transform}/{fault}/{schedule}/{n_clients}"``); a non-default
    obs axis prepends the level on top of everything
    (``"{obs}/{transform}/{fault}/{schedule}/{n_clients}"``).  Each
    cell_dict has the run_cell schema plus ``"schedule"`` (under a
    fault axis, ``"fault"`` + per-cell ``"fault_telemetry"`` event
    counts summed over seeds; under a transform axis, ``"transform"``
    + per-cell ``"wire"`` integer bytes-on-wire summed over seeds;
    under an obs axis, ``"obs"`` + per-cell ``"obs_series"``
    per-round series with a leading seed axis)
    -- except that wall_s is the SHARED batch wall and
    each cell's steps_per_sec is its lanes' share of it (cells sum to
    the batch's steps_per_sec).  round_traces counts actual retraces
    of the round body -- 1 means the whole multi-count (and
    multi-schedule / multi-fault: k, p and fault rates are traced
    per-lane state) batch ran on one compile (pinned in tests;
    ``repro.analysis``'s retrace pass proves the static side).
    shard: "auto" (largest dividing device count) | False | int.
    """
    dataset, mode, scfg = _coerce_sweep_config(dataset, mode, scfg)
    lb = build_lane_batch(dataset, mode, scfg)
    pcfg, scheds, counts = lb.pcfg, lb.scheds, tuple(scfg.client_counts)
    n_base, n_train, n_lanes = lb.n_base, lb.n_train, lb.n_lanes
    params, opt_state, sched_state = (lb.params, lb.opt_state,
                                      lb.sched_state)
    loop_keys, xtr, ytr, xte, yte, lay = (lb.loop_keys, lb.xtr, lb.ytr,
                                          lb.xte, lb.yte, lb.lay)
    round_fn, lanes, sync_only = lb.round_fn, lb.lanes, lb.sync_only
    plans, none_only = lb.plans, lb.none_only
    wires, wire_none = lb.wires, lb.wire_none_only
    obss, obs_none = lb.obss, lb.obs_none_only
    traces = 0

    def counted_round(*args):
        nonlocal traces
        traces += 1
        return round_fn(*args)

    vround = jax.vmap(counted_round)
    n_dev = _lane_shards(n_lanes, shard)
    if n_dev > 1:
        mesh = jax.make_mesh((n_dev,), ("data",))
        with sh.use_context(mesh):
            spec = sh.logical_spec("sweep_lane")    # -> P("data")
        vround = shard_map(vround, mesh=mesh, in_specs=(spec,) * 8,
                           out_specs=spec, check_vma=False)
    vround = jax.jit(vround, donate_argnums=(0, 1))
    vpred = jax.jit(jax.vmap(
        make_predict_fn(lb.model, pcfg, first_layer_fn=lb.first)))
    vfold = jax.jit(jax.vmap(jax.random.fold_in, in_axes=(0, None)))

    params, opt_state, sched_state, losses, wall, timed_rounds = \
        _train_rounds(vround, vfold, params, opt_state, sched_state,
                      loop_keys, xtr, ytr, lay, pcfg.rounds)

    preds = np.asarray(vpred(params, xte, lay))   # [L, max_c, B_test]
    yte_np, ytr_np = np.asarray(yte), np.asarray(ytr)
    f1s, accs = _lane_metrics(preds, yte_np, ytr_np, lanes)
    losses_np = np.asarray(losses)
    steps = timed_rounds * pcfg.epochs * make_perm_fn(pcfg,
                                                      n_train).n_batches
    cells = {}
    s = len(scfg.seeds)
    for oi, op in enumerate(obss):
        for wi, wp in enumerate(wires):
            for fi, pl in enumerate(plans):
                for si, sc in enumerate(scheds):
                    for ci, nc in enumerate(counts):
                        lo = (((oi * len(wires) + wi) * len(plans)
                               + fi) * len(scheds)
                              + si) * n_base + ci * s
                        sl = slice(lo, lo + s)
                        if not obs_none:
                            ck = (f"{op.spec}/{wp.spec}/{pl.spec}/"
                                  f"{sc.spec}/{nc}")
                        elif not wire_none:
                            ck = f"{wp.spec}/{pl.spec}/{sc.spec}/{nc}"
                        elif not none_only:
                            ck = f"{pl.spec}/{sc.spec}/{nc}"
                        elif not sync_only:
                            ck = f"{sc.spec}/{nc}"
                        else:
                            ck = nc
                        cell = {
                            "dataset": dataset, "mode": mode,
                            "n_clients": nc,
                            "schedule": sc.spec,
                            "seeds": list(scfg.seeds),
                            "f1_per_seed": f1s[sl],
                            "acc_per_seed": accs[sl],
                            "f1_mean": float(np.mean(f1s[sl])),
                            "f1_std": float(np.std(f1s[sl])),
                            "acc_mean": float(np.mean(accs[sl])),
                            "final_loss_mean":
                                float(losses_np[sl, -1].mean()),
                            # the whole multi-count batch trains
                            # together, so wall_s is SHARED across
                            # this group's cells and each cell's
                            # steps_per_sec is its own lanes' steps
                            # over that shared wall (cells sum to the
                            # batch throughput -- do not read a
                            # single padded cell's rate as a
                            # run_cell-style standalone measurement)
                            "wall_s": wall,
                            "steps_per_sec":
                                steps * s / max(wall, 1e-9),
                        }
                        if not none_only:
                            cell["fault"] = pl.spec
                            tel = lb.impl.telemetry(jax.tree.map(
                                lambda a: a[sl], sched_state))
                            cell["fault_telemetry"] = {
                                k: int(np.sum(v))
                                for k, v in tel.items()}
                        if not wire_none:
                            cell["transform"] = wp.spec
                            wtel = lb.impl.wire_telemetry(
                                jax.tree.map(lambda a: a[sl],
                                             sched_state))
                            cell["wire"] = {k: int(np.sum(v))
                                            for k, v in wtel.items()}
                        if not obs_none:
                            cell["obs"] = op.spec
                            # per-round series, leading seed axis
                            cell["obs_series"] = lb.impl.obs_series(
                                jax.tree.map(lambda a: a[sl],
                                             sched_state))
                        cells[ck] = cell
    out = {"cells": cells, "round_traces": traces, "lanes": n_lanes,
           "devices": n_dev, "wall_s": wall,
           "schedules": [sc.spec for sc in scheds],
           "cells_per_sec": len(cells) / max(wall, 1e-9),
           "steps_per_sec": steps * n_lanes / max(wall, 1e-9)}
    if not none_only:
        out["faults"] = [pl.spec for pl in plans]
    if not wire_none:
        out["transforms"] = [w.spec for w in wires]
    if not obs_none:
        out["obs"] = [o.spec for o in obss]
    return out


def run_grid(scfg: SweepConfig = SweepConfig(), shard=None):
    """Walk the full datasets x modes x client_counts grid -- one
    padded lane batch (ONE round compile, lanes sharded over devices)
    per (dataset, mode).  Returns {"cells": {key: cell}, "compare":
    {ds/n: {mode: f1_mean}}} where key = "dataset/mode/n_clients",
    exactly the pre-padding schema.

    ``scfg`` may also be a spec grid -- a sequence of
    ``repro.api.ExperimentSpec`` (e.g. from ``repro.api.spec_grid``)
    -- in which case the call is routed through ``repro.api.run_grid``
    (same schema, plus a per-cell ``spec_hash``).  ``shard`` defaults
    to the specs' shard policy on that route and to "auto" on the
    SweepConfig route; passing it explicitly overrides both."""
    if not isinstance(scfg, SweepConfig):
        from repro.api.session import run_grid as _api_run_grid
        return _api_run_grid(scfg, shard=shard)
    shard = "auto" if shard is None else shard
    cells, compare = {}, {}
    for ds, mode in itertools.product(scfg.datasets, scfg.modes):
        out = run_padded_cells(ds, mode, scfg, shard=shard)
        for nc, cell in out["cells"].items():
            cells[f"{ds}/{mode}/{nc}"] = cell
            compare.setdefault(f"{ds}/{nc}", {})[mode] = cell["f1_mean"]
    return {"cells": cells, "compare": compare}
