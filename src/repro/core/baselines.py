"""Centralized VFL baselines the paper compares against (Table II):

  * SplitNN-style split learning: each client owns a bottom network over
    ITS OWN features (no zero-padding); a designated server concatenates
    client embeddings and trains the top; gradients flow back through
    the cut layer (joint training).
  * PyVertical / Flower rows in Table II are the same split topology
    with the paper's participant counts; run_table2() in benchmarks
    re-creates each configuration.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import partition as PT
from repro.data import registry as DR
from repro.metrics import accuracy, f1_score
from repro.models import layers as L
from repro.optim import adam


@dataclass
class SplitNNConfig:
    dataset: str = "bank"
    n_clients: int = 2
    rounds: int = 20
    epochs: int = 20
    batch_size: int = 64
    lr: float = 1e-3
    hidden: int = 10
    seed: int = 0
    n_samples: Optional[int] = None


class SplitNN:
    def __init__(self, cfg: SplitNNConfig):
        self.cfg = cfg
        xtr, ytr, xte, yte = DR.make_dataset(cfg.dataset, cfg.n_samples,
                                             seed=cfg.seed)
        self.xtr, self.ytr, self.xte, self.yte = xtr, ytr, xte, yte
        self.n_features = xtr.shape[1]
        self.n_classes = DR.get_dataset(cfg.dataset).n_classes
        self.partition = PT.make_partition(cfg.dataset, self.n_features,
                                           cfg.n_clients, seed=cfg.seed)
        self.opt = adam(cfg.lr, max_grad_norm=None)
        self._step = jax.jit(self._make_step(), donate_argnums=(0, 1))
        self._jit_forward = jax.jit(self._forward)

    def init_params(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, cfg.n_clients + 2)
        params = {}
        for i, idx in enumerate(self.partition):
            params[f"bottom_{i}"] = L.dense_init(
                ks[i], len(idx), cfg.hidden, jnp.float32, bias=True,
                scale=(2.0 / max(len(idx), 1)) ** 0.5)
        cut = cfg.hidden * cfg.n_clients
        params["top_1"] = L.dense_init(ks[-2], cut, cfg.hidden,
                                       jnp.float32, bias=True)
        params["top_2"] = L.dense_init(ks[-1], cfg.hidden, self.n_classes,
                                       jnp.float32, bias=True)
        return params

    def _forward(self, params, x):
        hs = []
        for i, idx in enumerate(self.partition):
            xi = x[:, jnp.asarray(idx)]
            hs.append(jax.nn.relu(L.dense(params[f"bottom_{i}"], xi)))
        h = jnp.concatenate(hs, axis=-1)        # server-side concat
        h = jax.nn.relu(L.dense(params["top_1"], h))
        return L.dense(params["top_2"], h)

    def _make_step(self):
        def step(params, opt_state, xb, yb, i):
            def lossfn(p):
                logits = self._forward(p, xb)
                logp = jax.nn.log_softmax(logits, -1)
                return -jnp.take_along_axis(logp, yb[:, None], -1).mean()
            loss, grads = jax.value_and_grad(lossfn)(params)
            params, opt_state, _ = self.opt.update(grads, opt_state,
                                                   params, i)
            return params, opt_state, loss
        return step

    def predict(self, params, x):
        """[B] class predictions from the server-side forward."""
        logits = self._jit_forward(params, jnp.asarray(x))
        return np.asarray(jnp.argmax(logits, -1))

    def train(self, key=None, return_state=False):
        """Train; returns {"f1", "acc"}.  With return_state=True the
        tuple (metrics, params) instead -- repro.api's splitnn Session
        keeps the params for predict()."""
        cfg = self.cfg
        key = key if key is not None else jax.random.PRNGKey(cfg.seed)
        params = self.init_params(key)
        opt_state = self.opt.init(params)
        rng = np.random.default_rng(cfg.seed)
        n = len(self.xtr)
        bs = min(cfg.batch_size, n)
        nb = n // bs
        xtr, ytr = jnp.asarray(self.xtr), jnp.asarray(self.ytr)
        i = jnp.zeros((), jnp.int32)
        for r in range(cfg.rounds):
            for e in range(cfg.epochs):
                order = rng.permutation(n)[:nb * bs]
                for b in range(nb):
                    sl = order[b * bs:(b + 1) * bs]
                    params, opt_state, loss = self._step(
                        params, opt_state, xtr[sl], ytr[sl], i)
                    i = i + 1
        preds = self.predict(params, self.xte)
        avg = "macro" if self.n_classes > 2 else "binary"
        metrics = {"f1": f1_score(self.yte, preds, average=avg),
                   "acc": accuracy(self.yte, preds)}
        return (metrics, params) if return_state else metrics
