"""De-VertiFL training protocol (Algorithms 1 + 2), plus the
non-federated baseline and the VertiComb-style backward-exchange
baseline the paper compares against.

All n clients are simulated in one process by stacking per-client
parameters on a leading axis and vmapping; this is numerically
identical to n communicating peers (the exchange and FedAvg are the
only cross-client dataflows, and they are explicit).

Engine layout
-------------
The protocol is factored into pure functions so the whole federation
can be jitted, scanned, and vmapped:

  * make_first_layer_fn  the slice-aware first layer (see below)
  * make_step_fn      one optimizer step for all clients (mode-specific)
  * make_perm_fn      device-side epoch shuffles (jax.random.permutation)
  * make_round_fn     a full round -- epochs x batches as ONE lax.scan
                      with the round-end FedAvg folded in, so a round is
                      a single XLA executable with no host round-trips
  * make_predict_fn   per-client inference with the evaluation exchange

Slice-aware first layer
~~~~~~~~~~~~~~~~~~~~~~~
Every federation trains on the canonical column layout from
``repro.core.partition.canonicalize``: dataset columns are permuted
once at setup so client i owns the contiguous block-aligned feature
slice [offset_i, offset_i + F_i).  The step/round/predict functions
take a ``LayoutArrays(masks, offsets)`` argument (vmappable over a
seed axis, like masks were before), and ``ProtocolConfig.first_layer``
selects how layer 0 is computed:

  masked   the paper-literal reference: materialize the [n, B, F]
           zero-padded batch and run dense full-width matmuls.  Kept
           bit-for-bit as the reference path.
  slice    x[:, off:off+F_i] @ W[off:off+F_i] per client via XLA
           dynamic_slice -- no padding is materialized and the MXU/ALU
           work drops by ~(n-1)/n on layer 0.  Gradients scatter back
           into the client's W-row block; rows outside the slice get
           the same exact-zero gradient the masked path produces.
  pallas   the block-sparse ``vfl_matmul`` Pallas kernel (with its
           custom VJP) walking only the client's weight-row blocks --
           the TPU path; on CPU it runs in interpret mode.
  auto     pallas on TPU, slice elsewhere (the default).

masked and slice/pallas differ only in float reduction order, so
loss/F1 trajectories agree to allclose rather than bitwise
(tests/test_slice_engine.py pins this).

Padded client axes
~~~~~~~~~~~~~~~~~~
``ProtocolConfig.max_clients`` pads the client axis with dead slots
(``Layout.pad``): params/opt state/activations ride arrays of length
max_clients while only the first n_clients slots are live.  Every
cross-client dataflow honors ``LayoutArrays.client_mask`` -- the
exchange sums ``h * client_mask``, FedAvg weights by it, and loss
means divide by the LIVE count via a reciprocal multiply -- so dead
slots contribute exact-zero terms and the live clients' trajectories
are bit-for-bit the unpadded run's in all three first-layer lanes
(tests/test_padded_engine.py).  This is what lets repro.core.sweep
stack different client counts on one vmapped lane axis and compile a
dataset x mode grid once.

Exchange schedules
~~~~~~~~~~~~~~~~~~
``ProtocolConfig.schedule`` selects WHICH exchange tensor each client
consumes at each scanned step (the ``repro.schedule`` subsystem):
"sync" (default) keeps the paper-literal code path below untouched;
"stale_k:k", "double_buffer", and "partial:p" thread a schedule-state
slot through the scan carry (ring buffers of stale hidden stacks, the
two-slot round pipeline, per-round participation masks composed with
``client_mask``).  Non-sync schedules are devertifl-mode only; the
scan and python engines drive the same schedule hooks and stay
bit-for-bit.  See docs/ARCHITECTURE.md section 7.

``DeVertiFL.train`` drives make_round_fn under jit (engine="scan", the
default). A per-batch host-dispatched loop is retained as
engine="python" (same jitted step, host-side batch dispatch). Both
engines consume the identical device-generated permutation stream, so
their loss/F1 trajectories match bit-for-bit at a fixed seed
(tests/test_engine.py asserts this). repro.core.sweep vmaps
make_round_fn over a (seed x client-count x schedule) lane axis for
grid experiments and shards the lanes over the device mesh.

See docs/ARCHITECTURE.md for the scan-round key-derivation and
PermPlan contracts.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.barrier import tag
from repro.configs import get_config
from repro.core import partition as PT
from repro.core.exchange import fedavg, hidden_output_exchange
from repro.data import registry as DR
from repro.kernels.vfl_matmul import vfl_matmul
from repro.metrics import accuracy, f1_score
from repro.models.mlp_model import PaperMLP
from repro.optim import adam
from repro.registry import Registry


@dataclass
class ProtocolConfig:
    dataset: str = "mnist"              # mnist | fmnist | titanic | bank
    n_clients: int = 3
    rounds: int = 5
    epochs: int = 5
    batch_size: int = 64
    lr: float = 1e-3
    # Where HiddenOutputExchange happens. Algorithm 1 exchanges the model
    # output (y-hat); the text/Fig. 1 describe hidden-layer sharing. -1
    # means "logits" (Algorithm-1-faithful); k>=1 means after hidden
    # layer k (text-faithful). Both are supported; -1 is the default and
    # matches the pseudo-code.
    exchange_at: int = -1
    mode: str = "devertifl"             # devertifl | non_federated | verticomb
    fedavg: bool = True
    seed: int = 0
    n_samples: Optional[int] = None     # dataset size override (speed)
    engine: str = "scan"                # scan | python (reference loop)
    first_layer: str = "auto"           # auto | pallas | slice | masked
    # Exchange schedule (repro.schedule spec string): which exchange
    # tensor each client consumes at each step.  "sync" is the
    # paper-literal engine path, untouched; "stale_k:2",
    # "double_buffer", "partial:0.8", "stale_k:4+partial:0.5" run the
    # schedule-aware round (devertifl mode only).
    schedule: str = "sync"
    # Fault plan (repro.faults spec string): deterministic adversity
    # injected into the exchange.  "none" is the untouched engine
    # path; "crash:0.2", "straggle:0.5:2", "corrupt:0.05:scale",
    # "crash:0.2+corrupt:0.05" wrap the schedule impl in the
    # fault-aware state machine (devertifl mode only).
    fault: str = "none"
    # Exchange transform (repro.wire spec string): what the exchanged
    # hidden stacks look like on the wire.  "none" is the untouched
    # engine path; "int8", "topk:0.25", "dp:0.1",
    # "topk:0.5+int8+dp:0.1" wrap the engine impl in the wire
    # encode-decode round trip (devertifl mode only).
    transform: str = "none"
    # Observability level (repro.obs spec string): what the engine
    # records about itself.  "none" is the untouched engine path;
    # "basic"/"full" wrap the engine impl in in-scan metric taps
    # (devertifl mode only).  Observation-only: taps never change a
    # trajectory.
    obs: str = "none"
    # Pad the client axis to this length with dead (masked) slots; None
    # means no padding. Live trajectories are bit-for-bit unchanged --
    # padding only buys shape-uniformity across client counts.
    max_clients: Optional[int] = None
    # Explicit unequal per-client feature counts (must sum to the
    # dataset's feature count); None keeps the registry partition
    # strategy.  Skewed splits ride every first-layer lane unchanged
    # (repro.core.partition.skewed_partition).
    partition_sizes: Optional[Tuple[int, ...]] = None

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)

    @property
    def padded_clients(self) -> int:
        """Static client-axis length (max_clients or n_clients)."""
        return self.max_clients or self.n_clients


# legacy name->arch map, kept importable; the engine resolves arch via
# the dataset registry so registered custom datasets work everywhere
ARCH_FOR = {"mnist": "paper-mlp-mnist", "fmnist": "paper-mlp-fmnist",
            "titanic": "paper-mlp-titanic", "bank": "paper-mlp-bank"}


def arch_for(dataset: str) -> str:
    """Model-config name for a dataset, via the dataset registry."""
    return DR.get_dataset(dataset).arch


# First-layer backend registry: the three built-in lanes plus "auto"
# hold None (they are implemented inline below); a registered custom
# backend holds a factory ``make(model, pcfg, layout) -> first_fn``
# where ``first_fn(params, xb, lay) -> [n_clients, B, H]`` post-ReLU
# layer-0 activations (the make_first_layer_fn contract).
FIRST_LAYERS = Registry("first_layer")
for _name in ("auto", "masked", "slice", "pallas"):
    FIRST_LAYERS.register(_name, None)


def register_first_layer(name, make):
    """Register a custom first-layer backend for ProtocolConfig /
    ExperimentSpec ``first_layer=name``.  Not supported under the
    padded multi-count sweep vmap (same constraint as pallas)."""
    return FIRST_LAYERS.register(name, make)


def auto_first_layer() -> str:
    """What first_layer="auto" means on this backend.  THE single
    definition of the auto rule -- repro.api.ExperimentSpec
    canonicalizes "auto" through it at construction so a spec (and its
    spec_hash) records the lane that actually runs."""
    return "pallas" if jax.default_backend() == "tpu" else "slice"


def resolve_first_layer(pcfg) -> str:
    """Map the first_layer knob to a concrete path for this backend."""
    fl = pcfg.first_layer
    maker = FIRST_LAYERS.get(fl)    # unknown names raise with options
    if fl == "auto":
        fl = auto_first_layer()
    if pcfg.exchange_at == 0 and fl != "masked":
        # exchanging the raw zero-padded input predates layer 0; only
        # the masked formulation expresses it
        if maker is not None:
            raise ValueError(
                f"first_layer {fl!r} cannot express exchange_at=0 "
                "(the exchange predates layer 0); use "
                "first_layer='masked'")
        fl = "masked"
    return fl


def exchange_width(model, exchange_at) -> int:
    """Trailing width of the exchanged tensor -- what a schedule
    buffer must hold per client per batch row: logits (exchange_at ==
    -1), the raw input (0), or the hidden width (after layer k)."""
    if exchange_at == -1:
        return model.n_classes
    if exchange_at == 0:
        return model.in_features
    return model.hidden


def resolve_schedule(pcfg, model, n_train):
    """pcfg.schedule -> (Schedule, impl).  ``impl`` is None for the
    literal "sync" spec: the legacy engine path runs untouched, which
    is what keeps the paper-literal schedule bit-for-bit pinned.
    Non-sync schedules (including the degenerate stale_k:0 /
    partial:1.0, which run the schedule engine and reduce bitwise) are
    devertifl-mode only: the forward HiddenOutputExchange is what is
    being scheduled, and the backward-exchange/non-federated baselines
    have no data-only peer term for a buffer to replace."""
    from repro.schedule import get_schedule, make_schedule_impl
    sched = get_schedule(pcfg.schedule)
    if sched.is_sync:
        return sched, None
    if pcfg.mode != "devertifl":
        raise ValueError(
            f"schedule {sched.spec!r} requires mode='devertifl'; mode "
            f"{pcfg.mode!r} supports schedule='sync' only")
    impl = make_schedule_impl(
        sched, pcfg.padded_clients, min(pcfg.batch_size, n_train),
        exchange_width(model, pcfg.exchange_at))
    return sched, impl


def resolve_engine(pcfg, model, n_train):
    """pcfg.schedule + pcfg.fault + pcfg.transform + pcfg.obs ->
    (Schedule, impl).  With ``fault="none"``, ``transform="none"``
    and ``obs="none"`` this IS :func:`resolve_schedule` -- same
    objects, same (possibly None) impl, so the adversity-free engine
    stays bit-for-bit the pre-fault, pre-wire, pre-obs one and
    literal sync keeps its legacy path.  Non-none plans (devertifl
    only) wrap the schedule impl in the fault state machine, then the
    wire transform, then the metric taps (the chain is schedule ->
    fault -> wire -> obs: wire outermost of the machinery so it
    transforms what the inner layers buffer/screen, obs outermost of
    all so it observes exactly what is released); literal sync is
    first promoted to a depth-0 ring impl (``stale_k:0``, proven
    bitwise-sync by tests/test_schedule.py) so the wrappers have hooks
    to ride."""
    sched, impl = resolve_schedule(pcfg, model, n_train)
    bs = min(pcfg.batch_size, n_train)
    width = exchange_width(model, pcfg.exchange_at)

    def promoted(impl):
        if impl is None:
            from repro.schedule import LaneScheduleImpl
            impl = LaneScheduleImpl(0, pcfg.padded_clients, bs, width)
        return impl

    fault = getattr(pcfg, "fault", "none")
    from repro.faults import get_fault_plan, make_fault_impl
    plan = get_fault_plan(fault)
    if not plan.is_none:
        if pcfg.mode != "devertifl":
            raise ValueError(
                f"fault plan {plan.spec!r} requires mode='devertifl'; "
                f"mode {pcfg.mode!r} supports fault='none' only")
        impl = make_fault_impl(plan, promoted(impl),
                               pcfg.padded_clients, bs, width)
    transform = getattr(pcfg, "transform", "none")
    from repro.wire import get_wire_plan, make_wire_impl
    wire = get_wire_plan(transform)
    if not wire.is_none:
        if pcfg.mode != "devertifl":
            raise ValueError(
                f"transform {wire.spec!r} requires mode='devertifl'; "
                f"mode {pcfg.mode!r} supports transform='none' only")
        impl = make_wire_impl(wire, promoted(impl),
                              pcfg.padded_clients, bs, width)
    obs = getattr(pcfg, "obs", "none")
    from repro.obs import get_obs_plan, make_obs_impl
    op = get_obs_plan(obs)
    if not op.is_none:
        if pcfg.mode != "devertifl":
            raise ValueError(
                f"obs level {op.spec!r} requires mode='devertifl'; "
                f"mode {pcfg.mode!r} supports obs='none' only")
        impl = make_obs_impl(op, promoted(impl), pcfg.padded_clients,
                             bs, width, rounds=pcfg.rounds)
    return sched, impl


# ---------------------------------------------------------------------------
# pure protocol pieces (shared by DeVertiFL and repro.core.sweep)
# ---------------------------------------------------------------------------
def client_hidden(model, exchange_at, p, xm):
    """Forward up to the exchange point (hidden layer k, or logits)."""
    if exchange_at == -1:
        return model.head(p, model.forward_hidden(p, xm))
    return model.forward_hidden(p, xm, upto=exchange_at)


def client_hidden_from(model, exchange_at, p, h1):
    """client_hidden, but starting from the post-ReLU layer-0 output
    (the slice-aware first-layer paths compute layer 0 themselves)."""
    if exchange_at == -1:
        return model.head(p, model.forward_from(p, h1, start=1))
    return model.forward_from(p, h1, start=1, upto=exchange_at)


def rest(model, exchange_at, p, h):
    """Forward from the exchange point to logits."""
    if exchange_at == -1:
        return h
    for i in range(exchange_at, model.n_hidden):
        h = jax.nn.relu(jnp.matmul(h, p[f"layer_{i}"]["kernel"])
                        + p[f"layer_{i}"]["bias"])
    return model.head(p, h)


def _ce(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()


def _masked_mean(values, client_mask):
    """Mean over live clients: sum(v * mask) * (1/n_live).  The
    reciprocal MULTIPLY (not a divide) matters: XLA lowers ``mean`` to
    sum * (1/n), so this is bit-for-bit ``values[:n_live].mean()`` when
    the dead tail is masked to exact zeros -- a traced divide would
    differ in the last ulp."""
    term = tag(values * client_mask, "term", "loss", client_axis=0)
    return term.sum() * (1.0 / client_mask.sum())


def _masked_hidden_sum(h_all, client_mask):
    """[n, B, H] -> [B, H] exchange sum excluding dead clients (their
    terms are exact +0.0, preserving the unpadded reduction bits)."""
    hm = tag(h_all * client_mask[:, None, None], "term", "exchange",
             client_axis=0)
    return tag(hm.sum(0), "declass", "exchange")


def make_first_layer_fn(model, pcfg, layout, interpret=None):
    """first(params, xb, lay) -> [n_clients, B, H] post-ReLU layer-0
    activations.  xb is the canonical-order [B, F] batch; lay is the
    LayoutArrays view (lay.offsets is traced -- sweeps vmap it); the
    static slice sizes (and, for pallas, static offsets and block size)
    come from ``layout``.

    CAVEAT (pallas): the Pallas BlockSpec index_map needs *static*
    offsets, so first_pallas closes over ``layout.offsets`` and
    ignores the runtime ``lay.offsets``.  Callers must pass
    LayoutArrays derived from the same canonical Layout (canonical
    offsets are deterministic per (dataset, n_clients), and
    sweep._stacked_federations raises if lanes ever disagreed); a
    scalar-prefetch offset is the ROADMAP item that would lift this."""
    fl = resolve_first_layer(pcfg)
    # the masked reference keeps its whole-forward formulation inline in
    # make_step_fn / make_predict_fn; only the slice-aware paths split
    # the first layer out
    assert fl != "masked", fl
    assert layout is not None, f"first_layer={fl!r} needs a Layout"
    maker = FIRST_LAYERS.get(fl)
    if maker is not None:           # registered custom backend
        return maker(model, pcfg, layout)
    sizes = layout.sizes

    # Dead (padded) clients own an empty feature slice: their layer-0
    # matmul is the empty contraction [B,0]@[0,H] == 0, so h1 is
    # relu(bias) -- computed directly, no degenerate slice/kernel call.
    # The value never matters (client_mask zeroes dead contributions
    # downstream) but keeping the bias term preserves the historical
    # dynamic_slice semantics for zero-feature clients.
    def dead_h1(xb, b_i, h):
        return jax.nn.relu(jnp.broadcast_to(b_i, (xb.shape[0], h)))

    if fl == "slice":
        def first_slice(params, xb, lay):
            w = params["layer_0"]["kernel"]     # [n, F, H]
            b = params["layer_0"]["bias"]       # [n, H]
            outs = []
            for i, f_i in enumerate(sizes):
                if f_i == 0:
                    outs.append(dead_h1(xb, b[i], w.shape[-1]))
                    continue
                x_i = jax.lax.dynamic_slice(
                    xb, (0, lay.offsets[i]), (xb.shape[0], f_i))
                w_i = jax.lax.dynamic_slice(
                    w[i], (lay.offsets[i], 0), (f_i, w.shape[-1]))
                outs.append(jax.nn.relu(x_i @ w_i + b[i]))
            return jnp.stack(outs)
        return first_slice

    # pallas: BlockSpec index_maps need static offsets; the canonical
    # layout's offsets are deterministic per (dataset, n_clients), so
    # closing over them is safe even in seed-vmapped sweeps.
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    offsets, bk = layout.offsets, layout.block

    def first_pallas(params, xb, lay):
        w = params["layer_0"]["kernel"]
        b = params["layer_0"]["bias"]
        outs = []
        for i, (off, f_i) in enumerate(zip(offsets, sizes)):
            if f_i == 0:
                # dead (and degenerate zero-feature) clients never
                # reach the kernel -- so every kernel call here has
                # client_mask[i] == 1 and needs no gate=; the kernel's
                # gate stays for lanes whose liveness is only known at
                # runtime (e.g. a future scalar-prefetch sweep path)
                outs.append(dead_h1(xb, b[i], w.shape[-1]))
                continue
            x_i = jax.lax.slice_in_dim(xb, off, off + f_i, axis=1)
            y = vfl_matmul(x_i, w[i], off, bk=bk, interpret=interpret)
            outs.append(jax.nn.relu(y + b[i]))
        return jnp.stack(outs)
    return first_pallas


def make_step_fn(model, opt, pcfg, layout=None, first_layer_fn=None):
    """One all-clients optimizer step for pcfg.mode.

    Signature: step(params, opt_state, lay, xb, yb, step_idx)
      -> (params, opt_state, mean_loss).  lay is a LayoutArrays
    argument (not a closure) so sweeps can vmap it over per-seed (and
    per-client-count) partitions; xb is in canonical column order.

    Every cross-client reduction honors lay.client_mask: the exchange
    sums only live clients' hiddens (dead terms are exact zeros) and
    the reported loss is the mean over live clients.  With an all-ones
    mask (unpadded layouts) these are bit-for-bit the unmasked ops.

    first_layer_fn overrides the slice/pallas first layer (the padded
    sweep passes a shape-uniform gather-slice variant that reads sizes
    and offsets from lay instead of closing over layout statics).
    """
    fl = resolve_first_layer(pcfg)
    hidden = partial(client_hidden, model, pcfg.exchange_at)
    through = partial(rest, model, pcfg.exchange_at)

    def update(params, opt_state, grads, step_idx):
        params, opt_state, _ = jax.vmap(
            lambda g, s, p: opt.update(g, s, p, step_idx))(
                grads, opt_state, params)
        return params, opt_state

    if fl == "masked":
        # the paper-literal reference: whole-forward from the
        # materialized [n, B, F] zero-padded batch, per-client
        # value_and_grad -- kept exactly as the pre-slice engine
        def devertifl_step(params, opt_state, lay, xb, yb, step_idx):
            xm = xb[None] * lay.masks[:, None, :]   # [n, B, F] zeropad
            h_all = jax.vmap(hidden)(params, xm)
            h_sum = jax.lax.stop_gradient(
                _masked_hidden_sum(h_all, lay.client_mask))  # peers=data

            def client_loss(p, x_i):
                h_i = hidden(p, x_i)
                # value == full exchanged sum; grad flows only through h_i
                h = h_i + h_sum - jax.lax.stop_gradient(h_i)
                return _ce(through(p, h), yb)

            losses, grads = jax.vmap(jax.value_and_grad(client_loss))(
                params, xm)
            params, opt_state = update(params, opt_state, grads, step_idx)
            return params, opt_state, _masked_mean(losses, lay.client_mask)

        def nonfed_step(params, opt_state, lay, xb, yb, step_idx):
            xm = xb[None] * lay.masks[:, None, :]

            def client_loss(p, x_i):
                h_i = hidden(p, x_i)
                return _ce(through(p, h_i), yb)

            losses, grads = jax.vmap(jax.value_and_grad(client_loss))(
                params, xm)
            params, opt_state = update(params, opt_state, grads, step_idx)
            return params, opt_state, _masked_mean(losses, lay.client_mask)

        def verticomb_step(params, opt_state, lay, xb, yb, step_idx):
            xm = xb[None] * lay.masks[:, None, :]

            def total_loss(ps):
                h_all = jax.vmap(hidden)(ps, xm)
                # grads flow to all LIVE contributors; a dead client's
                # hidden is multiplied by 0, so its params get exact
                # zero grads from peers' losses
                h_sum = _masked_hidden_sum(h_all, lay.client_mask)
                logits = jax.vmap(lambda p: through(p, h_sum))(ps)
                losses = jax.vmap(_ce, in_axes=(0, None))(logits, yb)
                return _masked_mean(losses, lay.client_mask)

            loss, grads = jax.value_and_grad(total_loss)(params)
            params, opt_state = update(params, opt_state, grads, step_idx)
            return params, opt_state, loss

    else:
        # slice/pallas: layer 0 reads only the client's feature slice;
        # per-client grads come from grad(masked sum of per-client
        # losses) -- loss_i depends on params[i] alone (peer terms are
        # stop-gradient'ed), so the stacked gradient IS the per-client
        # gradient stack, and masking drops dead clients' grads
        first = first_layer_fn or make_first_layer_fn(model, pcfg, layout)
        hidden_from = partial(client_hidden_from, model, pcfg.exchange_at)

        def losses_fn(ps, lay, xb, yb, differentiable=None):
            h1 = first(ps, xb, lay)
            h_all = jax.vmap(hidden_from)(ps, h1)
            if differentiable is not None:
                h_all = hidden_output_exchange(
                    h_all, differentiable=differentiable,
                    client_mask=lay.client_mask)
            logits = jax.vmap(through)(ps, h_all)
            return jax.vmap(_ce, in_axes=(0, None))(logits, yb)   # [n]

        def devertifl_step(params, opt_state, lay, xb, yb, step_idx):
            def total(ps):
                losses = losses_fn(ps, lay, xb, yb, differentiable=False)
                return (losses * lay.client_mask).sum(), losses

            grads, losses = jax.grad(total, has_aux=True)(params)
            params, opt_state = update(params, opt_state, grads, step_idx)
            return params, opt_state, _masked_mean(losses, lay.client_mask)

        def nonfed_step(params, opt_state, lay, xb, yb, step_idx):
            def total(ps):
                losses = losses_fn(ps, lay, xb, yb)
                return (losses * lay.client_mask).sum(), losses

            grads, losses = jax.grad(total, has_aux=True)(params)
            params, opt_state = update(params, opt_state, grads, step_idx)
            return params, opt_state, _masked_mean(losses, lay.client_mask)

        def verticomb_step(params, opt_state, lay, xb, yb, step_idx):
            def total(ps):
                losses = losses_fn(ps, lay, xb, yb, differentiable=True)
                return _masked_mean(losses, lay.client_mask)

            loss, grads = jax.value_and_grad(total)(params)
            params, opt_state = update(params, opt_state, grads, step_idx)
            return params, opt_state, loss

    return {"devertifl": devertifl_step, "non_federated": nonfed_step,
            "verticomb": verticomb_step}[pcfg.mode]


class PermPlan(NamedTuple):
    """Epoch-shuffle plan from make_perm_fn.  n_dropped documents the
    silent tail drop: each epoch uses n_batches * batch_size samples,
    so the trailing ``n_train % batch_size`` samples of every epoch's
    permutation are discarded (a fresh permutation each epoch means a
    *different* random subset is dropped every epoch, so no sample is
    systematically excluded)."""
    perms: object          # perms(round_key) -> [epochs*n_batches, bs]
    n_batches: int
    batch_size: int
    n_dropped: int         # per-epoch discarded tail = n_train % bs


def make_perm_fn(pcfg, n_train) -> PermPlan:
    """Device-side epoch shuffles: perms(round_key) -> [epochs * n_batches,
    batch_size] int32 batch indices, one independent permutation per
    epoch.

    NOTE the tail-drop semantics: n_batches = n_train // batch_size, so
    the last ``n_train % batch_size`` indices of each epoch permutation
    are dropped (PermPlan.n_dropped).  This matches the common
    drop-last DataLoader behavior and keeps every scanned batch the
    same static shape."""
    bs = min(pcfg.batch_size, n_train)
    n_batches = n_train // bs

    def perms(key):
        keys = jax.random.split(key, pcfg.epochs)
        order = jax.vmap(
            lambda k: jax.random.permutation(k, n_train))(keys)
        return order[:, :n_batches * bs].reshape(
            pcfg.epochs * n_batches, bs)

    return PermPlan(perms, n_batches, bs, n_train - n_batches * bs)


def accepts_client_mask(fn) -> bool:
    """Whether an aggregation fn's signature takes client_mask=."""
    import inspect
    try:
        return "client_mask" in inspect.signature(fn).parameters
    except (TypeError, ValueError):
        return False


def call_fedavg(fedavg_fn, params, client_mask):
    """Invoke an aggregation fn, passing client_mask only if its
    signature accepts it -- custom aggregators from set_fedavg (e.g.
    the weighted-FedAvg ablation's ``lambda p: ...``) keep working
    unchanged, while the default exchange.fedavg weights by the mask
    so dead padding slots never dilute the average.  On PADDED client
    axes a mask-blind custom aggregator is rejected at build time by
    make_round_fn, never silently mis-averaged."""
    if accepts_client_mask(fedavg_fn):
        return fedavg_fn(params, client_mask=client_mask)
    return fedavg_fn(params)


def make_round_fn(model, opt, pcfg, n_train, fedavg_fn=None, layout=None,
                  first_layer_fn=None, sched_impl=None):
    """One De-VertiFL round as a single jittable function: generate the
    epoch permutations on device, lax.scan the step over every batch of
    every epoch (step_idx carried in the scan), then apply the P2P
    FedAvg (Algorithm 1 lines 16-19) to the carry-out parameters.

    Signature: round_fn(params, opt_state, step_idx, sched_state, key,
    xtr, ytr, lay) -> (params, opt_state, step_idx, sched_state,
    losses[epochs*n_batches]).  sched_state is the exchange-schedule
    carry slot (repro.schedule; ``{}`` for sync -- the sync body is
    the untouched legacy path and merely threads it through).  Data
    (canonical column order) and the LayoutArrays are arguments so a
    sweep can vmap the whole round over a leading lane axis (seeds,
    seeds x client counts on padded layouts, and now schedules).
    fedavg_fn overrides the uniform-mean aggregation (e.g. the
    weighted-FedAvg ablation); it is baked into the jitted round, so
    pass it here rather than patching afterwards.  first_layer_fn is
    forwarded to make_step_fn (padded-sweep override).  sched_impl
    overrides the schedule impl (sweeps pass a lane impl whose ring is
    sized across lanes); by default it resolves from pcfg.schedule.
    """
    plan = make_perm_fn(pcfg, n_train)
    perm_fn = plan.perms
    do_fedavg = pcfg.fedavg and pcfg.mode != "non_federated"
    fedavg_fn = fedavg_fn or fedavg
    padded = (pcfg.max_clients or 0) > pcfg.n_clients or (
        layout is not None and layout.n_real < layout.n_clients)
    if do_fedavg and padded and not accepts_client_mask(fedavg_fn):
        raise ValueError(
            "custom fedavg_fn must accept a client_mask= keyword when "
            "the client axis is padded (max_clients > n_clients): a "
            "mask-blind aggregator would average dead slots' params "
            "into every live client")
    impl = sched_impl
    if impl is None:
        _, impl = resolve_engine(pcfg, model, n_train)

    if impl is None:        # sync: the legacy round, bit-for-bit
        step = make_step_fn(model, opt, pcfg, layout=layout,
                            first_layer_fn=first_layer_fn)

        def round_fn(params, opt_state, step_idx, sched_state, key,
                     xtr, ytr, lay):
            idx = perm_fn(key)

            def body(carry, batch_idx):
                params, opt_state, step_idx = carry
                xb = jnp.take(xtr, batch_idx, axis=0)
                yb = jnp.take(ytr, batch_idx, axis=0)
                params, opt_state, loss = step(params, opt_state, lay,
                                               xb, yb, step_idx)
                return (params, opt_state, step_idx + 1), loss

            (params, opt_state, step_idx), losses = jax.lax.scan(
                body, (params, opt_state, step_idx), idx)
            if do_fedavg:
                params = call_fedavg(fedavg_fn, params, lay.client_mask)
            return params, opt_state, step_idx, sched_state, losses

        return round_fn

    # schedule-aware round: round_start draws the round's effective
    # participation mask, the scan threads the schedule state through
    # every step, FedAvg weights by the round's mask, round_end runs
    # the round-granularity hooks (double_buffer's swap)
    from repro.schedule import make_sched_step_fn
    if do_fedavg and not accepts_client_mask(fedavg_fn):
        raise ValueError(
            "custom fedavg_fn must accept a client_mask= keyword "
            "under a non-sync exchange schedule: the per-round "
            "participation mask weights the aggregation")
    step = make_sched_step_fn(model, opt, pcfg, impl, layout=layout,
                              first_layer_fn=first_layer_fn)
    steps_per_round = pcfg.epochs * plan.n_batches

    def round_fn(params, opt_state, step_idx, sched_state, key,
                 xtr, ytr, lay):
        idx = perm_fn(key)
        round_idx = step_idx // steps_per_round
        sched_state, eff_mask = impl.round_start(sched_state, lay, key,
                                                 round_idx)

        def body(carry, batch_idx):
            params, opt_state, step_idx, sched_state = carry
            xb = jnp.take(xtr, batch_idx, axis=0)
            yb = jnp.take(ytr, batch_idx, axis=0)
            params, opt_state, sched_state, loss = step(
                params, opt_state, lay, eff_mask, sched_state, xb, yb,
                step_idx)
            return (params, opt_state, step_idx + 1, sched_state), loss

        (params, opt_state, step_idx, sched_state), losses = \
            jax.lax.scan(body, (params, opt_state, step_idx,
                                sched_state), idx)
        if do_fedavg:
            # optional fault-layer hook: quarantined clients drop out
            # of the round's aggregation like dead padded slots
            fam = getattr(impl, "fedavg_mask", None)
            mask = eff_mask if fam is None else fam(sched_state,
                                                    eff_mask)
            params = call_fedavg(fedavg_fn, params, mask)
        sched_state = impl.round_end(sched_state)
        return params, opt_state, step_idx, sched_state, losses

    return round_fn


def make_h_all_fn(model, pcfg, layout=None, first_layer_fn=None):
    """h_all(params, x, lay) -> [n_clients, B, W] per-client
    activations at the exchange point (logits for exchange_at == -1,
    hidden-layer-k outputs otherwise) from a canonical-order [B, F]
    batch.  This is the per-row half of the inference path: every
    output row depends only on its own input row, which is what lets
    the serving slot pool (repro.serving.federated) batch rows from
    different requests and stay bitwise equal to predict()."""
    fl = resolve_first_layer(pcfg)

    if fl == "masked":
        hidden = partial(client_hidden, model, pcfg.exchange_at)

        def h_all_fn(params, x, lay):
            xm = x[None] * lay.masks[:, None, :]
            return jax.vmap(hidden)(params, xm)
    else:
        first = first_layer_fn or make_first_layer_fn(model, pcfg, layout)
        hidden_from = partial(client_hidden_from, model, pcfg.exchange_at)

        def h_all_fn(params, x, lay):
            return jax.vmap(hidden_from)(params, first(params, x, lay))

    return h_all_fn


def make_predict_fn(model, pcfg, layout=None, first_layer_fn=None):
    """predict(params, x, lay) -> [n_clients, B] class predictions.
    x is in canonical column order (Layout.apply).  Dead padded
    clients' rows are garbage -- callers average metrics over the live
    prefix only."""
    through = partial(rest, model, pcfg.exchange_at)
    h_all_fn = make_h_all_fn(model, pcfg, layout=layout,
                             first_layer_fn=first_layer_fn)

    def predict(params, x, lay):
        h_all = h_all_fn(params, x, lay)
        if pcfg.mode in ("devertifl", "verticomb"):
            h_all = hidden_output_exchange(h_all, differentiable=False,
                                           client_mask=lay.client_mask)
        logits = jax.vmap(through)(params, h_all)   # [n, B, C]
        return jnp.argmax(logits, axis=-1)          # per-client preds

    return predict


def train_keys(key):
    """Split a federation key into (init_key, loop_key); round r uses
    fold_in(loop_key, r). Shared by DeVertiFL.train and sweep so a
    sweep lane reproduces the standalone run bit-for-bit."""
    init_key, loop_key = jax.random.split(key)
    return init_key, loop_key


def init_padded_params(model, init_key, n_clients, padded_clients=None):
    """Per-client param stack with a padded client axis.  The LIVE
    clients' keys are ``split(init_key, n_clients)`` -- exactly the
    unpadded derivation, because ``split(key, n)[:k] != split(key, k)``
    and bit-for-bit padding equivalence requires the live inits to
    match.  Dead slots draw from an independent folded key; their
    values never reach a live client (masked out of the exchange and
    FedAvg before the first aggregation)."""
    padded_clients = padded_clients or n_clients
    keys = jax.random.split(init_key, n_clients)
    if padded_clients > n_clients:
        dead = jax.random.split(
            jax.random.fold_in(init_key, np.iinfo(np.int32).max),
            padded_clients - n_clients)
        keys = jnp.concatenate([keys, dead])
    return jax.vmap(model.init)(keys)


# ---------------------------------------------------------------------------
class DeVertiFL:
    """One federation instance: model, partition, per-client params.

    Data is held in the canonical column order of ``self.layout``
    internally; ``predict`` accepts raw (original-column-order) inputs
    and re-expresses them itself.
    """

    def __init__(self, pcfg: ProtocolConfig, fedavg_fn=None):
        self.pcfg = pcfg
        self._fedavg_fn = fedavg_fn
        self.mcfg = get_config(arch_for(pcfg.dataset))
        self.model = PaperMLP(self.mcfg)
        xtr, ytr, xte, yte = DR.make_dataset(pcfg.dataset, pcfg.n_samples,
                                             seed=pcfg.seed)
        self.xtr, self.ytr, self.xte, self.yte = xtr, ytr, xte, yte
        self.n_features = self.model.in_features
        self.layout = PT.make_layout(pcfg.dataset, self.n_features,
                                     pcfg.n_clients, seed=pcfg.seed,
                                     max_clients=pcfg.max_clients,
                                     sizes=pcfg.partition_sizes)
        # live clients' ORIGINAL feature ids (dead padding slots are an
        # engine detail; the public partition is the paper's)
        self.partition = self.layout.partition[:pcfg.n_clients]
        self._lay = self.layout.arrays()
        # public masks stay in RAW column order so they compose with the
        # public raw-order xtr/xte (fed.xte * fed.masks[i] is the
        # paper's client view); the engine uses the canonical _lay
        self.masks = jnp.asarray(PT.masks_for(self.partition,
                                              self.n_features))
        self._xtr = jnp.asarray(self.layout.apply(xtr))
        self._xte = jnp.asarray(self.layout.apply(xte))
        self._ytr = jnp.asarray(ytr)
        self.opt = adam(pcfg.lr, max_grad_norm=None)
        self._build_steps()

    # ------------------------------------------------------------------
    def init_params(self, key):
        return init_padded_params(self.model, key, self.pcfg.n_clients,
                                  self.pcfg.padded_clients)

    # ------------------------------------------------------------------
    def _build_steps(self):
        pcfg = self.pcfg
        n_train = len(self.xtr)
        fa = self._fedavg_fn or fedavg
        self._schedule, self._impl = resolve_engine(pcfg, self.model,
                                                    n_train)
        plan = make_perm_fn(pcfg, n_train)
        self.n_batches, self.bs = plan.n_batches, plan.batch_size
        self._steps_per_round = pcfg.epochs * plan.n_batches
        self._perms = jax.jit(plan.perms)
        self._round = jax.jit(
            make_round_fn(self.model, self.opt, pcfg, n_train,
                          fedavg_fn=fa, layout=self.layout,
                          sched_impl=self._impl),
            donate_argnums=(0, 1))
        self._fedavg = jax.jit(
            lambda p: call_fedavg(fa, p, self._lay.client_mask),
            donate_argnums=(0,))
        self._predict = jax.jit(
            make_predict_fn(self.model, pcfg, layout=self.layout))
        if self._impl is None:
            self._step = jax.jit(
                make_step_fn(self.model, self.opt, pcfg,
                             layout=self.layout),
                donate_argnums=(0, 1))
        else:
            # python-engine pieces for the schedule-aware round: the
            # SAME impl hooks and step builder the scan round bakes
            # in, jitted separately, so both engines stay bit-for-bit
            from repro.schedule import make_sched_step_fn
            self._sched_step = jax.jit(
                make_sched_step_fn(self.model, self.opt, pcfg,
                                   self._impl, layout=self.layout),
                donate_argnums=(0, 1))
            self._round_start = jax.jit(self._impl.round_start)
            self._fedavg_sched = jax.jit(
                lambda p, m: call_fedavg(fa, p, m), donate_argnums=(0,))
            fam = getattr(self._impl, "fedavg_mask", None)
            self._fedavg_mask = None if fam is None else jax.jit(fam)

    def init_sched_state(self):
        """Initial exchange-schedule scan-carry state (``{}`` for the
        sync schedule -- an empty pytree the round threads through)."""
        return {} if self._impl is None else \
            self._impl.init_state(self._schedule)

    def fault_telemetry(self, sched_state):
        """Cumulative fault-event counters carried in the scan state
        (repro.faults), or None when no fault plan is active."""
        tel = getattr(self._impl, "telemetry", None)
        return None if tel is None else tel(sched_state)

    def wire_telemetry(self, sched_state):
        """Cumulative bytes-on-wire counters carried in the scan state
        (repro.wire), or None when no transform is active."""
        tel = getattr(self._impl, "wire_telemetry", None)
        return None if tel is None else tel(sched_state)

    def obs_series(self, sched_state):
        """Per-round metric series carried in the scan state
        (repro.obs), as numpy arrays, or None when obs='none'."""
        ser = getattr(self._impl, "obs_series", None)
        return None if ser is None else ser(sched_state)

    def set_fedavg(self, fedavg_fn):
        """Swap the aggregation function (e.g. weighted FedAvg) and
        rebuild the jitted engines -- FedAvg is baked into the scan
        round, so patching self._fedavg alone would not affect it."""
        self._fedavg_fn = fedavg_fn
        self._build_steps()

    # ------------------------------------------------------------------
    def predict(self, params, x):
        xc = jnp.asarray(self.layout.apply(np.asarray(x)))
        return self._predict(params, xc, self._lay)

    def evaluate(self, params):
        # the test set is already cached in canonical order; skip
        # predict()'s per-call permutation of raw inputs
        preds = np.asarray(self._predict(params, self._xte, self._lay))
        avg = "macro" if len(np.unique(self.ytr)) > 2 else "binary"
        f1s = [f1_score(self.yte, preds[i], average=avg)
               for i in range(self.pcfg.n_clients)]
        accs = [accuracy(self.yte, preds[i])
                for i in range(self.pcfg.n_clients)]
        return {"f1": float(np.mean(f1s)), "acc": float(np.mean(accs)),
                "f1_per_client": f1s}

    # ------------------------------------------------------------------
    def _python_round(self, params, opt_state, step_idx, sched_state,
                      key):
        """Pre-refactor reference engine: per-batch host dispatch of the
        jitted step. Consumes the same device permutation stream (and,
        under a non-sync schedule, the same round_start/select/
        round_end hooks) as the scan engine, so trajectories are
        identical."""
        idx = np.asarray(self._perms(key))
        do_avg = self.pcfg.fedavg and self.pcfg.mode != "non_federated"
        losses = []
        if self._impl is None:
            for b in range(idx.shape[0]):
                params, opt_state, loss = self._step(
                    params, opt_state, self._lay,
                    self._xtr[idx[b]], self._ytr[idx[b]], step_idx)
                step_idx = step_idx + 1
                losses.append(loss)
            if do_avg:
                params = self._fedavg(params)
            return params, opt_state, step_idx, sched_state, \
                jnp.stack(losses)
        round_idx = step_idx // self._steps_per_round
        sched_state, eff_mask = self._round_start(sched_state,
                                                  self._lay, key,
                                                  round_idx)
        for b in range(idx.shape[0]):
            params, opt_state, sched_state, loss = self._sched_step(
                params, opt_state, self._lay, eff_mask, sched_state,
                self._xtr[idx[b]], self._ytr[idx[b]], step_idx)
            step_idx = step_idx + 1
            losses.append(loss)
        if do_avg:
            mask = eff_mask if self._fedavg_mask is None else \
                self._fedavg_mask(sched_state, eff_mask)
            params = self._fedavg_sched(params, mask)
        sched_state = self._impl.round_end(sched_state)
        return params, opt_state, step_idx, sched_state, \
            jnp.stack(losses)

    def train(self, key=None, eval_every_round=True, engine=None):
        pcfg = self.pcfg
        engine = engine or pcfg.engine
        key = key if key is not None else jax.random.PRNGKey(pcfg.seed)
        init_key, loop_key = train_keys(key)
        params = self.init_params(init_key)
        opt_state = jax.vmap(self.opt.init)(params)
        step_idx = jnp.zeros((), jnp.int32)
        sched_state = self.init_sched_state()
        history = []
        for r in range(pcfg.rounds):
            rkey = jax.random.fold_in(loop_key, r)
            if engine == "scan":
                params, opt_state, step_idx, sched_state, losses = \
                    self._round(params, opt_state, step_idx,
                                sched_state, rkey,
                                self._xtr, self._ytr, self._lay)
            elif engine == "python":
                params, opt_state, step_idx, sched_state, losses = \
                    self._python_round(params, opt_state, step_idx,
                                       sched_state, rkey)
            else:
                raise ValueError(f"unknown engine {engine!r}")
            if eval_every_round:
                ev = self.evaluate(params)
                ev["round"] = r
                ev["loss"] = float(losses[-1])
                ev["round_losses"] = np.asarray(losses)
                history.append(ev)
        final = self.evaluate(params)
        return {"history": history, "final": final, "params": params}


def train_federation(**kw):
    """DEPRECATED legacy front door, kept as a shim over ``repro.api``.

    Translates ProtocolConfig-style kwargs (``seed=`` becomes the
    spec's ``seeds=(seed,)``) into an ``ExperimentSpec``, runs it
    through ``build(spec).run()``, and returns the historical
    ``{"history", "final", "params"}`` dict -- bit-for-bit what
    ``DeVertiFL(ProtocolConfig(**kw)).train()`` returned
    (tests/test_api.py pins this).  The ``schedule=`` knob forwards
    like every other field and defaults to "sync", so legacy callers
    stay bit-for-bit on the paper-literal engine.  New code should
    construct the spec directly::

        from repro.api import ExperimentSpec, build
        result = build(ExperimentSpec(dataset="mnist", n_clients=5)).run()
    """
    import warnings
    warnings.warn(
        "train_federation(**kw) is deprecated; build an "
        "repro.api.ExperimentSpec and run it via repro.api.build(spec)"
        ".run() instead", DeprecationWarning, stacklevel=2)
    from repro.api import ExperimentSpec, build   # lazy: api sits above core
    kw = dict(kw)
    if "seed" in kw:
        kw["seeds"] = (kw.pop("seed"),)
    rr = build(ExperimentSpec(**kw)).run()
    return {"history": rr.history, "final": rr.metrics,
            "params": rr.params}
