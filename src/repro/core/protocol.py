"""De-VertiFL training protocol (Algorithms 1 + 2), plus the
non-federated baseline and the VertiComb-style backward-exchange
baseline the paper compares against.

All n clients are simulated in one process by stacking per-client
parameters on a leading axis and vmapping; this is numerically
identical to n communicating peers (the exchange and FedAvg are the
only cross-client dataflows, and they are explicit).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import partition as PT
from repro.core.exchange import fedavg, hidden_output_exchange
from repro.data import synthetic as SD
from repro.metrics import accuracy, f1_score
from repro.models.mlp_model import PaperMLP
from repro.optim import adam


@dataclass
class ProtocolConfig:
    dataset: str = "mnist"              # mnist | fmnist | titanic | bank
    n_clients: int = 3
    rounds: int = 5
    epochs: int = 5
    batch_size: int = 64
    lr: float = 1e-3
    # Where HiddenOutputExchange happens. Algorithm 1 exchanges the model
    # output (y-hat); the text/Fig. 1 describe hidden-layer sharing. -1
    # means "logits" (Algorithm-1-faithful); k>=1 means after hidden
    # layer k (text-faithful). Both are supported; -1 is the default and
    # matches the pseudo-code.
    exchange_at: int = -1
    mode: str = "devertifl"             # devertifl | non_federated | verticomb
    fedavg: bool = True
    seed: int = 0
    n_samples: Optional[int] = None     # dataset size override (speed)

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


_ARCH_FOR = {"mnist": "paper-mlp-mnist", "fmnist": "paper-mlp-fmnist",
             "titanic": "paper-mlp-titanic", "bank": "paper-mlp-bank"}


class DeVertiFL:
    """One federation instance: model, partition, per-client params."""

    def __init__(self, pcfg: ProtocolConfig):
        self.pcfg = pcfg
        self.mcfg = get_config(_ARCH_FOR[pcfg.dataset])
        self.model = PaperMLP(self.mcfg)
        xtr, ytr, xte, yte = SD.make_dataset(pcfg.dataset, pcfg.n_samples,
                                             seed=pcfg.seed)
        self.xtr, self.ytr, self.xte, self.yte = xtr, ytr, xte, yte
        self.n_features = self.model.in_features
        part = PT.make_partition(pcfg.dataset, self.n_features,
                                 pcfg.n_clients, seed=pcfg.seed)
        self.partition = part
        self.masks = jnp.asarray(PT.masks_for(part, self.n_features))
        self.opt = adam(pcfg.lr, max_grad_norm=None)
        self._build_steps()

    # ------------------------------------------------------------------
    def init_params(self, key):
        keys = jax.random.split(key, self.pcfg.n_clients)
        return jax.vmap(self.model.init)(keys)

    def _client_hidden(self, p, xm):
        """Forward up to the exchange point (hidden layer k, or logits)."""
        ex = self.pcfg.exchange_at
        if ex == -1:
            h = self.model.forward_hidden(p, xm)
            return self.model.head(p, h)
        return self.model.forward_hidden(p, xm, upto=ex)

    def _rest(self, p, h):
        """Forward from the exchange point to logits."""
        ex = self.pcfg.exchange_at
        if ex == -1:
            return h
        mdl = self.model
        for i in range(ex, mdl.n_hidden):
            h = jax.nn.relu(jax.numpy.matmul(h, p[f"layer_{i}"]["kernel"])
                            + p[f"layer_{i}"]["bias"])
        return mdl.head(p, h)

    @staticmethod
    def _ce(logits, labels):
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()

    # ------------------------------------------------------------------
    def _build_steps(self):
        mode = self.pcfg.mode
        masks = self.masks

        def devertifl_step(params, opt_state, xb, yb, step_idx):
            xm = xb[None] * masks[:, None, :]           # [n, B, F] zeropad
            h_all = jax.vmap(self._client_hidden)(params, xm)
            h_sum = jax.lax.stop_gradient(h_all.sum(0))  # peers as data

            def client_loss(p, x_i):
                h_i = self._client_hidden(p, x_i)
                # value == full exchanged sum; grad flows only through h_i
                h = h_i + h_sum - jax.lax.stop_gradient(h_i)
                return self._ce(self._rest(p, h), yb)

            losses, grads = jax.vmap(jax.value_and_grad(client_loss))(
                params, xm)
            params, opt_state, _ = jax.vmap(
                lambda g, s, p: self.opt.update(g, s, p, step_idx))(
                    grads, opt_state, params)
            return params, opt_state, losses.mean()

        def nonfed_step(params, opt_state, xb, yb, step_idx):
            xm = xb[None] * masks[:, None, :]

            def client_loss(p, x_i):
                h_i = self._client_hidden(p, x_i)
                return self._ce(self._rest(p, h_i), yb)

            losses, grads = jax.vmap(jax.value_and_grad(client_loss))(
                params, xm)
            params, opt_state, _ = jax.vmap(
                lambda g, s, p: self.opt.update(g, s, p, step_idx))(
                    grads, opt_state, params)
            return params, opt_state, losses.mean()

        def verticomb_step(params, opt_state, xb, yb, step_idx):
            xm = xb[None] * masks[:, None, :]

            def total_loss(ps):
                h_all = jax.vmap(self._client_hidden)(ps, xm)
                h_sum = h_all.sum(0)                    # grads flow to all
                logits = jax.vmap(lambda p: self._rest(p, h_sum))(ps)
                return jax.vmap(self._ce, in_axes=(0, None))(logits,
                                                             yb).mean()

            loss, grads = jax.value_and_grad(total_loss)(params)
            params, opt_state, _ = jax.vmap(
                lambda g, s, p: self.opt.update(g, s, p, step_idx))(
                    grads, opt_state, params)
            return params, opt_state, loss

        step = {"devertifl": devertifl_step, "non_federated": nonfed_step,
                "verticomb": verticomb_step}[mode]
        self._step = jax.jit(step, donate_argnums=(0, 1))
        self._fedavg = jax.jit(fedavg, donate_argnums=(0,))

    # ------------------------------------------------------------------
    def predict(self, params, x):
        xm = x[None] * self.masks[:, None, :]
        h_all = jax.vmap(self._client_hidden)(params, xm)
        if self.pcfg.mode in ("devertifl", "verticomb"):
            h_all = hidden_output_exchange(h_all, differentiable=False)
        logits = jax.vmap(self._rest)(params, h_all)    # [n, B, C]
        return jnp.argmax(logits, axis=-1)              # per-client preds

    def evaluate(self, params):
        preds = np.asarray(jax.jit(self.predict)(params,
                                                 jnp.asarray(self.xte)))
        avg = "macro" if len(np.unique(self.ytr)) > 2 else "binary"
        f1s = [f1_score(self.yte, preds[i], average=avg)
               for i in range(self.pcfg.n_clients)]
        accs = [accuracy(self.yte, preds[i])
                for i in range(self.pcfg.n_clients)]
        return {"f1": float(np.mean(f1s)), "acc": float(np.mean(accs)),
                "f1_per_client": f1s}

    # ------------------------------------------------------------------
    def train(self, key=None, eval_every_round=True):
        pcfg = self.pcfg
        key = key if key is not None else jax.random.PRNGKey(pcfg.seed)
        params = self.init_params(key)
        opt_state = jax.vmap(self.opt.init)(params)
        rng = np.random.default_rng(pcfg.seed)
        n = len(self.xtr)
        bs = min(pcfg.batch_size, n)
        n_batches = n // bs
        step_idx = jnp.zeros((), jnp.int32)
        history = []
        xtr = jnp.asarray(self.xtr)
        ytr = jnp.asarray(self.ytr)
        for r in range(pcfg.rounds):
            for e in range(pcfg.epochs):
                order = rng.permutation(n)[:n_batches * bs]
                for b in range(n_batches):
                    idx = order[b * bs:(b + 1) * bs]
                    params, opt_state, loss = self._step(
                        params, opt_state, xtr[idx], ytr[idx], step_idx)
                    step_idx = step_idx + 1
            if pcfg.fedavg and pcfg.mode != "non_federated":
                params = self._fedavg(params)
            if eval_every_round:
                ev = self.evaluate(params)
                ev["round"] = r
                ev["loss"] = float(loss)
                history.append(ev)
        final = self.evaluate(params)
        return {"history": history, "final": final, "params": params}


def train_federation(**kw):
    """Convenience: train_federation(dataset='mnist', n_clients=5, ...)"""
    pcfg = ProtocolConfig(**kw)
    return DeVertiFL(pcfg).train()
