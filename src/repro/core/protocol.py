"""De-VertiFL training protocol (Algorithms 1 + 2), plus the
non-federated baseline and the VertiComb-style backward-exchange
baseline the paper compares against.

All n clients are simulated in one process by stacking per-client
parameters on a leading axis and vmapping; this is numerically
identical to n communicating peers (the exchange and FedAvg are the
only cross-client dataflows, and they are explicit).

Engine layout
-------------
The protocol is factored into pure functions so the whole federation
can be jitted, scanned, and vmapped:

  * make_first_layer_fn  the slice-aware first layer (see below)
  * make_step_fn      one optimizer step for all clients (mode-specific)
  * make_perm_fn      device-side epoch shuffles (jax.random.permutation)
  * make_round_fn     a full round -- epochs x batches as ONE lax.scan
                      with the round-end FedAvg folded in, so a round is
                      a single XLA executable with no host round-trips
  * make_predict_fn   per-client inference with the evaluation exchange

Slice-aware first layer
~~~~~~~~~~~~~~~~~~~~~~~
Every federation trains on the canonical column layout from
``repro.core.partition.canonicalize``: dataset columns are permuted
once at setup so client i owns the contiguous block-aligned feature
slice [offset_i, offset_i + F_i).  The step/round/predict functions
take a ``LayoutArrays(masks, offsets)`` argument (vmappable over a
seed axis, like masks were before), and ``ProtocolConfig.first_layer``
selects how layer 0 is computed:

  masked   the paper-literal reference: materialize the [n, B, F]
           zero-padded batch and run dense full-width matmuls.  Kept
           bit-for-bit as the reference path.
  slice    x[:, off:off+F_i] @ W[off:off+F_i] per client via XLA
           dynamic_slice -- no padding is materialized and the MXU/ALU
           work drops by ~(n-1)/n on layer 0.  Gradients scatter back
           into the client's W-row block; rows outside the slice get
           the same exact-zero gradient the masked path produces.
  pallas   the block-sparse ``vfl_matmul`` Pallas kernel (with its
           custom VJP) walking only the client's weight-row blocks --
           the TPU path; on CPU it runs in interpret mode.
  auto     pallas on TPU, slice elsewhere (the default).

masked and slice/pallas differ only in float reduction order, so
loss/F1 trajectories agree to allclose rather than bitwise
(tests/test_slice_engine.py pins this).

``DeVertiFL.train`` drives make_round_fn under jit (engine="scan", the
default). A per-batch host-dispatched loop is retained as
engine="python" (same jitted step, host-side batch dispatch). Both
engines consume the identical device-generated permutation stream, so
their loss/F1 trajectories match bit-for-bit at a fixed seed
(tests/test_engine.py asserts this). repro.core.sweep vmaps
make_round_fn over seeds for grid experiments.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import partition as PT
from repro.core.exchange import fedavg, hidden_output_exchange
from repro.data import synthetic as SD
from repro.kernels.vfl_matmul import vfl_matmul
from repro.metrics import accuracy, f1_score
from repro.models.mlp_model import PaperMLP
from repro.optim import adam


@dataclass
class ProtocolConfig:
    dataset: str = "mnist"              # mnist | fmnist | titanic | bank
    n_clients: int = 3
    rounds: int = 5
    epochs: int = 5
    batch_size: int = 64
    lr: float = 1e-3
    # Where HiddenOutputExchange happens. Algorithm 1 exchanges the model
    # output (y-hat); the text/Fig. 1 describe hidden-layer sharing. -1
    # means "logits" (Algorithm-1-faithful); k>=1 means after hidden
    # layer k (text-faithful). Both are supported; -1 is the default and
    # matches the pseudo-code.
    exchange_at: int = -1
    mode: str = "devertifl"             # devertifl | non_federated | verticomb
    fedavg: bool = True
    seed: int = 0
    n_samples: Optional[int] = None     # dataset size override (speed)
    engine: str = "scan"                # scan | python (reference loop)
    first_layer: str = "auto"           # auto | pallas | slice | masked

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


ARCH_FOR = {"mnist": "paper-mlp-mnist", "fmnist": "paper-mlp-fmnist",
            "titanic": "paper-mlp-titanic", "bank": "paper-mlp-bank"}


def resolve_first_layer(pcfg) -> str:
    """Map the first_layer knob to a concrete path for this backend."""
    fl = pcfg.first_layer
    if fl == "auto":
        fl = "pallas" if jax.default_backend() == "tpu" else "slice"
    if fl not in ("masked", "slice", "pallas"):
        raise ValueError(f"unknown first_layer {pcfg.first_layer!r}")
    if pcfg.exchange_at == 0 and fl != "masked":
        # exchanging the raw zero-padded input predates layer 0; only
        # the masked formulation expresses it
        fl = "masked"
    return fl


# ---------------------------------------------------------------------------
# pure protocol pieces (shared by DeVertiFL and repro.core.sweep)
# ---------------------------------------------------------------------------
def client_hidden(model, exchange_at, p, xm):
    """Forward up to the exchange point (hidden layer k, or logits)."""
    if exchange_at == -1:
        return model.head(p, model.forward_hidden(p, xm))
    return model.forward_hidden(p, xm, upto=exchange_at)


def client_hidden_from(model, exchange_at, p, h1):
    """client_hidden, but starting from the post-ReLU layer-0 output
    (the slice-aware first-layer paths compute layer 0 themselves)."""
    if exchange_at == -1:
        return model.head(p, model.forward_from(p, h1, start=1))
    return model.forward_from(p, h1, start=1, upto=exchange_at)


def rest(model, exchange_at, p, h):
    """Forward from the exchange point to logits."""
    if exchange_at == -1:
        return h
    for i in range(exchange_at, model.n_hidden):
        h = jax.nn.relu(jnp.matmul(h, p[f"layer_{i}"]["kernel"])
                        + p[f"layer_{i}"]["bias"])
    return model.head(p, h)


def _ce(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()


def make_first_layer_fn(model, pcfg, layout, interpret=None):
    """first(params, xb, lay) -> [n_clients, B, H] post-ReLU layer-0
    activations.  xb is the canonical-order [B, F] batch; lay is the
    LayoutArrays view (lay.offsets is traced -- sweeps vmap it); the
    static slice sizes (and, for pallas, static offsets and block size)
    come from ``layout``.

    CAVEAT (pallas): the Pallas BlockSpec index_map needs *static*
    offsets, so first_pallas closes over ``layout.offsets`` and
    ignores the runtime ``lay.offsets``.  Callers must pass
    LayoutArrays derived from the same canonical Layout (canonical
    offsets are deterministic per (dataset, n_clients), and
    sweep._stacked_federations raises if lanes ever disagreed); a
    scalar-prefetch offset is the ROADMAP item that would lift this."""
    fl = resolve_first_layer(pcfg)
    # the masked reference keeps its whole-forward formulation inline in
    # make_step_fn / make_predict_fn; only the slice-aware paths split
    # the first layer out
    assert fl in ("slice", "pallas"), fl
    assert layout is not None, f"first_layer={fl!r} needs a Layout"
    sizes = layout.sizes

    if fl == "slice":
        def first_slice(params, xb, lay):
            w = params["layer_0"]["kernel"]     # [n, F, H]
            b = params["layer_0"]["bias"]       # [n, H]
            outs = []
            for i, f_i in enumerate(sizes):
                x_i = jax.lax.dynamic_slice(
                    xb, (0, lay.offsets[i]), (xb.shape[0], f_i))
                w_i = jax.lax.dynamic_slice(
                    w[i], (lay.offsets[i], 0), (f_i, w.shape[-1]))
                outs.append(jax.nn.relu(x_i @ w_i + b[i]))
            return jnp.stack(outs)
        return first_slice

    # pallas: BlockSpec index_maps need static offsets; the canonical
    # layout's offsets are deterministic per (dataset, n_clients), so
    # closing over them is safe even in seed-vmapped sweeps.
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    offsets, bk = layout.offsets, layout.block

    def first_pallas(params, xb, lay):
        w = params["layer_0"]["kernel"]
        b = params["layer_0"]["bias"]
        outs = []
        for i, (off, f_i) in enumerate(zip(offsets, sizes)):
            x_i = jax.lax.slice_in_dim(xb, off, off + f_i, axis=1)
            y = vfl_matmul(x_i, w[i], off, bk=bk, interpret=interpret)
            outs.append(jax.nn.relu(y + b[i]))
        return jnp.stack(outs)
    return first_pallas


def make_step_fn(model, opt, pcfg, layout=None):
    """One all-clients optimizer step for pcfg.mode.

    Signature: step(params, opt_state, lay, xb, yb, step_idx)
      -> (params, opt_state, mean_loss).  lay is a LayoutArrays
    argument (not a closure) so sweeps can vmap it over per-seed
    partitions; xb is in canonical column order.
    """
    fl = resolve_first_layer(pcfg)
    hidden = partial(client_hidden, model, pcfg.exchange_at)
    through = partial(rest, model, pcfg.exchange_at)

    def update(params, opt_state, grads, step_idx):
        params, opt_state, _ = jax.vmap(
            lambda g, s, p: opt.update(g, s, p, step_idx))(
                grads, opt_state, params)
        return params, opt_state

    if fl == "masked":
        # the paper-literal reference: whole-forward from the
        # materialized [n, B, F] zero-padded batch, per-client
        # value_and_grad -- kept exactly as the pre-slice engine
        def devertifl_step(params, opt_state, lay, xb, yb, step_idx):
            xm = xb[None] * lay.masks[:, None, :]   # [n, B, F] zeropad
            h_all = jax.vmap(hidden)(params, xm)
            h_sum = jax.lax.stop_gradient(h_all.sum(0))  # peers as data

            def client_loss(p, x_i):
                h_i = hidden(p, x_i)
                # value == full exchanged sum; grad flows only through h_i
                h = h_i + h_sum - jax.lax.stop_gradient(h_i)
                return _ce(through(p, h), yb)

            losses, grads = jax.vmap(jax.value_and_grad(client_loss))(
                params, xm)
            params, opt_state = update(params, opt_state, grads, step_idx)
            return params, opt_state, losses.mean()

        def nonfed_step(params, opt_state, lay, xb, yb, step_idx):
            xm = xb[None] * lay.masks[:, None, :]

            def client_loss(p, x_i):
                h_i = hidden(p, x_i)
                return _ce(through(p, h_i), yb)

            losses, grads = jax.vmap(jax.value_and_grad(client_loss))(
                params, xm)
            params, opt_state = update(params, opt_state, grads, step_idx)
            return params, opt_state, losses.mean()

        def verticomb_step(params, opt_state, lay, xb, yb, step_idx):
            xm = xb[None] * lay.masks[:, None, :]

            def total_loss(ps):
                h_all = jax.vmap(hidden)(ps, xm)
                h_sum = h_all.sum(0)                # grads flow to all
                logits = jax.vmap(lambda p: through(p, h_sum))(ps)
                return jax.vmap(_ce, in_axes=(0, None))(logits, yb).mean()

            loss, grads = jax.value_and_grad(total_loss)(params)
            params, opt_state = update(params, opt_state, grads, step_idx)
            return params, opt_state, loss

    else:
        # slice/pallas: layer 0 reads only the client's feature slice;
        # per-client grads come from grad(sum of per-client losses) --
        # loss_i depends on params[i] alone (peer terms are
        # stop-gradient'ed), so the stacked gradient IS the per-client
        # gradient stack
        first = make_first_layer_fn(model, pcfg, layout)
        hidden_from = partial(client_hidden_from, model, pcfg.exchange_at)

        def losses_fn(ps, lay, xb, yb, differentiable=None):
            h1 = first(ps, xb, lay)
            h_all = jax.vmap(hidden_from)(ps, h1)
            if differentiable is not None:
                h_all = hidden_output_exchange(
                    h_all, differentiable=differentiable)
            logits = jax.vmap(through)(ps, h_all)
            return jax.vmap(_ce, in_axes=(0, None))(logits, yb)   # [n]

        def devertifl_step(params, opt_state, lay, xb, yb, step_idx):
            def total(ps):
                losses = losses_fn(ps, lay, xb, yb, differentiable=False)
                return losses.sum(), losses

            grads, losses = jax.grad(total, has_aux=True)(params)
            params, opt_state = update(params, opt_state, grads, step_idx)
            return params, opt_state, losses.mean()

        def nonfed_step(params, opt_state, lay, xb, yb, step_idx):
            def total(ps):
                losses = losses_fn(ps, lay, xb, yb)
                return losses.sum(), losses

            grads, losses = jax.grad(total, has_aux=True)(params)
            params, opt_state = update(params, opt_state, grads, step_idx)
            return params, opt_state, losses.mean()

        def verticomb_step(params, opt_state, lay, xb, yb, step_idx):
            def total(ps):
                return losses_fn(ps, lay, xb, yb,
                                 differentiable=True).mean()

            loss, grads = jax.value_and_grad(total)(params)
            params, opt_state = update(params, opt_state, grads, step_idx)
            return params, opt_state, loss

    return {"devertifl": devertifl_step, "non_federated": nonfed_step,
            "verticomb": verticomb_step}[pcfg.mode]


class PermPlan(NamedTuple):
    """Epoch-shuffle plan from make_perm_fn.  n_dropped documents the
    silent tail drop: each epoch uses n_batches * batch_size samples,
    so the trailing ``n_train % batch_size`` samples of every epoch's
    permutation are discarded (a fresh permutation each epoch means a
    *different* random subset is dropped every epoch, so no sample is
    systematically excluded)."""
    perms: object          # perms(round_key) -> [epochs*n_batches, bs]
    n_batches: int
    batch_size: int
    n_dropped: int         # per-epoch discarded tail = n_train % bs


def make_perm_fn(pcfg, n_train) -> PermPlan:
    """Device-side epoch shuffles: perms(round_key) -> [epochs * n_batches,
    batch_size] int32 batch indices, one independent permutation per
    epoch.

    NOTE the tail-drop semantics: n_batches = n_train // batch_size, so
    the last ``n_train % batch_size`` indices of each epoch permutation
    are dropped (PermPlan.n_dropped).  This matches the common
    drop-last DataLoader behavior and keeps every scanned batch the
    same static shape."""
    bs = min(pcfg.batch_size, n_train)
    n_batches = n_train // bs

    def perms(key):
        keys = jax.random.split(key, pcfg.epochs)
        order = jax.vmap(
            lambda k: jax.random.permutation(k, n_train))(keys)
        return order[:, :n_batches * bs].reshape(
            pcfg.epochs * n_batches, bs)

    return PermPlan(perms, n_batches, bs, n_train - n_batches * bs)


def make_round_fn(model, opt, pcfg, n_train, fedavg_fn=None, layout=None):
    """One De-VertiFL round as a single jittable function: generate the
    epoch permutations on device, lax.scan the step over every batch of
    every epoch (step_idx carried in the scan), then apply the P2P
    FedAvg (Algorithm 1 lines 16-19) to the carry-out parameters.

    Signature: round_fn(params, opt_state, step_idx, key, xtr, ytr,
    lay) -> (params, opt_state, step_idx, losses[epochs*n_batches]).
    Data (canonical column order) and the LayoutArrays are arguments so
    a sweep can vmap the whole round over a leading seed axis.
    fedavg_fn overrides the uniform-mean aggregation (e.g. the
    weighted-FedAvg ablation); it is baked into the jitted round, so
    pass it here rather than patching afterwards.
    """
    step = make_step_fn(model, opt, pcfg, layout=layout)
    perm_fn = make_perm_fn(pcfg, n_train).perms
    do_fedavg = pcfg.fedavg and pcfg.mode != "non_federated"
    fedavg_fn = fedavg_fn or fedavg

    def round_fn(params, opt_state, step_idx, key, xtr, ytr, lay):
        idx = perm_fn(key)

        def body(carry, batch_idx):
            params, opt_state, step_idx = carry
            xb = jnp.take(xtr, batch_idx, axis=0)
            yb = jnp.take(ytr, batch_idx, axis=0)
            params, opt_state, loss = step(params, opt_state, lay,
                                           xb, yb, step_idx)
            return (params, opt_state, step_idx + 1), loss

        (params, opt_state, step_idx), losses = jax.lax.scan(
            body, (params, opt_state, step_idx), idx)
        if do_fedavg:
            params = fedavg_fn(params)
        return params, opt_state, step_idx, losses

    return round_fn


def make_predict_fn(model, pcfg, layout=None):
    """predict(params, x, lay) -> [n_clients, B] class predictions.
    x is in canonical column order (Layout.apply)."""
    fl = resolve_first_layer(pcfg)
    through = partial(rest, model, pcfg.exchange_at)

    if fl == "masked":
        hidden = partial(client_hidden, model, pcfg.exchange_at)

        def h_all_fn(params, x, lay):
            xm = x[None] * lay.masks[:, None, :]
            return jax.vmap(hidden)(params, xm)
    else:
        first = make_first_layer_fn(model, pcfg, layout)
        hidden_from = partial(client_hidden_from, model, pcfg.exchange_at)

        def h_all_fn(params, x, lay):
            return jax.vmap(hidden_from)(params, first(params, x, lay))

    def predict(params, x, lay):
        h_all = h_all_fn(params, x, lay)
        if pcfg.mode in ("devertifl", "verticomb"):
            h_all = hidden_output_exchange(h_all, differentiable=False)
        logits = jax.vmap(through)(params, h_all)   # [n, B, C]
        return jnp.argmax(logits, axis=-1)          # per-client preds

    return predict


def train_keys(key):
    """Split a federation key into (init_key, loop_key); round r uses
    fold_in(loop_key, r). Shared by DeVertiFL.train and sweep so a
    sweep lane reproduces the standalone run bit-for-bit."""
    init_key, loop_key = jax.random.split(key)
    return init_key, loop_key


# ---------------------------------------------------------------------------
class DeVertiFL:
    """One federation instance: model, partition, per-client params.

    Data is held in the canonical column order of ``self.layout``
    internally; ``predict`` accepts raw (original-column-order) inputs
    and re-expresses them itself.
    """

    def __init__(self, pcfg: ProtocolConfig, fedavg_fn=None):
        self.pcfg = pcfg
        self._fedavg_fn = fedavg_fn
        self.mcfg = get_config(ARCH_FOR[pcfg.dataset])
        self.model = PaperMLP(self.mcfg)
        xtr, ytr, xte, yte = SD.make_dataset(pcfg.dataset, pcfg.n_samples,
                                             seed=pcfg.seed)
        self.xtr, self.ytr, self.xte, self.yte = xtr, ytr, xte, yte
        self.n_features = self.model.in_features
        self.layout = PT.make_layout(pcfg.dataset, self.n_features,
                                     pcfg.n_clients, seed=pcfg.seed)
        self.partition = self.layout.partition
        self._lay = self.layout.arrays()
        # public masks stay in RAW column order so they compose with the
        # public raw-order xtr/xte (fed.xte * fed.masks[i] is the
        # paper's client view); the engine uses the canonical _lay
        self.masks = jnp.asarray(PT.masks_for(self.partition,
                                              self.n_features))
        self._xtr = jnp.asarray(self.layout.apply(xtr))
        self._xte = jnp.asarray(self.layout.apply(xte))
        self._ytr = jnp.asarray(ytr)
        self.opt = adam(pcfg.lr, max_grad_norm=None)
        self._build_steps()

    # ------------------------------------------------------------------
    def init_params(self, key):
        keys = jax.random.split(key, self.pcfg.n_clients)
        return jax.vmap(self.model.init)(keys)

    # ------------------------------------------------------------------
    def _build_steps(self):
        pcfg = self.pcfg
        n_train = len(self.xtr)
        fa = self._fedavg_fn or fedavg
        self._step = jax.jit(
            make_step_fn(self.model, self.opt, pcfg, layout=self.layout),
            donate_argnums=(0, 1))
        plan = make_perm_fn(pcfg, n_train)
        self.n_batches, self.bs = plan.n_batches, plan.batch_size
        self._perms = jax.jit(plan.perms)
        self._round = jax.jit(
            make_round_fn(self.model, self.opt, pcfg, n_train,
                          fedavg_fn=fa, layout=self.layout),
            donate_argnums=(0, 1))
        self._fedavg = jax.jit(fa, donate_argnums=(0,))
        self._predict = jax.jit(
            make_predict_fn(self.model, pcfg, layout=self.layout))

    def set_fedavg(self, fedavg_fn):
        """Swap the aggregation function (e.g. weighted FedAvg) and
        rebuild the jitted engines -- FedAvg is baked into the scan
        round, so patching self._fedavg alone would not affect it."""
        self._fedavg_fn = fedavg_fn
        self._build_steps()

    # ------------------------------------------------------------------
    def predict(self, params, x):
        xc = jnp.asarray(self.layout.apply(np.asarray(x)))
        return self._predict(params, xc, self._lay)

    def evaluate(self, params):
        # the test set is already cached in canonical order; skip
        # predict()'s per-call permutation of raw inputs
        preds = np.asarray(self._predict(params, self._xte, self._lay))
        avg = "macro" if len(np.unique(self.ytr)) > 2 else "binary"
        f1s = [f1_score(self.yte, preds[i], average=avg)
               for i in range(self.pcfg.n_clients)]
        accs = [accuracy(self.yte, preds[i])
                for i in range(self.pcfg.n_clients)]
        return {"f1": float(np.mean(f1s)), "acc": float(np.mean(accs)),
                "f1_per_client": f1s}

    # ------------------------------------------------------------------
    def _python_round(self, params, opt_state, step_idx, key):
        """Pre-refactor reference engine: per-batch host dispatch of the
        jitted step. Consumes the same device permutation stream as the
        scan engine, so trajectories are identical."""
        idx = np.asarray(self._perms(key))
        losses = []
        for b in range(idx.shape[0]):
            params, opt_state, loss = self._step(
                params, opt_state, self._lay,
                self._xtr[idx[b]], self._ytr[idx[b]], step_idx)
            step_idx = step_idx + 1
            losses.append(loss)
        if self.pcfg.fedavg and self.pcfg.mode != "non_federated":
            params = self._fedavg(params)
        return params, opt_state, step_idx, jnp.stack(losses)

    def train(self, key=None, eval_every_round=True, engine=None):
        pcfg = self.pcfg
        engine = engine or pcfg.engine
        key = key if key is not None else jax.random.PRNGKey(pcfg.seed)
        init_key, loop_key = train_keys(key)
        params = self.init_params(init_key)
        opt_state = jax.vmap(self.opt.init)(params)
        step_idx = jnp.zeros((), jnp.int32)
        history = []
        for r in range(pcfg.rounds):
            rkey = jax.random.fold_in(loop_key, r)
            if engine == "scan":
                params, opt_state, step_idx, losses = self._round(
                    params, opt_state, step_idx, rkey,
                    self._xtr, self._ytr, self._lay)
            elif engine == "python":
                params, opt_state, step_idx, losses = self._python_round(
                    params, opt_state, step_idx, rkey)
            else:
                raise ValueError(f"unknown engine {engine!r}")
            if eval_every_round:
                ev = self.evaluate(params)
                ev["round"] = r
                ev["loss"] = float(losses[-1])
                ev["round_losses"] = np.asarray(losses)
                history.append(ev)
        final = self.evaluate(params)
        return {"history": history, "final": final, "params": params}


def train_federation(**kw):
    """Convenience: train_federation(dataset='mnist', n_clients=5, ...)"""
    pcfg = ProtocolConfig(**kw)
    return DeVertiFL(pcfg).train()
