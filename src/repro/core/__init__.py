# The paper's primary contribution: the De-VertiFL decentralized
# vertical-federated training protocol (partitioning, forward-pass
# HiddenOutputExchange, local backward, P2P FedAvg), plus the baselines
# it is evaluated against.
from repro.core.protocol import (  # noqa: F401
    DeVertiFL, ProtocolConfig, train_federation,
)
from repro.core.exchange import hidden_output_exchange  # noqa: F401
from repro.core.partition import make_partition, masks_for  # noqa: F401
