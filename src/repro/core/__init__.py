# The paper's primary contribution: the De-VertiFL decentralized
# vertical-federated training protocol (partitioning, forward-pass
# HiddenOutputExchange, local backward, P2P FedAvg), plus the baselines
# it is evaluated against.
from repro.core.protocol import (  # noqa: F401
    DeVertiFL, ProtocolConfig, arch_for, exchange_width, make_round_fn,
    make_step_fn, register_first_layer, resolve_schedule,
    train_federation,
)
from repro.core.sweep import SweepConfig, run_cell, run_grid  # noqa: F401
from repro.core.exchange import hidden_output_exchange  # noqa: F401
from repro.core.partition import (  # noqa: F401
    Layout, LayoutArrays, canonicalize, make_layout, make_partition,
    masks_for, skewed_partition,
)
