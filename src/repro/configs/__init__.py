"""Architecture configs. Each assigned arch lives in its own module and
registers itself on import; load_all() imports every module once."""
import importlib

from repro.configs.base import (  # noqa: F401
    INPUT_SHAPES,
    InputShape,
    ModelConfig,
    VFLConfig,
    get_config,
    list_configs,
    register,
)

_MODULES = [
    "qwen2_7b", "rwkv6_1b6", "jamba_v0_1_52b", "deepseek_moe_16b",
    "llava_next_34b", "qwen1_5_0_5b", "mixtral_8x22b", "qwen1_5_4b",
    "gemma2_2b", "seamless_m4t_medium", "paper_mlp",
]

_loaded = False


def load_all():
    global _loaded
    if _loaded:
        return
    for m in _MODULES:
        importlib.import_module(f"repro.configs.{m}")
    _loaded = True
