"""LLaVA-NeXT-34B [hf:llava-hf/llava-v1.6-mistral-7b-hf lineage] — VLM:
Yi-34B-style dense decoder backbone consuming anyres-tiled patch
embeddings from a stubbed vision frontend (ViT + projector NOT
implemented; input_specs provides projected patch embeddings).

anyres: base 576 patches + 4 tiles x 576 = 2880 image tokens/sample.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llava-next-34b",
    family="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    attn_type="full",
    modality="vision_text",
    num_prefix_embeddings=2880,
    rope_theta=5_000_000.0,
    act="swiglu",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
))
