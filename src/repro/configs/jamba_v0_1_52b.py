"""Jamba-v0.1 52B [arXiv:2403.19887] — hybrid Mamba+attention at 1:7
(one attention layer per period of 8, offset 4), MoE 16 experts top-2 on
every other layer, GQA kv=8."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    ssm_type="mamba",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    attn_type="full",
    attn_layer_period=8,
    attn_layer_offset=4,
    num_experts=16,
    num_experts_per_tok=2,
    moe_every=2,
    moe_offset=1,
    moe_d_ff=14336,
    ssm_state_dim=16,
    ssm_conv_width=4,
    ssm_expand=2,
    act="swiglu",
    source="arXiv:2403.19887",
))
