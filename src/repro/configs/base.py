"""Config system: every architecture (assigned pool + the paper's own MLPs)
is an instance of ModelConfig, registered under its --arch id.

All fields are plain data so configs hash/compare cleanly and can be
serialized into EXPERIMENTS.md tables.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class VFLConfig:
    """De-VertiFL protocol knobs (the paper's technique).

    enabled: vertical-federated input block (feature-sharded embedding +
        HiddenOutputExchange psum) is used in the forward pass.
    exchange: 'zeropad_psum'  — paper-faithful: each client materializes a
                               full-width zero-padded hidden and the
                               exchange sums them (Algorithm 2).
              'allgather'     — beyond-paper optimized: clients exchange
                               only their owned slices (same semantics,
                               1/n collective bytes). Used in §Perf.
    fedavg_every: local steps between FedAvg parameter pmeans over the
        federated axis (paper: E epochs per round). 0 = every step
        (standard data-parallel equivalent).
    """
    enabled: bool = True
    exchange: str = "zeropad_psum"
    fedavg_every: int = 0


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio | mlp
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    # ---- attention variants ----
    attn_type: str = "full"          # full | swa | local_global | none
    window_size: int = 4096
    attn_logit_softcap: float = 0.0  # 0 = off (gemma2: 50.0)
    final_logit_softcap: float = 0.0 # gemma2: 30.0
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    # ---- MoE ----
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                # expert hidden dim (0 -> d_ff)
    moe_every: int = 1               # MoE on layers where (l % moe_every == moe_offset)
    moe_offset: int = 0
    first_layer_dense_ff: int = 0    # deepseek: dense FFN width on layer 0
    router_aux_weight: float = 0.01
    expert_capacity_factor: float = 1.25
    # ---- hybrid / SSM ----
    ssm_type: str = ""               # '' | 'mamba' | 'rwkv6'
    attn_layer_period: int = 0       # jamba: 1 attn layer per period
    attn_layer_offset: int = 0
    ssm_state_dim: int = 16
    ssm_conv_width: int = 4
    ssm_expand: int = 2
    rwkv_head_dim: int = 64
    # ---- enc-dec / modality ----
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    modality: str = "text"           # text | vision_text | audio_text
    num_prefix_embeddings: int = 0   # VLM patch tokens / audio frames per sample
    # ---- misc ----
    norm_type: str = "rmsnorm"       # rmsnorm | layernorm
    act: str = "swiglu"              # swiglu | gelu | relu
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: bool = True
    # '' = full remat; 'save_mixer_ffn' = keep per-block mixer/FFN
    # outputs (the TP-psum'd tensors) so backward does not re-run their
    # collectives (EXPERIMENTS.md section Perf iter 6)
    remat_policy: str = ""
    scan_layers: bool = True
    # ---- De-VertiFL ----
    vfl: VFLConfig = field(default_factory=VFLConfig)
    # provenance
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def sub_quadratic_decode(self) -> bool:
        """Eligible for long_500k: SSM/hybrid, or windowed attention."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.attn_type in ("swa", "local_global")

    @property
    def has_decode(self) -> bool:
        """Encoder-only archs have no decode step; enc-dec does."""
        return True  # all assigned archs decode (seamless decodes text)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---------- parameter counting (for roofline MODEL_FLOPS) ----------
    def param_counts(self) -> dict:
        """Returns dict with total and active (per-token) parameter counts."""
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        H, KV, hd = self.num_heads, self.num_kv_heads, self.head_dim
        n_ff_mats = 3 if self.act == "swiglu" else 2

        def attn_params():
            return D * (H * hd) + 2 * D * (KV * hd) + (H * hd) * D

        def dense_ffn(f):
            return n_ff_mats * D * f

        def mamba_params():
            d_in = self.ssm_expand * D
            p = D * 2 * d_in                       # in_proj (x, z)
            p += d_in * self.ssm_conv_width        # conv
            p += d_in * (2 * self.ssm_state_dim + 1)  # B, C, dt(rank-1 simplified)
            p += d_in * D                          # out_proj
            p += d_in * self.ssm_state_dim         # A
            return p

        def rwkv_params():
            # time-mix: r,k,v,g,o projections + decay lora; channel-mix 2 mats
            tm = 5 * D * D + 2 * D * 64
            cm = 2 * D * int(3.5 * D) if self.d_ff == 0 else (2 * D * self.d_ff)
            return tm + cm

        total = 0
        active = 0
        emb = V * D * (1 if self.tie_embeddings else 2)
        total += emb
        active += emb

        layers = range(self.num_layers)
        for l in layers:
            if self.family == "ssm" and self.ssm_type == "rwkv6":
                p = rwkv_params()
                total += p; active += p
                continue
            is_attn = True
            if self.attn_layer_period:
                is_attn = (l % self.attn_layer_period) == self.attn_layer_offset
            if self.family == "ssm":
                is_attn = False
            if is_attn and self.attn_type != "none":
                p = attn_params()
                total += p; active += p
            elif self.ssm_type == "mamba":
                p = mamba_params()
                total += p; active += p
            # FFN / MoE
            is_moe = (self.num_experts > 0
                      and (l % self.moe_every) == self.moe_offset
                      and not (l == 0 and self.first_layer_dense_ff))
            if l == 0 and self.first_layer_dense_ff:
                p = dense_ffn(self.first_layer_dense_ff)
                total += p; active += p
            elif is_moe:
                f = self.moe_d_ff or F
                per_expert = dense_ffn(f)
                total += self.num_experts * per_expert
                active += self.num_experts_per_tok * per_expert
                total += self.num_shared_experts * per_expert
                active += self.num_shared_experts * per_expert
                total += D * self.num_experts     # router
                active += D * self.num_experts
            else:
                p = dense_ffn(F)
                total += p; active += p
        if self.is_encoder_decoder:
            # encoder layers: self-attn + ffn; decoder already counted adds cross-attn
            enc = self.num_encoder_layers * (attn_params() + dense_ffn(F))
            cross = self.num_layers * attn_params()
            total += enc + cross
            active += enc + cross
        return {"total": total, "active": active}


_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    # populate registry
    from repro import configs as _c  # noqa: F401
    _c.load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list:
    from repro import configs as _c
    _c.load_all()
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}
