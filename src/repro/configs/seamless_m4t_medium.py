"""SeamlessM4T-medium [arXiv:2308.11596] — encoder-decoder; speech
frontend (mel + conformer feature extractor) is a STUB: input_specs
provides precomputed frame embeddings to the text/decoder transformer.
12 encoder + 12 decoder layers, d_model 1024, MHA kv=16."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,              # decoder layers
    num_encoder_layers=12,
    is_encoder_decoder=True,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    attn_type="full",
    modality="audio_text",
    num_prefix_embeddings=1024,  # encoder frames per sample
    act="relu",
    norm_type="layernorm",
    source="arXiv:2308.11596",
))
