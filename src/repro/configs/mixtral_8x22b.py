"""Mixtral-8x22B [arXiv:2401.04088] — 8-expert top-2 MoE, GQA kv=8, SWA."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    attn_type="swa",
    window_size=4096,
    num_experts=8,
    num_experts_per_tok=2,
    moe_d_ff=16384,
    act="swiglu",
    rope_theta=1_000_000.0,
    source="arXiv:2401.04088",
))
