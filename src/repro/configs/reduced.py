"""Reduced variants of each assigned architecture family for CPU smoke
tests: <=2 layers (plus family-structural minimums), d_model<=512,
<=4 experts, tiny vocab. Same code paths as the full configs."""
from __future__ import annotations

from repro.configs.base import ModelConfig, get_config


def reduced_config(name: str, **extra) -> ModelConfig:
    cfg = get_config(name)
    kw = dict(
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        remat=False,
        dtype="float32",
    )
    if cfg.family == "moe":
        # generous capacity: routing must be lossless at smoke-test token
        # counts so decode == forward exactly (drop behaviour is unit
        # -tested separately in test_moe.py)
        kw.update(num_experts=4, num_experts_per_tok=2, moe_d_ff=128,
                  expert_capacity_factor=8.0)
        if cfg.num_shared_experts:
            kw.update(num_shared_experts=1)
        if cfg.first_layer_dense_ff:
            kw.update(first_layer_dense_ff=256)
    if cfg.ssm_type == "rwkv6":
        kw.update(num_heads=4, num_kv_heads=4, rwkv_head_dim=64, d_ff=512)
    if cfg.family == "hybrid":
        kw.update(num_layers=4, attn_layer_period=2, attn_layer_offset=1,
                  num_experts=4, num_experts_per_tok=2, moe_every=2,
                  moe_offset=1, moe_d_ff=128, ssm_state_dim=8,
                  expert_capacity_factor=8.0)
    if cfg.attn_type in ("swa", "local_global"):
        kw.update(window_size=16)
    if cfg.modality == "vision_text":
        kw.update(num_prefix_embeddings=8)
    if cfg.is_encoder_decoder:
        kw.update(num_encoder_layers=2, num_prefix_embeddings=16)
    if cfg.num_heads and cfg.num_heads == cfg.num_kv_heads:
        kw.update(num_kv_heads=4)  # keep MHA archs MHA
    kw.update(extra)
    out = cfg.replace(**kw)
    object.__setattr__(out, "head_dim", 64)
    return out
