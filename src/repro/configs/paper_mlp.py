"""The paper's own models: 3-hidden-layer MLPs (10 neurons each) for
MNIST / FMNIST (10-class) and Titanic / Bank Marketing (binary).
Section III-IV of De-VertiFL."""
from repro.configs.base import ModelConfig, register


def _mlp(name, in_features, n_classes, hidden=10, n_hidden=3):
    return register(ModelConfig(
        name=name,
        family="mlp",
        num_layers=n_hidden,
        d_model=hidden,
        num_heads=0,
        num_kv_heads=0,
        head_dim=1,
        d_ff=hidden,
        vocab_size=in_features,     # = input feature count for MLPs
        attn_type="none",
        act="relu",
        norm_type="layernorm",
        scan_layers=False,
        remat=False,
        source="De-VertiFL section IV",
    ))


MNIST = _mlp("paper-mlp-mnist", 784, 10)
FMNIST = _mlp("paper-mlp-fmnist", 784, 10)
TITANIC = _mlp("paper-mlp-titanic", 9, 2)
BANK = _mlp("paper-mlp-bank", 51, 2)

N_CLASSES = {
    "paper-mlp-mnist": 10, "paper-mlp-fmnist": 10,
    "paper-mlp-titanic": 2, "paper-mlp-bank": 2,
}
IN_FEATURES = {
    "paper-mlp-mnist": 784, "paper-mlp-fmnist": 784,
    "paper-mlp-titanic": 9, "paper-mlp-bank": 51,
}
