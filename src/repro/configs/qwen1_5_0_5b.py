"""Qwen1.5-0.5B [hf:Qwen/Qwen1.5-0.5B] — dense, MHA (kv=16), QKV bias."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=2816,
    vocab_size=151936,
    qkv_bias=True,
    attn_type="full",
    rope_theta=1_000_000.0,
    act="swiglu",
    tie_embeddings=True,
    source="hf:Qwen/Qwen1.5-0.5B",
))
