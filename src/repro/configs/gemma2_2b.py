"""Gemma2-2B [arXiv:2408.00118] — alternating local(SWA 4096)/global
attention, attn & final logit softcaps, GQA kv=4, head_dim 256."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    attn_type="local_global",
    window_size=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    act="gelu",
    tie_embeddings=True,
    source="arXiv:2408.00118",
))
