"""RWKV6 "Finch" 1.6B [arXiv:2404.05892] — attention-free RNN with
data-dependent decay (ddlerp token shift + LoRA decay), head_dim 64."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    ssm_type="rwkv6",
    num_layers=24,
    d_model=2048,
    num_heads=32,           # 2048 / 64 wkv heads
    num_kv_heads=32,
    head_dim=64,
    rwkv_head_dim=64,
    d_ff=7168,              # channel-mix hidden (3.5x)
    vocab_size=65536,
    attn_type="none",
    act="relu",             # channel-mix uses relu^2
    norm_type="layernorm",
    source="arXiv:2404.05892",
))
