"""Qwen2-7B [arXiv:2407.10671] — dense GQA decoder, QKV bias."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-7b",
    family="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    attn_type="full",
    rope_theta=1_000_000.0,
    act="swiglu",
    norm_type="rmsnorm",
    source="arXiv:2407.10671",
))


# Beyond-assignment variant: sliding-window attention unlocks the
# long_500k decode shape for this otherwise full-attention arch (the
# assigned config above is untouched; see DESIGN.md section 4).
CONFIG_SWA = register(CONFIG.replace(
    name="qwen2-7b-swa",
    attn_type="swa",
    window_size=4096,
))
