"""DeepSeekMoE-16B [arXiv:2401.06066] — fine-grained MoE: 64 routed
experts top-6 + 2 shared experts, expert d_ff=1408; layer 0 is a dense
FFN (width 10944 per the paper); MHA kv=16."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=102400,
    attn_type="full",
    num_experts=64,
    num_experts_per_tok=6,
    num_shared_experts=2,
    moe_d_ff=1408,
    first_layer_dense_ff=10944,
    act="swiglu",
    source="arXiv:2401.06066",
))
