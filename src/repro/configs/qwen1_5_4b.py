"""Qwen1.5-4B [hf:Qwen/Qwen1.5-0.5B family] — dense, MHA (kv=20), QKV bias."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    num_layers=40,
    d_model=2560,
    num_heads=20,
    num_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab_size=151936,
    qkv_bias=True,
    attn_type="full",
    rope_theta=1_000_000.0,
    act="swiglu",
    source="hf:Qwen/Qwen1.5-4B",
))


# Beyond-assignment SWA variant (unlocks long_500k; see DESIGN.md §4).
CONFIG_SWA = register(CONFIG.replace(
    name="qwen1.5-4b-swa",
    attn_type="swa",
    window_size=4096,
))
