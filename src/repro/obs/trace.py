"""Host-side span tracing: where the wall-clock time of a run or a
serving session actually went.

:class:`SpanTracer` records nested context-manager spans
(``with tracer.span("round", cat="train", round=r): ...``) and point
instants with microsecond wall-clock timestamps.  It is strictly a
HOST-side instrument -- it never touches traced values, so arming it
cannot perturb trajectories -- and its whole cost is two
``perf_counter`` calls plus one dict append per span.

Exports:

  export(path)   Chrome trace-event JSON (the ``{"traceEvents":
                 [...]}`` container of "X" complete events + "i"
                 instants) -- loadable in Perfetto / chrome://tracing.
  summary()      a human-readable per-span-name aggregate table
                 (count, total ms, mean ms, share of traced wall).
  to_records()   the raw span dicts, JSON-safe -- what the unified
                 Telemetry record embeds.

:class:`NullTracer` is the ``obs="none"`` stand-in: every method is a
no-op (``span`` returns one shared nullcontext), so instrumented call
sites cost one attribute lookup when tracing is off -- the
zero-overhead-when-off invariant (docs/ARCHITECTURE.md section 12).

``profile_to(dir)`` optionally brackets a region with
``jax.profiler.start_trace/stop_trace`` so a device-level profile can
be captured alongside the host spans; it degrades to a plain span when
the profiler is unavailable on this backend.
"""
from __future__ import annotations

import contextlib
import json
import os
import time
from contextlib import contextmanager
from typing import List, Optional


class SpanTracer:
    """Nested wall-clock spans with Chrome trace-event export."""

    active = True

    def __init__(self):
        self.records: List[dict] = []   # closed spans + instants
        self._depth = 0
        self._t0 = time.perf_counter()
        self._pid = os.getpid()

    # ------------------------------------------------------------------
    def _us(self, t: float) -> float:
        return (t - self._t0) * 1e6

    @contextmanager
    def span(self, name: str, cat: str = "run", **args):
        """Record one nested span around the with-body."""
        depth = self._depth
        self._depth += 1
        t_in = time.perf_counter()
        try:
            yield
        finally:
            t_out = time.perf_counter()
            self._depth = depth
            self.records.append({
                "name": name, "cat": cat, "ph": "X",
                "ts": self._us(t_in),
                "dur": (t_out - t_in) * 1e6,
                "depth": depth, "args": args})

    def instant(self, name: str, cat: str = "run", **args):
        """Record a point event (a request lifecycle edge)."""
        self.records.append({
            "name": name, "cat": cat, "ph": "i",
            "ts": self._us(time.perf_counter()),
            "dur": 0.0, "depth": self._depth, "args": args})

    @contextmanager
    def profile_to(self, profile_dir: Optional[str]):
        """A span that additionally captures a ``jax.profiler`` device
        trace into ``profile_dir``.  ``None`` is a pure no-op (no span
        either -- the caller asked for nothing); an unavailable
        profiler degrades to the plain span."""
        if not profile_dir:
            yield
            return
        started = False
        try:
            import jax
            jax.profiler.start_trace(profile_dir)
            started = True
        except Exception:
            started = False
        try:
            with self.span("jax_profile", cat="profiler",
                           dir=profile_dir):
                yield
        finally:
            if started:
                import jax
                jax.profiler.stop_trace()

    # ------------------------------------------------------------------
    def to_records(self) -> List[dict]:
        """The raw span/instant dicts (JSON-safe; args stringified)."""
        return [{**r, "args": {k: _safe(v)
                               for k, v in r["args"].items()}}
                for r in self.records]

    def export(self, path: str) -> str:
        """Write Chrome trace-event JSON (Perfetto-loadable); returns
        ``path``.  Spans map to "X" complete events on one pid/tid so
        the viewer reconstructs the nesting from ts/dur containment."""
        events = []
        for r in self.to_records():
            ev = {"name": r["name"], "cat": r["cat"], "ph": r["ph"],
                  "ts": r["ts"], "pid": self._pid, "tid": 1,
                  "args": r["args"]}
            if r["ph"] == "X":
                ev["dur"] = r["dur"]
            else:
                ev["s"] = "t"       # instant scope: thread
            events.append(ev)
        blob = {"traceEvents": events, "displayTimeUnit": "ms"}
        d = os.path.dirname(os.path.abspath(path))
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(blob, f)
        return path

    def summary(self) -> str:
        """Per-span-name aggregate table over the recorded spans."""
        spans = [r for r in self.records if r["ph"] == "X"]
        if not spans:
            return "no spans recorded"
        agg = {}
        for r in spans:
            a = agg.setdefault(r["name"], [0, 0.0])
            a[0] += 1
            a[1] += r["dur"]
        # wall = top-level span time only (nested spans double-count)
        wall = sum(r["dur"] for r in spans if r["depth"] == 0) or 1.0
        lines = [f"{'span':<24} {'count':>6} {'total_ms':>10} "
                 f"{'mean_ms':>9} {'share':>6}"]
        for name, (n, tot) in sorted(agg.items(),
                                     key=lambda kv: -kv[1][1]):
            lines.append(f"{name:<24} {n:>6} {tot / 1e3:>10.2f} "
                         f"{tot / n / 1e3:>9.3f} "
                         f"{min(tot / wall, 1.0):>5.0%}")
        return "\n".join(lines)


class NullTracer:
    """The ``obs="none"`` tracer: every method is a no-op.  ``span``
    hands back one shared nullcontext, so an instrumented call site
    costs an attribute lookup and nothing else."""

    active = False
    _null = contextlib.nullcontext()

    def span(self, name: str, cat: str = "run", **args):
        return self._null

    def profile_to(self, profile_dir):
        return self._null

    def instant(self, name: str, cat: str = "run", **args):
        pass

    def to_records(self) -> List[dict]:
        return []

    def export(self, path: str):
        raise ValueError(
            "tracing is off (obs='none' builds a NullTracer); build "
            "the session with spec.obs='basic' or 'full' to record "
            "spans")

    def summary(self) -> str:
        return "tracing off (obs='none')"


def _safe(v):
    """JSON-safe arg value (numbers/strings pass, the rest reprs)."""
    return v if isinstance(v, (int, float, str, bool, type(None))) \
        else repr(v)
