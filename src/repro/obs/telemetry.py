"""The unified telemetry record: one versioned shape for *everything
a run or serving session measured about itself*.

Before PR 10 the measurement surface was fragmented: wall clock and
throughput in ``RunResult.timings``, fault-event counters in
``timings["fault"]``, bytes-on-wire in ``timings["wire"]``, serving
counters in ``ServeReport.counters``, and nothing tied them together.
:class:`Telemetry` folds them into one record:

  wall_s / steps / steps_per_sec    the run's clock and throughput
  fault                             fault-event + watchdog counters
  wire                              integer bytes-on-wire counters
  serve                             serving counters + latency stats
  series                            repro.obs per-round on-device
                                    series (loss, norms, quarantines,
                                    bytes, staleness)
  spans                             host-side SpanTracer records

``RunResult.telemetry`` and ``ServeReport.obs`` carry it; the legacy
``timings`` dict survives as a DEPRECATED alias derived from the
record (:meth:`Telemetry.to_timings`), so every pre-PR-10 consumer
keeps reading the exact keys it always read.  Counters that ride the
scan carry (fault events, bytes) are cumulative across checkpoint
resume -- the checkpoint restores them with the rest of the carried
state -- so a resumed run's record covers every round since round 0.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

# 1: initial schema -- wall/steps/throughput + fault/wire/serve
# counter sub-dicts + obs series + tracer spans
TELEMETRY_SCHEMA_VERSION = 1


def _clean(v):
    """JSON-safe: numpy arrays -> lists, numpy scalars -> python."""
    if isinstance(v, dict):
        return {k: _clean(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_clean(x) for x in v]
    if isinstance(v, np.ndarray):
        return v.tolist()
    if isinstance(v, (np.floating, np.integer)):
        return v.item()
    return v


@dataclass
class Telemetry:
    """One run's (or serving session's) unified measurement record."""
    wall_s: float = 0.0
    steps: int = 0
    steps_per_sec: float = 0.0
    fault: Optional[dict] = None    # event counters + watchdog trips
    wire: Optional[dict] = None     # integer bytes-on-wire
    serve: Optional[dict] = None    # serving counters + latency_ms
    series: Optional[dict] = None   # obs per-round series (numpy)
    spans: Optional[List[dict]] = None   # SpanTracer records
    schema_version: int = TELEMETRY_SCHEMA_VERSION

    # ------------------------------------------------------------------
    def to_timings(self) -> dict:
        """The DEPRECATED legacy ``RunResult.timings`` shape, derived
        from this record: {"wall_s", "steps_per_sec"} plus the
        historical "fault" / "wire" sub-dicts when present.  Old keys
        only -- new measurement lives on the record itself."""
        t = {"wall_s": self.wall_s,
             "steps_per_sec": self.steps_per_sec}
        if self.fault is not None:
            t["fault"] = dict(self.fault)
        if self.wire is not None:
            t["wire"] = dict(self.wire)
        return t

    def to_dict(self) -> dict:
        """JSON-safe dict (series arrays become lists)."""
        return {
            "schema_version": self.schema_version,
            "wall_s": self.wall_s,
            "steps": int(self.steps),
            "steps_per_sec": self.steps_per_sec,
            "fault": _clean(self.fault),
            "wire": _clean(self.wire),
            "serve": _clean(self.serve),
            "series": _clean(self.series),
            "spans": _clean(self.spans),
        }

    # ------------------------------------------------------------------
    @classmethod
    def from_timings(cls, timings: dict) -> "Telemetry":
        """Lift a legacy timings dict (custom mode runners still
        return one) into the unified record, preserving the
        historical sub-dicts."""
        timings = dict(timings or {})
        return cls(wall_s=float(timings.get("wall_s", 0.0)),
                   steps_per_sec=float(
                       timings.get("steps_per_sec", 0.0)),
                   fault=timings.get("fault"),
                   wire=timings.get("wire"))


def metrics_table(result) -> str:
    """A human-readable metrics + telemetry table for one RunResult
    (the ``python -m repro.obs`` renderer)."""
    tel = getattr(result, "telemetry", None) or Telemetry.from_timings(
        getattr(result, "timings", {}))
    lines = [f"spec_hash  {result.spec_hash}",
             f"git_sha    {result.git_sha}",
             f"wall_s     {tel.wall_s:.3f}",
             f"steps/sec  {tel.steps_per_sec:.1f}"]
    for k in sorted(result.metrics):
        v = result.metrics[k]
        if isinstance(v, float):
            lines.append(f"{k:<10} {v:.4f}")
    for name in ("fault", "wire", "serve"):
        d = getattr(tel, name)
        if d:
            lines.append(f"[{name}] " + "  ".join(
                f"{k}={v}" for k, v in sorted(d.items())
                if isinstance(v, (int, float))))
    if tel.series is not None:
        loss = np.asarray(tel.series["loss"])
        lines.append(f"[series] rounds={loss.shape[0]}  "
                     f"loss {loss[0]:.4f} -> {loss[-1]:.4f}  "
                     f"keys={','.join(sorted(tel.series))}")
    return "\n".join(lines)
