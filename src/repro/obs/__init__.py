"""repro.obs -- unified observability: in-scan metric taps, host-side
span tracing, and one versioned telemetry record.

Three layers (docs/ARCHITECTURE.md section 12):

  taps       ``ExperimentSpec.obs = "none" | "basic" | "full"`` rides
             the scan carry as traced lane state (like schedule /
             fault / wire), recording per-round on-device series:
             loss, exchange-stack norms, grad norms, quarantine
             counts, bytes-on-wire, staleness depth.  Observation-only
             and hash-excluded: ``obs="full"`` trajectories are
             bitwise ``obs="none"`` trajectories.
  trace      :class:`SpanTracer` host spans over build / round /
             eval / checkpoint / serving request lifecycles, exported
             as Chrome trace-event JSON (Perfetto-loadable).
             ``obs="none"`` sessions get the zero-overhead
             :class:`NullTracer`.
  telemetry  :class:`Telemetry` -- the one versioned record on
             ``RunResult.telemetry`` / ``ServeReport.obs`` folding
             wall clock, fault/wire/serve counters, obs series and
             spans; the legacy ``timings`` dict is derived from it as
             a deprecated alias.  :func:`prometheus_text` renders
             serving counters + latency histogram as Prometheus text
             exposition.

Quickstart::

    spec = ExperimentSpec(dataset="mnist", mode="devertifl",
                          obs="full", rounds=5)
    sess = Session(spec)
    res = sess.run()
    res.telemetry.series["loss"]        # [rounds] on-device series
    sess.tracer.export("trace.json")    # open in ui.perfetto.dev
    print(sess.tracer.summary())

CLI: ``python -m repro.obs --obs full --trace-out trace.json``.
"""
from repro.obs.registry import (OBS, LEVEL_BASIC, LEVEL_FULL,
                                LEVEL_NONE, ObsEntry, ObsPlan,
                                get_obs_plan, obs_names, register_obs)
from repro.obs.taps import (SERIES_KEYS, ObsImpl, make_obs_impl)
from repro.obs.trace import NullTracer, SpanTracer
from repro.obs.telemetry import (TELEMETRY_SCHEMA_VERSION, Telemetry,
                                 metrics_table)
from repro.obs.prom import LATENCY_BUCKETS_S, prometheus_text

__all__ = [
    "OBS", "LEVEL_NONE", "LEVEL_BASIC", "LEVEL_FULL",
    "ObsPlan", "ObsEntry", "get_obs_plan", "obs_names",
    "register_obs",
    "ObsImpl", "make_obs_impl", "SERIES_KEYS",
    "SpanTracer", "NullTracer",
    "Telemetry", "TELEMETRY_SCHEMA_VERSION", "metrics_table",
    "prometheus_text", "LATENCY_BUCKETS_S",
]
