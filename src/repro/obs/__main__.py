"""``python -m repro.obs`` -- run a (tiny) spec with taps + tracing
armed and render what it measured: the metrics/telemetry table, the
recorded per-round series, the span timeline, and optionally the
Chrome trace-event export and a serving Prometheus scrape.

    python -m repro.obs                              # synthetic smoke
    python -m repro.obs --obs full --rounds 5 \
        --trace-out /tmp/trace.json                  # open in Perfetto
    python -m repro.obs --serve 8 --prom             # serving metrics
    python -m repro.obs --schedule stale_k:1 --fault crash:0.2 \
        --transform int8                             # full stack
"""
from __future__ import annotations

import argparse
import sys

import numpy as np


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Run a small experiment with observability armed "
                    "and render its telemetry.")
    p.add_argument("--dataset", default="mnist")
    p.add_argument("--n-samples", type=int, default=512,
                   help="dataset size cap (small default keeps the "
                        "CLI a smoke run)")
    p.add_argument("--obs", default="full",
                   help="obs level: none | basic | full (default "
                        "full; 'none' renders only the legacy "
                        "timings)")
    p.add_argument("--rounds", type=int, default=3)
    p.add_argument("--n-clients", type=int, default=3)
    p.add_argument("--batch-size", type=int, default=32)
    p.add_argument("--schedule", default="sync")
    p.add_argument("--fault", default="none")
    p.add_argument("--transform", default="none")
    p.add_argument("--serve", type=int, default=0, metavar="N",
                   help="after training, serve N held-out entities "
                        "and include the serving telemetry")
    p.add_argument("--prom", action="store_true",
                   help="print the Prometheus text exposition for the "
                        "serving session (implies --serve 4 if "
                        "--serve not given)")
    p.add_argument("--trace-out", default=None,
                   help="write the Chrome trace-event JSON here "
                        "(load in ui.perfetto.dev)")
    p.add_argument("--profile-dir", default=None,
                   help="also capture a jax.profiler device trace "
                        "into this directory")
    return p


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    if args.prom and not args.serve:
        args.serve = 4

    from repro.api import ExperimentSpec, Session
    from repro.obs import metrics_table, prometheus_text

    spec = ExperimentSpec(
        dataset=args.dataset, mode="devertifl", obs=args.obs,
        rounds=args.rounds, n_clients=args.n_clients,
        batch_size=args.batch_size, n_samples=args.n_samples,
        schedule=args.schedule, fault=args.fault,
        transform=args.transform, eval_every=0)
    sess = Session(spec)

    with sess.tracer.profile_to(args.profile_dir):
        res = sess.run()

    print(metrics_table(res))
    tel = res.telemetry
    if tel is not None and tel.series is not None:
        print("\nper-round series")
        for k in sorted(tel.series):
            a = np.asarray(tel.series[k])
            row = a if a.ndim == 1 else a.mean(axis=1)
            print(f"  {k:<14} " + " ".join(
                f"{v:9.4f}" for v in row[:args.rounds]))

    if args.serve:
        from repro.api import ServeRequest, split_features
        lay = sess.federation.layout
        xte = np.asarray(sess.federation.xte)
        reqs = [ServeRequest(uid=f"cli-{i}", entity_id=f"e{i}",
                             slices=split_features(
                                 lay, xte[i % len(xte)]))
                for i in range(args.serve)]
        report = sess.serve(reqs)
        c = report.counters
        print(f"\nserving: {c['completed']}/{c['submitted']} "
              f"completed, p50 "
              f"{report.latency_ms.get('p50', 0.0):.2f} ms, "
              f"{report.throughput_rps:.0f} rps")
        if args.prom:
            print("\n" + prometheus_text(report), end="")

    print("\nspan timeline")
    print(sess.tracer.summary())
    if args.trace_out:
        path = sess.tracer.export(args.trace_out)
        print(f"\ntrace written: {path} (open in ui.perfetto.dev)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
