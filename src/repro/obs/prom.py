"""Prometheus text-exposition exporter for serving telemetry.

:func:`prometheus_text` renders a :class:`~repro.serving.ServeReport`
(or its ``to_dict()`` shape) as Prometheus text format 0.0.4 -- the
``# HELP`` / ``# TYPE`` / sample-line layout any Prometheus scraper or
``promtool check metrics`` accepts:

  repro_serve_submitted_total 12
  repro_serve_latency_seconds_bucket{le="0.005"} 9
  ...
  repro_serve_latency_seconds_sum 0.0421
  repro_serve_latency_seconds_count 12

Counters (``submitted``/``completed``/``rejected``/``evicted``) map to
``_total`` counter samples; level quantities (waiting, occupancy,
cache size, throughput) map to gauges; the per-request ``latency_s``
log folds into one cumulative histogram over static seconds buckets.
The exporter is a pure text renderer over an already-collected report
-- it never touches the server -- so it can run after ``serve()``
returns or inside a scrape handler wrapping a live ``server()``
session's ``report()``.
"""
from __future__ import annotations

import numpy as np

# histogram upper bounds, seconds (cumulative; +Inf appended)
LATENCY_BUCKETS_S = (0.001, 0.002, 0.005, 0.01, 0.025, 0.05, 0.1,
                     0.25, 1.0)

# ServeReport counter key -> (metric suffix, type, help)
_COUNTERS = (
    ("submitted", "submitted_total", "counter",
     "Requests submitted to the server."),
    ("completed", "completed_total", "counter",
     "Requests completed (prediction returned)."),
    ("rejected", "rejected_total", "counter",
     "Requests rejected at admission."),
    ("evicted", "evicted_total", "counter",
     "Requests evicted from slots."),
    ("steps", "steps_total", "counter",
     "Jitted serve steps executed."),
    ("step_traces", "step_traces_total", "counter",
     "Serve-step compilations (should stay 1)."),
    ("waiting", "waiting", "gauge",
     "Requests still assembling split features."),
    ("max_occupancy", "max_occupancy", "gauge",
     "Peak concurrent slot occupancy."),
    ("max_slots", "max_slots", "gauge",
     "Configured slot-pool capacity."),
)

_CACHE = (
    ("hits", "cache_hits_total", "counter",
     "Exchange-cache hits."),
    ("misses", "cache_misses_total", "counter",
     "Exchange-cache misses."),
    ("evictions", "cache_evictions_total", "counter",
     "Exchange-cache LRU evictions."),
    ("size", "cache_entries", "gauge",
     "Exchange-cache resident entries."),
)


def _num(v, default=0.0):
    try:
        return float(v)
    except (TypeError, ValueError):
        return default


def prometheus_text(report, prefix: str = "repro_serve") -> str:
    """Render a ServeReport (or its dict form) as Prometheus text
    exposition.  ``prefix`` namespaces every metric name."""
    if hasattr(report, "to_dict"):
        counters = dict(report.counters)
        cache = report.cache
        requests = report.telemetry
        thr = report.throughput_rps
    else:
        counters = dict(report.get("counters", {}))
        cache = report.get("cache")
        requests = report.get("telemetry", [])
        thr = report.get("throughput_rps", 0.0)

    lines = []

    def emit(suffix, mtype, help_, value, labels=""):
        name = f"{prefix}_{suffix}"
        lines.append(f"# HELP {name} {help_}")
        lines.append(f"# TYPE {name} {mtype}")
        lines.append(f"{name}{labels} {_fmt(value)}")

    for key, suffix, mtype, help_ in _COUNTERS:
        if key in counters:
            emit(suffix, mtype, help_, _num(counters[key]))
    emit("throughput_rps", "gauge",
         "Completed requests per wall-clock second.", _num(thr))
    if cache:
        for key, suffix, mtype, help_ in _CACHE:
            if key in cache:
                emit(suffix, mtype, help_, _num(cache[key]))

    # latency histogram: cumulative buckets over the request log
    lat = np.asarray([_num(t.get("latency_s"))
                      for t in requests if "latency_s" in t])
    name = f"{prefix}_latency_seconds"
    lines.append(f"# HELP {name} Request latency, submit to "
                 f"complete.")
    lines.append(f"# TYPE {name} histogram")
    for le in LATENCY_BUCKETS_S:
        n = int((lat <= le).sum()) if lat.size else 0
        lines.append(f'{name}_bucket{{le="{_fmt(le)}"}} {n}')
    lines.append(f'{name}_bucket{{le="+Inf"}} {lat.size}')
    lines.append(f"{name}_sum {_fmt(float(lat.sum()) if lat.size else 0.0)}")
    lines.append(f"{name}_count {lat.size}")
    return "\n".join(lines) + "\n"


def _fmt(v) -> str:
    """Prometheus sample value: integers bare, floats repr'd."""
    f = float(v)
    return str(int(f)) if f == int(f) else repr(f)
