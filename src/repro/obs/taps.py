"""In-scan metric taps: a wrapper impl that rides the schedule
four-hook contract and records per-round series ON DEVICE, in the
scan carry -- no host sync per step, no retrace, ``round_traces == 1``
preserved, and the obs level is a vmappable sweep lane axis exactly
like staleness depth, fault rate and wire transform.

:class:`ObsImpl` wraps any resolved schedule / fault / wire impl
(literal sync is handed over as a depth-0
:class:`~repro.schedule.LaneScheduleImpl`) and sits OUTERMOST in the
engine chain -- ``schedule -> fault -> wire -> obs`` -- so it observes
exactly what the inner machinery releases:

  select(state, h_now):
      h_ref, inner = inner.select(inner_state, h_now)
      record ||h_ref||_2 per client      # the released stack's norms

plus a fifth, optional hook the step builder drives AFTER the
optimizer update (``make_sched_step_fn``):

  tap_step(state, losses, grads, lay) -> state
      accumulate the masked-mean loss and per-client gradient norms

The taps are strictly read-only: every value they record is one the
round already computed, and nothing they write feeds back into
params, the exchange, or the key streams -- which is why
``obs="full"`` trajectories are BITWISE ``obs="none"`` trajectories
(tests/test_obs.py pins it) and why ``obs`` is excluded from
spec_hash.  Level gates (``tap_on`` for basic+, ``full_on`` for the
per-client series) ride the carried state as traced scalars; lanes
with different levels share one trace, and a "none" lane records
exact zeros.  ``round_end`` folds the round's accumulators -- and the
inner layers' cumulative counters (guard quarantines, encoded bytes,
staleness depth), found by walking the statically-nested ``"inner"``
chain -- into per-round series arrays via
``dynamic_update_index_in_dim``; ``obs_series`` surfaces them as
numpy on the host.  Recorded values cross to the host through the
declared ``obs`` channel tag, so the taint auditor sees the series
egress as a declared declassification, not a leak.
"""
from __future__ import annotations

import inspect

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.barrier import tag

# obs_series key -> carried series slot (all [rounds] or [rounds, n])
SERIES_KEYS = ("loss", "exchange_norm", "grad_norm", "quarantined",
               "encoded_bytes", "staleness")


def _find(state, key):
    """Walk the statically-nested impl state (outer dict, then its
    ``"inner"`` chain) for a carried slot.  The nesting is static
    under trace, so this is a Python-time lookup; None when no layer
    carries the slot (e.g. no fault plan -> no quarantine counter)."""
    while isinstance(state, dict):
        if key in state:
            return state[key]
        state = state.get("inner")
    return None


class ObsImpl:
    """Metric taps layered over an inner schedule/fault/wire impl,
    carried as traced scan state.  Per-lane level gates select what is
    recorded inside one trace; ``rounds`` (static) sizes the series."""

    def __init__(self, plan, inner, n_clients, batch_size, width,
                 rounds):
        if rounds < 1:
            raise ValueError(f"rounds must be >= 1, got {rounds}")
        self.plan = plan
        self.inner = inner
        self.n_clients = int(n_clients)
        self.batch_size = int(batch_size)
        self.width = int(width)
        self.rounds = int(rounds)
        # compile-time level bound: tap work ABOVE this level is not
        # even traced (a basic-only session never computes stack or
        # grad norms -- multiplying by a zero gate would still pay
        # for them).  Sweeps stacking mixed levels build the impl at
        # the max stacked level, so the traced gates below still
        # select per lane inside the one shared trace.
        self.static_level = int(plan.level)
        # WireImpl.init_state takes plan= and wire=; FaultImpl's takes
        # plan=; LaneScheduleImpl's takes neither
        self._inner_kws = {
            k for k in ("plan", "wire")
            if k in inspect.signature(inner.init_state).parameters}

    def init_state(self, sched, plan=None, wire=None, obs=None):
        obs = self.plan if obs is None else obs
        if obs.custom is not None:
            raise ValueError(
                f"custom obs plan {obs.spec!r} cannot ride an obs "
                "lane state; it provides its own impl")
        if obs.level > self.static_level:
            raise ValueError(
                f"obs level {obs.spec!r} exceeds the level this impl "
                f"was compiled for ({self.plan.spec!r}); build the "
                "impl from the highest stacked level")
        kw = {}
        for name, val in (("plan", plan), ("wire", wire)):
            if val is not None:
                if name not in self._inner_kws:
                    raise ValueError(
                        f"{name}= given but the inner impl's "
                        f"init_state does not take it")
                kw[name] = val
        n, R = self.n_clients, self.rounds
        return {
            "inner": self.inner.init_state(sched, **kw),
            # traced level gates (lane axis; explicit dtypes keep the
            # retrace lint quiet and lane jaxprs identical)
            "tap_on": jnp.asarray(
                1.0 if obs.level >= 1 else 0.0, jnp.float32),
            "full_on": jnp.asarray(
                1.0 if obs.level >= 2 else 0.0, jnp.float32),
            # current round index (round_start stores it; round_end
            # writes the series row)
            "o_round": jnp.zeros((), jnp.int32),
            # per-round accumulators, zeroed every round_start
            # (aggregate scalars, excluded from the per-slot contract
            # like the loss stream)
            "o_loss": jnp.zeros((), jnp.float32),
            "o_steps": jnp.zeros((), jnp.float32),
            "o_exn": jnp.zeros((n,), jnp.float32),
            "o_gn": jnp.zeros((n,), jnp.float32),
            # per-round series (the obs_series payload)
            "s_loss": jnp.zeros((R,), jnp.float32),
            "s_exn": jnp.zeros((R, n), jnp.float32),
            "s_gn": jnp.zeros((R, n), jnp.float32),
            "s_quar": jnp.zeros((R,), jnp.int32),
            "s_bytes": jnp.zeros((R,), jnp.int32),
            "s_stale": jnp.zeros((R,), jnp.int32),
        }

    def round_start(self, state, lay, key, round_idx):
        # the inner engine sees the untouched round key, so its
        # participation/fault/wire streams are bit-for-bit the
        # obs-free ones
        inner, eff = self.inner.round_start(state["inner"], lay, key,
                                            round_idx)
        z = jnp.zeros_like
        state = {**state, "inner": inner,
                 "o_round": round_idx.astype(jnp.int32),
                 "o_loss": z(state["o_loss"]),
                 "o_steps": z(state["o_steps"]),
                 "o_exn": z(state["o_exn"]),
                 "o_gn": z(state["o_gn"])}
        return state, eff

    def select(self, state, h_now):
        st = dict(state)
        h_ref, st["inner"] = self.inner.select(st["inner"], h_now)
        # per-client L2 norm of the RELEASED stack (post-wire,
        # post-schedule): what actually crossed to peers this step.
        # Recording it is a declared declassification -- the norms
        # leave the exchange flow for the host-readable series
        if self.static_level >= 2:
            exn = tag(jnp.sqrt((h_ref * h_ref).sum(axis=(1, 2))),
                      "declass", "obs")
            st["o_exn"] = st["o_exn"] + st["full_on"] * exn
        return h_ref, st

    def tap_step(self, state, losses, grads, lay):
        """The fifth (optional) hook: called by the step builder once
        per optimizer step, AFTER the update, with the per-client loss
        vector and gradient pytree the step already computed.  Pure
        recording -- the returned state differs only in accumulators.
        """
        st = dict(state)
        m = lay.client_mask
        loss = (losses * m).sum() / jnp.maximum(m.sum(), 1.0)
        st["o_loss"] = st["o_loss"] + st["tap_on"] * \
            tag(loss, "declass", "obs")
        st["o_steps"] = st["o_steps"] + st["tap_on"]
        if self.static_level >= 2:
            gn2 = sum((g.reshape(g.shape[0], -1) ** 2).sum(axis=1)
                      for g in jax.tree.leaves(grads))
            st["o_gn"] = st["o_gn"] + st["full_on"] * \
                tag(jnp.sqrt(gn2), "declass", "obs")
        return st

    def round_end(self, state):
        st = dict(state)
        # inner FIRST: the fault layer folds this round's quarantine
        # events into its cumulative counter in round_end, and the
        # series row must include them
        st["inner"] = self.inner.round_end(st["inner"])
        r = jnp.clip(st["o_round"], 0, self.rounds - 1)
        steps = jnp.maximum(st["o_steps"], 1.0)
        on = st["tap_on"] > 0

        def put(series, val):
            return jax.lax.dynamic_update_index_in_dim(
                series, val.astype(series.dtype), r, axis=0)

        st["s_loss"] = put(st["s_loss"], st["o_loss"] / steps)
        st["s_exn"] = put(st["s_exn"], st["o_exn"] / steps)
        st["s_gn"] = put(st["s_gn"], st["o_gn"] / steps)
        # inner layers' cumulative counters, read from the statically
        # nested carry: absent layers record zeros
        for skey, ikey in (("s_quar", "quar_events"),
                           ("s_bytes", "enc_bytes")):
            v = _find(st["inner"], ikey)
            v = jnp.zeros((), jnp.int32) if v is None else v
            st[skey] = put(st[skey], jnp.where(on, v, 0))
        k = _find(st["inner"], "k")     # staleness depth (ring lanes)
        k = jnp.zeros((), jnp.int32) if k is None else k
        st["s_stale"] = put(st["s_stale"], jnp.where(on, k, 0))
        return st

    @property
    def identity_select(self):
        """The taps only READ ``h_ref``; whether select is statically
        the identity is the inner engine's property.  When it is
        (depth-0 sync under obs alone), the step builder takes its
        single-forward fast path and still calls select for the
        recorders."""
        return getattr(self.inner, "identity_select", False)

    # ------------------------------------------------------------------
    # pass-through hooks: the obs layer is observation-only, so the
    # inner machinery's aggregation mask and telemetry surface
    # unchanged through the outermost wrapper
    def fedavg_mask(self, state, eff_mask):
        fam = getattr(self.inner, "fedavg_mask", None)
        return eff_mask if fam is None else fam(state["inner"],
                                                eff_mask)

    def telemetry(self, state):
        tel = getattr(self.inner, "telemetry", None)
        return None if tel is None else tel(state["inner"])

    def wire_telemetry(self, state):
        tel = getattr(self.inner, "wire_telemetry", None)
        return None if tel is None else tel(state["inner"])

    # ------------------------------------------------------------------
    def obs_series(self, state):
        """The recorded per-round series from a (possibly
        lane-batched) carried state, as numpy arrays keyed by
        :data:`SERIES_KEYS`."""
        return {"loss": np.asarray(state["s_loss"]),
                "exchange_norm": np.asarray(state["s_exn"]),
                "grad_norm": np.asarray(state["s_gn"]),
                "quarantined": np.asarray(state["s_quar"]),
                "encoded_bytes": np.asarray(state["s_bytes"]),
                "staleness": np.asarray(state["s_stale"])}


def make_obs_impl(plan, inner, n_clients, batch_size, width, rounds):
    """Build the obs layer for a parsed ObsPlan over a resolved
    schedule/fault/wire impl.  Custom plans delegate to their
    registered factory."""
    if plan.custom is not None:
        _, make, args = plan.custom
        return make(inner=inner, n_clients=n_clients,
                    batch_size=batch_size, width=width, rounds=rounds,
                    args=args)
    return ObsImpl(plan, inner, n_clients, batch_size, width, rounds)
