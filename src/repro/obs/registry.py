"""The obs-level registry: how much the federation records about
itself while it trains.

An obs level is named by a compact spec string parsed against the
``OBS`` registry into a frozen :class:`ObsPlan` record:

  none    no in-scan taps; the engine runs its untouched code path,
          bit-for-bit (the protocol never wraps the engine impl for
          it), the host tracer is a no-op NullTracer, and the spec
          hash is unchanged -- ``obs`` lives in ``HASH_EXCLUDE``
          because taps provably never change trajectories.
  basic   cheap per-round series recorded on device in the scan carry:
          masked-mean loss, guard-quarantine counts, bytes-on-wire,
          staleness depth.  The host span tracer is armed.
  full    everything basic records plus the per-client series: L2
          norms of the released exchange stacks and per-client
          gradient norms.

Levels are observation-only: the taps read values the round already
computes and write them into carried series arrays -- no training
value is ever touched, so ``obs="full"`` trajectories are bitwise
``obs="none"`` trajectories (tests/test_obs.py pins it).  Levels ride
the padded sweep as a traced lane axis exactly like staleness depth,
fault rate and wire transforms: the level gates are per-lane scalars
in the carried state, so obs x transform x fault x schedule x count
grids compile once.  Custom obs impls register via
:func:`register_obs` and, like custom schedules, are refused in
multi-obs sweep lanes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Tuple

from repro.registry import Registry

OBS = Registry("obs")

# level numbers (what the traced gates derive from)
LEVEL_NONE, LEVEL_BASIC, LEVEL_FULL = 0, 1, 2


@dataclass(frozen=True)
class ObsPlan:
    """Parsed, canonical obs plan.  ``spec`` is the canonical string
    -- the identity checkpoint stamps and sweep cell keys use (never
    spec_hash: obs is hash-excluded)."""
    spec: str
    level: int = LEVEL_NONE
    custom: Optional[Tuple] = None      # (name, make_factory, args)

    @property
    def is_none(self) -> bool:
        """True only for the literal "none" plan -- the engine keeps
        its tap-free code path for it.  A "none" LANE inside an obs
        sweep runs the obs engine with the gates traced to 0 and is
        proven bitwise-equal by test, not aliased."""
        return self.level == LEVEL_NONE and self.custom is None


@dataclass(frozen=True)
class ObsEntry:
    """Registry entry: ``parse(args) -> dict`` of ObsPlan field
    updates for built-ins; ``make`` is the custom impl factory."""
    name: str
    parse: Callable
    make: Optional[Callable] = None


def _parse_level(level):
    def parse(args, _level=level):
        if args:
            raise ValueError(
                f"obs levels take no arguments, got {args}")
        return {"level": _level}
    return parse


OBS.register("none", ObsEntry("none", _parse_level(LEVEL_NONE)))
OBS.register("basic", ObsEntry("basic", _parse_level(LEVEL_BASIC)))
OBS.register("full", ObsEntry("full", _parse_level(LEVEL_FULL)))


def register_obs(name, make, overwrite=False) -> ObsEntry:
    """Register a custom obs impl for ``ExperimentSpec.obs = name``
    (or ``"name:arg1:arg2"``).

    ``make(inner, n_clients, batch_size, width, rounds, args)`` must
    return an impl providing the schedule four-hook contract
    (docs/ARCHITECTURE.md section 12); ``inner`` is the resolved
    schedule/fault/wire impl the obs layer wraps (never None --
    literal sync is handed over as a depth-0 ring impl).  The impl
    may additionally provide the ``tap_step`` / ``obs_series`` hooks
    and must forward ``fedavg_mask`` / ``telemetry`` /
    ``wire_telemetry`` to its inner impl.

    Custom obs plans run devertifl-mode federations only and are
    refused in multi-obs sweep lanes (same constraint as custom
    schedules)."""
    def parse(args, _name=name, _make=make):
        return {"custom": (_name, _make, tuple(args))}

    return OBS.register(name, ObsEntry(name, parse, make),
                        overwrite=overwrite)


def obs_names() -> list:
    """Registered obs level names."""
    return OBS.names()


def get_obs_plan(spec) -> ObsPlan:
    """Parse an obs spec string (or pass an ObsPlan through) into the
    canonical :class:`ObsPlan` record.  Unknown names raise with the
    registered options listed."""
    if isinstance(spec, ObsPlan):
        return spec
    text = str(spec).strip()
    if not text:
        raise ValueError("malformed obs spec '' (empty)")
    name, *args = text.split(":")
    entry = OBS.get(name)           # unknown names raise w/ options
    fields = entry.parse(args)
    custom = fields.get("custom")
    canon = text if custom else name
    return ObsPlan(spec=canon, **fields)
