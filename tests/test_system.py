"""End-to-end system tests: training reduces loss on learnable data,
checkpoint resume is exact, serving decodes, and the benchmark/ dry-run
plumbing functions."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.configs.reduced import reduced_config
from repro.data import markov_lm_batches
from repro.launch.serve import make_serve_step
from repro.launch.train import make_train_step
from repro.models import build_model
from repro.optim import adam

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _train(cfg, steps, seed=0, params=None, opt_state=None, start=0):
    model = build_model(cfg)
    opt = adam(3e-3)
    if params is None:
        params = model.init(jax.random.PRNGKey(seed))
        opt_state = opt.init(params)
    fn = jax.jit(make_train_step(model, opt))
    it = markov_lm_batches(cfg.vocab_size, 4, 64, seed=seed)
    batches = [next(it) for _ in range(steps)]
    step = jnp.asarray(start, jnp.int32)
    losses = []
    for i in range(start, steps):
        b = {k: jnp.asarray(v) for k, v in batches[i].items()}
        params, opt_state, step, m = fn(params, opt_state, step, b)
        losses.append(float(m["loss"]))
    return params, opt_state, losses, model


def test_lm_training_learns():
    cfg = reduced_config("qwen1.5-0.5b", vocab_size=256)
    _, _, losses, _ = _train(cfg, 30)
    assert losses[-1] < losses[0] - 0.5, losses[::10]
    assert losses[-1] < np.log(256)  # better than uniform


def test_checkpoint_resume_exact():
    """Stop at step k, save, restore, continue: identical final params
    to an uninterrupted run (determinism + checkpoint fidelity)."""
    cfg = reduced_config("qwen1.5-0.5b", vocab_size=128)
    model = build_model(cfg)
    opt = adam(1e-3)
    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    fn = jax.jit(make_train_step(model, opt))
    it = markov_lm_batches(cfg.vocab_size, 2, 32, seed=3)
    batches = [{k: jnp.asarray(v) for k, v in next(it).items()}
               for _ in range(8)]

    # continuous run
    p1, s1 = params, opt_state
    step = jnp.zeros((), jnp.int32)
    for b in batches:
        p1, s1, step, _ = fn(p1, s1, step, b)

    # interrupted run with checkpoint at step 4
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        p2, s2 = params, opt_state
        step = jnp.zeros((), jnp.int32)
        for b in batches[:4]:
            p2, s2, step, _ = fn(p2, s2, step, b)
        save_checkpoint(d, 4, {"params": p2, "opt": s2})
        restored = load_checkpoint(d, 4, {"params": p2, "opt": s2})
        p2, s2 = restored["params"], restored["opt"]
        step = jnp.asarray(4, jnp.int32)
        for b in batches[4:]:
            p2, s2, step, _ = fn(p2, s2, step, b)

    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_serve_step_autoregressive():
    cfg = reduced_config("gemma2-2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    state = model.init_decode_state(2, 16)
    fn = jax.jit(make_serve_step(model), donate_argnums=(1,))
    toks = jnp.zeros((2, 1), jnp.int32)
    seen = []
    for _ in range(5):
        toks, state = fn(params, state, toks)
        assert toks.shape == (2, 1)
        seen.append(int(toks[0, 0]))
    assert all(0 <= t < cfg.vocab_size for t in seen)
    assert int(state["position"][0]) == 5


def test_input_specs_cover_all_pairs():
    """input_specs builds for every (arch, shape) without allocation."""
    from repro.launch.dryrun import ARCHS, SHAPES, skip_reason
    from repro.launch.specs import input_specs
    from repro.configs import INPUT_SHAPES
    n = 0
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES:
            if skip_reason(cfg, shape):
                continue
            spec = input_specs(cfg, shape)
            s = INPUT_SHAPES[shape]
            if s.kind == "decode":
                assert spec["tokens"].shape == (s.global_batch, 1)
            else:
                total = spec["tokens"].shape[1] + (
                    spec["prefix_emb"].shape[1]
                    if "prefix_emb" in spec and cfg.modality ==
                    "vision_text" else 0)
                assert total == s.seq_len
            n += 1
    assert n >= 30


def test_dryrun_records_complete():
    """Every (arch x shape x mesh) has a dry-run record and none
    errored (the multi-pod deliverable)."""
    d = os.path.join(REPO, "benchmarks", "results", "dryrun")
    if not os.path.isdir(d) or len(os.listdir(d)) < 80:
        pytest.skip("dry-run sweep not yet complete")
    from repro.launch.dryrun import ARCHS, SHAPES
    for arch in ARCHS:
        for shape in SHAPES:
            for mesh in ("16x16", "2x16x16"):
                path = os.path.join(
                    d, f"{arch}__{shape}__{mesh}__zeropad_psum.json")
                assert os.path.exists(path), path
                with open(path) as f:
                    rec = json.load(f)
                assert rec["status"] in ("ok", "skipped"), \
                    f"{path}: {rec.get('error')}"
                if rec["status"] == "ok":
                    assert rec["roofline"]["bound_s"] > 0


def test_train_driver_cli():
    """The launch/train.py driver runs end-to-end (reduced config)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch",
         "qwen1.5-0.5b", "--reduced", "--steps", "3", "--batch", "2",
         "--seq", "32"],
        capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "done" in r.stdout


def test_serve_driver_cli():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch",
         "gemma2-2b", "--reduced", "--steps", "4", "--batch", "2",
         "--cache", "16"],
        capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "tok/s" in r.stdout


def test_swa_variant_configs_registered():
    cfg = get_config("qwen2-7b-swa")
    assert cfg.sub_quadratic_decode and cfg.window_size == 4096
    base = get_config("qwen2-7b")
    assert base.attn_type == "full"  # assigned config untouched
