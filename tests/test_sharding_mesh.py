"""Sharding tests that need multiple devices: run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main test
process (and every other test) keeps seeing the single real CPU device.

Validates:
  * zeropad_psum == allgather == no-mesh embedding (the De-VertiFL
    exchange's two implementations agree with the centralized oracle)
  * param_specs produce loadable shardings for a reduced model
  * the federated train step (pod-axis FedAvg) runs and syncs replicas
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_in_subprocess(body: str):
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import jax.numpy as jnp
        import numpy as np
        assert jax.device_count() == 8, jax.devices()
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_exchange_modes_agree_with_centralized():
    run_in_subprocess("""
        from repro import sharding as sh
        from repro.configs.reduced import reduced_config
        from repro.models import build_model

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = reduced_config("qwen1.5-0.5b")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        B, S = 4, 16
        tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                    cfg.vocab_size)
        batch = {"tokens": tokens, "labels": tokens}

        # centralized oracle: no mesh
        ref, _ = jax.jit(model.forward_logits)(params, batch)

        outs = {}
        for mode in ("zeropad_psum", "allgather"):
            cfg2 = cfg.replace(vfl=cfg.vfl.__class__(enabled=True,
                                                     exchange=mode))
            model2 = build_model(cfg2)
            with sh.use_context(mesh):
                out, _ = jax.jit(model2.forward_logits)(params, batch)
            outs[mode] = np.asarray(out, np.float32)
        ref = np.asarray(ref, np.float32)
        np.testing.assert_allclose(outs["zeropad_psum"], ref,
                                   atol=2e-3, rtol=2e-3)
        np.testing.assert_allclose(outs["allgather"], ref,
                                   atol=2e-3, rtol=2e-3)
        print("exchange modes agree")
    """)


def test_param_specs_shard_and_run():
    run_in_subprocess("""
        from repro import sharding as sh
        from repro.configs.reduced import reduced_config
        from repro.models import build_model
        from repro.optim import adam
        from repro.launch.train import make_train_step, shardings_for_train
        from repro.launch import specs as SP

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = reduced_config("deepseek-moe-16b")
        with sh.use_context(mesh):
            model = build_model(cfg)
            opt = adam(1e-3)
            B, S = 4, 32
            batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                     "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
            (ps, os_, _, bs), pshape, oshape = shardings_for_train(
                model, opt, batch, mesh)
            params = model.init(jax.random.PRNGKey(0))
            params = jax.device_put(params, ps)
            opt_state = jax.device_put(opt.init(params), os_)
            tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                        cfg.vocab_size)
            real = jax.device_put({"tokens": tokens, "labels": tokens}, bs)
            fn = jax.jit(make_train_step(model, opt),
                         in_shardings=(ps, os_, None, bs),
                         donate_argnums=(0, 1))
            params, opt_state, step, m = fn(params, opt_state,
                                            jnp.zeros((), jnp.int32), real)
            assert np.isfinite(float(m["loss"]))
            print("sharded train step ok, loss", float(m["loss"]))
    """)


def test_federated_pod_fedavg_syncs_replicas():
    run_in_subprocess("""
        from repro import sharding as sh
        from repro.configs.reduced import reduced_config
        from repro.models import build_model
        from repro.optim import adam
        from repro.launch.train import make_federated_train_step

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = reduced_config("qwen1.5-0.5b")
        n_pods = 2
        with sh.use_context(mesh):
            model = build_model(cfg)
            opt = adam(1e-3)
            keys = jax.random.split(jax.random.PRNGKey(0), n_pods)
            params_f = jax.vmap(model.init)(keys)   # distinct replicas
            opt_f = jax.vmap(opt.init)(params_f)
            step_fn = jax.jit(make_federated_train_step(
                model, opt, n_pods, fedavg_every=2))
            B, S = 4, 16
            toks = jax.random.randint(jax.random.PRNGKey(1),
                                      (n_pods, B, S), 0, cfg.vocab_size)
            batch_f = {"tokens": toks, "labels": toks}
            step = jnp.zeros((), jnp.int32)
            # step 0: no sync -> replicas differ; step 1: FedAvg -> equal
            params_f, opt_f, step, m = step_fn(params_f, opt_f, step,
                                               batch_f)
            leaf = jax.tree.leaves(params_f)[0]
            diff0 = float(jnp.abs(leaf[0] - leaf[1]).max())
            params_f, opt_f, step, m = step_fn(params_f, opt_f, step,
                                               batch_f)
            leaf = jax.tree.leaves(params_f)[0]
            diff1 = float(jnp.abs(leaf[0] - leaf[1]).max())
            assert diff0 > 0, "replicas should differ before FedAvg"
            assert diff1 < 1e-6, f"FedAvg must sync replicas ({diff1})"
            print("federated rounds ok", diff0, diff1)
    """)


def test_constrain_dedup_and_divisibility():
    run_in_subprocess("""
        from repro import sharding as sh
        from jax.sharding import PartitionSpec as P

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with sh.use_context(mesh):
            # batch=1 -> batch axes dropped; kv_seq picks up all axes
            x = jnp.zeros((1, 64, 4, 8))
            y = sh.constrain(x, "batch", "kv_seq", "heads", None)
            spec = y.sharding.spec
            assert spec[0] is None, spec
            # kv_seq got data+model (dedup'd against the empty batch)
            flat = []
            for e in spec:
                if isinstance(e, tuple): flat += list(e)
                elif e: flat.append(e)
            assert flat.count("data") <= 1 and flat.count("model") <= 1
            print("constrain spec:", spec)
    """)
