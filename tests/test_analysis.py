"""The repro.analysis static auditor: taint privacy flow (a planted
leaky first layer MUST be flagged with its equation chain; the shipped
lanes MUST be clean), padded-lane deadness over n_real=1 lanes /
stale_k ring buffers / partial masks, the retrace-hazard linter's
static ``round_traces == 1`` claim, the shared ir helpers the roofline
parsers now consume, the waiver machinery, and the CLI lane."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import audit, audit_tracing, tag
from repro.analysis import ir
from repro.analysis import report as report_mod
from repro.analysis import taint as taint_mod
from repro.analysis.audit import TracedRound, audit_combos, combo_name
from repro.analysis.report import (AnalysisReport, Finding, Waiver,
                                   apply_waivers)
from repro.core.protocol import ProtocolConfig, register_first_layer

TRACE = dict(n_samples=32, batch_size=16, epochs=1, rounds=1)


def _pcfg(**kw):
    base = dict(mode="devertifl", schedule="sync", first_layer="masked",
                n_clients=3)
    base.update(kw)
    return ProtocolConfig(**base)


# ---------------------------------------------------------------------------
# ir helpers (shared with the roofline parsers)
# ---------------------------------------------------------------------------
@pytest.mark.fast
def test_ir_hlo_helpers():
    assert ir.parse_shapes("f32[8,128]") == [("f32", "8,128")]
    assert ir.shape_elems("") == 1 and ir.shape_elems("3,4") == 12
    assert ir.shape_bytes("bf16", "8,128") == 8 * 128 * 2
    assert ir.bytes_of("(f32[2,2], s32[3])") == 16 + 12


@pytest.mark.fast
def test_roofline_consumes_ir_helpers():
    # single source of truth: the roofline modules import, not copy
    from repro.roofline import analysis as ra
    from repro.roofline import hlo_costs as hc
    assert ra._shape_bytes is ir.shape_bytes
    assert ra._SHAPE_RE is ir.SHAPE_RE
    assert hc._bytes_of is ir.bytes_of
    assert hc._parse_shapes is ir.parse_shapes


@pytest.mark.fast
def test_ir_all_eqns_walks_subjaxprs():
    def f(x):
        return jax.lax.scan(lambda c, _: (c * 2.0, c), x,
                            None, length=3)[0]
    jx = jax.make_jaxpr(f)(1.0)
    prims = {e.primitive.name for _, e in ir.all_eqns(jx.jaxpr)}
    assert "scan" in prims and "mul" in prims


# ---------------------------------------------------------------------------
# barrier tags
# ---------------------------------------------------------------------------
@pytest.mark.fast
def test_tag_identity_and_audit_gating():
    x = jnp.ones((3, 2))
    # outside an audit trace the tag is a no-op and leaves no IR
    np.testing.assert_array_equal(tag(x, "term", "exchange"), x)
    jx = jax.make_jaxpr(lambda v: tag(v, "term", "exchange"))(x)
    assert "repro_audit_tag" not in str(jx)
    with audit_tracing():
        jx = jax.make_jaxpr(lambda v: tag(v, "term", "exchange"))(x)
    assert "repro_audit_tag" in str(jx)
    # and the primitive itself stays an identity
    with audit_tracing():
        np.testing.assert_array_equal(
            jax.jit(lambda v: tag(v, "term", "exchange"))(x), x)


# ---------------------------------------------------------------------------
# taint lattice
# ---------------------------------------------------------------------------
@pytest.mark.fast
def test_taint_join_and_collapse():
    u = taint_mod.uniform(0b101)
    p = taint_mod.perslot(0, np.array([1, 2, 4], np.int64))
    assert taint_mod.collapse(p) == 0b111
    j = taint_mod.join(u, p)
    assert taint_mod.collapse(j) & 0b101 == 0b101
    same = taint_mod.join(p, taint_mod.perslot(
        0, np.array([2, 2, 2], np.int64)))
    assert same.axis == 0
    assert list(same.bits) == [3, 2, 6]


# ---------------------------------------------------------------------------
# report / waivers
# ---------------------------------------------------------------------------
@pytest.mark.fast
def test_report_waivers_and_roundtrip():
    f1 = Finding("taint", "cross-client-flow", "devertifl/sync/slice",
                 "leak")
    f2 = Finding("retrace", "captured-weak-scalar", "verticomb/sync/x",
                 "scalar")
    report_mod.WAIVERS.append(
        Waiver("taint", "cross-client-flow", "devertifl/*",
               "pinned: test"))
    try:
        waived = apply_waivers([f1, f2])
    finally:
        report_mod.WAIVERS.pop()
    assert waived[0].waived and not waived[1].waived
    rep = AnalysisReport(combos=("devertifl/sync/slice",),
                         findings=tuple(waived),
                         channels={"exchange": 2},
                         static_round_traces=1,
                         passes_run=("taint", "retrace"))
    assert [f.code for f in rep.violations] == ["captured-weak-scalar"]
    assert not rep.ok
    d = json.loads(rep.to_json())
    assert d["static_round_traces"] == 1
    assert d["findings"][0]["waived"] == "pinned: test"


# ---------------------------------------------------------------------------
# the planted leak: raw features crossing clients OUTSIDE the channels
# ---------------------------------------------------------------------------
def _make_leaky(model, pcfg, layout):
    sizes = layout.sizes

    def first(params, xb, lay):
        w = params["layer_0"]["kernel"]
        b = params["layer_0"]["bias"]
        outs = []
        for i, f_i in enumerate(sizes):
            x_i = jax.lax.dynamic_slice(
                xb, (0, lay.offsets[i]), (xb.shape[0], f_i))
            w_i = jax.lax.dynamic_slice(
                w[i], (lay.offsets[i], 0), (f_i, w.shape[-1]))
            h = jax.nn.relu(x_i @ w_i + b[i])
            # THE LEAK: every client's hidden sees the whole raw batch
            outs.append(h + xb.mean())
        return jnp.stack(outs)
    return first


def test_leaky_first_layer_is_flagged_with_chain():
    from repro.core.protocol import FIRST_LAYERS
    if "leaky_test" not in FIRST_LAYERS.names():
        register_first_layer("leaky_test", _make_leaky)
    rep = audit(_pcfg(first_layer="leaky_test"), passes=("taint",))
    vio = [f for f in rep.violations if f.code == "cross-client-flow"]
    assert vio, "planted leak was not flagged"
    # the offending-flow chain must trace back into the leaky first
    # layer (this file), not just name the output
    chained = "\n".join(c for f in vio for c in f.chain)
    assert "test_analysis.py" in chained
    # ... and the clean reference lane stays clean under the same run
    clean = audit(_pcfg(first_layer="masked"), passes=("taint",))
    assert not clean.violations


@pytest.mark.fast
def test_shipped_lanes_taint_clean():
    for fl in ("masked", "slice"):
        rep = audit(_pcfg(first_layer=fl), passes=("taint",))
        assert not rep.violations, rep.summary()
        assert rep.channels.get("exchange"), "exchange tags not seen"
        assert rep.channels.get("fedavg"), "fedavg tags not seen"


# ---------------------------------------------------------------------------
# deadness: padded n_real=1 lanes, stale_k ring buffers, partial masks
# ---------------------------------------------------------------------------
@pytest.mark.fast
def test_deadness_padded_single_real_lane():
    rep = audit(_pcfg(n_clients=1, max_clients=3),
                passes=("deadness",))
    assert not rep.violations, rep.summary()
    assert not any(f.code == "no-terms-observed" for f in rep.findings)


def test_deadness_schedule_buffers_and_partial_masks():
    for sched in ("stale_k:2", "partial:0.5:det"):
        rep = audit(_pcfg(n_clients=2, max_clients=4, schedule=sched),
                    passes=("deadness",))
        assert not rep.violations, (sched, rep.summary())


# ---------------------------------------------------------------------------
# retrace: the static round_traces == 1 claim
# ---------------------------------------------------------------------------
def test_retrace_static_round_traces():
    rep = audit(_pcfg(first_layer="slice"), passes=("retrace",),
                lane_check=False)
    assert not rep.violations, rep.summary()
    assert rep.static_round_traces == 1


def test_audit_combos_merges_and_stamps():
    # the default fault axis appends one hot composite plan per
    # schedule (devertifl only), after the fault-free combos; pin the
    # transform axis off here to keep the traced run small -- the
    # default transform grid arithmetic is pinned below without
    # tracing
    rep = audit_combos(modes=("devertifl",),
                       schedules=("sync", "stale_k:1"),
                       first_layers=("masked",),
                       transforms=("none",),
                       passes=("taint", "retrace"), lane_check=False)
    assert len(rep.combos) == 4
    assert sum("crash" in c for c in rep.combos) == 2
    assert not rep.violations, rep.summary()
    assert rep.static_round_traces == 1
    narrow = audit_combos(modes=("devertifl",),
                          schedules=("sync",),
                          first_layers=("masked",), faults=("none",),
                          transforms=("none",),
                          passes=("taint",), lane_check=False)
    assert len(narrow.combos) == 1


@pytest.mark.fast
def test_default_combos_transform_axis():
    # the default transform axis multiplies schedules (devertifl
    # only) and chains each hot transform with the composite fault
    # once: base 2 + fault 1x2 + wire 2x2 + chain 2x1 = 10 combos
    from repro.analysis.audit import default_combos
    combos = default_combos(modes=("devertifl",),
                            schedules=("sync", "stale_k:1"),
                            first_layers=("masked",))
    assert len(combos) == 10
    wired = [c for c in combos if c[4] != "none"]
    assert len(wired) == 6
    assert sum(c[3] != "none" for c in wired) == 2
    # non-devertifl modes never get fault or transform combos
    combos_nf = default_combos(modes=("non_federated",),
                               schedules=("sync",),
                               first_layers=("masked",))
    assert all(c[3] == "none" and c[4] == "none" for c in combos_nf)


# ---------------------------------------------------------------------------
# harness plumbing
# ---------------------------------------------------------------------------
@pytest.mark.fast
def test_traced_round_combo_and_seeds():
    tr = TracedRound(_pcfg(first_layer="slice").replace(**TRACE))
    assert combo_name(tr.pcfg) == "devertifl/sync/slice"
    seeds = tr.taint_seeds()
    assert len(seeds) == len(tr.jaxpr.jaxpr.invars)
    # per-column feature taint: every owner bit appears on the batch
    xtr_seeds = [s for s in seeds
                 if s.axis is not None and s.bits.shape[0] == 784]
    assert xtr_seeds, "xtr per-column seeding missing"
    assert int(np.bitwise_or.reduce(xtr_seeds[0].bits)) == 0b111


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
def test_cli_smoke(tmp_path, capsys):
    from repro.analysis.__main__ import main
    out = tmp_path / "report.json"
    rc = main(["--smoke", "--modes", "devertifl", "-q",
               "--out", str(out)])
    assert rc == 0
    d = json.loads(out.read_text())
    assert d["static_round_traces"] == 1
    assert d["combos"] == ["devertifl/sync/slice"]
    assert not [f for f in d["findings"]
                if f["severity"] == "error" and not f["waived"]]
