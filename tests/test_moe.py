"""MoE dispatch unit tests: lossless-capacity equivalence to a dense
reference, capacity-drop behaviour, and shared-expert contribution."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.reduced import reduced_config
from repro.models import moe as M


def dense_moe_ref(params, x, cfg):
    """Reference: run EVERY expert on every token, combine top-k."""
    B, S, D = x.shape
    T = B * S
    xf = x.reshape(T, D)
    logits = xf.astype(jnp.float32) @ params["router"]["kernel"]
    probs = jax.nn.softmax(logits, -1)
    top_w, top_idx = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    top_w = top_w / top_w.sum(-1, keepdims=True)
    h = jnp.einsum("td,edf->tef", xf, params["experts"]["w_gate"])
    u = jnp.einsum("td,edf->tef", xf, params["experts"]["w_up"])
    y_all = jnp.einsum("tef,efd->ted", jax.nn.silu(h) * u,
                       params["experts"]["w_down"])
    mask = jax.nn.one_hot(top_idx, cfg.num_experts)          # [T,k,E]
    w = (mask * top_w[..., None]).sum(1)                     # [T,E]
    y = jnp.einsum("te,ted->td", w.astype(x.dtype), y_all)
    if "shared" in params:
        from repro.models import layers as L
        y = y + L.mlp_apply(params["shared"], x, "swiglu").reshape(T, D)
    return y.reshape(B, S, D)


@pytest.mark.parametrize("arch", ["mixtral-8x22b", "deepseek-moe-16b"])
def test_moe_matches_dense_reference(arch):
    cfg = reduced_config(arch).replace(expert_capacity_factor=64.0)
    key = jax.random.PRNGKey(0)
    params = M.moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, aux = M.moe_apply(params, x, cfg)
    ref = dense_moe_ref(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=2e-4, rtol=2e-4)
    assert float(aux) >= 0


def test_moe_capacity_drops_tokens():
    """With capacity factor ~0, every token is dropped -> output is just
    the shared experts (or zero without them)."""
    cfg = reduced_config("mixtral-8x22b").replace(
        expert_capacity_factor=1e-9)
    params = M.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model))
    y, _ = M.moe_apply(params, x, cfg)
    # mixtral-reduced has no shared experts: C=1 min so SOME tokens fit;
    # norm must be well below the ample-capacity output norm
    cfg_ample = cfg.replace(expert_capacity_factor=64.0)
    y2, _ = M.moe_apply(params, x, cfg_ample)
    assert float(jnp.abs(y).sum()) < float(jnp.abs(y2).sum())


def test_moe_aux_loss_uniform_router_is_one():
    """Uniform routing probabilities give aux ~= weight (the Switch
    normalization makes balanced load = 1.0 before weighting)."""
    cfg = reduced_config("mixtral-8x22b")
    params = M.moe_init(jax.random.PRNGKey(3), cfg, jnp.float32)
    params["router"]["kernel"] = jnp.zeros_like(
        params["router"]["kernel"])  # uniform probs
    x = jax.random.normal(jax.random.PRNGKey(4), (4, 64, cfg.d_model))
    _, aux = M.moe_apply(params, x, cfg)
    assert abs(float(aux) - cfg.router_aux_weight) < 0.3 * cfg.router_aux_weight


def test_deepseek_shared_experts_always_active():
    cfg = reduced_config("deepseek-moe-16b")
    params = M.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    assert "shared" in params
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, cfg.d_model))
    y_with, _ = M.moe_apply(params, x, cfg)
    p2 = dict(params)
    p2.pop("shared")
    y_without, _ = M.moe_apply(p2, x, cfg)
    assert float(jnp.abs(y_with - y_without).max()) > 1e-4
