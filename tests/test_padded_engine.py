"""The padded client axis and the multi-count sweep engine.

Contracts pinned here (see docs/ARCHITECTURE.md):

  * Layout.pad appends dead slots that own nothing: all-zero mask
    rows, size-0 slices, client_mask 0.
  * A padded federation (n_clients=3, max_clients=8) trains its LIVE
    clients bit-for-bit identically to the unpadded run in ALL THREE
    first-layer lanes -- the exchange sum, FedAvg weighting, and loss
    means see exact-zero dead terms only.
  * A dataset x mode sweep over >= 3 client counts compiles its round
    function ONCE (round_traces == 1), and its masked lanes reproduce
    the standalone runs bit-for-bit.
  * Sharding the lane axis over the device mesh (shard_map) changes
    nothing: sharded results == single-device results.
  * vfl_matmul's gate: 1.0 is a bitwise no-op, 0.0 zeroes the output
    and BOTH cotangents (the masked dW scatter).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import partition as PT
from repro.core.exchange import fedavg, hidden_output_exchange
from repro.core.protocol import (DeVertiFL, ProtocolConfig,
                                 init_padded_params)
from repro.core.sweep import (SweepConfig, run_grid, run_padded_cells)
from repro.kernels.vfl_matmul import vfl_matmul

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# Layout.pad / LayoutArrays.client_mask
# ---------------------------------------------------------------------------
@pytest.mark.fast
def test_layout_pad_structure():
    lay = PT.make_layout("titanic", 9, 3, seed=1)
    pad = lay.pad(7)
    assert (pad.n_real, pad.n_clients) == (3, 7)
    assert pad.sizes == lay.sizes + (0,) * 4
    assert pad.offsets == lay.offsets + (0,) * 4
    assert pad.block == lay.block
    # live rows identical, dead rows all-zero
    np.testing.assert_array_equal(pad.masks()[:3], lay.masks())
    assert pad.masks()[3:].sum() == 0
    np.testing.assert_array_equal(pad.client_mask(),
                                  [1, 1, 1, 0, 0, 0, 0])
    arrs = pad.arrays()
    assert arrs.client_mask.shape == (7,)
    assert arrs.sizes.shape == (7,) and arrs.offsets.shape == (7,)
    # pad is idempotent at the same width and refuses to shrink
    assert pad.pad(7) is pad
    with pytest.raises(ValueError):
        lay.pad(2)
    # make_layout(max_clients=...) is the same padding
    pad2 = PT.make_layout("titanic", 9, 3, seed=1, max_clients=7)
    assert pad2.sizes == pad.sizes and pad2.n_real == 3


@pytest.mark.fast
def test_init_padded_params_live_prefix_matches_unpadded():
    """Live clients' init must be the unpadded derivation exactly
    (split(key, n)[:k] != split(key, k), so this is a real contract)."""
    from repro.configs import get_config
    from repro.models.mlp_model import PaperMLP
    model = PaperMLP(get_config("paper-mlp-titanic"))
    key = jax.random.PRNGKey(0)
    plain = init_padded_params(model, key, 3)
    padded = init_padded_params(model, key, 3, 8)
    for a, b in zip(jax.tree.leaves(plain), jax.tree.leaves(padded)):
        assert b.shape[0] == 8
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b[:3]))


# ---------------------------------------------------------------------------
# masked cross-client reductions
# ---------------------------------------------------------------------------
@pytest.mark.fast
def test_exchange_client_mask_drops_dead_contributions():
    h = jnp.asarray(np.random.default_rng(0).normal(
        size=(5, 4, 6)).astype(np.float32))
    cm = jnp.asarray([1, 1, 1, 0, 0], jnp.float32)
    out = hidden_output_exchange(h, client_mask=cm)
    ref = hidden_output_exchange(h[:3])
    # live rows see only live peers' sums
    np.testing.assert_array_equal(np.asarray(out[:3]), np.asarray(ref))


@pytest.mark.fast
def test_fedavg_client_mask_weighted():
    leaf = jnp.asarray(np.random.default_rng(1).normal(
        size=(5, 2, 3)).astype(np.float32))
    cm = jnp.asarray([1, 1, 1, 0, 0], jnp.float32)
    out = fedavg({"w": leaf}, client_mask=cm)["w"]
    ref = fedavg({"w": leaf[:3]})["w"]
    # dead params never dilute the mean; every slot (dead included)
    # ends synced to the live mean
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(ref[0]))
    np.testing.assert_array_equal(
        np.asarray(out), np.broadcast_to(np.asarray(out[:1]), out.shape))


# ---------------------------------------------------------------------------
# vfl_matmul gate (masked dW scatter)
# ---------------------------------------------------------------------------
@pytest.mark.fast
def test_vfl_matmul_gate():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(12, 8)).astype(np.float32))
    g = jnp.asarray(rng.normal(size=(8, 8)).astype(np.float32))

    def loss(x, w, gate):
        return (vfl_matmul(x, w, 4, gate=gate, bk=4) * g).sum()

    y_plain = vfl_matmul(x, w, 4, bk=4)
    # gate=1.0 is a bitwise no-op on y and both grads
    np.testing.assert_array_equal(
        np.asarray(vfl_matmul(x, w, 4, gate=jnp.float32(1.0), bk=4)),
        np.asarray(y_plain))
    dx1, dw1 = jax.grad(loss, argnums=(0, 1))(x, w, jnp.float32(1.0))
    dx0, dw0 = jax.grad(loss, argnums=(0, 1))(
        x, w, jnp.float32(0.0))
    dxp, dwp = jax.grad(lambda x, w: (vfl_matmul(x, w, 4, bk=4)
                                      * g).sum(), argnums=(0, 1))(x, w)
    np.testing.assert_array_equal(np.asarray(dx1), np.asarray(dxp))
    np.testing.assert_array_equal(np.asarray(dw1), np.asarray(dwp))
    # gate=0.0: y, dx, and the dW scatter rows are all exact zeros
    assert float(np.abs(np.asarray(
        vfl_matmul(x, w, 4, gate=jnp.float32(0.0), bk=4))).max()) == 0.0
    assert float(np.abs(np.asarray(dx0)).max()) == 0.0
    assert float(np.abs(np.asarray(dw0)).max()) == 0.0
    # ungated dW only ever touches the client's row block
    assert float(np.abs(np.asarray(dwp[:4])).max()) == 0.0
    assert float(np.abs(np.asarray(dwp[4:8])).max()) > 0.0


# ---------------------------------------------------------------------------
# padded federation == unpadded federation, bit for bit, all lanes
# ---------------------------------------------------------------------------
def _traj(pcfg):
    r = DeVertiFL(pcfg).train()
    return (np.concatenate([h["round_losses"] for h in r["history"]]),
            np.array([h["f1"] for h in r["history"]]),
            r["final"]["f1"])


@pytest.mark.parametrize("fl", ["masked", "slice", "pallas"])
def test_padded_federation_bitwise(fl):
    """n_clients=3 padded to max_clients=8 trains the live clients
    bit-for-bit identically to the unpadded run in every first-layer
    lane: loss trajectory, per-round F1, final F1 all exactly equal."""
    base = ProtocolConfig(dataset="titanic", n_clients=3, rounds=2,
                          epochs=2, seed=0, first_layer=fl)
    l0, f0, fin0 = _traj(base)
    l1, f1, fin1 = _traj(base.replace(max_clients=8))
    np.testing.assert_array_equal(l0, l1)
    np.testing.assert_array_equal(f0, f1)
    assert fin0 == fin1


@pytest.mark.fast
def test_padded_rejects_mask_blind_custom_fedavg():
    """A custom aggregator that cannot see client_mask would average
    dead slots' random params into live clients -- refused at build
    time, not silently mis-averaged."""
    import jax as _jax
    pcfg = ProtocolConfig(dataset="titanic", n_clients=3, max_clients=8,
                          rounds=1, epochs=1)
    with pytest.raises(ValueError, match="client_mask"):
        DeVertiFL(pcfg, fedavg_fn=lambda p: _jax.tree.map(
            lambda l: l, p))
    # mask-aware custom aggregators are fine
    DeVertiFL(pcfg, fedavg_fn=lambda p, client_mask=None: fedavg(
        p, client_mask=client_mask))
    # and mask-blind ones remain fine without padding
    DeVertiFL(ProtocolConfig(dataset="titanic", n_clients=3, rounds=1,
                             epochs=1),
              fedavg_fn=lambda p: _jax.tree.map(lambda l: l, p))


@pytest.mark.parametrize("mode", ["non_federated", "verticomb"])
def test_padded_federation_bitwise_other_modes(mode):
    base = ProtocolConfig(dataset="titanic", n_clients=3, rounds=2,
                          epochs=1, seed=0, mode=mode)
    l0, _, fin0 = _traj(base)
    l1, _, fin1 = _traj(base.replace(max_clients=6))
    np.testing.assert_array_equal(l0, l1)
    assert fin0 == fin1


# ---------------------------------------------------------------------------
# multi-count padded sweep: one compile, bitwise masked lanes
# ---------------------------------------------------------------------------
def test_padded_sweep_compiles_once_and_matches_standalone():
    """A sweep over THREE client counts compiles the round function
    exactly once (the compile-once acceptance criterion), and every
    masked lane reproduces the corresponding standalone unpadded
    DeVertiFL run bit-for-bit."""
    seeds = (0, 1)
    counts = (2, 3, 4)
    out = run_padded_cells(
        "titanic", "devertifl",
        SweepConfig(client_counts=counts, seeds=seeds, rounds=2,
                    epochs=2, first_layer="masked"))
    assert out["round_traces"] == 1, out
    assert out["lanes"] == len(counts) * len(seeds)
    for nc in counts:
        cell = out["cells"][nc]
        for i, s in enumerate(seeds):
            solo = DeVertiFL(ProtocolConfig(
                dataset="titanic", n_clients=nc, rounds=2, epochs=2,
                seed=s, first_layer="masked")).train(
                    eval_every_round=False)
            assert cell["f1_per_seed"][i] == solo["final"]["f1"], \
                (nc, s)


def test_padded_sweep_gather_slice_lane_allclose():
    """The shape-uniform gather-slice first layer (slice/pallas/auto
    under the lane vmap) pads the contraction, so it is allclose --
    not bitwise -- to the standalone dynamic_slice run."""
    out = run_padded_cells(
        "titanic", "devertifl",
        SweepConfig(client_counts=(2, 3), seeds=(0,), rounds=2,
                    epochs=2, first_layer="slice"))
    assert out["round_traces"] == 1
    for nc in (2, 3):
        solo = DeVertiFL(ProtocolConfig(
            dataset="titanic", n_clients=nc, rounds=2, epochs=2,
            seed=0, first_layer="slice")).train(eval_every_round=False)
        assert abs(out["cells"][nc]["f1_per_seed"][0]
                   - solo["final"]["f1"]) <= 0.02


def test_run_grid_schema_unchanged():
    """run_grid still emits {"cells": {"ds/mode/n": ...}, "compare"}
    with per-count cell dicts, now driven by the padded engine."""
    grid = run_grid(SweepConfig(
        datasets=("titanic",), modes=("devertifl", "non_federated"),
        client_counts=(2, 3), seeds=(0,), rounds=1, epochs=1))
    assert set(grid["cells"]) == {"titanic/devertifl/2",
                                  "titanic/devertifl/3",
                                  "titanic/non_federated/2",
                                  "titanic/non_federated/3"}
    cell = grid["cells"]["titanic/devertifl/2"]
    assert {"f1_mean", "f1_std", "acc_mean", "steps_per_sec"} <= set(cell)
    assert set(grid["compare"]["titanic/2"]) == {"devertifl",
                                                 "non_federated"}


# ---------------------------------------------------------------------------
# sharded lanes == single device (8 fake CPU devices, subprocess so the
# main process keeps its single real device -- same pattern as
# tests/test_sharding_mesh.py)
# ---------------------------------------------------------------------------
def test_sharded_sweep_matches_single_device():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        assert jax.device_count() == 8, jax.devices()
        from repro.core.sweep import SweepConfig, run_padded_cells

        scfg = SweepConfig(client_counts=(2, 3, 4, 5), seeds=(0, 1),
                           rounds=2, epochs=1, first_layer="masked")
        single = run_padded_cells("titanic", "devertifl", scfg,
                                  shard=False)
        shard = run_padded_cells("titanic", "devertifl", scfg,
                                 shard="auto")
        assert single["devices"] == 1 and shard["devices"] == 8, \\
            (single["devices"], shard["devices"])
        for nc in (2, 3, 4, 5):
            a, b = single["cells"][nc], shard["cells"][nc]
            assert a["f1_per_seed"] == b["f1_per_seed"], nc
            assert a["final_loss_mean"] == b["final_loss_mean"], nc
        print("sharded == single-device over", shard["devices"],
              "devices")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
