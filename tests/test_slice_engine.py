"""The slice-aware protocol engine: canonical column layout,
masked / slice / pallas first-layer equivalence, sweep integration,
the perm-plan tail-drop contract, and the bench smoke lane.

masked is the paper-literal zero-padding reference; slice and pallas
compute the identical first layer over only the client's contiguous
feature slice, so trajectories agree to allclose (float reduction
order differs) rather than bitwise.
"""
import os
import sys

import jax
import numpy as np
import pytest

from repro.core import partition as PT
from repro.core.protocol import (DeVertiFL, ProtocolConfig, make_perm_fn,
                                 resolve_first_layer)
from repro.core.sweep import SweepConfig, run_cell

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# canonical layout
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("ds,nf", [("mnist", 784), ("titanic", 9),
                                   ("bank", 51)])
@pytest.mark.parametrize("n", [2, 3, 5])
def test_layout_canonicalization(ds, nf, n):
    lay = PT.make_layout(ds, nf, n, seed=1)
    # perm is a permutation of all features
    assert np.array_equal(np.sort(lay.perm), np.arange(nf))
    assert np.array_equal(lay.perm[lay.inv_perm], np.arange(nf))
    # contiguous disjoint complete slices in partition order
    assert lay.offsets[0] == 0
    assert np.array_equal(np.asarray(lay.offsets),
                          np.concatenate([[0], np.cumsum(lay.sizes)[:-1]]))
    assert sum(lay.sizes) == nf
    for i, (off, sz) in enumerate(zip(lay.offsets, lay.sizes)):
        # canonical slice i holds exactly client i's original features
        np.testing.assert_array_equal(lay.perm[off:off + sz],
                                      lay.partition[i])
        # block-alignment for the Pallas BlockSpec index_map
        assert off % lay.block == 0 and sz % lay.block == 0
    # masks are contiguous slabs implementing the same zeropad
    m = lay.masks()
    assert m.sum() == nf
    for i, (off, sz) in enumerate(zip(lay.offsets, lay.sizes)):
        assert m[i, off:off + sz].all() and m[i].sum() == sz


def test_layout_apply_matches_client_view():
    """Canonical slice i of permuted data == the client's raw features;
    slab-masked canonical data == permuted zeropad view."""
    lay = PT.make_layout("titanic", 9, 3, seed=5)
    x = np.random.default_rng(0).normal(size=(7, 9)).astype(np.float32)
    xc = lay.apply(x)
    old_masks = PT.masks_for(lay.partition, 9)
    for i, (off, sz) in enumerate(zip(lay.offsets, lay.sizes)):
        np.testing.assert_array_equal(xc[:, off:off + sz],
                                      x[:, lay.partition[i]])
        np.testing.assert_array_equal(xc * lay.masks()[i],
                                      (x * old_masks[i])[:, lay.perm])


@pytest.mark.fast
def test_resolve_first_layer():
    assert resolve_first_layer(ProtocolConfig(first_layer="masked")) == \
        "masked"
    auto = resolve_first_layer(ProtocolConfig(first_layer="auto"))
    assert auto == ("pallas" if jax.default_backend() == "tpu" else "slice")
    # exchanging the raw input (exchange_at=0) forces the masked path
    assert resolve_first_layer(ProtocolConfig(first_layer="slice",
                                              exchange_at=0)) == "masked"
    with pytest.raises(ValueError):
        resolve_first_layer(ProtocolConfig(first_layer="bogus"))


# ---------------------------------------------------------------------------
# engine equivalence: masked vs slice vs pallas
# ---------------------------------------------------------------------------
def _trajectories(pcfg):
    r = DeVertiFL(pcfg).train()
    losses = np.concatenate([h["round_losses"] for h in r["history"]])
    f1s = np.array([h["f1"] for h in r["history"]])
    return losses, f1s, r["final"]["f1"]


@pytest.mark.parametrize("mode", ["devertifl", "non_federated",
                                  "verticomb"])
def test_first_layer_paths_allclose_titanic(mode):
    """Same seed => masked, slice, and pallas(interpret) loss/F1
    trajectories agree (allclose: only float reduction order differs)."""
    base = ProtocolConfig(dataset="titanic", n_clients=3, rounds=2,
                          epochs=2, mode=mode, seed=0)
    ref_l, ref_f1, ref_final = _trajectories(base.replace(
        first_layer="masked"))
    for fl in ("slice", "pallas"):
        l, f1, final = _trajectories(base.replace(first_layer=fl))
        np.testing.assert_allclose(l, ref_l, rtol=1e-4, atol=1e-5,
                                   err_msg=f"{fl} loss vs masked")
        np.testing.assert_allclose(f1, ref_f1, atol=0.02,
                                   err_msg=f"{fl} F1 vs masked")
        assert abs(final - ref_final) <= 0.02


def test_first_layer_paths_allclose_mnist():
    """The bench config's shape: mnist has non-trivial block-aligned
    offsets (block=28), exercising the pallas index_map offset."""
    base = ProtocolConfig(dataset="mnist", n_clients=3, rounds=1,
                          epochs=2, n_samples=1200, seed=0)
    ref_l, ref_f1, _ = _trajectories(base.replace(first_layer="masked"))
    for fl in ("slice", "pallas"):
        l, f1, _ = _trajectories(base.replace(first_layer=fl))
        np.testing.assert_allclose(l, ref_l, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(f1, ref_f1, atol=0.02)


def test_scan_matches_python_loop_slice():
    """The slice path keeps the scan == python-loop bitwise invariant
    (both engines share the same jitted step)."""
    pcfg = ProtocolConfig(dataset="titanic", n_clients=3, rounds=2,
                          epochs=2, seed=0, first_layer="slice")
    scan = DeVertiFL(pcfg).train(engine="scan")
    loop = DeVertiFL(pcfg).train(engine="python")
    np.testing.assert_array_equal(
        np.concatenate([h["round_losses"] for h in scan["history"]]),
        np.concatenate([h["round_losses"] for h in loop["history"]]))
    assert scan["final"]["f1"] == loop["final"]["f1"]


def test_sweep_slice_lane_matches_standalone():
    """Seed lane s of a slice-layout sweep == DeVertiFL(seed=s,
    first_layer='slice').train() -- per-seed column permutations
    (titanic's random partitions differ by seed) ride the vmapped
    LayoutArrays correctly."""
    seeds = (0, 1)
    cell = run_cell("titanic", "devertifl", 3,
                    SweepConfig(seeds=seeds, rounds=2, epochs=2,
                                first_layer="slice"))
    for i, s in enumerate(seeds):
        solo = DeVertiFL(ProtocolConfig(
            dataset="titanic", n_clients=3, rounds=2, epochs=2,
            seed=s, first_layer="slice")).train(eval_every_round=False)
        assert cell["f1_per_seed"][i] == solo["final"]["f1"]


# ---------------------------------------------------------------------------
# perm plan: the tail-drop contract
# ---------------------------------------------------------------------------
@pytest.mark.fast
def test_perm_plan_tail_drop():
    """Regression-pin the epoch-shuffle semantics: n_batches =
    n_train // bs, and the trailing n_train % bs indices of every
    epoch's permutation are dropped (a different random subset each
    epoch)."""
    pcfg = ProtocolConfig(epochs=3, batch_size=64)
    plan = make_perm_fn(pcfg, 150)
    assert (plan.n_batches, plan.batch_size, plan.n_dropped) == (2, 64, 22)
    idx = np.asarray(plan.perms(jax.random.PRNGKey(0)))
    assert idx.shape == (pcfg.epochs * 2, 64)
    assert idx.min() >= 0 and idx.max() < 150
    per_epoch = idx.reshape(pcfg.epochs, -1)
    for e in range(pcfg.epochs):
        # within an epoch indices are distinct (a permutation prefix)
        assert np.unique(per_epoch[e]).size == per_epoch[e].size
    # epochs drop different tails (independent permutations)
    assert not np.array_equal(np.sort(per_epoch[0]), np.sort(per_epoch[1]))


@pytest.mark.fast
def test_perm_plan_small_dataset():
    """n_train < batch_size clamps bs to n_train: nothing is dropped."""
    plan = make_perm_fn(ProtocolConfig(epochs=2, batch_size=64), 10)
    assert (plan.n_batches, plan.batch_size, plan.n_dropped) == (1, 10, 0)


# ---------------------------------------------------------------------------
# bench smoke lane: append-only trajectory file
# ---------------------------------------------------------------------------
@pytest.mark.fast
def test_protocol_bench_smoke_appends(tmp_path):
    """The smoke bench runs all engine lanes at toy sizes and appends
    (never clobbers) the trajectory file, migrating the pre-slice
    single-dict format into the list."""
    import json
    sys.path.insert(0, REPO_ROOT)
    try:
        from benchmarks import protocol_bench
    finally:
        sys.path.remove(REPO_ROOT)
    path = tmp_path / "BENCH_protocol.json"
    legacy = {"config": {}, "loop_steps_per_sec": 1.0,
              "scan_steps_per_sec": 2.0}
    path.write_text(json.dumps(legacy))
    rows = protocol_bench.run(smoke=True, results_path=str(path))
    lanes = {name.split("/")[1] for name, _, _ in rows}
    assert {"masked", "slice", "pallas", "loop"} <= lanes
    data = json.loads(path.read_text())
    assert isinstance(data, list) and len(data) == 2
    assert data[0] == legacy                      # old entry preserved
    entry = data[1]
    assert {"date", "git_sha", "config", "engines"} <= set(entry)
    assert {"masked", "slice", "pallas", "loop"} <= set(entry["engines"])
    assert entry["config"]["smoke"] is True
