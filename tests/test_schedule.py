"""The repro.schedule exchange-scheduling subsystem.

Contracts pinned here (docs/ARCHITECTURE.md section 7):

  * schedule spec parsing/canonicalization and the registry's
    actionable unknown-name errors (+ register_schedule extension)
  * schedule="sync" IS the legacy engine (same code path; pinned
    bitwise across mode x first_layer x padded lanes), and the
    degenerate schedule-engine members stale_k:0 / partial:1.0 reduce
    to sync BIT-FOR-BIT in both the masked and slice lanes
  * scan and python engines drive identical schedule hooks (bitwise)
  * buffer-age semantics: stale_k consumes exactly the stack pushed k
    steps ago; cold-start buffers are zeros, so the first k steps
    match the exchange-free (non_federated) trajectory; double_buffer
    round 0 is fully exchange-free
  * degenerate federations: n_clients=1 and padded n_real=1 lanes
    train bit-for-bit like their unpadded selves under every schedule
  * schedule grids compile ONCE across schedule values in
    run_padded_cells (round_traces == 1), with sync lanes bitwise
    equal to the sync-only sweep
  * Session checkpoints round-trip schedule state bitwise; resuming
    under a different schedule fails with an actionable error
  * the train_federation shim forwards schedule= and warns with
    stacklevel=2 (the warning points at the caller)
  * sync spec_hashes are UNCHANGED by the schedule field (pinned
    against the pre-schedule hash) and non-sync schedules fork them
"""
import warnings

import jax
import numpy as np
import pytest

from repro.api import ExperimentSpec, build, run_grid, spec_grid
from repro.core.protocol import (DeVertiFL, ProtocolConfig,
                                 train_federation)
from repro.core.sweep import SweepConfig, run_cell, run_padded_cells
from repro.schedule import (LaneScheduleImpl, Schedule, get_schedule,
                            register_schedule, schedule_names)

TINY = dict(dataset="titanic", n_clients=3, rounds=2, epochs=2, seed=0)


def _traj(pcfg, engine=None):
    r = DeVertiFL(pcfg).train(engine=engine)
    return (np.concatenate([h["round_losses"] for h in r["history"]]),
            np.array([h["f1"] for h in r["history"]]),
            r["final"])


# ---------------------------------------------------------------------------
# registry + parsing
# ---------------------------------------------------------------------------
@pytest.mark.fast
def test_schedule_parsing_and_canonicalization():
    assert get_schedule("sync").is_sync
    assert get_schedule("stale_k").spec == "stale_k:1"
    assert get_schedule("stale_k:4").k == 4
    assert get_schedule("double_buffer").double_buffer
    p = get_schedule("partial:0.8")
    assert (p.p, p.deterministic) == (0.8, False)
    assert get_schedule("partial:0.8:det").deterministic
    combo = get_schedule("stale_k:4+partial:0.5")
    assert (combo.k, combo.p) == (4, 0.5)
    assert combo.spec == "stale_k:4+partial:0.5"
    # degenerate members keep their literal identity (they run the
    # schedule engine; bitwise-sync is proven below, not aliased)
    assert not get_schedule("stale_k:0").is_sync
    assert not get_schedule("partial:1.0").is_sync
    # Schedule objects pass through
    s = get_schedule("stale_k:2")
    assert get_schedule(s) is s


@pytest.mark.fast
def test_schedule_parse_errors_are_actionable():
    with pytest.raises(ValueError) as e:
        get_schedule("fedbcd")
    for name in schedule_names():
        assert name in str(e.value)
    for bad, frag in [("sync+partial:0.5", "compose"),
                      ("double_buffer+stale_k:1", "compose"),
                      ("partial:0", "0 < p <= 1"),
                      ("partial:1.5", "0 < p <= 1"),
                      ("stale_k:-1", "k >= 0"),
                      ("stale_k:1+stale_k:2", "duplicate"),
                      ("double_buffer:3", "no arguments"),
                      ("partial", "participation probability")]:
        with pytest.raises(ValueError, match=frag):
            get_schedule(bad)


@pytest.mark.fast
def test_register_custom_schedule():
    """A registered custom schedule runs end to end through the spec
    front door; its impl supplies the four round hooks."""
    class FrozenExchange:
        """Consumes the round-0 cold-start zeros forever: every round
        trains exchange-free (a do-nothing schedule, but it exercises
        the full custom plumbing)."""
        def __init__(self, n_clients, batch_size, width):
            import jax.numpy as jnp
            self._zeros = jnp.zeros((n_clients, batch_size, width),
                                    jnp.float32)

        def init_state(self, sched):
            return {}

        def round_start(self, state, lay, key, round_idx):
            return state, lay.client_mask

        def select(self, state, h_now):
            return self._zeros, state

        def round_end(self, state):
            return state

    if "frozen" not in schedule_names():
        register_schedule(
            "frozen",
            lambda n_clients, batch_size, width, args:
                FrozenExchange(n_clients, batch_size, width))
    assert "frozen" in schedule_names()
    rr = build(ExperimentSpec(dataset="titanic", n_clients=2, rounds=1,
                              epochs=1, seeds=(0,),
                              schedule="frozen")).run()
    assert 0.0 <= rr.metrics["f1"] <= 1.0
    # custom schedules stand alone and are refused in sweep lanes
    with pytest.raises(ValueError, match="compose"):
        get_schedule("frozen+partial:0.5")
    with pytest.raises(ValueError, match="custom"):
        run_padded_cells("titanic", "devertifl",
                         SweepConfig(client_counts=(2,), seeds=(0,),
                                     rounds=1, epochs=1,
                                     schedules=("frozen",)))


@pytest.mark.fast
def test_lane_impl_buffer_age_semantics():
    """The ring consumes exactly the stack pushed k steps ago."""
    impl = LaneScheduleImpl(max_k=3, n_clients=1, batch_size=1, width=1)
    st = impl.init_state(get_schedule("stale_k:2"))
    import jax.numpy as jnp
    consumed = []
    for t in range(6):
        h_now = jnp.full((1, 1, 1), float(t + 1))
        h_ref, st = impl.select(st, h_now)
        consumed.append(float(h_ref[0, 0, 0]))
    # cold start: zeros until the ring holds k pushes, then t-2's value
    assert consumed == [0.0, 0.0, 1.0, 2.0, 3.0, 4.0]
    # k=0 consumes the current stack even with a deep ring
    st0 = impl.init_state(get_schedule("stale_k:0"))
    h_ref, _ = impl.select(st0, jnp.full((1, 1, 1), 7.0))
    assert float(h_ref[0, 0, 0]) == 7.0


# ---------------------------------------------------------------------------
# spec integration + hash stability
# ---------------------------------------------------------------------------
@pytest.mark.fast
def test_sync_spec_hash_unchanged_and_schedule_forks():
    """The schedule field must not fork pre-existing sync spec ids
    (pinned against the hash recorded BEFORE the schedule axis
    existed), while non-sync schedules get their own ids."""
    spec = ExperimentSpec(dataset="titanic", n_clients=3, rounds=2,
                          epochs=1)
    assert spec.schedule == "sync"
    assert spec.spec_hash == "58715f95206928f5"      # pre-PR-5 value
    assert spec.resume_hash == "48945ac24cd700a7"    # pre-PR-5 value
    stale = spec.replace(schedule="stale_k:2")
    assert stale.spec_hash != spec.spec_hash
    assert stale.resume_hash != spec.resume_hash
    # canonicalization: formatting cannot fork the hash
    assert spec.replace(schedule="stale_k").spec_hash == \
        spec.replace(schedule="stale_k:1").spec_hash


@pytest.mark.fast
def test_spec_schedule_validation():
    with pytest.raises(ValueError) as e:
        ExperimentSpec(dataset="titanic", schedule="nope")
    assert "stale_k" in str(e.value)
    for mode in ("non_federated", "verticomb", "splitnn"):
        with pytest.raises(ValueError, match="devertifl"):
            ExperimentSpec(dataset="titanic", mode=mode,
                           schedule="stale_k:1")
    # sync runs everywhere
    ExperimentSpec(dataset="titanic", mode="verticomb", schedule="sync")


# ---------------------------------------------------------------------------
# sync pins + bitwise degenerate reductions
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fl", ["masked", "slice"])
@pytest.mark.parametrize("sched", ["stale_k:0", "partial:1.0"])
def test_degenerate_schedules_reduce_to_sync_bitwise(fl, sched):
    """stale_k:0 and partial:1.0 run the schedule-aware engine yet
    reproduce the sync trajectory bit for bit in both first-layer
    families: loss stream, per-round F1, final metrics."""
    base = ProtocolConfig(first_layer=fl, **TINY)
    l0, f0, fin0 = _traj(base)
    l1, f1, fin1 = _traj(base.replace(schedule=sched))
    np.testing.assert_array_equal(l0, l1)
    np.testing.assert_array_equal(f0, f1)
    assert fin0 == fin1


def test_degenerate_schedules_reduce_to_sync_padded():
    """The reduction holds on padded client axes too (dead slots stay
    exact-zero contributors under the schedule engine)."""
    base = ProtocolConfig(max_clients=6, **TINY)
    l0, _, fin0 = _traj(base)
    for sched in ("stale_k:0", "partial:1.0"):
        l1, _, fin1 = _traj(base.replace(schedule=sched))
        np.testing.assert_array_equal(l0, l1)
        assert fin0 == fin1


@pytest.mark.parametrize("sched", ["stale_k:2", "partial:0.8",
                                   "double_buffer",
                                   "stale_k:1+partial:0.5"])
def test_scan_matches_python_engine_under_schedules(sched):
    """Both engines drive the same schedule hooks: identical loss
    trajectories and final metrics, bit for bit."""
    pcfg = ProtocolConfig(schedule=sched, **TINY)
    l_scan, f_scan, fin_scan = _traj(pcfg, engine="scan")
    l_py, f_py, fin_py = _traj(pcfg, engine="python")
    np.testing.assert_array_equal(l_scan, l_py)
    np.testing.assert_array_equal(f_scan, f_py)
    assert fin_scan == fin_py


def test_cold_start_buffers_match_exchange_free_steps():
    """Zeros in the ring mean the first k steps train exchange-free:
    their losses equal the non_federated trajectory's first k steps,
    and step k diverges once the first real stale stack arrives.
    The two sides are DIFFERENT compiled programs (the schedule adds
    an exact-zero exchange term XLA may fuse differently), so the
    equality bar is ulp-tight allclose, not bitwise."""
    k = 3
    stale = _traj(ProtocolConfig(schedule=f"stale_k:{k}", **TINY))[0]
    nonfed = _traj(ProtocolConfig(mode="non_federated", **TINY))[0]
    np.testing.assert_allclose(stale[:k], nonfed[:k], rtol=1e-6)
    assert abs(stale[k] - nonfed[k]) > 1e-4
    # double_buffer: the WHOLE first round is exchange-free
    pcfg1 = ProtocolConfig(**{**TINY, "rounds": 1})
    db = _traj(pcfg1.replace(schedule="double_buffer"))[0]
    nf = _traj(pcfg1.replace(mode="non_federated"))[0]
    np.testing.assert_allclose(db, nf, rtol=1e-6)


def test_deterministic_partial_full_participation_is_sync():
    """partial:1.0:det rotates a keep-everyone set: bitwise sync."""
    base = ProtocolConfig(**TINY)
    l0, _, fin0 = _traj(base)
    l1, _, fin1 = _traj(base.replace(schedule="partial:1.0:det"))
    np.testing.assert_array_equal(l0, l1)
    assert fin0 == fin1
    # a real dropout schedule must actually change the trajectory
    l2, _, _ = _traj(base.replace(schedule="partial:0.5:det"))
    assert not np.array_equal(l0, l2)


# ---------------------------------------------------------------------------
# degenerate federations
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("sched", ["sync", "stale_k:1", "double_buffer",
                                   "partial:0.5", "partial:0.5:det"])
def test_single_client_federation_every_schedule(sched):
    """n_clients=1: no peers to exchange with, every schedule trains
    finitely (the participation guard keeps the lone client in)."""
    pcfg = ProtocolConfig(dataset="titanic", n_clients=1, rounds=1,
                          epochs=1, seed=0, schedule=sched)
    losses, _, fin = _traj(pcfg)
    assert np.isfinite(losses).all()
    assert 0.0 <= fin["f1"] <= 1.0


@pytest.mark.parametrize("sched", ["stale_k:1", "double_buffer",
                                   "partial:0.5"])
def test_padded_n_real_1_matches_unpadded(sched):
    """A lone live client on a padded axis trains bit-for-bit like the
    unpadded single-client run under every schedule (dead slots are
    exact-zero exchange/FedAvg/participation terms)."""
    base = ProtocolConfig(dataset="titanic", n_clients=1, rounds=2,
                          epochs=1, seed=0, schedule=sched)
    l0, _, fin0 = _traj(base)
    l1, _, fin1 = _traj(base.replace(max_clients=4))
    np.testing.assert_array_equal(l0, l1)
    assert fin0 == fin1


def test_padded_schedule_federation_bitwise():
    """Padding is invisible under non-sync schedules too: n_clients=3
    padded to 6 trains the live clients bit-for-bit."""
    for sched in ("stale_k:2", "partial:0.5"):
        base = ProtocolConfig(schedule=sched, **TINY)
        l0, _, fin0 = _traj(base)
        l1, _, fin1 = _traj(base.replace(max_clients=6))
        np.testing.assert_array_equal(l0, l1)
        assert fin0 == fin1


# ---------------------------------------------------------------------------
# schedule lanes in the sweep engine
# ---------------------------------------------------------------------------
def test_schedule_grid_compiles_once_and_sync_lane_is_exact():
    """A schedules x counts x seeds batch compiles its round ONCE (k
    and p are traced per-lane state), its sync lanes equal the
    sync-only sweep bitwise, and its cells carry schedule-qualified
    keys."""
    counts, seeds = (2, 3), (0,)
    scheds = ("sync", "stale_k:2", "stale_k:2+partial:0.5")
    out = run_padded_cells(
        "titanic", "devertifl",
        SweepConfig(client_counts=counts, seeds=seeds, rounds=2,
                    epochs=1, first_layer="masked", schedules=scheds))
    assert out["round_traces"] == 1, out
    assert out["lanes"] == len(scheds) * len(counts) * len(seeds)
    assert set(out["cells"]) == {f"{sc}/{nc}" for sc in scheds
                                 for nc in counts}
    ref = run_padded_cells(
        "titanic", "devertifl",
        SweepConfig(client_counts=counts, seeds=seeds, rounds=2,
                    epochs=1, first_layer="masked"))
    assert set(ref["cells"]) == set(counts)     # legacy keys untouched
    for nc in counts:
        assert out["cells"][f"sync/{nc}"]["f1_per_seed"] == \
            ref["cells"][nc]["f1_per_seed"]
        assert out["cells"][f"sync/{nc}"]["final_loss_mean"] == \
            ref["cells"][nc]["final_loss_mean"]


def test_schedule_sweep_rejects_bad_combinations():
    scfg = SweepConfig(client_counts=(2,), seeds=(0,), rounds=1,
                       epochs=1)
    with pytest.raises(ValueError, match="devertifl"):
        run_padded_cells("titanic", "non_federated",
                         scfg.__class__(**{**scfg.__dict__,
                                           "schedules": ("stale_k:1",)}))
    with pytest.raises(ValueError, match="double_buffer"):
        run_padded_cells(
            "titanic", "devertifl",
            scfg.__class__(**{**scfg.__dict__,
                              "schedules": ("double_buffer",
                                            "stale_k:1")}))
    with pytest.raises(ValueError, match="one schedule"):
        run_cell("titanic", "devertifl", 2,
                 scfg.__class__(**{**scfg.__dict__,
                                   "schedules": ("sync",
                                                 "stale_k:1")}))


def test_double_buffer_single_schedule_sweep():
    """double_buffer cannot mix with other schedules but sweeps fine
    as its own batch (its state vmaps like any other carry)."""
    out = run_padded_cells(
        "titanic", "devertifl",
        SweepConfig(client_counts=(2, 3), seeds=(0,), rounds=1,
                    epochs=1, schedules=("double_buffer",)))
    assert out["round_traces"] == 1
    assert set(out["cells"]) == {"double_buffer/2", "double_buffer/3"}


def test_spec_grid_schedule_axis_and_multi_seed_session():
    """spec_grid grows a schedules axis; run_grid keys non-sync cells
    as ds/mode/sched/n and stamps spec hashes; a multi-seed session
    with a schedule runs the run_cell path."""
    scheds = ("sync", "stale_k:1")
    specs = spec_grid(datasets=("titanic",), modes=("devertifl",),
                      client_counts=(2,), seeds=(0,), schedules=scheds,
                      rounds=1, epochs=1)
    assert len(specs) == 2
    grid = run_grid(specs)
    assert set(grid["cells"]) == {"titanic/devertifl/sync/2",
                                  "titanic/devertifl/stale_k:1/2"}
    for cell in grid["cells"].values():
        assert cell["spec_hash"]
    rr = build(ExperimentSpec(dataset="titanic", n_clients=2, rounds=1,
                              epochs=1, seeds=(0, 1),
                              schedule="stale_k:1")).run()
    assert len(rr.metrics["f1_per_seed"]) == 2


# ---------------------------------------------------------------------------
# checkpoint / resume round-trips
# ---------------------------------------------------------------------------
def test_schedule_checkpoint_resume_bitwise(tmp_path):
    """resume() restores the schedule state (stale ring buffers)
    bitwise: the resumed run equals the uninterrupted one, and a
    checkpoint written under one schedule refuses to resume under
    another with an error that names the schedule."""
    d = str(tmp_path / "ckpt")
    kw = dict(dataset="titanic", epochs=1, seeds=(0,),
              schedule="stale_k:2")
    full = build(ExperimentSpec(rounds=4, **kw)).run()
    build(ExperimentSpec(rounds=2, checkpoint_dir=d, checkpoint_every=1,
                         **kw)).run()
    res = build(ExperimentSpec(rounds=4, checkpoint_dir=d,
                               checkpoint_every=1, **kw)).resume()
    assert res.resumed_from == 2
    assert res.metrics == full.metrics
    for i, r in enumerate((2, 3)):
        np.testing.assert_array_equal(res.history[i]["round_losses"],
                                      full.history[r]["round_losses"])
    # a different schedule (even the same family) is refused actionably
    with pytest.raises(ValueError, match="different exchange schedule"):
        build(ExperimentSpec(rounds=4, checkpoint_dir=d,
                             checkpoint_every=1,
                             **{**kw, "schedule": "stale_k:4"})).resume()
    with pytest.raises(ValueError, match="different exchange schedule"):
        build(ExperimentSpec(rounds=4, checkpoint_dir=d,
                             checkpoint_every=1,
                             **{**kw, "schedule": "sync"})).resume()


def test_partial_schedule_checkpoint_resume_bitwise(tmp_path):
    """The participation stream derives from the round key (fold_in
    tag), so resume() reproduces the same per-round masks without any
    carried key material -- resumed == uninterrupted bitwise."""
    d = str(tmp_path / "ckpt")
    kw = dict(dataset="titanic", epochs=1, seeds=(0,),
              schedule="partial:0.5")
    full = build(ExperimentSpec(rounds=4, **kw)).run()
    build(ExperimentSpec(rounds=2, checkpoint_dir=d, checkpoint_every=1,
                         **kw)).run()
    res = build(ExperimentSpec(rounds=4, checkpoint_dir=d,
                               checkpoint_every=1, **kw)).resume()
    assert res.metrics == full.metrics
    for i, r in enumerate((2, 3)):
        np.testing.assert_array_equal(res.history[i]["round_losses"],
                                      full.history[r]["round_losses"])


# ---------------------------------------------------------------------------
# the train_federation shim
# ---------------------------------------------------------------------------
def test_train_federation_forwards_schedule_and_warns_at_caller():
    """The deprecation shim forwards schedule= through the spec (same
    trajectory as the direct engine) and warns with stacklevel=2, so
    the warning names THIS file, not the shim's."""
    kw = dict(dataset="titanic", n_clients=2, rounds=1, epochs=1,
              seed=0, schedule="stale_k:1")
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        out = train_federation(**kw)
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert dep and dep[0].filename == __file__
    legacy = DeVertiFL(ProtocolConfig(**kw)).train()
    assert out["final"] == legacy["final"]
    np.testing.assert_array_equal(
        np.concatenate([h["round_losses"] for h in out["history"]]),
        np.concatenate([h["round_losses"] for h in legacy["history"]]))


# ---------------------------------------------------------------------------
# benches
# ---------------------------------------------------------------------------
@pytest.mark.fast
def test_staleness_bench_smoke_appends(tmp_path):
    """The staleness bench runs its whole schedule grid on one compile
    and appends a spec-hash-stamped entry."""
    import json
    import os
    import sys
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, repo)
    try:
        from benchmarks import staleness
    finally:
        sys.path.remove(repo)
    path = tmp_path / "BENCH_staleness.json"
    rows = staleness.run(smoke=True, results_path=str(path))
    assert any(name.startswith("staleness/") for name, _, _ in rows)
    data = json.loads(path.read_text())
    assert isinstance(data, list) and len(data) == 1
    entry = data[0]
    assert entry["round_traces"] == 1
    assert "sync" in entry["grid"]
    for cell in entry["grid"].values():
        assert len(cell["spec_hash"]) == 16
