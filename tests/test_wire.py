"""The repro.wire exchange-transform subsystem.

Contracts pinned here (docs/ARCHITECTURE.md section 11):

  * transform spec parsing/canonicalization (components reorder to
    topk -> int8 -> dp, numbers normalize) and the registry's
    actionable errors (+ register_transform extension)
  * transform="none" IS the legacy engine (spec hashes pinned against
    the pre-wire values; the protocol leaves the engine unwrapped; no
    wire telemetry in timings), and non-none transforms fork
    spec/resume hashes
  * codec exactness: topk p=1.0 is a bitwise identity, the int8
    round trip is idempotent bit-for-bit, dp noise is a reproducible
    per-client fold_in stream disjoint from the fault/participation
    tags
  * transformed runs are deterministic, padding-invariant (incl. the
    n_real=1 degenerate federation), and identical across the scan
    and python engines -- also chained behind a schedule and a fault
    plan
  * transform x fault x schedule x count sweep lanes compile ONCE
    (round_traces == 1) with the "none" lanes bitwise equal to the
    wire-free sweep; bytes-on-wire surface per cell and in
    RunResult.timings["wire"] as integers
  * a checkpoint's schedule|fault|wire stream stamp refuses
    cross-transform resumes
  * serving: the ExchangeCache stores packed WirePayload entries
    smaller than raw fp32, cache hits reproduce fresh results bitwise
    (codec idempotence), custom transforms are refused with a codec
    error
  * the static auditor stays clean over wired combos and sees the
    declared "wire" release channel
  * skewed (unequal per-client) Layout partitions train bitwise
    padded==unpadded on every first-layer lane
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api import ExperimentSpec, ServeRequest, build, run_grid, \
    spec_grid, split_features
from repro.core.partition import make_layout, skewed_partition
from repro.core.protocol import DeVertiFL, ProtocolConfig
from repro.core.sweep import SweepConfig, run_cell, run_padded_cells
from repro.wire import (WirePayload, WireImpl, dp_noise, get_wire_plan,
                        int8_roundtrip, pack, register_transform,
                        topk_select, transform_names, unpack,
                        wire_apply, wire_apply_static)

TINY = dict(dataset="titanic", n_clients=3, rounds=2, epochs=2, seed=0)
# a composite transform exercising all three built-in stages at once
HOT = "topk:0.5+int8+dp:0.1"


def _traj(pcfg, engine=None):
    r = DeVertiFL(pcfg).train(engine=engine)
    return (np.concatenate([h["round_losses"] for h in r["history"]]),
            np.array([h["f1"] for h in r["history"]]),
            r["final"])


# ---------------------------------------------------------------------------
# a test-only custom transform: delegates every hook untouched, so its
# trajectory must equal the transform-free engine bit-for-bit
# ---------------------------------------------------------------------------
class _PassthroughImpl:
    def __init__(self, inner):
        self.inner = inner

    def init_state(self, sched, **kw):
        return self.inner.init_state(sched, **kw)

    def round_start(self, state, lay, key, round_idx):
        return self.inner.round_start(state, lay, key, round_idx)

    def select(self, state, h_now):
        return self.inner.select(state, h_now)

    def round_end(self, state):
        return self.inner.round_end(state)


register_transform(
    "test_passthrough",
    lambda inner, n_clients, batch_size, width, args:
        _PassthroughImpl(inner),
    overwrite=True)


# ---------------------------------------------------------------------------
# registry + parsing
# ---------------------------------------------------------------------------
@pytest.mark.fast
def test_wire_parsing_and_canonicalization():
    assert get_wire_plan("none").is_none
    assert not get_wire_plan("int8").is_none
    # components reorder to the canonical topk -> int8 -> dp order and
    # numbers normalize, so formatting cannot fork an identity
    assert get_wire_plan("dp:0.1+topk:0.5").spec == "topk:0.5+dp:0.1"
    assert get_wire_plan("int8+topk:0.25").spec == "topk:0.25+int8"
    assert get_wire_plan("topk:0.50").spec == "topk:0.5"
    p = get_wire_plan("dp:0.20+int8+topk:0.25")
    assert p.spec == "topk:0.25+int8+dp:0.2"
    assert (p.topk, p.int8, p.dp) == (0.25, True, 0.2)
    assert p.topk_p == 0.25 and p.dp_sigma == 0.2
    none = get_wire_plan("none")
    assert none.topk_p == 1.0 and none.dp_sigma == 0.0
    # WirePlan passes through; registry lists the built-in families
    assert get_wire_plan(p) is p
    assert {"none", "topk", "int8", "dp"} <= set(transform_names())


@pytest.mark.fast
def test_wire_parse_errors_are_actionable():
    with pytest.raises(ValueError) as e:
        get_wire_plan("gzip")
    assert "topk" in str(e.value)           # options listed
    for bad, msg in [
        ("topk", "keep fraction"),
        ("topk:0", "0 < p <= 1"),
        ("topk:1.5", "0 < p <= 1"),
        ("topk:lots", "float"),
        ("dp", "noise scale"),
        ("dp:-1", "sigma > 0"),
        ("dp:0", "sigma > 0"),
        ("int8:4", "no arguments"),
        ("none:x", "no arguments"),
        ("int8+int8", "duplicate"),
        ("none+int8", "does not compose"),
        ("test_passthrough+int8", "does not compose"),
        ("int8++dp:0.1", "malformed"),
    ]:
        with pytest.raises(ValueError, match=msg):
            get_wire_plan(bad)


# ---------------------------------------------------------------------------
# spec integration + hash stability
# ---------------------------------------------------------------------------
@pytest.mark.fast
def test_none_spec_hash_unchanged_and_transform_forks():
    """The transform field must not fork pre-existing spec ids (pinned
    against the hashes recorded BEFORE the wire axis existed), while
    non-none transforms get their own ids and formatting cannot fork
    them."""
    spec = ExperimentSpec(dataset="titanic", n_clients=3, rounds=2,
                          epochs=1)
    assert spec.transform == "none"
    assert spec.spec_hash == "58715f95206928f5"      # pre-PR-5 value
    assert spec.resume_hash == "48945ac24cd700a7"    # pre-PR-5 value
    hot = spec.replace(transform="int8")
    assert hot.spec_hash != spec.spec_hash
    assert hot.resume_hash != spec.resume_hash
    assert spec.replace(transform="dp:0.1+topk:0.5").spec_hash == \
        spec.replace(transform="topk:0.5+dp:0.1").spec_hash
    assert spec.replace(transform="topk:0.50").spec_hash == \
        spec.replace(transform="topk:0.5").spec_hash


@pytest.mark.fast
def test_spec_transform_validation():
    with pytest.raises(ValueError) as e:
        ExperimentSpec(dataset="titanic", transform="nope")
    assert "topk" in str(e.value)
    for mode in ("non_federated", "verticomb"):
        with pytest.raises(ValueError, match="devertifl"):
            ExperimentSpec(dataset="titanic", mode=mode,
                           transform="int8")
        # transform-free specs run everywhere
        ExperimentSpec(dataset="titanic", mode=mode, transform="none")


# ---------------------------------------------------------------------------
# codec unit contracts
# ---------------------------------------------------------------------------
@pytest.mark.fast
def test_codec_exactness():
    key = jax.random.PRNGKey(0)
    h = jax.random.normal(key, (3, 8, 16)) * \
        jnp.exp(jax.random.normal(jax.random.fold_in(key, 1),
                                  (3, 8, 16)) * 3)
    h = h.at[0, 0, 0].set(-0.0)            # the sign-bit tripwire
    # topk p=1.0 keeps every entry's bits untouched (exact where)
    full = topk_select(h, jnp.float32(1.0))
    np.testing.assert_array_equal(
        np.asarray(full).view(np.int32), np.asarray(h).view(np.int32))
    # topk p=0.5 keeps entries bit-for-bit, exact zeros elsewhere
    half = np.asarray(topk_select(h, jnp.float32(0.5)))
    kept = half != 0
    assert 0 < kept.sum() < h.size
    np.testing.assert_array_equal(half[kept], np.asarray(h)[kept])
    # int8 round trip is idempotent bit-for-bit
    r1 = int8_roundtrip(h)
    r2 = int8_roundtrip(r1)
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
    assert not np.array_equal(np.asarray(r1), np.asarray(h))
    # dp noise: reproducible, per-client fold_in derivation
    n1 = dp_noise(key, 3, (8, 16))
    n2 = dp_noise(key, 3, (8, 16))
    np.testing.assert_array_equal(np.asarray(n1), np.asarray(n2))
    np.testing.assert_array_equal(
        np.asarray(n1)[1],
        np.asarray(jax.random.normal(jax.random.fold_in(key, 1),
                                     (8, 16))))


@pytest.mark.fast
def test_wire_apply_gates_match_static():
    """The traced-gate path (sweep lanes) and the static path (serving
    / probes) agree bitwise for every component subset."""
    key = jax.random.PRNGKey(7)
    h = jax.random.normal(key, (3, 4, 8))
    for spec in ("topk:0.5", "int8", "topk:0.25+int8+dp:0.3"):
        p = get_wire_plan(spec)
        gated = wire_apply(
            h, key,
            topk_on=jnp.float32(p.topk is not None),
            topk_p=jnp.float32(p.topk_p),
            int8_on=jnp.float32(p.int8),
            dp_on=jnp.float32(p.dp is not None),
            dp_sigma=jnp.float32(p.dp_sigma))
        static = wire_apply_static(p, h, key=key)
        np.testing.assert_array_equal(np.asarray(gated),
                                      np.asarray(static))
    # every gate off: the input's bits come back untouched
    noop = wire_apply(h, key, topk_on=jnp.float32(0),
                      topk_p=jnp.float32(1.0), int8_on=jnp.float32(0),
                      dp_on=jnp.float32(0), dp_sigma=jnp.float32(0))
    np.testing.assert_array_equal(np.asarray(noop), np.asarray(h))


@pytest.mark.fast
def test_pack_unpack_roundtrip():
    """unpack(pack(plan, h)) is bitwise h for codec-encoded stacks,
    and the packed nbytes beats raw fp32 where the codec should win."""
    h = np.asarray(jax.random.normal(jax.random.PRNGKey(3), (3, 32)))
    for spec in ("int8", "topk:0.25", "topk:0.25+int8"):
        plan = get_wire_plan(spec)
        enc = np.asarray(wire_apply_static(plan, jnp.asarray(h)))
        payload = pack(plan, enc)
        assert isinstance(payload, WirePayload)
        np.testing.assert_array_equal(unpack(payload), enc)
    raw = h.size * 4
    assert pack(get_wire_plan("int8"), np.asarray(
        wire_apply_static(get_wire_plan("int8"),
                          jnp.asarray(h)))).nbytes < raw
    # dense none-plan pack is the fp32 cost exactly
    assert pack(get_wire_plan("none"), h).nbytes == raw


# ---------------------------------------------------------------------------
# engine: none identity, determinism, padding, scan == python
# ---------------------------------------------------------------------------
def test_none_keeps_legacy_path_without_wire_timings():
    fed = DeVertiFL(ProtocolConfig(**TINY))
    assert fed._impl is None                # engine left unwrapped
    hot = DeVertiFL(ProtocolConfig(transform="int8", **TINY))
    assert isinstance(hot._impl, WireImpl)
    res = build(ExperimentSpec(dataset="titanic", n_clients=2,
                               rounds=1, epochs=1, seeds=(0,))).run()
    assert "wire" not in res.timings


def test_transform_deterministic_and_differs_from_none():
    """Same transform -> bitwise the same trajectory (fold_in noise);
    a hot transform actually changes the trajectory; everything stays
    finite."""
    hot = ProtocolConfig(transform=HOT, **TINY)
    l1, f1, fin1 = _traj(hot)
    l2, f2, fin2 = _traj(hot)
    np.testing.assert_array_equal(l1, l2)
    np.testing.assert_array_equal(f1, f2)
    assert fin1 == fin2
    l0, _, _ = _traj(ProtocolConfig(**TINY))
    assert not np.array_equal(l0, l1)
    assert np.isfinite(l1).all()


def test_topk_full_keep_matches_none_bitwise():
    """topk:1.0 runs the wire engine yet reduces to the transform-free
    trajectory bit-for-bit (exact where select; the degenerate member
    is proven, not aliased) -- and so does a custom passthrough."""
    l0, f0, fin0 = _traj(ProtocolConfig(**TINY))
    l1, f1, fin1 = _traj(ProtocolConfig(transform="topk:1.0", **TINY))
    np.testing.assert_array_equal(l0, l1)
    np.testing.assert_array_equal(f0, f1)
    assert fin0 == fin1
    l2, f2, fin2 = _traj(ProtocolConfig(transform="test_passthrough",
                                        **TINY))
    np.testing.assert_array_equal(l0, l2)
    assert fin0 == fin2


def test_transform_padding_invariance():
    """A padded federation's live clients ship and receive the same
    bytes as the unpadded twin: per-client fold_in noise, dead slots
    masked -- down to the n_real=1 degenerate federation."""
    hot = ProtocolConfig(transform=HOT, **TINY)
    l0, _, fin0 = _traj(hot)
    l1, _, fin1 = _traj(hot.replace(max_clients=6))
    np.testing.assert_array_equal(l0, l1)
    assert fin0 == fin1
    solo = ProtocolConfig(dataset="titanic", n_clients=1, rounds=2,
                          epochs=1, seed=0, transform=HOT)
    s0, _, sfin0 = _traj(solo)
    s1, _, sfin1 = _traj(solo.replace(max_clients=3))
    np.testing.assert_array_equal(s0, s1)
    assert sfin0 == sfin1


@pytest.mark.parametrize("transform,sched,fault", [
    ("int8", "sync", "none"),
    ("dp:0.1", "stale_k:2", "none"),
    (HOT, "stale_k:1", "crash:0.5:2+corrupt:0.5"),
])
def test_scan_matches_python_engine_under_transforms(transform, sched,
                                                     fault):
    pcfg = ProtocolConfig(schedule=sched, fault=fault,
                          transform=transform, **TINY)
    l_scan, f_scan, fin_scan = _traj(pcfg, engine="scan")
    l_py, f_py, fin_py = _traj(pcfg, engine="python")
    np.testing.assert_array_equal(l_scan, l_py)
    np.testing.assert_array_equal(f_scan, f_py)
    assert fin_scan == fin_py


def test_timings_wire_integer_bytes():
    spec = ExperimentSpec(dataset="titanic", n_clients=3, rounds=2,
                          epochs=1, seeds=(0,), transform="int8")
    res = build(spec).run()
    tel = res.timings["wire"]
    assert set(tel) == {"raw_bytes", "encoded_bytes",
                        "raw_bytes_per_round", "encoded_bytes_per_round"}
    assert all(isinstance(v, int) for v in tel.values())
    assert 0 < tel["encoded_bytes"] < tel["raw_bytes"]
    assert tel["raw_bytes_per_round"] == tel["raw_bytes"] // 2


# ---------------------------------------------------------------------------
# wire lanes in the sweep engine
# ---------------------------------------------------------------------------
def test_wire_grid_compiles_once_and_none_lane_is_exact():
    """A transforms x faults x schedules x counts batch compiles its
    round ONCE (gates/knobs are traced per-lane state), its
    "none"-transform fault-free lanes equal the wire-free fault-free
    sweep bitwise, and its wired cells carry integer byte counters."""
    counts, seeds = (2, 3), (0,)
    scheds = ("sync", "stale_k:1")
    faults = ("none", "crash:0.5:2")
    transforms = ("none", "int8", HOT)
    out = run_padded_cells(
        "titanic", "devertifl",
        SweepConfig(client_counts=counts, seeds=seeds, rounds=2,
                    epochs=1, schedules=scheds, faults=faults,
                    transforms=transforms))
    assert out["round_traces"] == 1, out
    assert out["lanes"] == len(transforms) * len(faults) * \
        len(scheds) * len(counts) * len(seeds)
    assert set(out["cells"]) == {f"{t}/{f}/{sc}/{nc}"
                                 for t in transforms for f in faults
                                 for sc in scheds for nc in counts}
    assert out["transforms"] == list(transforms)
    ref = run_padded_cells(
        "titanic", "devertifl",
        SweepConfig(client_counts=counts, seeds=seeds, rounds=2,
                    epochs=1, schedules=scheds))
    for sc in scheds:
        for nc in counts:
            assert out["cells"][f"none/none/{sc}/{nc}"]["f1_per_seed"] \
                == ref["cells"][f"{sc}/{nc}"]["f1_per_seed"]
            assert out["cells"][f"none/none/{sc}/{nc}"][
                "final_loss_mean"] == \
                ref["cells"][f"{sc}/{nc}"]["final_loss_mean"]
    hot = out["cells"][f"{HOT}/crash:0.5:2/stale_k:1/3"]
    assert hot["transform"] == HOT
    w = hot["wire"]
    assert set(w) == {"raw_bytes", "encoded_bytes"}
    assert all(isinstance(v, int) for v in w.values())
    assert w["raw_bytes"] > 0
    q = out["cells"]["int8/none/sync/3"]["wire"]
    assert 0 < q["encoded_bytes"] < q["raw_bytes"]


def test_wire_sweep_rejects_bad_combinations():
    base = dict(client_counts=(2,), seeds=(0,), rounds=1, epochs=1)
    with pytest.raises(ValueError, match="one transform"):
        run_cell("titanic", "devertifl", 2,
                 SweepConfig(transforms=("none", "int8"), **base))
    with pytest.raises(ValueError, match="devertifl"):
        run_padded_cells("titanic", "non_federated",
                         SweepConfig(transforms=("int8",), **base))
    with pytest.raises(ValueError, match="custom transforms"):
        run_padded_cells("titanic", "devertifl",
                         SweepConfig(transforms=("test_passthrough",),
                                     **base))


def test_spec_grid_transform_axis_and_run_grid_keys():
    """spec_grid grows a transforms axis; run_grid prepends the wire
    spec to non-default cell keys and stamps spec hashes."""
    specs = spec_grid(datasets=("titanic",), modes=("devertifl",),
                      client_counts=(2,), seeds=(0,),
                      transforms=("none", "int8"), rounds=1, epochs=1)
    assert len(specs) == 2
    assert [s.transform for s in specs] == ["none", "int8"]
    grid = run_grid(specs)
    assert set(grid["cells"]) == {"titanic/devertifl/none/none/sync/2",
                                  "titanic/devertifl/int8/none/sync/2"}
    for cell in grid["cells"].values():
        assert cell["spec_hash"]
    assert "wire" in grid["cells"]["titanic/devertifl/int8/none/sync/2"]


# ---------------------------------------------------------------------------
# checkpoint stream stamp
# ---------------------------------------------------------------------------
def test_wire_checkpoint_resume_bitwise_and_stamp_refusal(tmp_path):
    """resume() restores wire state (byte counters, noise stream
    position) bitwise, and the schedule|fault|wire stream stamp
    refuses resuming under a different transform."""
    d = str(tmp_path / "ckpt")
    kw = dict(dataset="titanic", epochs=1, seeds=(0,), transform=HOT)
    full = build(ExperimentSpec(rounds=4, **kw)).run()
    build(ExperimentSpec(rounds=2, checkpoint_dir=d,
                         checkpoint_every=1, **kw)).run()
    res = build(ExperimentSpec(rounds=4, checkpoint_dir=d,
                               checkpoint_every=1, **kw)).resume()
    assert res.resumed_from == 2
    assert res.metrics == full.metrics
    assert res.timings["wire"] == full.timings["wire"]
    for other in ("int8", "none"):
        with pytest.raises(
                ValueError,
                match="different exchange schedule, fault plan or wire"):
            build(ExperimentSpec(rounds=4, checkpoint_dir=d,
                                 checkpoint_every=1,
                                 **{**kw, "transform": other})).resume()


# ---------------------------------------------------------------------------
# serving: encoded cache payloads, cached == fresh bitwise
# ---------------------------------------------------------------------------
def test_serving_stores_packed_payloads_and_cache_hits_are_bitwise():
    """Under a transform the ExchangeCache stores packed WirePayload
    entries (smaller than raw fp32 for int8) and a cache-hit serve
    reproduces the fresh serve bit-for-bit -- the codec-idempotence
    guarantee, since the cached stack was already round-tripped."""
    sess = build(ExperimentSpec(dataset="titanic", mode="devertifl",
                                n_clients=3, rounds=1, epochs=1,
                                seeds=(0,), eval_every=0,
                                transform="int8"))
    sess.run()
    lay = sess.federation.layout
    xte = np.asarray(sess.federation.xte)[:4]
    srv = sess.server(max_slots=2, cache=16)
    srv.submit(ServeRequest(uid=0, entity_id="hot",
                            slices=split_features(lay, xte[0])))
    srv.run()
    payloads = list(srv.cache._store.values())
    assert payloads and all(isinstance(p, WirePayload)
                            for p in payloads)
    width = payloads[0].shape[-1]
    assert payloads[0].nbytes < 4 * 3 * width     # beats raw fp32
    srv.submit(ServeRequest(uid=1, entity_id="hot"))  # no slices
    report = srv.run()
    assert report.cache["hits"] == 1
    np.testing.assert_array_equal(report.results[1],
                                  report.results[0])


def test_serving_refuses_custom_transforms():
    sess = build(ExperimentSpec(dataset="titanic", mode="devertifl",
                                n_clients=2, rounds=1, epochs=1,
                                seeds=(0,), eval_every=0,
                                transform="test_passthrough"))
    sess.run()
    with pytest.raises(ValueError, match="serving codec"):
        sess.server(max_slots=2)


# ---------------------------------------------------------------------------
# the static auditor over wired combos
# ---------------------------------------------------------------------------
def test_audit_wired_combo_is_clean():
    """Taint (hiddens leave only through the declared wire channel),
    deadness, and retrace (wire state rides the carry) all hold on the
    full schedule -> fault -> wire chain."""
    from repro.analysis.audit import audit
    pcfg = ProtocolConfig(dataset="titanic", n_clients=3, rounds=1,
                          epochs=1, seed=0, schedule="stale_k:2",
                          fault="crash:0.2:2", transform=HOT)
    rep = audit(pcfg, lane_check=False)
    assert rep.ok, rep.summary()
    assert rep.static_round_traces == 1
    assert rep.channels.get("wire", 0) > 0


# ---------------------------------------------------------------------------
# skewed Layout partitions
# ---------------------------------------------------------------------------
@pytest.mark.fast
def test_skewed_partition_validation():
    parts = skewed_partition(9, (5, 3, 1), seed=0)
    assert [len(p) for p in parts] == [5, 3, 1]
    assert sorted(np.concatenate(parts)) == list(range(9))
    with pytest.raises(ValueError, match="sum"):
        skewed_partition(9, (5, 3))
    with pytest.raises(ValueError, match="positive"):
        skewed_partition(9, (9, 0))
    with pytest.raises(ValueError, match="n_clients"):
        make_layout("titanic", 9, 3, sizes=(5, 4))
    lay = make_layout("titanic", 9, 3, sizes=(5, 3, 1), max_clients=5)
    assert lay.sizes == (5, 3, 1, 0, 0)
    assert lay.offsets[:3] == (0, 5, 8)
    # sizes (hence offsets) are seed-independent: the pallas lane's
    # static-offset requirement holds across sweep seeds
    assert make_layout("titanic", 9, 3, seed=7,
                       sizes=(5, 3, 1)).offsets == lay.offsets[:3]


@pytest.mark.parametrize("fl", ["masked", "slice", "pallas"])
def test_skewed_layout_padded_bitwise_per_lane(fl):
    """On an unequal (5, 3, 1) titanic split, every first-layer lane
    trains its padded federation bit-for-bit like the unpadded one."""
    base = ProtocolConfig(partition_sizes=(5, 3, 1), first_layer=fl,
                          **TINY)
    l0, f0, fin0 = _traj(base)
    l1, f1, fin1 = _traj(base.replace(max_clients=8))
    np.testing.assert_array_equal(l0, l1)
    np.testing.assert_array_equal(f0, f1)
    assert fin0 == fin1


def test_skewed_layout_lanes_agree():
    """The three first-layer lanes agree on the skewed split to the
    same tolerance the equal-split equivalence tests pin (allclose:
    only float reduction order differs)."""
    base = ProtocolConfig(partition_sizes=(5, 3, 1), **TINY)
    ref_l, ref_f1, ref_fin = _traj(base.replace(first_layer="masked"))
    for fl in ("slice", "pallas"):
        l, f1, fin = _traj(base.replace(first_layer=fl))
        np.testing.assert_allclose(l, ref_l, rtol=1e-4, atol=1e-5,
                                   err_msg=f"{fl} loss vs masked")
        np.testing.assert_allclose(f1, ref_f1, atol=0.02)
        assert abs(fin["f1"] - ref_fin["f1"]) <= 0.02


def test_skewed_layout_composes_with_wire_and_faults():
    """A skewed split under the full schedule -> fault -> wire chain
    stays deterministic and padding-invariant."""
    pcfg = ProtocolConfig(partition_sizes=(5, 3, 1), schedule="stale_k:1",
                          fault="crash:0.5:2", transform=HOT, **TINY)
    l0, _, fin0 = _traj(pcfg)
    l1, _, fin1 = _traj(pcfg)
    np.testing.assert_array_equal(l0, l1)
    l2, _, fin2 = _traj(pcfg.replace(max_clients=6))
    np.testing.assert_array_equal(l0, l2)
    assert fin0 == fin1 == fin2


# ---------------------------------------------------------------------------
# the bench
# ---------------------------------------------------------------------------
def test_wire_bench_smoke_appends(tmp_path):
    """The wire bench runs its transform grid on one compile, probes
    each cell, and appends a spec-hash-stamped entry whose cells carry
    f1, integer bytes-on-wire, and the inversion-probe error."""
    import json
    import os
    import sys
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, repo)
    try:
        from benchmarks import wire as wire_bench
    finally:
        sys.path.remove(repo)
    path = tmp_path / "BENCH_wire.json"
    rows = wire_bench.run(smoke=True, results_path=str(path))
    assert any(name.startswith("wire/") for name, _, _ in rows)
    data = json.loads(path.read_text())
    assert isinstance(data, list) and len(data) == 1
    entry = data[0]
    assert entry["round_traces"] == 1
    assert entry["smoke"] is True
    assert "none/sync" in entry["grid"]
    for cell in entry["grid"].values():
        assert len(cell["spec_hash"]) == 16
        assert np.isfinite(cell["f1_mean"])
        w = cell["wire"]
        assert all(isinstance(v, int) for v in w.values())
        assert w["raw_bytes"] > 0
        assert np.isfinite(cell["probe"]["inversion_rel_mse"])
        assert cell["probe"]["steps_per_sec"] > 0
    q = entry["grid"]["int8/sync"]["wire"]
    assert q["encoded_bytes"] < q["raw_bytes"]
