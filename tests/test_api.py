"""The repro.api front door: spec validation against the registries,
process-stable hashing, jit-cache reuse, and the parity bar -- spec
-> Session runs reproduce every legacy entry point bit-for-bit
(DeVertiFL.train in all mode x first_layer x padding lanes, run_cell,
run_grid, SplitNN), plus checkpoint/resume and the train_federation
deprecation shim."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (ExperimentSpec, RunResult, build, dataset_names,
                       first_layer_names, mode_names, register_dataset,
                       register_mode, run_grid, spec_grid)
from repro.core.baselines import SplitNN, SplitNNConfig
from repro.core.protocol import (DeVertiFL, ProtocolConfig,
                                 init_padded_params, train_federation)
from repro.core.sweep import SweepConfig, run_cell
from repro.core.sweep import run_grid as sweep_run_grid

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

TINY = dict(dataset="titanic", n_clients=3, rounds=2, epochs=1)


# ---------------------------------------------------------------------------
# eager validation with actionable errors
# ---------------------------------------------------------------------------
@pytest.mark.fast
def test_unknown_names_raise_with_registered_options():
    with pytest.raises(ValueError) as e:
        ExperimentSpec(dataset="cifar")
    for name in dataset_names():
        assert name in str(e.value)
    with pytest.raises(ValueError) as e:
        ExperimentSpec(mode="fedsgd")
    for name in mode_names():
        assert name in str(e.value)
    with pytest.raises(ValueError) as e:
        ExperimentSpec(first_layer="dense")
    for name in first_layer_names():
        assert name in str(e.value)


@pytest.mark.fast
def test_spec_validation_is_eager_and_actionable():
    for kw, frag in [
        (dict(engine="jit"), "engine"),
        (dict(n_clients=0), "n_clients"),
        (dict(max_clients=2, n_clients=5), "max_clients"),
        (dict(exchange_at=7), "exchange_at"),
        (dict(checkpoint_every=2), "checkpoint_dir"),
        (dict(seeds=(0, 1), engine="python"), "scan"),
    ]:
        with pytest.raises(ValueError, match=frag):
            ExperimentSpec(dataset="titanic", **kw)
    # run(key=) is refused when a checkpoint would record the wrong
    # key stream for resume()
    with pytest.raises(ValueError, match="key="):
        build(ExperimentSpec(dataset="titanic", checkpoint_dir="/tmp/c",
                             checkpoint_every=1)).run(
            key=jax.random.PRNGKey(9))
    for kw, frag in [
        (dict(seeds=(0, 1), max_clients=8), "max_clients"),
        (dict(seeds=()), "seeds"),
        (dict(shard=True), "shard"),
        (dict(eval_every=-1), "eval_every"),
    ]:
        with pytest.raises(ValueError, match=frag):
            ExperimentSpec(dataset="titanic", **kw)


@pytest.mark.fast
def test_spec_normalization_and_replace():
    # ints and lists coerce to seed tuples (hashability + UX)
    assert ExperimentSpec(seeds=4).seeds == (4,)
    assert ExperimentSpec(seeds=[0, 1]).seeds == (0, 1)
    spec = ExperimentSpec(dataset="titanic")
    assert spec.replace(n_clients=5).n_clients == 5
    with pytest.raises(ValueError):        # replace re-validates
        spec.replace(n_clients=-1)
    # frozen + hashable
    assert hash(spec) == hash(ExperimentSpec(dataset="titanic"))
    with pytest.raises(Exception):
        spec.rounds = 3


# ---------------------------------------------------------------------------
# hashing: process-stable, observation-knob-blind, jit-cache-aligned
# ---------------------------------------------------------------------------
@pytest.mark.fast
def test_spec_hash_stable_across_processes():
    spec = ExperimentSpec(dataset="titanic", n_clients=4, rounds=7,
                          seeds=(0, 1), first_layer="slice")
    code = ("from repro.api import ExperimentSpec;"
            "print(ExperimentSpec(dataset='titanic', n_clients=4,"
            " rounds=7, seeds=(0, 1), first_layer='slice').spec_hash)")
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               PYTHONHASHSEED="12345")   # prove hash() salting is moot
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, env=env,
                         timeout=240)
    assert out.returncode == 0, out.stderr
    assert out.stdout.strip() == spec.spec_hash


@pytest.mark.fast
def test_auto_first_layer_canonicalizes_at_construction():
    """'auto' resolves per backend at spec construction, so the spec
    (and spec_hash) records the lane that actually runs -- two
    backends' auto lanes are allclose, not bitwise, and must not
    share one hash."""
    from repro.core.protocol import auto_first_layer
    spec = ExperimentSpec(dataset="titanic", first_layer="auto")
    assert spec.first_layer == auto_first_layer() != "auto"
    assert spec.spec_hash == ExperimentSpec(
        dataset="titanic", first_layer=auto_first_layer()).spec_hash


@pytest.mark.fast
def test_mode_aliases_canonicalize():
    """Aliases name the same experiment, so they must not fork the
    spec (or its hash): backward_exchange IS verticomb."""
    a = ExperimentSpec(dataset="titanic", mode="backward_exchange")
    b = ExperimentSpec(dataset="titanic", mode="verticomb")
    assert a.mode == "verticomb"
    assert a == b and a.spec_hash == b.spec_hash


@pytest.mark.fast
def test_spec_hash_ignores_observation_knobs():
    spec = ExperimentSpec(dataset="titanic")
    assert spec.spec_hash == spec.replace(
        eval_every=0, checkpoint_dir="/tmp/x", checkpoint_every=0,
        shard=False).spec_hash
    # every result-determining field forks the hash
    assert spec.spec_hash != spec.replace(first_layer="masked").spec_hash
    assert spec.spec_hash != spec.replace(seeds=(1,)).spec_hash


@pytest.mark.fast
def test_equal_specs_share_the_jit_cache():
    """ExperimentSpec is a leafless pytree whose treedef carries the
    spec: equal specs hit the trace cache, different specs retrace."""
    traces = []

    @jax.jit
    def f(spec, x):
        traces.append(1)
        return x * spec.n_clients

    x = jnp.arange(3.0)
    f(ExperimentSpec(dataset="titanic", n_clients=3), x)
    f(ExperimentSpec(dataset="titanic", n_clients=3), x)
    assert len(traces) == 1
    f(ExperimentSpec(dataset="titanic", n_clients=5), x)
    assert len(traces) == 2


# ---------------------------------------------------------------------------
# registries are extensible
# ---------------------------------------------------------------------------
def test_register_custom_dataset_runs_everywhere():
    def loader(n=600, seed=0):
        rng = np.random.default_rng(seed)
        x = rng.normal(size=(n, 9)).astype(np.float32)
        y = (x.sum(1) > 0).astype(np.int32)
        return x, y

    if "toy9" not in dataset_names():
        register_dataset("toy9", loader, n_classes=2,
                         arch="paper-mlp-titanic", partition="random")
    assert "toy9" in dataset_names()
    rr = build(ExperimentSpec(**{**TINY, "dataset": "toy9"})).run()
    assert 0.0 <= rr.metrics["f1"] <= 1.0
    # and through the sweep engine (multi-seed cell)
    rr2 = build(ExperimentSpec(dataset="toy9", n_clients=2, rounds=1,
                               epochs=1, seeds=(0, 1))).run()
    assert len(rr2.metrics["f1_per_seed"]) == 2
    # the registered name now appears in unknown-name errors
    with pytest.raises(ValueError, match="toy9"):
        ExperimentSpec(dataset="nope")


@pytest.mark.fast
def test_register_custom_mode():
    class EchoRunner:
        def __init__(self, spec):
            self.spec = spec

        def run(self):
            return ({"f1": 1.0, "acc": 1.0}, [], None, {"wall_s": 0.0})

    if "echo" not in mode_names():
        register_mode("echo", lambda spec: EchoRunner(spec))
    rr = build(ExperimentSpec(dataset="titanic", mode="echo")).run()
    assert rr.metrics == {"f1": 1.0, "acc": 1.0}
    assert rr.schema_version == 5


# ---------------------------------------------------------------------------
# parity: spec-driven == legacy, bit for bit
# ---------------------------------------------------------------------------
def _legacy_traj(pcfg):
    r = DeVertiFL(pcfg).train()
    return (np.concatenate([h["round_losses"] for h in r["history"]]),
            np.array([h["f1"] for h in r["history"]]), r["final"])


@pytest.mark.parametrize("mode", ["devertifl", "non_federated",
                                  "verticomb"])
@pytest.mark.parametrize("fl", ["masked", "slice", "pallas"])
@pytest.mark.parametrize("padded", [False, True])
def test_session_reproduces_legacy_bitwise(mode, fl, padded):
    """build(spec).run() == DeVertiFL(ProtocolConfig(...)).train() for
    every mode x first_layer x {padded, unpadded} lane: loss
    trajectories, per-round F1, and final metrics all exactly equal."""
    max_clients = 6 if padded else None
    pcfg = ProtocolConfig(mode=mode, seed=0, first_layer=fl,
                          max_clients=max_clients, **TINY)
    losses, f1s, final = _legacy_traj(pcfg)
    rr = build(ExperimentSpec(mode=mode, seeds=(0,), first_layer=fl,
                              max_clients=max_clients, **TINY)).run()
    np.testing.assert_array_equal(
        np.concatenate([h["round_losses"] for h in rr.history]), losses)
    np.testing.assert_array_equal(
        np.array([h["f1"] for h in rr.history]), f1s)
    assert rr.metrics == final


def test_session_python_engine_matches_legacy():
    pcfg = ProtocolConfig(engine="python", seed=1, **TINY)
    _, _, final = _legacy_traj(pcfg)
    rr = build(ExperimentSpec(engine="python", seeds=(1,), **TINY)).run()
    assert rr.metrics == final


def test_multi_seed_session_matches_run_cell():
    seeds = (0, 1)
    rr = build(ExperimentSpec(seeds=seeds, **TINY)).run()
    cell = run_cell("titanic", "devertifl", TINY["n_clients"],
                    SweepConfig(seeds=seeds, rounds=TINY["rounds"],
                                epochs=TINY["epochs"]))
    assert rr.metrics["f1"] == cell["f1_mean"]
    assert rr.metrics["f1_per_seed"] == cell["f1_per_seed"]
    assert rr.metrics["acc_per_seed"] == cell["acc_per_seed"]
    assert rr.metrics["final_loss_mean"] == cell["final_loss_mean"]


def test_run_padded_cells_accepts_alias_mode_argument():
    """Spec grids canonicalize mode aliases; the mode *argument* must
    resolve through the registry too, so the alias doesn't falsely
    mismatch its own canonical name."""
    from repro.core.sweep import run_padded_cells
    specs = spec_grid(datasets=("titanic",),
                      modes=("backward_exchange",), client_counts=(2,),
                      seeds=(0,), rounds=1, epochs=1)
    out = run_padded_cells("titanic", "backward_exchange", specs)
    assert set(out["cells"]) == {2}


def test_spec_grid_matches_legacy_run_grid():
    """api.run_grid over a spec grid == sweep.run_grid over the
    equivalent SweepConfig (PR 3's padded engine), cell for cell."""
    kw = dict(datasets=("titanic",),
              modes=("devertifl", "non_federated"),
              client_counts=(2, 3), seeds=(0,))
    specs = spec_grid(rounds=1, epochs=1, **kw)
    assert len(specs) == 4
    g_api = run_grid(specs)
    g_old = sweep_run_grid(SweepConfig(rounds=1, epochs=1, **kw))
    assert set(g_api["cells"]) == set(g_old["cells"])
    for k, old in g_old["cells"].items():
        new = dict(g_api["cells"][k])
        assert new.pop("spec_hash")
        for kk, v in old.items():
            if kk in ("wall_s", "steps_per_sec"):
                continue            # timings are not deterministic
            assert new[kk] == v, (k, kk)
    assert g_api["compare"] == g_old["compare"]


def test_splitnn_session_matches_baseline():
    spec = ExperimentSpec(dataset="bank", mode="splitnn", n_clients=2,
                          rounds=1, epochs=2, n_samples=1500)
    rr = build(spec).run()
    legacy = SplitNN(SplitNNConfig(dataset="bank", n_clients=2,
                                   rounds=1, epochs=2,
                                   n_samples=1500)).train()
    assert rr.metrics == legacy
    # params are kept so predict() works
    assert rr.params is not None


# ---------------------------------------------------------------------------
# the train_federation deprecation shim
# ---------------------------------------------------------------------------
def test_train_federation_shim_warns_and_matches_legacy():
    kw = dict(seed=2, **TINY)
    with pytest.warns(DeprecationWarning, match="ExperimentSpec"):
        out = train_federation(**kw)
    legacy = DeVertiFL(ProtocolConfig(**kw)).train()
    assert out["final"] == legacy["final"]
    np.testing.assert_array_equal(
        np.concatenate([h["round_losses"] for h in out["history"]]),
        np.concatenate([h["round_losses"] for h in legacy["history"]]))
    for leaf_a, leaf_b in zip(jax.tree.leaves(out["params"]),
                              jax.tree.leaves(legacy["params"])):
        np.testing.assert_array_equal(np.asarray(leaf_a),
                                      np.asarray(leaf_b))


# ---------------------------------------------------------------------------
# checkpointing: Session wiring + padded round-trips
# ---------------------------------------------------------------------------
def test_session_checkpoint_resume_bitwise(tmp_path):
    """resume() from the latest checkpoint continues bit-for-bit where
    the uninterrupted run would be: identical round losses and final
    metrics (round r consumes only carried state + fold_in(key, r))."""
    d = str(tmp_path / "ckpt")
    full = build(ExperimentSpec(dataset="titanic", rounds=4, epochs=1,
                                seeds=(0,))).run()
    build(ExperimentSpec(dataset="titanic", rounds=2, epochs=1,
                         seeds=(0,), checkpoint_dir=d,
                         checkpoint_every=1)).run()
    res = build(ExperimentSpec(dataset="titanic", rounds=4, epochs=1,
                               seeds=(0,), checkpoint_dir=d,
                               checkpoint_every=1)).resume()
    assert res.resumed_from == 2
    assert res.metrics == full.metrics
    for i, r in enumerate((2, 3)):
        assert res.history[i]["round"] == r
        np.testing.assert_array_equal(res.history[i]["round_losses"],
                                      full.history[r]["round_losses"])
    # resume with no checkpoints is a fresh run
    fresh = build(ExperimentSpec(dataset="titanic", rounds=2, epochs=1,
                                 seeds=(0,),
                                 checkpoint_dir=str(tmp_path / "empty"),
                                 checkpoint_every=1)).resume()
    assert fresh.resumed_from is None
    # a checkpoint BEYOND spec.rounds must not masquerade as this
    # spec's run (the spec_hash joinability contract)
    with pytest.raises(ValueError, match="beyond spec.rounds"):
        build(ExperimentSpec(dataset="titanic", rounds=1, epochs=1,
                             seeds=(0,), checkpoint_dir=d,
                             checkpoint_every=1)).resume()
    # ...and neither may another experiment's checkpoint in a reused
    # dir (resume_hash is rounds-blind but forks on lr/seed/etc)
    with pytest.raises(ValueError, match="resume_hash"):
        build(ExperimentSpec(dataset="titanic", rounds=6, epochs=1,
                             seeds=(0,), lr=1e-2, checkpoint_dir=d,
                             checkpoint_every=1)).resume()


def test_padded_session_predict_trims_dead_slots():
    sess = build(ExperimentSpec(dataset="titanic", rounds=1, epochs=1,
                                seeds=(0,), n_clients=3, max_clients=5))
    sess.run()
    preds = sess.predict(np.zeros((4, 9), np.float32))
    assert np.asarray(preds).shape == (3, 4)   # live clients only


@pytest.mark.fast
def test_checkpoint_roundtrips_padded_trees(tmp_path):
    """Padded per-client param/opt trees (dead client slots, empty
    arrays) and NamedTuple nodes (LayoutArrays) round-trip through
    save/load unchanged -- values, dtypes, and structure."""
    from repro.checkpoint import (latest_step, load_checkpoint,
                                  save_checkpoint)
    from repro.configs import get_config
    from repro.core import partition as PT
    from repro.models.mlp_model import PaperMLP
    from repro.optim import adam

    model = PaperMLP(get_config("paper-mlp-titanic"))
    params = init_padded_params(model, jax.random.PRNGKey(0), 3, 8)
    opt_state = jax.vmap(adam(1e-3).init)(params)
    lay = PT.make_layout("titanic", 9, 3, seed=0, max_clients=8).arrays()
    tree = {"params": params, "opt_state": opt_state, "lay": lay,
            "step_idx": jnp.zeros((), jnp.int32),
            "empty": jnp.zeros((0, 5))}
    save_checkpoint(str(tmp_path), 3, tree)
    assert latest_step(str(tmp_path)) == 3
    restored = load_checkpoint(str(tmp_path), 3, tree)
    assert jax.tree.structure(restored) == jax.tree.structure(tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert np.asarray(a).dtype == np.asarray(b).dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # loading into a differently-padded like_tree fails actionably
    bad_like = dict(tree,
                    params=init_padded_params(model,
                                              jax.random.PRNGKey(0), 3, 6))
    with pytest.raises(ValueError, match="padded"):
        load_checkpoint(str(tmp_path), 3, bad_like)


# ---------------------------------------------------------------------------
# RunResult record
# ---------------------------------------------------------------------------
def test_run_result_schema_and_serialization():
    rr = build(ExperimentSpec(dataset="titanic", rounds=1, epochs=1,
                              seeds=(0,))).run()
    assert isinstance(rr, RunResult) and rr.schema_version == 5
    assert rr.spec_hash == rr.spec.spec_hash and len(rr.spec_hash) == 16
    d = json.loads(json.dumps(rr.to_dict()))
    assert d["schema_version"] == 5
    assert d["spec"]["dataset"] == "titanic"
    assert {"metrics", "history", "timings", "git_sha",
            "spec_hash"} <= set(d)
    assert "params" not in d
    # predict() rides the last run's params
    sess = build(ExperimentSpec(dataset="titanic", rounds=1, epochs=1,
                                seeds=(0,)))
    out = sess.run()
    preds = sess.predict(np.zeros((4, 9), np.float32))
    assert np.asarray(preds).shape == (3, 4)
    assert out.params is not None
