"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional test extra: pip install hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.exchange import fedavg, hidden_output_exchange
from repro.core.partition import make_partition
from repro.kernels.rwkv6_scan import rwkv6_scan_ref
from repro.metrics import f1_score
from repro.models.model import padded_vocab


# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(n_features=st.integers(2, 900), n_clients=st.integers(1, 10),
       ds=st.sampled_from(["titanic", "bank"]))
def test_partition_disjoint_complete(n_features, n_clients, ds):
    """Vertical partitioning covers every feature exactly once for any
    (features, clients) combination."""
    part = make_partition(ds, n_features, n_clients)
    allidx = np.concatenate(part) if len(part) else np.array([])
    assert sorted(allidx.tolist()) == list(range(n_features))


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 8), b=st.integers(1, 5), h=st.integers(1, 7))
def test_exchange_is_sum_invariant(n, b, h):
    """Exchange output is invariant to client permutation and equals the
    sum for every client (Algorithm 2)."""
    x = np.random.RandomState(0).randn(n, b, h).astype(np.float32)
    out = np.asarray(hidden_output_exchange(jnp.asarray(x)))
    perm = np.random.RandomState(1).permutation(n)
    out_p = np.asarray(hidden_output_exchange(jnp.asarray(x[perm])))
    np.testing.assert_allclose(out[perm], out_p, atol=1e-5)
    np.testing.assert_allclose(out, np.broadcast_to(x.sum(0), out.shape),
                               atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 6))
def test_fedavg_idempotent(n):
    """FedAvg twice == FedAvg once (averaging identical replicas)."""
    tree = {"w": jnp.asarray(np.random.RandomState(n).randn(n, 3, 3))}
    once = fedavg(tree)
    twice = fedavg(once)
    np.testing.assert_allclose(np.asarray(once["w"]),
                               np.asarray(twice["w"]), atol=1e-6)


# ---------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(labels=st.lists(st.integers(0, 3), min_size=2, max_size=60),
       preds=st.lists(st.integers(0, 3), min_size=2, max_size=60))
def test_f1_bounds_and_perfect(labels, preds):
    n = min(len(labels), len(preds))
    y, p = np.array(labels[:n]), np.array(preds[:n])
    f1 = f1_score(y, p, "macro")
    assert 0.0 <= f1 <= 1.0
    assert f1_score(y, y, "macro") == 1.0


@settings(max_examples=10, deadline=None)
@given(v=st.integers(1, 300000))
def test_padded_vocab_properties(v):
    p = padded_vocab(v)
    assert p >= v and p % 128 == 0 and p - v < 128


# ---------------------------------------------------------------------------
@settings(max_examples=8, deadline=None)
@given(split=st.integers(1, 7))
def test_rwkv_scan_state_composition(split):
    """Running the WKV scan on [0,T) equals running [0,s) then [s,T)
    with the carried state -- the invariant that makes chunked kernels
    and decode-from-prefill correct."""
    B, T, H, hd = 1, 8, 2, 8
    rng = np.random.RandomState(split)
    r, k, v = (jnp.asarray(rng.randn(B, T, H, hd), jnp.float32)
               for _ in range(3))
    w = jnp.asarray(1 / (1 + np.exp(-rng.randn(B, T, H, hd))) * 0.5 + 0.4,
                    jnp.float32)
    u = jnp.asarray(rng.randn(H, hd) * 0.2, jnp.float32)
    full = rwkv6_scan_ref(r, k, v, w, u)

    s = split % T
    if s == 0:
        return
    # manual scan with state carry across the split
    def scan_with_state(r, k, v, w, S0):
        def step(S, inp):
            ri, ki, vi, wi = inp
            kv = ki[..., :, None] * vi[..., None, :]
            o = jnp.einsum("bhk,bhkv->bhv", ri, S + u[..., :, None] * kv)
            S = wi[..., :, None] * S + kv
            return S, o
        args = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
        S, o = jax.lax.scan(step, S0, args)
        return S, jnp.moveaxis(o, 0, 1)

    S0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    S1, o1 = scan_with_state(r[:, :s], k[:, :s], v[:, :s], w[:, :s], S0)
    _, o2 = scan_with_state(r[:, s:], k[:, s:], v[:, s:], w[:, s:], S1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([o1, o2], 1)),
                               np.asarray(full), atol=1e-4, rtol=1e-4)
