"""The scan-based federation engine and the seed-vmapped sweep.

  * the fused lax.scan round reproduces the per-batch Python reference
    loop bit-for-bit (both consume the same device permutation stream)
  * a sweep lane is bit-for-bit the standalone DeVertiFL run of the
    same seed
  * sweep smoke test: the paper's collaboration gain (devertifl >=
    non_federated F1) on the synthetic titanic task
"""
import jax
import numpy as np
import pytest

from repro.core.protocol import DeVertiFL, ProtocolConfig
from repro.core.sweep import SweepConfig, run_cell, run_grid


def _losses(result):
    return np.concatenate([h["round_losses"] for h in result["history"]])


@pytest.mark.parametrize("mode", ["devertifl", "non_federated",
                                  "verticomb"])
def test_scan_matches_python_loop(mode):
    """Same seed => the scan engine's loss trajectory and final F1 equal
    the reference per-batch loop's, bit for bit."""
    pcfg = ProtocolConfig(dataset="titanic", n_clients=3, rounds=2,
                          epochs=2, mode=mode, seed=0)
    scan = DeVertiFL(pcfg).train(engine="scan")
    loop = DeVertiFL(pcfg).train(engine="python")
    np.testing.assert_array_equal(_losses(scan), _losses(loop))
    assert scan["final"]["f1"] == loop["final"]["f1"]
    assert scan["final"]["acc"] == loop["final"]["acc"]


def test_scan_step_count_and_fedavg():
    """A round runs epochs * (n // bs) steps and ends FedAvg-synced."""
    pcfg = ProtocolConfig(dataset="titanic", n_clients=3, rounds=1,
                          epochs=3, batch_size=128, seed=0)
    fed = DeVertiFL(pcfg)
    r = fed.train()
    n_batches = len(fed.xtr) // min(pcfg.batch_size, len(fed.xtr))
    assert len(r["history"][0]["round_losses"]) == pcfg.epochs * n_batches
    # round-end FedAvg (folded into the jitted round) synced the clients
    for leaf in jax.tree.leaves(r["params"]):
        arr = np.asarray(leaf)
        np.testing.assert_allclose(arr, np.broadcast_to(arr[:1], arr.shape),
                                   rtol=1e-6, atol=1e-7)


def test_set_fedavg_reaches_scan_round():
    """Custom aggregation must be baked into the jitted scan round --
    a zeroing aggregator leaves all-zero params after one round."""
    fed = DeVertiFL(ProtocolConfig(dataset="titanic", n_clients=2,
                                   rounds=1, epochs=1, seed=0))
    fed.set_fedavg(lambda p: jax.tree.map(lambda l: l * 0.0, p))
    r = fed.train(eval_every_round=False)
    for leaf in jax.tree.leaves(r["params"]):
        assert float(np.abs(np.asarray(leaf)).max()) == 0.0


def test_sweep_lane_matches_standalone():
    """Seed lane s of a sweep cell == DeVertiFL(seed=s).train()."""
    seeds = (0, 1)
    cell = run_cell("titanic", "non_federated", 3,
                    SweepConfig(seeds=seeds, rounds=3, epochs=2))
    for i, s in enumerate(seeds):
        solo = DeVertiFL(ProtocolConfig(
            dataset="titanic", n_clients=3, rounds=3, epochs=2,
            mode="non_federated", seed=s)).train(eval_every_round=False)
        assert cell["f1_per_seed"][i] == solo["final"]["f1"]


@pytest.mark.slow
def test_sweep_devertifl_beats_non_federated():
    """Paper's core claim, asserted through the sweep engine on the
    synthetic titanic task (3 seeds, one compilation per mode)."""
    scfg = SweepConfig(seeds=(0, 1, 2), rounds=6, epochs=4)
    grid = run_grid(scfg.__class__(
        datasets=("titanic",), modes=("devertifl", "non_federated"),
        client_counts=(3,), seeds=scfg.seeds, rounds=scfg.rounds,
        epochs=scfg.epochs))
    cmp = grid["compare"]["titanic/3"]
    assert cmp["devertifl"] >= cmp["non_federated"], cmp
