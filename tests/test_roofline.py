"""Roofline machinery unit tests: HLO parsing, loop-aware multipliers,
wire-byte formulas."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.roofline.analysis import (collective_bytes_from_hlo,
                                     roofline_terms)
from repro.roofline.hlo_costs import analyze, split_computations


def test_roofline_terms_bottleneck():
    t = roofline_terms(197e12, 0.0, 0.0)     # exactly 1s of compute
    assert abs(t["compute_s"] - 1.0) < 1e-9
    assert t["bottleneck"] == "compute"
    t = roofline_terms(0.0, 819e9, 50e9 * 3)
    assert t["bottleneck"] == "collective"
    assert abs(t["memory_s"] - 1.0) < 1e-9


def _compile_hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_hlo_dot_flops_counted():
    a = jnp.zeros((128, 256), jnp.float32)
    b = jnp.zeros((256, 64), jnp.float32)
    txt = _compile_hlo(lambda x, y: x @ y, a, b)
    t = analyze(txt)
    expect = 2 * 128 * 256 * 64
    assert abs(t["flops"] - expect) / expect < 0.05, t["flops"]


def test_hlo_loop_multiplier():
    """A scan of 10 matmuls must count 10x the flops of one matmul."""
    a = jnp.zeros((64, 64), jnp.float32)

    def one(x):
        return x @ x

    def looped(x):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    t1 = analyze(_compile_hlo(one, a))
    t10 = analyze(_compile_hlo(looped, a))
    ratio = t10["flops"] / max(t1["flops"], 1)
    assert 8 <= ratio <= 12, ratio


def test_collective_regex_parses_groups():
    hlo = """
ENTRY %main (p: f32[256,128]) -> f32[256,128] {
  %p = f32[256,128] parameter(0)
  ROOT %all-reduce.1 = f32[256,128] all-reduce(%p), replica_groups=[16,16]<=[256], to_apply=%add
}
"""
    out = collective_bytes_from_hlo(hlo)
    size = 256 * 128 * 4
    expect = 2 * size * 15 / 16
    assert abs(out["all-reduce"] - expect) < 1, out


def test_split_computations_finds_entry():
    hlo = """
%helper (x: f32[2]) -> f32[2] {
  %x = f32[2] parameter(0)
  ROOT %neg = f32[2] negate(%x)
}

ENTRY %main (p: f32[2]) -> f32[2] {
  %p = f32[2] parameter(0)
  ROOT %c = f32[2] call(%p), to_apply=%helper
}
"""
    comps, entry = split_computations(hlo)
    assert entry == "main"
    assert "helper" in comps
