"""The repro.faults fault-injection / guard / recovery subsystem.

Contracts pinned here (docs/ARCHITECTURE.md section 9):

  * fault spec parsing/canonicalization and the registry's actionable
    errors (+ register_fault extension)
  * fault="none" IS the legacy engine (spec hashes pinned against the
    pre-fault values; no fault telemetry in timings), and non-none
    plans fork spec/resume hashes
  * injected faults are deterministic (same spec -> bitwise the same
    trajectory), padding-invariant, and identical across the scan and
    python engines
  * the exchange guard screens corrupted payloads: corrupt:1.0 runs
    keep every loss finite, quarantine exactly the corrupted
    client-rounds, and drop them from FedAvg
  * fault x schedule x count sweep lanes compile ONCE
    (round_traces == 1) with the "none" lanes bitwise equal to the
    fault-free sweep
  * the divergence watchdog rolls back to the last good state and
    retries under a reseeded key; exhausted retries raise
    DivergenceError with the knobs to turn
  * resume() skips corrupt/truncated checkpoints to the newest intact
    one, and a checkpoint's schedule|fault stream stamp refuses
    cross-plan resumes
  * metrics refuse non-finite inputs instead of scoring them
  * the static auditor stays clean over faulted combos
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ExperimentSpec, build, run_grid, spec_grid
from repro.core.exchange import screen_exchange
from repro.core.protocol import DeVertiFL, ProtocolConfig, train_keys
from repro.core.sweep import SweepConfig, run_cell, run_padded_cells
from repro.faults import (GUARD_MAX, RESEED_TAG, DivergenceError,
                          RetryPolicy, diverged, fault_names,
                          get_fault_plan, make_fault_impl,
                          register_fault)
from repro.schedule import LaneScheduleImpl, get_schedule

TINY = dict(dataset="titanic", n_clients=3, rounds=2, epochs=2, seed=0)
# a composite plan exercising all three built-in families at once
HOT = "crash:0.5:2+straggle:0.5:1+corrupt:0.5"


def _traj(pcfg, engine=None):
    r = DeVertiFL(pcfg).train(engine=engine)
    return (np.concatenate([h["round_losses"] for h in r["history"]]),
            np.array([h["f1"] for h in r["history"]]),
            r["final"])


# ---------------------------------------------------------------------------
# a test-only custom fault: NaN-poisons the whole exchange for a round
# when a coin drawn from the ROUND KEY comes up heads, so the only way
# past it is the watchdog's reseeded retry (rolling back without
# reseeding would replay the same coin forever)
# ---------------------------------------------------------------------------
_POISON_TAG = 0x0BAD


class _PoisonImpl:
    def __init__(self, inner, p):
        self.inner, self.p = inner, p

    def init_state(self, sched):
        return {"inner": self.inner.init_state(sched),
                "poison": jnp.zeros((), jnp.float32)}

    def round_start(self, state, lay, key, round_idx):
        inner, eff = self.inner.round_start(state["inner"], lay, key,
                                            round_idx)
        coin = jax.random.bernoulli(
            jax.random.fold_in(key, _POISON_TAG), self.p)
        return {"inner": inner,
                "poison": coin.astype(jnp.float32)}, eff

    def select(self, state, h_now):
        h_ref, inner = self.inner.select(state["inner"], h_now)
        h_ref = jnp.where(state["poison"] > 0,
                          jnp.full_like(h_ref, jnp.nan), h_ref)
        return h_ref, {**state, "inner": inner}

    def round_end(self, state):
        return {**state, "inner": self.inner.round_end(state["inner"])}


register_fault(
    "test_poison",
    lambda inner, n_clients, batch_size, width, args: _PoisonImpl(
        inner, float(args[0]) if args else 0.5),
    overwrite=True)


def _poison_draws(seed, p=0.5):
    """Replay the session's key derivation for round 0: the canonical
    round key and its attempt-1 reseed, each folded with the poison
    tag -- (coin(attempt 0), coin(attempt 1))."""
    _, loop_key = train_keys(jax.random.PRNGKey(seed))
    rk0 = jax.random.fold_in(loop_key, 0)
    rk1 = jax.random.fold_in(
        jax.random.fold_in(rk0, RESEED_TAG), 1)

    def coin(k):
        return bool(jax.random.bernoulli(
            jax.random.fold_in(k, _POISON_TAG), p))

    return coin(rk0), coin(rk1)


# ---------------------------------------------------------------------------
# registry + parsing
# ---------------------------------------------------------------------------
@pytest.mark.fast
def test_fault_parsing_and_canonicalization():
    assert get_fault_plan("none").is_none
    p = get_fault_plan("crash:0.2")
    assert (p.crash, p.crash_dur, p.spec) == (0.2, 1, "crash:0.2")
    # default args normalize away; non-defaults survive
    assert get_fault_plan("crash:0.2:1").spec == "crash:0.2"
    assert get_fault_plan("crash:0.2:3").spec == "crash:0.2:3"
    assert get_fault_plan("corrupt:0.05:nan").spec == "corrupt:0.05"
    s = get_fault_plan("straggle:0.5:2")
    assert (s.straggle, s.straggle_d, s.max_delay) == (0.5, 2, 2)
    c = get_fault_plan("corrupt:0.05:scale")
    assert (c.corrupt, c.corrupt_kind) == (0.05, "scale")
    # composition canonicalizes to crash/straggle/corrupt order
    combo = get_fault_plan("corrupt:0.1+crash:0.3")
    assert combo.spec == "crash:0.3+corrupt:0.1"
    assert (combo.crash_p, combo.straggle_p, combo.corrupt_p) == \
        (0.3, 0.0, 0.1)
    assert (combo.max_dur, combo.max_delay) == (1, 0)
    assert not combo.is_none
    # FaultPlan objects pass through
    assert get_fault_plan(combo) is combo
    for name in ("none", "crash", "straggle", "corrupt",
                 "test_poison"):
        assert name in fault_names()


@pytest.mark.fast
def test_fault_parse_errors_are_actionable():
    with pytest.raises(ValueError) as e:
        get_fault_plan("gremlins:0.5")
    for name in ("crash", "straggle", "corrupt"):
        assert name in str(e.value)
    for bad, frag in [("crash:0", "0 < p <= 1"),
                      ("crash:1.5", "0 < p <= 1"),
                      ("crash:0.2:0", "dur >= 1"),
                      ("crash", "probability"),
                      ("straggle:0.5", "delay"),
                      ("straggle:0.5:0", "delay >= 1"),
                      ("corrupt:0.1:flip", "'nan' or 'scale'"),
                      ("corrupt:x", "float probability"),
                      ("none:1", "no arguments"),
                      ("none+crash:0.2", "compose"),
                      ("test_poison:0.5+crash:0.2", "compose"),
                      ("crash:0.2+crash:0.3", "duplicate"),
                      ("+crash:0.2", "malformed")]:
        with pytest.raises(ValueError, match=frag):
            get_fault_plan(bad)


# ---------------------------------------------------------------------------
# spec integration + hash stability
# ---------------------------------------------------------------------------
@pytest.mark.fast
def test_none_spec_hash_unchanged_and_fault_forks():
    """The fault field must not fork pre-existing spec ids (pinned
    against the hashes recorded BEFORE the fault axis existed), while
    non-none plans get their own ids and formatting cannot fork them."""
    spec = ExperimentSpec(dataset="titanic", n_clients=3, rounds=2,
                          epochs=1)
    assert spec.fault == "none"
    assert spec.spec_hash == "58715f95206928f5"      # pre-PR-5 value
    assert spec.resume_hash == "48945ac24cd700a7"    # pre-PR-5 value
    hot = spec.replace(fault="crash:0.2")
    assert hot.spec_hash != spec.spec_hash
    assert hot.resume_hash != spec.resume_hash
    assert spec.replace(fault="crash:0.2:1").spec_hash == hot.spec_hash
    assert spec.replace(fault="corrupt:0.1:nan").spec_hash == \
        spec.replace(fault="corrupt:0.1").spec_hash


@pytest.mark.fast
def test_spec_fault_validation():
    with pytest.raises(ValueError) as e:
        ExperimentSpec(dataset="titanic", fault="nope")
    assert "crash" in str(e.value)
    for mode in ("non_federated", "verticomb", "splitnn"):
        with pytest.raises(ValueError, match="devertifl"):
            ExperimentSpec(dataset="titanic", mode=mode,
                           fault="crash:0.2")
        # fault-free specs run everywhere
        ExperimentSpec(dataset="titanic", mode=mode, fault="none")


# ---------------------------------------------------------------------------
# guard + fault-layer unit contracts
# ---------------------------------------------------------------------------
@pytest.mark.fast
def test_screen_exchange_quarantines_bad_slices():
    payload = jnp.stack([jnp.full((2, 3), jnp.nan),
                         jnp.full((2, 3), 2.0 * GUARD_MAX),
                         jnp.ones((2, 3))])
    last_good = jnp.full((3, 2, 3), 7.0)
    screened, bad = screen_exchange(payload, last_good, GUARD_MAX)
    np.testing.assert_array_equal(np.asarray(bad),
                                  [True, True, False])
    # bad slices are REPLACED (masking after the sum would still
    # poison it: NaN * 0.0 is NaN), good ones untouched
    np.testing.assert_array_equal(np.asarray(screened[0]),
                                  np.full((2, 3), 7.0))
    np.testing.assert_array_equal(np.asarray(screened[1]),
                                  np.full((2, 3), 7.0))
    np.testing.assert_array_equal(np.asarray(screened[2]),
                                  np.ones((2, 3)))
    assert np.isfinite(np.asarray(screened)).all()


@pytest.mark.fast
def test_fedavg_mask_drops_quarantined_with_fallback():
    inner = LaneScheduleImpl(0, 3, 4, 5)
    impl = make_fault_impl(get_fault_plan("corrupt:0.5"), inner,
                           3, 4, 5)
    st = impl.init_state(get_schedule("sync"))
    eff = jnp.ones((3,), jnp.float32)
    st = {**st, "quar": jnp.asarray([1.0, 0.0, 0.0], jnp.float32)}
    np.testing.assert_array_equal(
        np.asarray(impl.fedavg_mask(st, eff)), [0.0, 1.0, 1.0])
    # all-quarantined rounds fall back to the unmasked round (an
    # all-zero FedAvg weighting would zero the params)
    st = {**st, "quar": jnp.ones((3,), jnp.float32)}
    np.testing.assert_array_equal(
        np.asarray(impl.fedavg_mask(st, eff)), np.asarray(eff))
    # an impl sized for a shallow ring refuses deeper plans
    with pytest.raises(ValueError, match="straggler ring"):
        impl.init_state(get_schedule("sync"),
                        plan=get_fault_plan("straggle:0.5:3"))


@pytest.mark.fast
def test_retry_policy_validation_and_backoff():
    p = RetryPolicy(max_retries=3, backoff=1.0, backoff_cap=3.0)
    assert (p.sleep_s(1), p.sleep_s(2), p.sleep_s(3)) == \
        (1.0, 2.0, 3.0)                          # capped exponential
    assert RetryPolicy().sleep_s(5) == 0.0       # default: no sleep
    for kw in (dict(max_retries=-1), dict(backoff=-1.0),
               dict(loss_threshold=0.0)):
        with pytest.raises(ValueError):
            RetryPolicy(**kw)
    assert diverged([1.0, np.nan], 1e4)
    assert diverged([1.0, -2e4], 1e4)
    assert not diverged([1.0, 2.0], 1e4)


@pytest.mark.fast
def test_metrics_refuse_nonfinite():
    from repro.metrics.classification import accuracy, f1_score
    y = np.array([0, 1, 1, 0])
    assert accuracy(y, y) == 1.0
    bad = np.array([0.0, np.nan, 1.0, np.inf])
    with pytest.raises(ValueError,
                       match="y_pred contains 2 non-finite"):
        accuracy(y, bad)
    with pytest.raises(ValueError, match="y_true"):
        f1_score(bad, y)
    # finite floats (and integer labels, always) pass
    assert f1_score(y.astype(np.float32),
                    y.astype(np.float32)) == 1.0


# ---------------------------------------------------------------------------
# injection determinism + engine/padding equivalences
# ---------------------------------------------------------------------------
def test_fault_injection_deterministic_and_differs_from_none():
    """Same plan -> bitwise the same trajectory (fold_in coins); a hot
    plan actually changes the trajectory; the guard keeps every loss
    finite through it."""
    hot = ProtocolConfig(fault="crash:0.5:2+corrupt:0.5", **TINY)
    l1, f1, fin1 = _traj(hot)
    l2, f2, fin2 = _traj(hot)
    np.testing.assert_array_equal(l1, l2)
    np.testing.assert_array_equal(f1, f2)
    assert fin1 == fin2
    l0, _, _ = _traj(ProtocolConfig(**TINY))
    assert not np.array_equal(l0, l1)
    assert np.isfinite(l1).all()


def test_fault_padding_invariance():
    """A padded federation draws the same fates for its live clients
    as its unpadded twin: per-slot fold_in coins, dead slots masked."""
    hot = ProtocolConfig(fault=HOT, **TINY)
    l0, _, fin0 = _traj(hot)
    l1, _, fin1 = _traj(hot.replace(max_clients=6))
    np.testing.assert_array_equal(l0, l1)
    assert fin0 == fin1


@pytest.mark.parametrize("fault,sched", [
    ("crash:0.5", "sync"),
    ("straggle:0.7:2", "sync"),
    (HOT, "stale_k:2"),
])
def test_scan_matches_python_engine_under_faults(fault, sched):
    pcfg = ProtocolConfig(schedule=sched, fault=fault, **TINY)
    l_scan, f_scan, fin_scan = _traj(pcfg, engine="scan")
    l_py, f_py, fin_py = _traj(pcfg, engine="python")
    np.testing.assert_array_equal(l_scan, l_py)
    np.testing.assert_array_equal(f_scan, f_py)
    assert fin_scan == fin_py


# ---------------------------------------------------------------------------
# the exchange guard end to end
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind", ["nan", "scale"])
def test_corrupt_guard_quarantines_and_losses_stay_finite(kind):
    """corrupt:1.0 poisons every client's payload every round; the
    guard quarantines all of them (telemetry counts client-rounds),
    losses and metrics stay finite, and the watchdog never trips."""
    spec = ExperimentSpec(dataset="titanic", n_clients=3, rounds=2,
                          epochs=1, seeds=(0,),
                          fault=f"corrupt:1.0:{kind}")
    res = build(spec).run()
    tel = res.timings["fault"]
    assert tel["corruptions"] == 3 * 2       # every client, every round
    assert tel["quarantined"] == tel["corruptions"]
    assert tel["crashes"] == tel["straggles"] == 0
    assert (tel["watchdog_trips"], tel["retries"]) == (0, 0)
    losses = np.concatenate([h["round_losses"] for h in res.history])
    assert np.isfinite(losses).all()
    assert np.isfinite(res.metrics["f1"])


def test_none_keeps_legacy_path_without_fault_timings():
    res = build(ExperimentSpec(dataset="titanic", n_clients=2,
                               rounds=1, epochs=1, seeds=(0,))).run()
    assert "fault" not in res.timings


# ---------------------------------------------------------------------------
# fault lanes in the sweep engine
# ---------------------------------------------------------------------------
def test_fault_grid_compiles_once_and_none_lane_is_exact():
    """A faults x schedules x counts batch compiles its round ONCE
    (rates/durations/kind are traced per-lane state), its "none" lanes
    equal the fault-free sweep bitwise, and its faulted cells carry
    telemetry."""
    counts, seeds = (2, 3), (0,)
    scheds = ("sync", "stale_k:1")
    faults = ("none", "crash:0.5:2+corrupt:0.5")
    out = run_padded_cells(
        "titanic", "devertifl",
        SweepConfig(client_counts=counts, seeds=seeds, rounds=2,
                    epochs=1, schedules=scheds, faults=faults))
    assert out["round_traces"] == 1, out
    assert out["lanes"] == \
        len(faults) * len(scheds) * len(counts) * len(seeds)
    assert set(out["cells"]) == {f"{f}/{sc}/{nc}" for f in faults
                                 for sc in scheds for nc in counts}
    assert out["faults"] == list(faults)
    ref = run_padded_cells(
        "titanic", "devertifl",
        SweepConfig(client_counts=counts, seeds=seeds, rounds=2,
                    epochs=1, schedules=scheds))
    for sc in scheds:
        for nc in counts:
            assert out["cells"][f"none/{sc}/{nc}"]["f1_per_seed"] == \
                ref["cells"][f"{sc}/{nc}"]["f1_per_seed"]
            assert out["cells"][f"none/{sc}/{nc}"]["final_loss_mean"] \
                == ref["cells"][f"{sc}/{nc}"]["final_loss_mean"]
    hot = out["cells"]["crash:0.5:2+corrupt:0.5/stale_k:1/3"]
    assert hot["fault"] == "crash:0.5:2+corrupt:0.5"
    tel = hot["fault_telemetry"]
    assert set(tel) == {"crashes", "straggles", "corruptions",
                        "quarantined"}
    assert tel["quarantined"] == tel["corruptions"]


def test_fault_sweep_rejects_bad_combinations():
    base = dict(client_counts=(2,), seeds=(0,), rounds=1, epochs=1)
    with pytest.raises(ValueError, match="one fault plan"):
        run_cell("titanic", "devertifl", 2,
                 SweepConfig(faults=("none", "crash:0.2"), **base))
    with pytest.raises(ValueError, match="devertifl"):
        run_padded_cells("titanic", "non_federated",
                         SweepConfig(faults=("crash:0.2",), **base))
    with pytest.raises(ValueError, match="custom fault plans"):
        run_padded_cells("titanic", "devertifl",
                         SweepConfig(faults=("test_poison:0.5",),
                                     **base))


def test_spec_grid_fault_axis_and_run_grid_keys():
    """spec_grid grows a faults axis; run_grid prepends the plan to
    non-default cell keys and stamps spec hashes."""
    specs = spec_grid(datasets=("titanic",), modes=("devertifl",),
                      client_counts=(2,), seeds=(0,),
                      faults=("none", "crash:0.5"), rounds=1, epochs=1)
    assert len(specs) == 2
    assert [s.fault for s in specs] == ["none", "crash:0.5"]
    grid = run_grid(specs)
    assert set(grid["cells"]) == {"titanic/devertifl/none/sync/2",
                                  "titanic/devertifl/crash:0.5/sync/2"}
    for cell in grid["cells"].values():
        assert cell["spec_hash"]


# ---------------------------------------------------------------------------
# divergence recovery
# ---------------------------------------------------------------------------
def test_watchdog_rolls_back_and_reseeds_past_a_poisoned_round():
    """Pick a seed whose poison coin is heads on the canonical round
    key and tails on the attempt-1 reseed: the run must trip once,
    roll back, retry reseeded, and finish finite."""
    seed = next(s for s in range(64)
                if _poison_draws(s) == (True, False))
    spec = ExperimentSpec(dataset="titanic", n_clients=3, rounds=1,
                          epochs=1, seeds=(seed,),
                          fault="test_poison:0.5")
    res = build(spec).run(retry=RetryPolicy(max_retries=2))
    assert res.timings["fault"] == {"watchdog_trips": 1, "retries": 1}
    losses = np.concatenate([h["round_losses"] for h in res.history])
    assert np.isfinite(losses).all()
    assert np.isfinite(res.metrics["f1"])


def test_divergence_error_when_retries_exhaust():
    spec = ExperimentSpec(dataset="titanic", n_clients=3, rounds=1,
                          epochs=1, seeds=(0,),
                          fault="test_poison:1.0")
    with pytest.raises(DivergenceError, match="reseeded"):
        build(spec).run(retry=RetryPolicy(max_retries=1))
    with pytest.raises(TypeError, match="RetryPolicy"):
        build(spec).run(retry=42)


# ---------------------------------------------------------------------------
# checkpoint hardening + stream stamps
# ---------------------------------------------------------------------------
def test_fault_checkpoint_resume_bitwise_and_stamp_refusal(tmp_path):
    """resume() restores fault state (countdowns, rings, last-good
    buffers) bitwise, and the schedule|fault stream stamp refuses
    resuming under a different plan with an error naming both."""
    d = str(tmp_path / "ckpt")
    kw = dict(dataset="titanic", epochs=1, seeds=(0,),
              schedule="stale_k:1", fault=HOT)
    full = build(ExperimentSpec(rounds=4, **kw)).run()
    build(ExperimentSpec(rounds=2, checkpoint_dir=d,
                         checkpoint_every=1, **kw)).run()
    res = build(ExperimentSpec(rounds=4, checkpoint_dir=d,
                               checkpoint_every=1, **kw)).resume()
    assert res.resumed_from == 2
    assert res.metrics == full.metrics
    for i, r in enumerate((2, 3)):
        np.testing.assert_array_equal(res.history[i]["round_losses"],
                                      full.history[r]["round_losses"])
    for other in ("crash:0.5", "none"):
        with pytest.raises(
                ValueError,
                match="different exchange schedule, fault plan or wire"):
            build(ExperimentSpec(rounds=4, checkpoint_dir=d,
                                 checkpoint_every=1,
                                 **{**kw, "fault": other})).resume()


def test_resume_skips_corrupt_checkpoints_to_newest_intact(tmp_path):
    """A truncated newest checkpoint is skipped with a warning and
    resume falls back to the next older intact step -- bitwise the
    uninterrupted run; with EVERY checkpoint corrupt it warns and
    trains from scratch."""
    d = str(tmp_path / "ckpt")
    kw = dict(dataset="titanic", epochs=1, seeds=(0,),
              fault="crash:0.5+corrupt:0.5")
    full = build(ExperimentSpec(rounds=4, **kw)).run()
    build(ExperimentSpec(rounds=3, checkpoint_dir=d,
                         checkpoint_every=1, **kw)).run()
    newest = os.path.join(d, "session_00000003.npz")
    assert os.path.exists(newest)
    with open(newest, "r+b") as f:
        f.truncate(40)
    with pytest.warns(RuntimeWarning, match="skipping corrupt"):
        res = build(ExperimentSpec(rounds=4, checkpoint_dir=d,
                                   checkpoint_every=1, **kw)).resume()
    assert res.resumed_from == 2
    assert res.metrics == full.metrics
    # the resumed run above re-wrote steps 3-4; corrupt EVERYTHING
    for fn in os.listdir(d):
        with open(os.path.join(d, fn), "r+b") as f:
            f.truncate(10)
    with pytest.warns(RuntimeWarning, match="training from scratch"):
        res2 = build(ExperimentSpec(rounds=4, checkpoint_dir=d,
                                    checkpoint_every=1, **kw)).resume()
    assert res2.resumed_from is None
    assert res2.metrics == full.metrics


# ---------------------------------------------------------------------------
# the static auditor over faulted combos
# ---------------------------------------------------------------------------
def test_audit_faulted_combo_is_clean():
    """Taint (per-slot separation through guard select_n's), deadness
    (padded slots stay dead under injected faults), and retrace (fault
    state rides the carry) all hold on a hot composite plan."""
    from repro.analysis.audit import audit
    pcfg = ProtocolConfig(dataset="titanic", n_clients=3, rounds=1,
                          epochs=1, seed=0, schedule="stale_k:2",
                          fault="crash:0.2:2+straggle:0.5:2"
                                "+corrupt:0.05")
    rep = audit(pcfg, lane_check=False)
    assert rep.ok, rep.summary()
    assert rep.static_round_traces == 1
    assert rep.channels.get("fault", 0) > 0
