"""Federated serving harness (repro.serving.federated behind
``Session.serve()``).

The load-bearing pin: **serving is predict, bit for bit** -- for any
slot count, request arrival order, per-client slice delivery order,
batch composition, queue pressure, and cache state (on/off/hit/miss),
every completed request's per-client predictions equal the
corresponding column of ``Session.predict()`` exactly.  Plus
hypothesis property tests on the slot scheduler: admitted requests
complete exactly once, occupancy never exceeds the pool, eviction
happens only under declared queue pressure, and a fixed seed makes
the admission order deterministic.
"""
import json

import numpy as np
import pytest

from repro.api import (ExchangeCache, ExperimentSpec, ServeRequest,
                       build, split_features)

SPEC = dict(dataset="mnist", mode="devertifl", n_clients=3, rounds=1,
            epochs=1, n_samples=512, eval_every=0)
N_REF = 24


@pytest.fixture(scope="module")
def trained():
    """One trained tiny session + raw test rows + the predict()
    reference block every parity test compares against."""
    sess = build(ExperimentSpec(**SPEC))
    sess.run()
    xte = np.asarray(sess.federation.xte)[:N_REF]
    ref = np.asarray(sess.predict(xte))          # [n_live, N_REF]
    return sess, xte, ref


def make_requests(sess, xte, rows, uids=None, entities=None):
    lay = sess.federation.layout
    uids = uids if uids is not None else list(rows)
    entities = entities if entities is not None else \
        [f"e{r}" for r in rows]
    return [ServeRequest(uid=u, entity_id=e,
                         slices=split_features(lay, xte[r]))
            for u, e, r in zip(uids, entities, rows)]


def assert_parity(report, ref, uid_to_row):
    for uid, row in uid_to_row.items():
        got = report.results[uid]
        assert np.array_equal(got, ref[:, row]), \
            f"request {uid} (row {row}): {got} != {ref[:, row]}"


# ---------------------------------------------------------------------------
# parity pins
# ---------------------------------------------------------------------------
@pytest.mark.fast
def test_serve_matches_predict_bitwise(trained):
    sess, xte, ref = trained
    reqs = make_requests(sess, xte, range(N_REF))
    report = sess.serve(reqs, max_slots=4)
    assert report.counters["completed"] == N_REF
    assert_parity(report, ref, {r: r for r in range(N_REF)})


@pytest.mark.parametrize("max_slots", [1, 2, 7, 32])
def test_slot_count_invariance(trained, max_slots):
    """The slot-pool size changes batching and padding (dead slots run
    garbage behind the slot_mask gate) but not one bit of any result."""
    sess, xte, ref = trained
    rows = list(range(10))
    report = sess.serve(make_requests(sess, xte, rows),
                        max_slots=max_slots)
    assert report.counters["max_occupancy"] <= max_slots
    assert report.counters["step_traces"] == 1
    assert_parity(report, ref, {r: r for r in rows})


@pytest.mark.parametrize("cache", [None, 2, 128])
def test_cache_state_invariance(trained, cache):
    """Cache off, thrashing (capacity 2), or ample -- and a second
    pass full of repeat entities -- all produce identical bits."""
    sess, xte, ref = trained
    rows = [0, 1, 2, 3, 4, 1, 2, 0, 5, 1]
    uids = list(range(len(rows)))
    reqs = make_requests(sess, xte, rows, uids=uids,
                         entities=[f"e{r}" for r in rows])
    report = sess.serve(reqs, max_slots=3, cache=cache)
    assert_parity(report, ref, dict(zip(uids, rows)))
    if cache is None:
        assert report.cache is None
    else:
        assert report.cache["hits"] + report.cache["misses"] == len(rows)


def test_arrival_order_invariance(trained):
    """Shuffled submit order + per-request shuffled, globally
    interleaved per-client slice delivery: results match predict()
    row-for-row no matter who sends last."""
    sess, xte, ref = trained
    lay = sess.federation.layout
    rows = list(range(12))
    rng = np.random.default_rng(0)
    for trial in range(3):
        srv = sess.server(max_slots=4)
        order = rng.permutation(rows)
        offers = []
        for r in order:
            srv.submit(ServeRequest(uid=int(r), entity_id=f"t{trial}-{r}"))
            sl = split_features(lay, xte[r])
            offers += [(int(r), c, sl[c]) for c in sl]
        rng.shuffle(offers)
        for uid, c, payload in offers:
            srv.offer(uid, c, payload)
        report = srv.run()
        assert report.counters["completed"] == len(rows)
        assert_parity(report, ref, {r: r for r in rows})


def test_partial_assembly_never_admits(trained):
    """A request missing one client's slice stays out of the slot
    pool; delivering the last slice (mid-stream, after steps already
    ran) completes it with the same bits."""
    sess, xte, ref = trained
    lay = sess.federation.layout
    srv = sess.server(max_slots=2)
    sl = split_features(lay, xte[0])
    srv.submit(ServeRequest(uid="slow", entity_id="slow"))
    srv.offer("slow", 0, sl[0])
    srv.offer("slow", 1, sl[1])
    assert srv.step() == 0                  # nothing admissible
    assert srv.pending == ["slow"]
    # a complete request overtakes the stuck one
    srv.submit(make_requests(sess, xte, [3], uids=["fast"])[0])
    assert srv.step() == 1
    assert np.array_equal(srv.results["fast"], ref[:, 3])
    srv.offer("slow", 2, sl[2])             # last slice arrives late
    report = srv.run()
    assert report.counters["waiting"] == 0
    assert np.array_equal(report.results["slow"], ref[:, 0])


@pytest.mark.fast
def test_cache_hit_serves_without_any_slices(trained):
    """After one fresh serve, a repeat entity is served from the
    hot-entity cache with NO feature delivery from any client --
    bitwise the same prediction."""
    sess, xte, ref = trained
    srv = sess.server(max_slots=2, cache=16)
    srv.submit(make_requests(sess, xte, [5], uids=[0],
                             entities=["hot"])[0])
    srv.run()
    srv.submit(ServeRequest(uid=1, entity_id="hot"))    # no slices
    report = srv.run()
    assert report.cache["hits"] == 1
    assert np.array_equal(report.results[1], ref[:, 5])
    assert np.array_equal(report.results[1], report.results[0])
    cached_rec = [t for t in report.telemetry if t["uid"] == 1][0]
    assert cached_rec["cached"] is True


def test_cache_keyed_by_spec_hash(trained):
    """A cache shared across servers can never leak one spec's
    activations into another's predictions: the spec hash is part of
    the key, so the same entity_id under a different spec misses."""
    sess, xte, ref = trained
    other = build(ExperimentSpec(**{**SPEC, "seeds": (1,)}))
    other.run()
    assert other.spec.spec_hash != sess.spec.spec_hash
    shared = ExchangeCache(capacity=64)
    srv_a = sess.server(max_slots=2, cache=shared)
    srv_a.submit(make_requests(sess, xte, [4], uids=["a"],
                               entities=["shared-entity"])[0])
    srv_a.run()
    assert shared.hits == 0 and len(shared) == 1
    # same entity id, different spec: must MISS and recompute under
    # other's params
    xte_o = np.asarray(other.federation.xte)[:N_REF]
    srv_b = other.server(max_slots=2, cache=shared)
    srv_b.submit(ServeRequest(
        uid="b", entity_id="shared-entity",
        slices=split_features(other.federation.layout, xte_o[4])))
    rep_b = srv_b.run()
    assert shared.hits == 0 and len(shared) == 2
    ref_b = np.asarray(other.predict(xte_o))
    assert np.array_equal(rep_b.results["b"], ref_b[:, 4])


def test_padded_client_axis_parity(trained):
    """A padded federation (max_clients > n_clients: dead client slots
    ride the stack) serves the same bits as the unpadded one."""
    sess, xte, ref = trained
    padded = build(ExperimentSpec(**SPEC, max_clients=5))
    padded.run()
    reqs = make_requests(padded, xte, range(8))
    report = padded.serve(reqs, max_slots=3)
    ref_p = np.asarray(padded.predict(xte[:8]))
    assert ref_p.shape[0] == SPEC["n_clients"]      # live prefix only
    for r in range(8):
        assert np.array_equal(report.results[r], ref_p[:, r])
        assert np.array_equal(report.results[r], ref[:, r])


@pytest.mark.parametrize("first_layer", ["masked", "slice"])
def test_first_layer_lane_parity(trained, first_layer):
    """Serving rides whatever first-layer lane the spec trains --
    including the paper-literal masked reference."""
    _, xte, _ = trained
    sess = build(ExperimentSpec(**{**SPEC, "first_layer": first_layer}))
    sess.run()
    ref = np.asarray(sess.predict(xte[:6]))
    report = sess.serve(make_requests(sess, xte, range(6)), max_slots=4)
    assert_parity(report, ref, {r: r for r in range(6)})


# ---------------------------------------------------------------------------
# admission / eviction under load
# ---------------------------------------------------------------------------
def test_rejection_only_under_declared_pressure(trained):
    sess, xte, ref = trained
    srv = sess.server(max_slots=1, queue_cap=2, overflow="reject")
    reqs = make_requests(sess, xte, range(6))
    for r in reqs:
        srv.submit(r)
    report = srv.run()
    # queue admits 2; everything beyond was rejected at full queue
    assert report.counters["completed"] == 2
    assert sorted(report.rejected) == [2, 3, 4, 5]
    assert all(p == 2 for p in srv.pressure_log)
    assert len(srv.pressure_log) == len(report.rejected)
    assert_parity(report, ref, {r: r for r in report.results})


def test_evict_oldest_sheds_the_head(trained):
    sess, xte, ref = trained
    srv = sess.server(max_slots=1, queue_cap=2,
                      overflow="evict_oldest")
    for r in make_requests(sess, xte, range(5)):
        srv.submit(r)
    report = srv.run()
    # each overflow evicts the then-oldest queued request
    assert sorted(report.evicted) == [0, 1, 2]
    assert sorted(report.results) == [3, 4]
    assert all(p == 2 for p in srv.pressure_log)
    assert_parity(report, ref, {r: r for r in report.results})


def test_no_pressure_without_cap(trained):
    sess, xte, _ = trained
    srv = sess.server(max_slots=1)          # queue_cap=None: unbounded
    for r in make_requests(sess, xte, range(10)):
        srv.submit(r)
    report = srv.run()
    assert report.counters["completed"] == 10
    assert srv.pressure_log == []
    assert report.rejected == [] and report.evicted == []


# ---------------------------------------------------------------------------
# telemetry / report / compile-once
# ---------------------------------------------------------------------------
def test_one_compile_across_occupancies(trained):
    """Occupancy 1, partial, and full pools all run the SAME compiled
    step: traced gates, never python branches."""
    sess, xte, _ = trained
    srv = sess.server(max_slots=4, cache=8)
    for batch in ([0], [1, 2, 3], [4, 5, 6, 7], [0, 1]):  # incl repeats
        for r in make_requests(sess, xte, batch,
                               uids=[f"{len(srv.results)}-{r}"
                                     for r in batch]):
            srv.submit(r)
        srv.run()
    assert srv.step_traces == 1
    assert srv.steps >= 4


def test_telemetry_and_report_schema(trained):
    sess, xte, _ = trained
    report = sess.serve(make_requests(sess, xte, range(5)), max_slots=2)
    for t in report.telemetry:
        assert t["t_submit"] <= t["t_ready"] <= t["t_admit"] \
            <= t["t_done"]
        assert t["latency_s"] >= 0 and t["queue_s"] >= 0
    assert report.latency_ms["p50"] <= report.latency_ms["p99"] \
        <= report.latency_ms["max"]
    assert report.throughput_rps > 0
    assert report.spec_hash == sess.spec.spec_hash
    json.dumps(report.to_dict())            # JSON-safe end to end


def test_exchange_cache_lru_semantics():
    cache = ExchangeCache(capacity=2)
    a, b, c = (np.full((3, 4), v, np.float32) for v in (1, 2, 3))
    cache.put(("s", "a"), a)
    cache.put(("s", "b"), b)
    assert cache.lookup(("s", "a")) is a    # refreshes recency
    cache.put(("s", "c"), c)                # evicts LRU == "b"
    assert ("s", "b") not in cache
    assert cache.lookup(("s", "b")) is None
    assert cache.lookup(("s", "a")) is a
    assert cache.stats["evictions"] == 1
    assert cache.stats["size"] == 2


# ---------------------------------------------------------------------------
# errors
# ---------------------------------------------------------------------------
def test_serve_errors(trained):
    sess, xte, _ = trained
    lay = sess.federation.layout
    fresh = build(ExperimentSpec(**SPEC))
    with pytest.raises(ValueError, match="before run"):
        fresh.server()
    nonfed = build(ExperimentSpec(**{**SPEC, "mode": "splitnn"}))
    with pytest.raises(ValueError, match="federated"):
        nonfed.server(params={})
    srv = sess.server(max_slots=2)
    with pytest.raises(KeyError, match="unknown request"):
        srv.offer("nope", 0, np.zeros(lay.sizes[0]))
    srv.submit(ServeRequest(uid=0, entity_id="x"))
    with pytest.raises(ValueError, match="duplicate"):
        srv.submit(ServeRequest(uid=0))
    with pytest.raises(ValueError, match="out of range"):
        srv.offer(0, 99, np.zeros(4))
    with pytest.raises(ValueError, match="features"):
        srv.offer(0, 0, np.zeros(lay.sizes[0] + 1))
    with pytest.raises(ValueError, match="overflow"):
        sess.server(overflow="drop-all")
    with pytest.raises(TypeError, match="cache"):
        sess.server(cache=1.5)
    with pytest.raises(ValueError, match="max_slots"):
        sess.server(max_slots=0)


# ---------------------------------------------------------------------------
# property tests: the slot scheduler
#
# Randomized serialized workloads (a plan = submits, per-client offers
# in arbitrary global interleaving, step() calls sprinkled through)
# drive scheduler invariants.  The plan generator is a pure function
# of a numpy seed, so the suite runs everywhere: hypothesis (the
# optional test extra) explores + shrinks the seed space when
# installed, and a fixed seed sample covers it otherwise.
# ---------------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                         # optional extra not baked in
    HAVE_HYPOTHESIS = False


def plan_cases(n):
    """Seed-driving decorator: hypothesis when available, a fixed
    parametrized sample otherwise.  Either way the test body receives
    ``seed`` and builds the plan itself."""
    def wrap(fn):
        if HAVE_HYPOTHESIS:
            return settings(max_examples=n, deadline=None)(
                given(seed=st.integers(0, 2**31 - 1))(fn))
        return pytest.mark.parametrize("seed", range(n))(fn)
    return wrap


def build_plan(rng):
    """A randomized but fully serialized serving workload.  Admission
    order is a deterministic function of the plan, and the plan is a
    deterministic function of the seed."""
    n_reqs = int(rng.integers(2, 11))
    max_slots = int(rng.integers(1, 5))
    queue_cap = None if rng.random() < 0.4 else int(rng.integers(1, 4))
    overflow = ("reject", "evict_oldest")[int(rng.integers(0, 2))]
    rows = rng.integers(0, 8, n_reqs)
    events = []
    for uid, row in enumerate(rows):
        events.append(("submit", uid, int(row)))
        for c in range(SPEC["n_clients"]):
            events.append(("offer", uid, int(row), c))
    shuffled = [events[i] for i in rng.permutation(len(events))]
    # submit must precede its offers: hold early offers, flush on submit
    fixed, held, seen = [], {}, set()
    for ev in shuffled:
        if ev[0] == "offer" and ev[1] not in seen:
            held.setdefault(ev[1], []).append(ev)
            continue
        fixed.append(ev)
        if ev[0] == "submit":
            seen.add(ev[1])
            fixed.extend(held.pop(ev[1], []))
    for _ in range(int(rng.integers(0, 5))):   # sprinkle step() calls
        fixed.insert(int(rng.integers(0, len(fixed) + 1)), ("step",))
    return (max_slots, queue_cap, overflow, tuple(fixed))


def _drive(sess, xte, plan):
    """Execute a serialized event plan against a fresh server and
    return (server, report).  Plans are pure data, so the same plan
    replays exactly."""
    max_slots, queue_cap, overflow, events = plan
    srv = sess.server(max_slots=max_slots, queue_cap=queue_cap,
                      overflow=overflow, cache=16)
    lay = sess.federation.layout
    for ev in events:
        if ev[0] == "submit":
            _, uid, row = ev
            srv.submit(ServeRequest(uid=uid, entity_id=f"row{row}"))
        elif ev[0] == "offer":
            _, uid, row, client = ev
            srv.offer(uid, client,
                      split_features(lay, xte[row])[client])
        else:                               # ("step",)
            srv.step()
    report = srv.run()
    return srv, report


@plan_cases(10)
def test_scheduler_invariants(trained, seed):
    """Every admitted request completes exactly once; occupancy never
    exceeds the pool; eviction/rejection happen only at declared
    pressure (ready queue exactly at cap)."""
    sess, xte, ref = trained
    plan = build_plan(np.random.default_rng(seed))
    max_slots, queue_cap, overflow, events = plan
    srv, report = _drive(sess, xte, plan)
    # admitted <=> completed, exactly once
    assert len(srv.admission_log) == len(set(srv.admission_log))
    assert sorted(report.results) == sorted(srv.admission_log)
    assert report.counters["completed"] == len(srv.admission_log)
    # pool bound
    assert report.counters["max_occupancy"] <= max_slots
    # shed/evicted sets are disjoint from completions
    shed = set(report.rejected) | set(report.evicted)
    assert shed.isdisjoint(report.results)
    # pressure ledger: one entry per shed request, queue at cap
    assert len(srv.pressure_log) == len(shed)
    if queue_cap is None:
        assert srv.pressure_log == []
    else:
        assert all(p == queue_cap for p in srv.pressure_log)
    # and through it all: parity
    row_of = {ev[1]: ev[2] for ev in events if ev[0] == "submit"}
    for uid, preds in report.results.items():
        assert np.array_equal(preds, ref[:, row_of[uid]])


@plan_cases(5)
def test_fixed_seed_admission_deterministic(trained, seed):
    """The same plan (a fixed-seed load generator's output) replayed
    on a fresh server reproduces the admission order, the shed set,
    and every result bitwise."""
    sess, xte, _ = trained
    plan = build_plan(np.random.default_rng(seed))
    srv1, rep1 = _drive(sess, xte, plan)
    srv2, rep2 = _drive(sess, xte, plan)
    assert srv1.admission_log == srv2.admission_log
    assert rep1.rejected == rep2.rejected
    assert rep1.evicted == rep2.evicted
    assert sorted(rep1.results) == sorted(rep2.results)
    for uid in rep1.results:
        assert np.array_equal(rep1.results[uid], rep2.results[uid])
