"""Continuous-batching engine: batched greedy generation must equal
sequential single-request generation (slot isolation + prefill splicing
are exact)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.reduced import reduced_config
from repro.models import build_model
from repro.serving import Request, ServingEngine


def sequential_generate(model, params, prompt, n_new, cache_len):
    batch = {"tokens": jnp.asarray([prompt], jnp.int32)}
    if model.cfg.is_encoder_decoder or model.cfg.modality != "text":
        batch["prefix_emb"] = jnp.zeros(
            (1, model.cfg.num_prefix_embeddings, model.cfg.d_model))
    logits, st = jax.jit(
        lambda p, b: model.prefill(p, b, cache_len=cache_len))(params,
                                                               batch)
    toks = [int(jnp.argmax(logits[0, -1]))]
    step = jax.jit(model.decode_step)
    for _ in range(n_new - 1):
        lg, st = step(params, st, jnp.asarray([[toks[-1]]], jnp.int32))
        toks.append(int(jnp.argmax(lg[0, -1])))
    return toks


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "rwkv6-1.6b",
                                  "mixtral-8x22b"])
def test_engine_matches_sequential(arch):
    cfg = reduced_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n).tolist()
               for n in (5, 9, 3, 7)]
    n_new = 6

    engine = ServingEngine(model, params, max_batch=2, cache_len=64)
    for i, p in enumerate(prompts):
        engine.submit(Request(uid=i, prompt=p, max_new_tokens=n_new))
    out = engine.run()
    assert engine.stats["done"] == len(prompts)

    for i, p in enumerate(prompts):
        ref = sequential_generate(model, params, p, n_new, 64)
        assert out[i] == ref, f"{arch} request {i}: {out[i]} vs {ref}"


def test_engine_stop_token_and_refill():
    cfg = reduced_config("qwen1.5-0.5b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, max_batch=1, cache_len=64)
    # more requests than slots -> queue drains via refill
    for i in range(3):
        engine.submit(Request(uid=i, prompt=[1, 2, 3],
                              max_new_tokens=4))
    out = engine.run()
    assert sorted(out) == [0, 1, 2]
    assert all(len(v) <= 4 for v in out.values())
