"""De-VertiFL protocol correctness: exchange semantics, gradient
locality (local backward), FedAvg, and the paper's headline claim
(federated beats non-federated when features are vertically split)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import train_federation
from repro.core.exchange import fedavg, hidden_output_exchange
from repro.core.protocol import DeVertiFL, ProtocolConfig


def test_exchange_value_semantics():
    """Exchanged value for every client == sum over clients (Alg. 2)."""
    h = jax.random.normal(jax.random.PRNGKey(0), (5, 4, 10))
    out = hidden_output_exchange(h)
    expect = jnp.broadcast_to(h.sum(0, keepdims=True), h.shape)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-6)


def test_exchange_gradient_locality():
    """De-VertiFL: dLoss_i/dh_j == 0 for j != i (peers' contributions
    are data, not differentiable paths -- local backward)."""
    h = jax.random.normal(jax.random.PRNGKey(1), (3, 2, 4))

    def loss_for_client(i):
        def f(h_all):
            ex = hidden_output_exchange(h_all)
            return (ex[i] ** 2).sum()
        return jax.grad(f)(h)

    g = loss_for_client(0)
    assert float(jnp.abs(g[0]).sum()) > 0
    assert float(jnp.abs(g[1]).sum()) == 0.0
    assert float(jnp.abs(g[2]).sum()) == 0.0

    # VertiComb-style backward exchange: gradients flow to every client
    def f_diff(h_all):
        ex = hidden_output_exchange(h_all, differentiable=True)
        return (ex[0] ** 2).sum()
    g2 = jax.grad(f_diff)(h)
    assert float(jnp.abs(g2[1]).sum()) > 0


def test_fedavg_is_mean():
    tree = {"w": jax.random.normal(jax.random.PRNGKey(2), (4, 3, 3)),
            "b": jax.random.normal(jax.random.PRNGKey(3), (4, 7))}
    out = fedavg(tree)
    for k in tree:
        m = np.asarray(tree[k]).mean(0)
        for i in range(4):
            np.testing.assert_allclose(np.asarray(out[k][i]), m,
                                       atol=1e-6)


def test_zero_padding_masks():
    """Partition is disjoint and complete; masks implement zeropad."""
    from repro.core.partition import make_partition, masks_for
    for ds, nf in (("mnist", 784), ("titanic", 9), ("bank", 51)):
        for n in (2, 3, 7):
            part = make_partition(ds, nf, n)
            allidx = np.concatenate(part)
            assert len(allidx) == nf
            assert len(np.unique(allidx)) == nf
            masks = masks_for(part, nf)
            assert masks.sum() == nf


def test_mnist_row_round_robin():
    """Fig. 2: client i of n gets image rows i, i+n, ... (whole rows)."""
    from repro.core.partition import make_partition
    part = make_partition("mnist", 784, 7)
    # client 0: rows 0, 7, 14, 21 -> 4*28 = 112 features (paper's example)
    assert len(part[0]) == 112
    rows = np.unique(part[0] // 28)
    np.testing.assert_array_equal(rows, [0, 7, 14, 21])


@pytest.mark.slow
def test_federated_beats_non_federated():
    """The paper's core claim (Fig. 3): with vertically split features,
    De-VertiFL outperforms isolated per-client training."""
    common = dict(dataset="mnist", n_clients=5, rounds=10, epochs=5,
                  n_samples=4000, seed=0)
    fed = train_federation(**common)
    non = train_federation(mode="non_federated", fedavg=False, **common)
    assert fed["final"]["f1"] > non["final"]["f1"] + 0.05, \
        (fed["final"], non["final"])


def test_single_client_equals_centralized():
    """n_clients=1: the federation degenerates to centralized training
    (exchange adds nothing, FedAvg is identity)."""
    fed = train_federation(dataset="titanic", n_clients=1, rounds=3,
                           epochs=2, seed=1)
    non = train_federation(dataset="titanic", n_clients=1, rounds=3,
                           epochs=2, seed=1, mode="non_federated",
                           fedavg=False)
    assert abs(fed["final"]["f1"] - non["final"]["f1"]) < 0.05


def test_verticomb_baseline_runs():
    r = train_federation(dataset="titanic", n_clients=3, rounds=3,
                         epochs=1, mode="verticomb")
    assert 0.0 <= r["final"]["f1"] <= 1.0


def test_splitnn_baseline_runs():
    from repro.core.baselines import SplitNN, SplitNNConfig
    r = SplitNN(SplitNNConfig(dataset="bank", n_clients=2, rounds=2,
                              epochs=2, n_samples=1500)).train()
    assert 0.0 <= r["f1"] <= 1.0
